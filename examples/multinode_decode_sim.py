"""Reproduce the paper's headline result end-to-end: multi-node decode-heavy
TP inference, NCCL vs NVRAR, for Llama-3.1 70B and 405B on the Perlmutter
model — plus the TPU multi-pod projection.

    PYTHONPATH=src python examples/multinode_decode_sim.py
"""
from repro.inference.simulator import simulate_batch_latency, A100, V5E
from repro.core.comm_model import PERLMUTTER, TPU_V5E
from repro.configs.llama3_paper import LLAMA31_70B, LLAMA31_405B


def sweep(model, chip, net, gpus, label):
    print(f"\n{label} — decode-heavy (1426 prompt / 3072 decode), #P=32")
    print(f"{'chips':>6} {'TP+NCCL':>10} {'TP+NVRAR':>10} {'speedup':>8}")
    for n in gpus:
        t_n, _ = simulate_batch_latency(model, chip, net, n, scheme="tp",
                                        ar_algo="nccl", prompt_len=1426,
                                        decode_len=3072, n_prompts=32)
        t_v, _ = simulate_batch_latency(model, chip, net, n, scheme="tp",
                                        ar_algo="nvrar", prompt_len=1426,
                                        decode_len=3072, n_prompts=32)
        print(f"{n:6d} {t_n:9.1f}s {t_v:9.1f}s {t_n/t_v:7.2f}x")


def main():
    sweep(LLAMA31_70B, A100, PERLMUTTER, (8, 16, 32),
          "Llama-3.1-70B on Perlmutter (paper Fig. 7 left)")
    sweep(LLAMA31_405B, A100, PERLMUTTER, (16, 32, 64, 128),
          "Llama-3.1-405B on Perlmutter (paper Fig. 7 middle)")
    sweep(LLAMA31_405B, V5E, TPU_V5E, (512, 1024),
          "Llama-3.1-405B on TPU v5e multi-pod (this repo's target)")


if __name__ == "__main__":
    main()
