"""Quickstart: build a tiny model, train it briefly, generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.launch.train import run_training
from repro.configs import get_smoke
from repro.models.transformer import make_plan
from repro.inference.engine import InferenceEngine


def main():
    # 1) train a smoke-scale llama on the synthetic Markov LM task
    out = run_training("llama3.2-1b", steps=40, global_batch=8, seq_len=32,
                       microbatches=2, base_lr=1e-2, log_every=10)
    losses = [h["loss"] for h in out["history"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]

    # 2) serve the trained weights
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    eng = InferenceEngine(ap, out["params"], s_max=96)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16))
    res = eng.generate(prompts, 16)
    print(f"generated {res.new_tokens.shape} tokens, "
          f"{res.decode_tokens_per_s:.0f} tok/s decode")
    print("sample:", res.new_tokens[0][:10].tolist())


if __name__ == "__main__":
    main()
