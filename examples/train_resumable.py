"""Fault-tolerant training demo: train, checkpoint, simulate a crash,
resume — the loss curve continues exactly.

    PYTHONPATH=src python examples/train_resumable.py
"""
import tempfile

from repro.launch.train import run_training


def main():
    with tempfile.TemporaryDirectory() as ckpt:
        a = run_training("rwkv6-7b", steps=20, global_batch=8, seq_len=32,
                         microbatches=1, ckpt_dir=ckpt, ckpt_every=10,
                         log_every=5)
        print("-- simulated crash at step 20; restarting --")
        b = run_training("rwkv6-7b", steps=40, global_batch=8, seq_len=32,
                         microbatches=1, ckpt_dir=ckpt, ckpt_every=10,
                         log_every=5)
        assert b["history"][0]["step"] == 20
        print(f"resumed at step {b['history'][0]['step']}, final loss "
              f"{b['history'][-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
