"""End-to-end serving driver (the paper's kind of workload): serve a ~100M
llama-style model with batched requests through the continuous-batching
scheduler, reporting TTFT and throughput.

    PYTHONPATH=src python examples/serve_batched.py [--small]

(--small switches to a smoke model so the demo finishes in seconds on CPU.)
"""
import argparse

import jax

from repro.models.common import ModelConfig
from repro.models.transformer import make_plan, init_params
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica

M100 = ModelConfig(  # ~100M params
    name="llama-100m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
    rope_theta=1e4)

SMALL = ModelConfig(
    name="llama-2m", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512, vocab_size=4096)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--small", action="store_true")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=8)
    args = p.parse_args()
    cfg = SMALL if args.small else M100
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.0f}M params)")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    # paged KV cache (16-token blocks) + recompile-free chunked admission
    # (arch is nominal: ap/params for the demo model are passed explicitly)
    sched = build_replica(
        ReplicaSpec(arch="llama3.2-1b", slots=args.slots, s_max=192,
                    block_size=16, admit_mode="chunked"),
        ap=ap, params=params)
    reqs = make_trace(args.requests, mean_in=24, mean_out=16, rate=4.0,
                      vocab=cfg.vocab_size, seed=0)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    m = sched.metrics(done)
    print(f"{m.completed} requests, {m.total_new_tokens} tokens in "
          f"{m.wall_s:.1f}s ({m.throughput_tok_s:.1f} tok/s)")
    print(f"TTFT p50 {m.ttft_steps_p50:.1f} steps ({m.ttft_s_p50*1e3:.0f} "
          f"ms), TPOT p50 {m.tpot_steps_p50:.2f} steps; KV peak "
          f"{m.peak_kv_tokens} of {args.slots * 192} dense tokens")


if __name__ == "__main__":
    main()
