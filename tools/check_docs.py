"""Docs-consistency gate: every ``launch/serve.py`` CLI flag must be
documented in ``docs/serving.md``.

    PYTHONPATH=src python tools/check_docs.py

Introspects the live argparse parser (``repro.launch.serve.build_parser``)
rather than grepping source, so aliases and flags added through helpers are
covered too.  Run by CI (and by ``tests/test_docs.py`` inside the tier-1
suite) so a new serve flag cannot land without its documentation.
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVING_MD = os.path.join(REPO, "docs", "serving.md")


def serve_flags():
    """All option strings of the serve CLI (--help excluded)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch.serve import build_parser
    flags = []
    for action in build_parser()._actions:
        for opt in action.option_strings:
            if opt.startswith("--") and opt != "--help":
                flags.append(opt)
    return flags


def main() -> int:
    if not os.path.exists(SERVING_MD):
        print(f"[check_docs] FAIL: {SERVING_MD} does not exist")
        return 1
    with open(SERVING_MD) as f:
        doc = f.read()
    missing = [fl for fl in serve_flags() if f"`{fl}" not in doc]
    if missing:
        print(f"[check_docs] FAIL: {len(missing)} serve flag(s) missing "
              f"from docs/serving.md:")
        for fl in missing:
            print(f"  - {fl}")
        return 1
    print(f"[check_docs] OK: all {len(serve_flags())} serve flags "
          f"documented in docs/serving.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
