"""Decode-vs-full-forward consistency: prefill then one decode step must
reproduce the full forward's logits for every architecture family (exact
cache semantics: KV, SSM conv/state, RWKV shift/wkv, enc-dec cross-KV)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core import LOCAL
from repro.models import (ModelConfig, make_plan, init_params, init_cache,
                          forward_lm, decode_step)

B, S = 2, 12


def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=97,
                dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": tiny("dense"),
    "dense-bias": tiny("dense", qkv_bias=True),
    "dense-swa": tiny("dense", sliding_window=4),
    "moe": tiny("moe", n_experts=8, top_k=2, d_ff_expert=32,
                capacity_factor=8.0),
    "hybrid": tiny("hybrid", d_inner=128, ssm_state=8, sliding_window=8),
    "rwkv": tiny("ssm", d_model=128, rwkv_head_dim=64, decay_lora=8),
    "encdec": tiny("encdec", enc_layers=2, enc_seq=12, norm="layernorm",
                   act="gelu"),
    "vlm": tiny("vlm", n_patches=4),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_full_forward(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(0)
    ap = make_plan(cfg, 1)
    params = init_params(key, ap)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    fw = {}
    if cfg.family == "encdec":
        fw["frame_embeds"] = jax.random.normal(key, (B, cfg.enc_seq,
                                               cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        fw["patch_embeds"] = jax.random.normal(key, (B, cfg.n_patches,
                                               cfg.d_model), jnp.float32)

    logits_full, _, _, _ = forward_lm(params, tok, ap, LOCAL, **fw)
    lg_p, _, states, enc = forward_lm(params, tok[:, :S - 1], ap, LOCAL,
                                      collect_state=True, **fw)
    cache = init_cache(ap, B, S + 4)
    if "k" in cache:
        cache["k"] = lax.dynamic_update_slice(
            cache["k"], states["k"].astype(cache["k"].dtype), (0,) * 5)
        cache["v"] = lax.dynamic_update_slice(
            cache["v"], states["v"].astype(cache["v"].dtype), (0,) * 5)
    for nm in ("conv", "ssm", "shift_tm", "shift_cm", "wkv"):
        if nm in cache:
            cache[nm] = states[nm].astype(cache[nm].dtype)
    if "enc_k" in cache:
        from repro.models.layers import cross_kv
        ek, ev = jax.vmap(lambda bp: cross_kv(bp["xattn"], enc))(
            params["blocks"])
        cache["enc_k"] = ek.astype(cache["enc_k"].dtype)
        cache["enc_v"] = ev.astype(cache["enc_v"].dtype)

    lg_d, _ = decode_step(params, cache, tok[:, S - 1],
                          jnp.full((B,), S - 1, jnp.int32), ap, LOCAL)
    ref = np.asarray(logits_full[:, S - 1], np.float32)
    got = np.asarray(lg_d, np.float32)
    err = np.abs(ref - got).max() / (np.abs(ref).max() + 1e-9)
    assert err < 2e-3, f"{name}: rel err {err}"
