"""The event-driven cluster simulator must reproduce the paper's three
observations and the NVRAR speedup bands (the quantitative backbone of the
benchmark harness)."""
import numpy as np
import pytest

from repro.inference.simulator import (simulate_batch_latency, simulate_trace,
                                       A100, ClusterSim)
from repro.core.comm_model import PERLMUTTER
from repro.configs.llama3_paper import LLAMA31_70B as M70, LLAMA31_405B as M405


def _lat(model, n, scheme, algo, pl, dl, npr=8, **kw):
    t, _ = simulate_batch_latency(model, A100, PERLMUTTER, n, scheme=scheme,
                                  ar_algo=algo, prompt_len=pl, decode_len=dl,
                                  n_prompts=npr, **kw)
    return t


def test_obs1_tp_does_not_strong_scale():
    lat = [_lat(M70, n, "tp", "nccl", 1426, 3072) for n in (8, 16, 32)]
    assert max(lat) / min(lat) < 1.15  # flat: no strong scaling
    # but 4 -> 8 still helps (paper Fig. 1)
    assert _lat(M70, 4, "tp", "nccl", 1426, 3072) > lat[0]


def test_obs1_hp_wins_prefill_tp_wins_decode():
    # prefill-heavy, larger #P: HP < TP at scale
    assert _lat(M70, 32, "hp", "nccl", 2363, 128, npr=32) < \
        _lat(M70, 32, "tp", "nccl", 2363, 128, npr=32)
    # decode-heavy: TP << HP
    assert _lat(M70, 16, "tp", "nccl", 1426, 3072) < \
        0.6 * _lat(M70, 16, "hp", "nccl", 1426, 3072)


def test_obs2_decode_gemm_tile_floor():
    sim = ClusterSim(M70, A100, PERLMUTTER, 8, scheme="tp")
    t32 = sim._step_time(32, 1426, phase="decode", layers=1, with_ar=False)
    t16 = sim._step_time(16, 1426, phase="decode", layers=1, with_ar=False)
    assert abs(t32.matmul - t16.matmul) / t32.matmul < 1e-6  # tile floor
    t4096 = sim._step_time(4096, 1426, phase="prefill", layers=1,
                           with_ar=False)
    t2048 = sim._step_time(2048, 1426, phase="prefill", layers=1,
                           with_ar=False)
    assert t2048.matmul < 0.6 * t4096.matmul  # prefill halves fine


def test_nvrar_band_70b_405b():
    for model, gpus, lo, hi in ((M70, 32, 1.2, 2.2), (M405, 64, 1.3, 2.2)):
        s = _lat(model, gpus, "tp", "nccl", 1426, 3072, npr=32) / \
            _lat(model, gpus, "tp", "nvrar", 1426, 3072, npr=32)
        assert lo < s < hi, (model.name, s)


def test_nvrar_single_node_no_gain():
    s = _lat(M70, 4, "tp", "nccl", 1426, 3072) / \
        _lat(M70, 4, "tp", "nvrar", 1426, 3072)
    assert 0.85 < s <= 1.0  # paper Fig. 6: slight slowdown within a node


def test_straggler_ring_pays_more():
    base_r = _lat(M70, 16, "tp", "ring", 1426, 3072)
    slow_r = _lat(M70, 16, "tp", "ring", 1426, 3072, straggler_delay=2e-5)
    base_n = _lat(M70, 16, "tp", "nvrar", 1426, 3072)
    slow_n = _lat(M70, 16, "tp", "nvrar", 1426, 3072, straggler_delay=2e-5)
    # identical absolute penalty per AR; relative hit is worse for the
    # latency-lean algorithm, but neither explodes
    assert slow_r > base_r and slow_n > base_n


def test_trace_throughput_ordering():
    rng = np.random.default_rng(0)
    n = 200
    li = np.maximum(2, rng.lognormal(np.log(600), 0.6, n)).astype(int)
    lo = np.maximum(1, rng.lognormal(np.log(250), 0.6, n)).astype(int)
    arr = np.cumsum(rng.gamma(0.5, 0.2, n))
    out = {}
    for label, scheme, algo in (("nccl", "tp", "nccl"),
                                ("nvrar", "tp", "nvrar")):
        out[label] = simulate_trace(M70, A100, PERLMUTTER, 16, scheme=scheme,
                                    ar_algo=algo, arrivals=arr, in_lens=li,
                                    out_lens=lo,
                                    concurrency=32)["throughput_tok_s"]
    assert out["nvrar"] > out["nccl"]
