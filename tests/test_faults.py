"""Fault-injected serving (DESIGN.md §11, docs/robustness.md).

The contract under test: every injected fault schedule is deterministic
(a pure hash of seed/kind/ids), every non-shed greedy request's tokens
are bitwise-identical to the fault-free trace, shed requests are always
reported, and every recovery ladder (retry -> re-prefill -> shed,
quarantine -> recompute, OOM -> evict -> recompute) terminates.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.autotune import AutoTuner, TABLE_VERSION
from repro.inference.faults import (FAULT_KINDS, FaultInjector, FaultPlan,
                                    hash_unit)
from repro.inference.kv_cache import BundleIntegrityError, KVBundle
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica
from repro.inference.speculative import Drafter

# spec templates: paged colocated batcher / disagg with a dense prefill
# pool in front of the paged decode pool (the historical test shape)
RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96, block_size=8)
DS = RS.replace(disagg=True, prefill_block_size=0)


@pytest.fixture(scope="module")
def tiny_lm():
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _trace(cfg, n=10, seed=4, mean_in=10, mean_out=6, rate=3.0):
    return make_trace(n, mean_in=mean_in, mean_out=mean_out, rate=rate,
                      vocab=cfg.vocab_size, seed=seed)


def _colocated(ap, params, reqs, injector=None, drafter=None, **kw):
    sched = build_replica(RS.replace(**kw), ap=ap, params=params,
                          injector=injector, drafter=drafter)
    done = sched.run(reqs)
    return {r.rid: r.output for r in done}, sched


def _disagg(ap, params, reqs, injector=None, **kw):
    # one injector drives the coordinator's handoff hooks AND the decode
    # batcher's step hooks (the build_replica contract)
    coord = build_replica(DS.replace(**kw), ap=ap, params=params,
                          injector=injector)
    done = coord.run(reqs)
    return {r.rid: r.output for r in done}, coord


# ---------------------------------------------------------------------------
# fault plan / injector determinism
# ---------------------------------------------------------------------------


def test_hash_unit_deterministic_and_uniform_ish():
    a = hash_unit(7, "handoff_drop", 3, 1)
    assert a == hash_unit(7, "handoff_drop", 3, 1)
    assert 0.0 <= a < 1.0
    # different ids / kinds / seeds decorrelate
    draws = {hash_unit(s, k, i) for s in (0, 1) for i in range(8)
             for k in ("handoff_drop", "nan_logits")}
    assert len(draws) == 32


def test_fault_events_nest_across_rates():
    """The event set at rate r1 is a subset of the set at r2 >= r1 —
    the property the bench's goodput monotonicity stands on."""
    lo = FaultInjector(FaultPlan(seed=3, handoff_drop=0.1))
    hi = FaultInjector(FaultPlan(seed=3, handoff_drop=0.4))
    fired_lo = {(r, a) for r in range(20) for a in range(4)
                if lo.drop_handoff(r, a)}
    fired_hi = {(r, a) for r in range(20) for a in range(4)
                if hi.drop_handoff(r, a)}
    assert fired_lo and fired_lo < fired_hi
    assert lo.counts["handoff_drop"] == len(fired_lo)


def test_nan_events_fire_once_per_progress_key():
    """A quarantined request replays the same (rid, progress) keys; the
    injector must not re-poison it into a livelock."""
    inj = FaultInjector(FaultPlan(seed=0, nan_logits=0.5))
    first = [inj.poison_slot(5, e) for e in range(10)]
    again = [inj.poison_slot(5, e) for e in range(10)]
    assert any(first) and not any(again)
    inj.reset_stats()   # a reset replays the same schedule
    assert [inj.poison_slot(5, e) for e in range(10)] == first


def test_fault_plan_parse_string_json_and_errors(tmp_path):
    p = FaultPlan.parse("seed=9, handoff_drop=0.25,stall_steps=5")
    assert (p.seed, p.handoff_drop, p.stall_steps) == (9, 0.25, 5)
    doc = tmp_path / "plan.json"
    doc.write_text(json.dumps({"seed": 2, "nan_logits": 0.1}))
    p2 = FaultPlan.parse(str(doc))
    assert (p2.seed, p2.nan_logits) == (2, 0.1)
    assert p2.any_faults and not FaultPlan().any_faults
    with pytest.raises(ValueError, match="unknown fault-plan key"):
        FaultPlan.parse("bogus=1")
    with pytest.raises(ValueError, match="outside"):
        FaultPlan.parse("handoff_drop=1.5")
    with pytest.raises(ValueError, match="key=value"):
        FaultPlan.parse("seed")
    assert set(FaultInjector(p).stats()) == set(FAULT_KINDS)


# ---------------------------------------------------------------------------
# KV bundle integrity
# ---------------------------------------------------------------------------


def test_bundle_checksum_detects_corruption():
    rng = np.random.default_rng(0)
    k = rng.standard_normal((2, 6, 2, 8)).astype(np.float32)
    b = KVBundle(k=k.copy(), v=k.copy() + 1).seal()
    b.verify()                       # sealed + intact: fine
    KVBundle(k=k.copy(), v=k.copy()).verify()   # unsealed: no-op
    FaultInjector.corrupt_bundle(b)  # silent bit damage, not NaN
    assert np.isfinite(b.k).all()
    with pytest.raises(BundleIntegrityError, match="checksum"):
        b.verify()
    # shape/dtype are part of the digest too
    b2 = KVBundle(k=k.copy(), v=k.copy()).seal()
    b2.k = b2.k.reshape(2, 6, 8, 2)
    b2.v = b2.v.reshape(2, 6, 8, 2)
    with pytest.raises(BundleIntegrityError):
        b2.verify()


# ---------------------------------------------------------------------------
# autotuner load hardening (satellite: degrade, never raise mid-trace)
# ---------------------------------------------------------------------------


def test_autotuner_load_degrades_on_corrupt_file(tmp_path):
    for payload in ("{not json", "", "[1, 2]", '"str"'):
        f = tmp_path / "t.json"
        f.write_text(payload)
        with pytest.warns(RuntimeWarning, match="degrading to analytic"):
            t = AutoTuner.load(str(f))
        assert t.table == {} and t.choose(1 << 20, 8, 2) is not None
    with pytest.warns(RuntimeWarning, match="degrading to analytic"):
        AutoTuner.load(str(tmp_path / "missing.json"))


def test_autotuner_load_degrades_on_stale_version(tmp_path):
    t = AutoTuner()
    t.choose(1 << 20, 8, 2)
    doc = t.to_json()
    doc["version"] = TABLE_VERSION + 1
    f = tmp_path / "stale.json"
    f.write_text(json.dumps(doc))
    with pytest.warns(RuntimeWarning, match="schema version"):
        assert AutoTuner.load(str(f)).table == {}


def test_autotuner_valid_roundtrip_and_bad_entry_drop(tmp_path):
    t = AutoTuner()
    t.choose(1 << 20, 8, 2)
    t.choose(1 << 12, 4, 1)
    f = tmp_path / "ok.json"
    t.save(str(f))
    t2 = AutoTuner.load(str(f))   # clean file: no warning, table kept
    assert {k: v.strategy for k, v in t2.table.items()} \
        == {k: v.strategy for k, v in t.table.items()}
    doc = t.to_json()
    good_key = next(iter(doc["table"]))
    doc["table"]["garbage key"] = doc["table"][good_key]
    doc["table"]["b16/f8/s2/bfloat16"] = {"strategy": "warp_drive",
                                          "rd_chunks": 1}
    doc["sp_table"]["nonsense"] = True
    f2 = tmp_path / "mixed.json"
    f2.write_text(json.dumps(doc))
    with pytest.warns(RuntimeWarning, match="dropped 3"):
        t3 = AutoTuner.load(str(f2))
    assert set(t3.table) == set(t.table)   # the good entries survive


# ---------------------------------------------------------------------------
# handoff drop / corruption: retry -> re-prefill -> shed ladder
# ---------------------------------------------------------------------------


def test_handoff_drops_retry_and_stay_bitwise_exact(tiny_lm):
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=11, handoff_drop=0.3))
    got, coord = _disagg(ap, params, _trace(cfg), injector=inj)
    assert coord.handoff_drops > 0 and coord.handoff_retries > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


def test_corrupt_handoffs_reprefill_and_stay_bitwise_exact(tiny_lm):
    """Half of all prefills produce corrupt bundles: every corruption is
    *detected* (checksum) and re-prefilled; a request whose re-prefill
    budget runs out is shed with a reason, and every survivor is
    bitwise-exact."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=5, handoff_corrupt=0.5))
    reqs = _trace(cfg)
    got, coord = _disagg(ap, params, reqs, injector=inj)
    m = coord.metrics(reqs)
    assert coord.handoff_corrupt > 0 and coord.handoff_reprefills > 0
    assert m.handoff_corrupt == coord.handoff_corrupt
    assert m.completed + m.shed_requests == len(reqs)
    for r in reqs:
        if r.output is None:
            assert r.shed_reason == "handoff_corrupt"
        else:
            np.testing.assert_array_equal(ref[r.rid], r.output)


def test_total_handoff_failure_sheds_everything_and_terminates(tiny_lm):
    """handoff_drop=1.0: every transfer attempt dies.  The run must still
    terminate (bounded retries, bounded re-prefills) and every request
    must be shed with a reason — never silently dropped."""
    cfg, ap, params = tiny_lm
    reqs = _trace(cfg, n=6)
    inj = FaultInjector(FaultPlan(seed=1, handoff_drop=1.0))
    got, coord = _disagg(ap, params, reqs, injector=inj,
                         max_handoff_retries=2, max_reprefills=1)
    assert all(v is None for v in got.values())
    assert all(r.shed_reason == "handoff_failed" for r in reqs)
    assert all(r.shed_step >= 0 for r in reqs)
    m = coord.metrics(reqs)
    assert m.shed_requests == len(reqs) and m.completed == 0
    # bounded ladder: per prefill, at most (retries+1) transfer attempts
    assert coord.handoff_drops \
        <= len(reqs) * (coord.max_reprefills + 1) \
        * (coord.max_handoff_retries + 1)


# ---------------------------------------------------------------------------
# bounded handoff queue + stalls (satellite: backpressure, not unbounded RAM)
# ---------------------------------------------------------------------------


def test_decode_stall_backpressures_bounded_ready_queue(tiny_lm):
    """With the decode pool stalling and a tiny ready cap, the prefill
    pool must hold prompts (backpressure) instead of growing the handoff
    queue without bound — and the run still completes bitwise-exact."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=2, decode_stall=0.4, stall_steps=2))
    got, coord = _disagg(ap, params, _trace(cfg), injector=inj,
                         max_ready=3, prefill_per_step=4)
    m = coord.metrics(list(_trace(cfg)))
    assert m.decode_stall_steps > 0
    assert m.backpressure_steps > 0
    assert m.peak_ready_depth <= 3 and m.ready_cap == 3
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


def test_prefill_stall_only_delays_never_corrupts(tiny_lm):
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=6, prefill_stall=0.5, stall_steps=3))
    got, coord = _disagg(ap, params, _trace(cfg), injector=inj)
    assert coord.prefill_stall_steps > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


# ---------------------------------------------------------------------------
# deadline shedding
# ---------------------------------------------------------------------------


def test_deadline_shed_reports_and_preserves_survivors(tiny_lm):
    """A tight TTFT deadline with a stalling prefill pool sheds some
    requests; survivors stay bitwise-exact and shed + completed covers
    the whole trace (nothing silently lost)."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=3, prefill_stall=0.6, stall_steps=4))
    reqs = _trace(cfg)
    got, coord = _disagg(ap, params, reqs, injector=inj, deadline_ms=4.0)
    m = coord.metrics(reqs)
    assert m.shed_requests > 0, "deadline never tripped — not a test"
    assert m.shed_requests + m.completed == len(reqs)
    for r in reqs:
        if r.output is None:
            assert r.shed_reason == "deadline" and r.shed_step >= 0
        else:
            np.testing.assert_array_equal(ref[r.rid], r.output)


def test_colocated_deadline_shed(tiny_lm):
    """The colocated batcher honors per-run deadlines too: with one slot
    and bursty arrivals, late-queue requests are shed, and the rest are
    bitwise-identical to the no-deadline run."""
    cfg, ap, params = tiny_lm
    reqs_ref = _trace(cfg, n=8, rate=10.0)
    sched = build_replica(RS.replace(slots=1), ap=ap, params=params)
    ref = {r.rid: r.output for r in sched.run(reqs_ref)}
    reqs = _trace(cfg, n=8, rate=10.0)
    tight = build_replica(RS.replace(slots=1, deadline_ms=10.0),
                          ap=ap, params=params)
    done = tight.run(reqs)
    m = tight.metrics(done)
    assert m.shed_requests > 0
    assert m.shed_requests + m.completed == len(reqs)
    for r in reqs:
        if r.output is not None:
            np.testing.assert_array_equal(ref[r.rid], r.output)
        else:
            assert r.shed_reason == "deadline"


# ---------------------------------------------------------------------------
# NaN quarantine + OOM bursts (colocated decode path)
# ---------------------------------------------------------------------------


def test_nan_quarantine_recomputes_bitwise_exact(tiny_lm):
    """Injected non-finite KV must be caught by the device-side finite
    guard, the slot quarantined, and the recompute must reproduce the
    fault-free stream exactly — with no NaN left behind in the cache to
    re-poison later occupants of the freed blocks."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=7, nan_logits=0.08))
    got, sched = _colocated(ap, params, _trace(cfg), injector=inj)
    m = sched.metrics(list(_trace(cfg)))
    assert m.quarantines > 0, "no quarantine fired — not a test"
    assert m.quarantines == inj.counts["nan_logits"], \
        "quarantine storm: one injection must cost exactly one quarantine"
    assert m.wasted_tokens > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])
    assert np.isfinite(
        np.asarray(sched.cache["k"], np.float32)).all(), \
        "scrub-on-quarantine left NaN in freed blocks"


def test_injected_oom_bursts_evict_and_recompute(tiny_lm):
    """An OOM burst only bites when a slot actually needs new blocks, so
    run long generations (many growth events) under a high burst rate:
    growing slots are evicted, recomputed, and stay bitwise-exact."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg, mean_out=14))
    inj = FaultInjector(FaultPlan(seed=9, oom=0.5))
    reqs = _trace(cfg, mean_out=14)
    got, sched = _colocated(ap, params, reqs, injector=inj)
    m = sched.metrics(reqs)
    assert m.injected_oom > 0, "no burst hit a growth event — not a test"
    assert m.completed == len(reqs)
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


def test_straggler_delays_never_change_tokens(tiny_lm):
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg))
    inj = FaultInjector(FaultPlan(seed=4, straggler=0.3, straggler_s=0.0))
    got, sched = _colocated(ap, params, _trace(cfg), injector=inj)
    m = sched.metrics(list(_trace(cfg)))
    assert m.straggler_steps > 0   # latency noise only, tokens untouched
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


# ---------------------------------------------------------------------------
# speculative decoding degraded mode
# ---------------------------------------------------------------------------


def test_spec_verify_fault_autodisables_slot_and_stays_exact(tiny_lm):
    """A NaN fault under spec decode quarantines the slot AND permanently
    degrades that request to correction-token-only decode; the emitted
    stream still equals plain fault-free decode."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg, mean_out=10))
    inj = FaultInjector(FaultPlan(seed=8, nan_logits=0.1))
    got, sched = _colocated(ap, params, _trace(cfg, mean_out=10),
                            injector=inj, spec_mode="ngram", spec_k=3)
    m = sched.metrics(list(_trace(cfg, mean_out=10)))
    assert m.quarantines > 0
    assert m.spec_autodisables > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


class _AlwaysWrongDrafter(Drafter):
    """Proposes tokens the greedy target will (almost) never emit."""

    def _propose(self, slot, hist, k):
        return [(hist[-1] + 17 + i) % 50 for i in range(k)]


def test_spec_acceptance_collapse_autodisable(tiny_lm):
    """A pathologically bad drafter trips the zero-accept-streak breaker
    (spec_autodisable_after) and the run degrades to exact plain decode
    instead of burning k+1-wide verify passes forever."""
    cfg, ap, params = tiny_lm
    ref, _ = _colocated(ap, params, _trace(cfg, mean_out=12))
    got, sched = _colocated(ap, params, _trace(cfg, mean_out=12),
                            spec_mode="ngram", spec_k=3,
                            drafter=_AlwaysWrongDrafter(),
                            spec_autodisable_after=2)
    m = sched.metrics(list(_trace(cfg, mean_out=12)))
    assert m.spec_autodisables > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


# ---------------------------------------------------------------------------
# preemption fairness under overcommit (satellite: randomized)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_overcommitted_pool_completes_and_replays_identically(tiny_lm,
                                                              seed):
    """Randomized overcommit: a paged pool too small for the offered load
    must still finish every request within a bounded step budget (the
    preemption ladder is fair — no livelock), and a replay of the same
    trace must reproduce identical outputs AND identical preemption
    counts (scheduling itself is deterministic)."""
    cfg, ap, params = tiny_lm

    def go():
        reqs = _trace(cfg, n=8, seed=100 + seed, mean_out=8, rate=6.0)
        sched = build_replica(RS.replace(slots=4, n_blocks=14),
                              ap=ap, params=params)
        done = sched.run(reqs, max_steps=3000)
        assert all(r.output is not None for r in done), \
            "overcommitted pool failed to drain"
        return {r.rid: (r.output, r.preempted) for r in done}, \
            sched.metrics(done)

    a, ma = go()
    b, mb = go()
    assert ma.preemptions == mb.preemptions
    assert ma.wasted_tokens == mb.wasted_tokens
    for rid in a:
        np.testing.assert_array_equal(a[rid][0], b[rid][0])
        assert a[rid][1] == b[rid][1]


# ---------------------------------------------------------------------------
# serve CLI wiring
# ---------------------------------------------------------------------------


def test_serve_cli_fault_flags(capsys):
    from repro.launch import serve
    serve.main(["--arch", "llama3.2-1b", "--smoke", "--mode", "trace",
                "--requests", "6", "--block-size", "8",
                "--fault-plan", "seed=7,nan_logits=0.05,oom=0.1",
                "--deadline-ms", "500"])
    out = capsys.readouterr().out
    assert "robustness:" in out and "faults injected:" in out


def test_serve_cli_rejects_faults_in_batch_mode():
    from repro.launch import serve
    with pytest.raises(SystemExit, match="trace-mode only"):
        serve.main(["--arch", "llama3.2-1b", "--smoke", "--mode", "batch",
                    "--fault-plan", "nan_logits=0.1"])
    with pytest.raises(SystemExit, match="trace-mode only"):
        serve.main(["--arch", "llama3.2-1b", "--smoke", "--mode", "batch",
                    "--deadline-ms", "5"])
