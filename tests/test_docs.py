"""Docs-consistency checks run inside the tier-1 suite so documentation
drift fails CI on every matrix leg (see tools/check_docs.py)."""
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

import check_docs  # noqa: E402


def test_every_serve_flag_documented():
    assert check_docs.main() == 0


def test_readme_links_docs_suite():
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for doc in ("docs/serving.md", "docs/benchmarks.md",
                "docs/paper_mapping.md"):
        assert doc in readme, f"README must link {doc}"
        assert os.path.exists(os.path.join(REPO, doc)), f"{doc} missing"
