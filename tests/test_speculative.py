"""Speculative decoding subsystem tests: drafter behaviour, verify-step
equivalence (greedy bitwise parity with plain decode, dense and paged),
KV rollback via allocator truncation, adaptive speculation length, and
the new ServeMetrics fields."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import make_plan, init_params
from repro.inference.kv_cache import BlockAllocator, TRASH_BLOCK
from repro.inference.scheduler import Request, make_trace
from repro.inference.spec import ReplicaSpec, build_engine, build_replica
from repro.inference.speculative import (AdaptiveK, NGramDrafter,
                                         ReplayDrafter, make_drafter)

RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


# ---------------------------------------------------------------------------
# drafters (pure host)
# ---------------------------------------------------------------------------


def test_ngram_drafter_lookup_and_fallback():
    d = NGramDrafter(max_n=3)
    d.reset(0, [1, 2, 3, 9, 1, 2, 3])
    # suffix [1,2,3] recurs at the start -> propose its continuation [9,...]
    got = d.draft(0, 3)
    assert got.tolist() == [9, 1, 2]
    assert d.hit_rate == 1.0
    # no recurring suffix at all -> fallback repeats the last token
    d.reset(1, [5, 6, 7, 8])
    assert d.draft(1, 2).tolist() == [8, 8]
    # always returns exactly k tokens
    d.reset(2, [4])
    assert d.draft(2, 4).shape == (4,)


def test_ngram_drafter_prefers_longest_and_most_recent():
    d = NGramDrafter(max_n=3)
    # suffix [2,3]: occurrences at 0 (-> 7) and 3 (-> 8); most recent wins
    d.reset(0, [2, 3, 7, 2, 3, 8, 2, 3])
    assert d.draft(0, 1).tolist() == [8]


def test_replay_drafter_oracle():
    prompt = (10, 11, 12)
    d = ReplayDrafter({prompt: [1, 2, 3, 4, 5]})
    d.reset(0, list(prompt) + [1])          # first token already emitted
    assert d.draft(0, 3).tolist() == [2, 3, 4]
    d.observe(0, [2, 3])
    assert d.draft(0, 3).tolist() == [4, 5, 5]  # tail padded
    # unknown prompt -> fallback, not a crash
    d.reset(1, [99, 98, 1])
    assert d.draft(1, 2).shape == (2,)


def test_adaptive_k_ladder():
    ak = AdaptiveK(ks=(2, 4, 8))
    assert ak.k == 2
    for _ in range(8):                      # sustained full acceptance
        ak.update(ak.k, ak.k)
    assert ak.k == 8
    for _ in range(12):                     # sustained rejection
        ak.update(0, ak.k)
    assert ak.k == 2
    with pytest.raises(ValueError):
        AdaptiveK(ks=(0, 2))


def test_make_drafter_modes():
    assert isinstance(make_drafter("ngram"), NGramDrafter)
    assert isinstance(make_drafter("replay"), ReplayDrafter)
    with pytest.raises(ValueError):
        make_drafter("definitely-not-a-mode")


# ---------------------------------------------------------------------------
# allocator truncation (the KV rollback primitive)
# ---------------------------------------------------------------------------


def test_block_allocator_truncate():
    a = BlockAllocator(n_blocks=9, block_size=4, slots=2,
                       max_blocks_per_slot=4)
    assert a.ensure(0, 14)                  # 4 blocks
    owned = list(a.owned(0))
    freed = a.truncate(0, 6)                # keep 2 blocks
    assert freed == 2
    assert list(a.owned(0)) == owned[:2]
    assert (a.table[0, 2:] == TRASH_BLOCK).all()
    a.check()
    # freed blocks are immediately reusable (LIFO: hottest first)
    assert a.ensure(1, 8)
    a.check()
    # truncate to a covered size is a no-op
    v = a.version
    assert a.truncate(0, 5) == 0
    assert a.version == v
    # truncate to zero == free
    assert a.truncate(0, 0) == 2
    assert (a.table[0] == TRASH_BLOCK).all()
    a.check()


# ---------------------------------------------------------------------------
# greedy spec == plain greedy, engine and batcher, dense and paged
# ---------------------------------------------------------------------------


def _trace_outputs(ap, params, vocab, *, n=8, mean_out=6, rate=4.0,
                   seed=2, drafter=None, **kw):
    sched = build_replica(RS.replace(**kw), ap=ap, params=params,
                          drafter=drafter)
    reqs = make_trace(n, mean_in=10, mean_out=mean_out, rate=rate,
                      vocab=vocab, seed=seed)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    return {r.rid: r.output for r in done}, sched.metrics(done)


def test_engine_spec_generate_matches_plain(tiny_lm):
    cfg, ap, params = tiny_lm
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 12))
    ref = build_engine(RS.replace(s_max=64), ap=ap,
                       params=params).generate(prompts, 10)
    for k in (2, 4, 8):
        res = build_engine(RS.replace(s_max=64, spec_mode="ngram",
                                      spec_k=k), ap=ap,
                           params=params).generate(prompts, 10)
        np.testing.assert_array_equal(ref.new_tokens, res.new_tokens)
    # paged engine cache under spec
    res_p = build_engine(RS.replace(s_max=64, block_size=16,
                                    spec_mode="ngram", spec_k=4),
                         ap=ap, params=params).generate(prompts, 10)
    np.testing.assert_array_equal(ref.new_tokens, res_p.new_tokens)


def test_engine_spec_rejects_non_dense():
    cfg = get_smoke("rwkv6-7b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    with pytest.raises(ValueError):
        build_engine(ReplicaSpec(arch="rwkv6-7b", s_max=64,
                                 spec_mode="ngram"), ap=ap, params=params)


def test_batcher_spec_trace_matches_plain(tiny_lm):
    """Acceptance gate: ngram spec decode at any k is bitwise the plain
    greedy stream — dense, paged, and paged + chunked admission."""
    cfg, ap, params = tiny_lm
    plain, _ = _trace_outputs(ap, params, cfg.vocab_size)
    for kw in (dict(spec_mode="ngram", spec_k=2),
               dict(spec_mode="ngram", spec_k=4, block_size=8),
               dict(spec_mode="ngram", spec_k=8, block_size=8,
                    admit_mode="chunked", admit_chunk=16)):
        got, m = _trace_outputs(ap, params, cfg.vocab_size, **kw)
        for rid in plain:
            np.testing.assert_array_equal(plain[rid], got[rid])
        assert m.spec_steps == m.steps and m.drafted_tokens > 0


def test_batcher_spec_max_new_edges(tiny_lm):
    """Budget truncation: requests whose remaining budget is smaller than
    an accepted run must stop at exactly max_new tokens."""
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(7)
    # highly repetitive prompts -> high ngram acceptance -> multi-token takes
    base = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    prompt = np.tile(base, 6)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new=mn, arrival_s=0.0)
            for i, mn in enumerate((1, 2, 5, 40))]
    ref = {}
    eng = build_engine(RS, ap=ap, params=params)
    for r in reqs:
        ref[r.rid] = eng.generate(r.prompt[None], r.max_new).new_tokens[0]
    sched = build_replica(RS.replace(slots=4, spec_mode="ngram",
                                     spec_k=8), ap=ap, params=params)
    done = sched.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                      for r in reqs])
    for r in done:
        assert len(r.output) == r.max_new
        np.testing.assert_array_equal(ref[r.rid], r.output)


def test_batcher_spec_admit_at_capacity_edge(tiny_lm):
    """A prompt of length s_max-1 admits at the last in-bounds position;
    like the plain step, spec must still decode once there (capacity-cap
    floor of 1) instead of computing a zero-token take — and the stream
    must match the plain batcher exactly."""
    cfg, ap, params = tiny_lm
    s_max = 32
    prompt = np.random.default_rng(11).integers(
        0, cfg.vocab_size, s_max - 1).astype(np.int32)

    def run(**kw):
        sched = build_replica(RS.replace(slots=2, s_max=s_max, **kw),
                              ap=ap, params=params)
        r = Request(rid=0, prompt=prompt.copy(), max_new=8)
        sched.run([r])
        return r.output

    ref = run()
    for kw in (dict(spec_mode="ngram", spec_k=4),
               dict(spec_mode="ngram", spec_k=4, block_size=8)):
        np.testing.assert_array_equal(ref, run(**kw))


def test_spec_oracle_drafter_cuts_steps(tiny_lm):
    """Replay (oracle) drafter: acceptance ~1 and the trace completes in a
    fraction of the sequential decode steps — the mechanism's speedup,
    measured in engine steps (deterministic, CI-stable)."""
    cfg, ap, params = tiny_lm
    plain, m0 = _trace_outputs(ap, params, cfg.vocab_size, mean_out=12)
    streams = {}
    reqs = make_trace(8, mean_in=10, mean_out=12, rate=4.0,
                      vocab=cfg.vocab_size, seed=2)
    for r in reqs:
        streams[tuple(int(t) for t in r.prompt)] = list(plain[r.rid])
    got, m1 = _trace_outputs(ap, params, cfg.vocab_size, mean_out=12,
                             block_size=8, spec_mode="replay", spec_k=4,
                             drafter=ReplayDrafter(streams))
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], got[rid])
    assert m1.acceptance_rate > 0.8
    assert m1.steps < m0.steps * 0.6, (m1.steps, m0.steps)
    assert m1.drafter_hit_rate > 0.8


def test_spec_preemption_rollback_correctness(tiny_lm):
    """Tight paged pool + speculative growth: preemption and rejected-draft
    truncation must still emit exactly the undisturbed streams, and drain
    with every block back in the pool."""
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(5)
    protos = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                 16).astype(np.int32),
                      max_new=40, arrival_s=0.0) for i in range(3)]
    eng = build_engine(RS, ap=ap, params=params)
    ref = {r.rid: eng.generate(r.prompt[None], r.max_new).new_tokens[0]
           for r in protos}
    sched = build_replica(RS.replace(block_size=8, n_blocks=13,
                                     spec_mode="ngram", spec_k=4),
                          ap=ap, params=params)
    done = sched.run([Request(rid=r.rid, prompt=r.prompt,
                              max_new=r.max_new) for r in protos])
    m = sched.metrics(done)
    assert m.preemptions > 0
    for r in done:
        np.testing.assert_array_equal(ref[r.rid], r.output)
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0


def test_spec_adaptive_k(tiny_lm):
    """Adaptive k climbs the ladder under an oracle drafter and still
    produces the exact greedy streams."""
    cfg, ap, params = tiny_lm
    plain, _ = _trace_outputs(ap, params, cfg.vocab_size, mean_out=12)
    streams = {}
    for r in make_trace(8, mean_in=10, mean_out=12, rate=4.0,
                        vocab=cfg.vocab_size, seed=2):
        streams[tuple(int(t) for t in r.prompt)] = list(plain[r.rid])
    got, m = _trace_outputs(ap, params, cfg.vocab_size, mean_out=12,
                            spec_mode="replay", spec_k=8,
                            spec_adaptive=True,
                            drafter=ReplayDrafter(streams))
    for rid in plain:
        np.testing.assert_array_equal(plain[rid], got[rid])
    assert m.spec_k_mean > 2.0          # ladder moved off the smallest k


def test_spec_sampled_deterministic_under_seed(tiny_lm):
    """temperature/top_k spec serving: per-token rejection sampling is
    exact w.r.t. the target distribution (argued in DESIGN.md §8); here we
    pin the testable properties — determinism under a seed, seed
    sensitivity, and exact budget lengths."""
    cfg, ap, params = tiny_lm

    def run(seed):
        sched = build_replica(RS.replace(slots=2, temperature=1.5,
                                         top_k=20, seed=seed,
                                         spec_mode="ngram", spec_k=4),
                              ap=ap, params=params)
        reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i,
                        max_new=12, arrival_s=0.0) for i in range(3)]
        return {r.rid: r.output for r in sched.run(reqs)}

    a1, a2, b = run(0), run(0), run(1)
    for rid in a1:
        assert len(a1[rid]) == 12
        np.testing.assert_array_equal(a1[rid], a2[rid])
    assert any(not np.array_equal(a1[rid], b[rid]) for rid in a1)


def test_spec_metrics_fields(tiny_lm):
    cfg, ap, params = tiny_lm
    _, m = _trace_outputs(ap, params, cfg.vocab_size,
                          spec_mode="ngram", spec_k=4)
    d = m.to_dict()
    for f in ("spec_steps", "drafted_tokens", "accepted_tokens",
              "acceptance_rate", "accepted_tokens_per_step",
              "drafter_hit_rate", "spec_k_mean"):
        assert f in d, f
    # k tokens drafted per active slot per verify pass
    assert 0 < d["drafted_tokens"] <= 4 * d["spec_steps"] * 3
    assert 0.0 <= d["acceptance_rate"] <= 1.0
    assert d["spec_k_mean"] == 4.0
    # plain serving reports zeroed spec fields
    _, m0 = _trace_outputs(ap, params, cfg.vocab_size)
    assert m0.spec_steps == 0 and m0.drafted_tokens == 0
    assert m0.acceptance_rate == 0.0
