"""Pallas kernel validation: interpret-mode shape/dtype sweeps against the
pure-jnp oracles, plus hypothesis property tests on the invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pure-pytest fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref,
                                            paged_decode_attention,
                                            paged_decode_attention_ref)
from repro.kernels.rwkv6_scan import rwkv6_scan, rwkv6_scan_ref
from repro.kernels.ssm_scan import ssm_scan, ssm_scan_ref

rng = np.random.default_rng(0)


def _tol(dt):
    return 3e-2 if dt == jnp.bfloat16 else 3e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    (2, 4, 2, 128, 128, 64, True, 0, jnp.float32),
    (1, 4, 1, 200, 200, 64, True, 0, jnp.float32),   # ragged pad
    (2, 2, 2, 256, 256, 128, True, 64, jnp.bfloat16),  # sliding window
    (1, 8, 2, 128, 384, 64, False, 0, jnp.float32),  # non-causal (encoder)
    (1, 2, 2, 128, 128, 64, True, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", FLASH_CASES,
                         ids=[f"case{i}" for i in range(len(FLASH_CASES))])
def test_flash_attention(case):
    B, Hq, Hkv, Sq, Skv, hd, causal, window, dt = case
    q = jnp.asarray(rng.standard_normal((B, Hq, Sq, hd)), dt)
    k = jnp.asarray(rng.standard_normal((B, Hkv, Skv, hd)), dt)
    v = jnp.asarray(rng.standard_normal((B, Hkv, Skv, hd)), dt)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dt), rtol=_tol(dt))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (4, 4, 2, 512, 64, 0, jnp.float32),
    (3, 8, 1, 300, 128, 0, jnp.float32),
    (8, 2, 2, 1024, 64, 128, jnp.bfloat16),
    (5, 6, 2, 256, 64, 0, jnp.float32),
]


@pytest.mark.parametrize("case", DECODE_CASES,
                         ids=[f"case{i}" for i in range(len(DECODE_CASES))])
def test_decode_attention(case):
    B, Hq, Hkv, S, hd, window, dt = case
    q = jnp.asarray(rng.standard_normal((B, Hq, hd)), dt)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), dt)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, hd)), dt)
    pos = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    out = decode_attention(q, k, v, pos, window=window, interpret=True)
    ref = decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dt), rtol=_tol(dt))


@given(st.integers(1, 6), st.integers(0, 255))
@settings(max_examples=10, deadline=None)
def test_decode_attention_position_property(b_seed, pos_val):
    """Tokens beyond position must not influence the output."""
    B, Hq, Hkv, S, hd = 2, 2, 1, 256, 64
    r = np.random.default_rng(b_seed)
    q = jnp.asarray(r.standard_normal((B, Hq, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, Hkv, hd)), jnp.float32)
    pos = jnp.full((B,), pos_val, jnp.int32)
    out1 = decode_attention(q, k, v, pos, interpret=True)
    # scrub everything past pos: output must be identical
    mask = (jnp.arange(S) <= pos_val)[None, :, None, None]
    out2 = decode_attention(q, jnp.where(mask, k, 999.0),
                            jnp.where(mask, v, -999.0), pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention (block-table gather)
# ---------------------------------------------------------------------------

# (B, Hq, Hkv, bs, max_blocks, n_blocks, hd, window, dtype)
PAGED_CASES = [
    (4, 4, 2, 16, 8, 40, 64, 0, jnp.float32),
    (3, 8, 1, 32, 4, 16, 128, 0, jnp.float32),
    (2, 2, 2, 64, 4, 12, 64, 128, jnp.bfloat16),   # sliding window
    (5, 6, 2, 8, 8, 48, 64, 0, jnp.float32),
]


@pytest.mark.parametrize("case", PAGED_CASES,
                         ids=[f"case{i}" for i in range(len(PAGED_CASES))])
def test_paged_decode_attention(case):
    B, Hq, Hkv, bs, mb, nb, hd, window, dt = case
    assert nb >= B * mb + 1
    q = jnp.asarray(rng.standard_normal((B, Hq, hd)), dt)
    k = jnp.asarray(rng.standard_normal((nb, bs, Hkv, hd)), dt)
    v = jnp.asarray(rng.standard_normal((nb, bs, Hkv, hd)), dt)
    # random (collision-free) logical->physical mapping; block 0 is trash
    tbl = jnp.asarray(1 + rng.permutation(nb - 1)[:B * mb].reshape(B, mb),
                      jnp.int32)
    pos = jnp.asarray(rng.integers(0, mb * bs, B), jnp.int32)
    out = paged_decode_attention(q, k, v, tbl, pos, window=window,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, k, v, tbl, pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dt), rtol=_tol(dt))


def test_paged_decode_attention_matches_dense_gather():
    """Paged kernel on a scattered table == dense kernel on the gathered
    logical view (the model-level parity the serving stack relies on)."""
    B, Hq, Hkv, bs, mb, nb, hd = 3, 4, 2, 32, 4, 16, 64
    r = np.random.default_rng(7)
    q = jnp.asarray(r.standard_normal((B, Hq, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((nb, bs, Hkv, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((nb, bs, Hkv, hd)), jnp.float32)
    tbl = jnp.asarray(1 + r.permutation(nb - 1)[:B * mb].reshape(B, mb),
                      jnp.int32)
    pos = jnp.asarray([5, 63, 127], jnp.int32)
    out_p = paged_decode_attention(q, k, v, tbl, pos, interpret=True)
    k_log = k[tbl].reshape(B, mb * bs, Hkv, hd)
    v_log = v[tbl].reshape(B, mb * bs, Hkv, hd)
    out_d = decode_attention(q, k_log, v_log, pos, interpret=True)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_attention_trash_isolation():
    """Scribbling on the trash block (0) and on unreachable blocks must not
    change the output — the isolation invariant preemption relies on."""
    B, Hq, Hkv, bs, mb, nb, hd = 2, 2, 1, 16, 4, 32, 64
    r = np.random.default_rng(11)
    q = jnp.asarray(r.standard_normal((B, Hq, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((nb, bs, Hkv, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((nb, bs, Hkv, hd)), jnp.float32)
    tbl = jnp.asarray(1 + r.permutation(nb - 1)[:B * mb].reshape(B, mb),
                      jnp.int32)
    pos = jnp.asarray([30, 61], jnp.int32)
    out1 = paged_decode_attention(q, k, v, tbl, pos, interpret=True)
    live = np.unique(np.asarray(tbl))
    dead = np.setdiff1d(np.arange(nb), live)
    k2 = k.at[dead].set(999.0)
    v2 = v.at[dead].set(-999.0)
    out2 = paged_decode_attention(q, k2, v2, tbl, pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


# ---------------------------------------------------------------------------
# rwkv6 chunked scan
# ---------------------------------------------------------------------------

RWKV_CASES = [(2, 128, 2, 64, 64), (1, 100, 3, 64, 32), (2, 64, 1, 32, 64)]


@pytest.mark.parametrize("case", RWKV_CASES,
                         ids=[f"case{i}" for i in range(len(RWKV_CASES))])
def test_rwkv6_scan(case):
    B, T, H, hd, chunk = case
    r = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.uniform(-6, -0.5, (B, T, H, hd)),
                                jnp.float32))
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32) * 0.1
    s0 = jnp.asarray(rng.standard_normal((B, H, hd, hd)), jnp.float32) * 0.1
    y, sf = rwkv6_scan(r, k, v, logw, u, s0, chunk=chunk, interpret=True)
    yr, sfr = rwkv6_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr), atol=2e-4,
                               rtol=1e-3)


def test_rwkv6_chunked_model_form_matches_step_exact():
    """The model layer's chunked jnp form is itself oracle-consistent."""
    from repro.models.rwkv import rwkv_scan_chunked
    B, T, H, hd = 2, 96, 2, 32
    r = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, H, hd)), jnp.float32)
    logw = -jnp.exp(jnp.asarray(rng.uniform(-6, -0.5, (B, T, H, hd)),
                                jnp.float32))
    u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32) * 0.1
    y1, s1 = rwkv_scan_chunked(r, k, v, logw, u, chunk=32)
    y2, s2 = rwkv6_scan_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

SSM_CASES = [(2, 128, 128, 16, 64, 128), (1, 100, 64, 8, 32, 64),
             (2, 64, 200, 16, 64, 128)]


@pytest.mark.parametrize("case", SSM_CASES,
                         ids=[f"case{i}" for i in range(len(SSM_CASES))])
def test_ssm_scan(case):
    B, T, Ci, S, ct, bc = case
    x = jnp.asarray(rng.standard_normal((B, T, Ci)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, T, Ci)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((B, T, S)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((B, T, S)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4, (Ci, S)), jnp.float32)
    h0 = jnp.asarray(rng.standard_normal((B, Ci, S)), jnp.float32) * 0.1
    y, hf = ssm_scan(x, dt, b, c, a, h0, chunk_t=ct, block_c=bc,
                     interpret=True)
    yr, hfr = ssm_scan_ref(x, dt, b, c, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr), atol=1e-4,
                               rtol=1e-4)


@given(st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_ssm_scan_state_chaining(seed):
    """Scanning [0:T] equals scanning [0:T/2] then [T/2:T] with the carried
    state — the invariant elastic restart relies on."""
    B, T, Ci, S = 1, 64, 64, 8
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((B, T, Ci)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.001, 0.1, (B, T, Ci)), jnp.float32)
    b = jnp.asarray(r.standard_normal((B, T, S)), jnp.float32)
    c = jnp.asarray(r.standard_normal((B, T, S)), jnp.float32)
    a = -jnp.asarray(r.uniform(0.5, 4, (Ci, S)), jnp.float32)
    y_full, h_full = ssm_scan_ref(x, dt, b, c, a)
    h = T // 2
    y1, s1 = ssm_scan_ref(x[:, :h], dt[:, :h], b[:, :h], c[:, :h], a)
    y2, s2 = ssm_scan_ref(x[:, h:], dt[:, h:], b[:, h:], c[:, h:], a, s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(h_full),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused grouped expert FFN (MoE dispatch path)
# ---------------------------------------------------------------------------

from repro.kernels.moe_gemm import moe_expert_ffn, moe_expert_ffn_ref

MOE_CASES = [(4, 128, 64, 128, jnp.float32), (2, 100, 128, 200, jnp.float32),
             (8, 256, 64, 96, jnp.bfloat16)]


@pytest.mark.parametrize("case", MOE_CASES,
                         ids=[f"case{i}" for i in range(len(MOE_CASES))])
def test_moe_expert_ffn(case):
    E, C, D, F, dt = case
    x = jnp.asarray(rng.standard_normal((E, C, D)), dt)
    wg = jnp.asarray(rng.standard_normal((E, D, F)) * 0.05, dt)
    wu = jnp.asarray(rng.standard_normal((E, D, F)) * 0.05, dt)
    wd = jnp.asarray(rng.standard_normal((E, F, D)) * 0.05, dt)
    out = moe_expert_ffn(x, wg, wu, wd, interpret=True)
    ref = moe_expert_ffn_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dt), rtol=_tol(dt))


# ---------------------------------------------------------------------------
# quantized-collective pack/unpack (ar_quant wire format)
# ---------------------------------------------------------------------------

from repro.kernels.rd_allreduce.quant import quantize_pack, unpack_dequant
from repro.kernels.rd_allreduce.quant_kernel import (quantize_pack_pallas,
                                                     unpack_dequant_pallas)

QP_CASES = [(8, 128, 4, 512), (8, 64, 1, 256), (4, 64, 4, 384),
            (4, 128, 2, 128)]


@pytest.mark.parametrize("case", QP_CASES,
                         ids=[f"b{b}g{g}" for b, g, _, _ in QP_CASES])
def test_quant_pack_kernel_matches_reference(case):
    """The fused Pallas pack/unpack is bit-for-bit the jnp reference: same
    int8 payload (nibble layout included), same bf16 scales, same f32
    dequant — interpret mode, both bit widths."""
    bits, group, R, D = case
    x = jnp.asarray(rng.standard_normal((R, D)) * 3.0, jnp.float32)
    q_ref, s_ref = quantize_pack(x, bits, group)
    q_k, s_k = quantize_pack_pallas(x, bits=bits, group=group,
                                    interpret=True)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(s_k, np.float32),
                                  np.asarray(s_ref, np.float32))
    d_ref = unpack_dequant(q_ref, s_ref, bits, group)
    d_k = unpack_dequant_pallas(q_k, s_k, bits=bits, group=group,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_ref))
