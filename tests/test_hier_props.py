"""Property tests on the hierarchical-collective schedule mathematics
(device-free: the schedule invariants the shard_map code relies on)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pure-pytest fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st


@given(st.sampled_from([2, 4, 8, 16, 32, 64, 128, 256]))
@settings(deadline=None)
def test_xor_schedule_is_perfect_matching_each_step(n):
    """Recursive doubling step i: peer = rank ^ 2^i is an involution with
    no fixed points — every device exchanges with exactly one other."""
    step = 1
    while step < n:
        peers = np.arange(n) ^ step
        assert np.all(peers != np.arange(n))
        assert np.array_equal(peers[peers], np.arange(n))
        step <<= 1


@given(st.sampled_from([2, 4, 8, 16, 32, 64]))
@settings(deadline=None)
def test_rd_converges_to_full_sum(n):
    """Simulate the RD dataflow on scalars: after log2(n) XOR exchanges,
    every rank holds the global sum."""
    rng = np.random.default_rng(n)
    vals = rng.standard_normal(n)
    acc = vals.copy()
    step = 1
    while step < n:
        acc = acc + acc[np.arange(n) ^ step]
        step <<= 1
    np.testing.assert_allclose(acc, np.full(n, vals.sum()), rtol=1e-9)


@given(st.sampled_from([2, 4, 8, 16, 32]))
@settings(deadline=None)
def test_halving_schedule_slice_tracking(n):
    """Recursive halving: the kept-half bit-walk leaves rank r holding
    logical chunk r (the invariant rd_halving_all_reduce's AG phase relies
    on)."""
    for r in range(n):
        lo, size, stride = 0, n, n >> 1
        while size > 1:
            half = size // 2
            if (r // stride) % 2:
                lo += half
            size, stride = half, stride >> 1
        assert lo == r


@given(st.integers(1, 4096), st.integers(0, 100))
@settings(max_examples=100, deadline=None)
def test_int8_group_quantization_error_bound(nelem, seed):
    """The compressed-exchange quantizer: error <= group_absmax / 127 per
    element (half a quantization step would be /254; rounding gives /127
    worst case -> use that bound)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(nelem) * rng.uniform(0.1, 100)
    group = 128
    pad = (-nelem) % group
    xp = np.pad(x, (0, pad)).reshape(-1, group)
    scale = np.maximum(np.abs(xp).max(1, keepdims=True) / 127.0, 1e-30)
    q = np.clip(np.round(xp / scale), -127, 127)
    err = np.abs(q * scale - xp)
    assert np.all(err <= scale * 0.5 + 1e-12)


def test_tp_reduce_scatter_slow_phase_selection(monkeypatch):
    """PR 5 bugfix regression: tp_reduce_scatter's slow phase must route
    ``flat`` to lax.psum and EVERY hierarchical strategy through
    ``_slow_phase`` (the old code buried the flat remap in a conditional
    that could never fire, and sent hier_ring around _slow_phase)."""
    import jax.numpy as jnp
    from repro.core import hierarchical as hier
    from repro.core.pcontext import ParallelCtx

    calls = []
    monkeypatch.setattr(
        hier, "_slow_phase",
        lambda x, slow, ctx: (calls.append(("slow_phase",
                                            ctx.ar_strategy)), x)[1])
    monkeypatch.setattr(
        hier.lax, "psum",
        lambda x, axes: (calls.append(("psum", tuple(axes))), x)[1])
    x = jnp.ones((4, 8))
    for strat in ("flat", "hier_ring", "hier_rd", "hier_rd_halving"):
        calls.clear()
        ctx = ParallelCtx(tp_slow=("pod",), ar_strategy=strat)
        out = hier.tp_reduce_scatter(x, ctx, dim=0)
        assert out.shape == x.shape
        if strat == "flat":
            assert calls == [("psum", ("pod",))], (strat, calls)
        else:
            assert calls == [("slow_phase", strat)], (strat, calls)
