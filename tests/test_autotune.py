"""Device-free tests for the message-size-aware all-reduce autotuner
(repro.core.autotune): analytic dispatch, crossover behavior, measurement
refinement, and JSON persistence."""
import json
import os

import pytest

from repro.core import autotune as at
from repro.core import comm_model as cm
from repro.core.pcontext import ParallelCtx

KB = 1024
MB = 1024 * KB


def test_auto_is_a_valid_ctx_strategy():
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="auto")
    assert ctx.ar_strategy == "auto"
    with pytest.raises(ValueError):
        ParallelCtx(ar_strategy="definitely_not_a_strategy")


def test_predict_times_positive_and_monotone():
    for net in (cm.TPU_V5E, cm.PERLMUTTER):
        t_small = at.predict_times(64 * KB, 16, 4, net)
        t_big = at.predict_times(64 * MB, 16, 4, net)
        for s in at.DISPATCHABLE:
            assert t_small[s] > 0
            assert t_big[s] > t_small[s], (net.name, s)


def test_auto_selects_different_strategies_small_vs_large_tpu_v5e():
    """Acceptance: on the tpu_v5e NetworkSpec the dispatcher must flip
    strategies between a 64 KB and a 64 MB payload (the paper's crossover:
    recursive doubling in the latency regime, bandwidth-optimal algorithms
    once the wire dominates)."""
    small = at.analytic_choice(64 * KB, 16, 4, cm.TPU_V5E)
    large = at.analytic_choice(64 * MB, 16, 4, cm.TPU_V5E)
    assert small.strategy != large.strategy, (small, large)
    # and the small-message pick is the paper's NVRAR-style RD
    assert small.strategy == "hier_rd"
    times_small = at.predict_times(64 * KB, 16, 4, cm.TPU_V5E)
    times_large = at.predict_times(64 * MB, 16, 4, cm.TPU_V5E)
    assert times_small[small.strategy] == min(
        times_small[s] for s in at.DISPATCHABLE)
    assert times_large[large.strategy] == min(
        times_large[s] for s in at.DISPATCHABLE)


def test_single_level_topology_degenerates():
    choice = at.analytic_choice(256 * KB, 8, 1, cm.TPU_V5E)
    assert choice.strategy in at.DISPATCHABLE  # no slow axis: any is fine
    times = at.predict_times(256 * KB, 8, 1, cm.TPU_V5E)
    assert len(set(times.values())) == 1  # all equal: one-level reduction


def test_rd_chunks_kick_in_for_large_rd_messages():
    # At slow=2, full-exchange RD matches every rival's bandwidth with the
    # fewest latency steps, so hier_rd wins at any size — and once the
    # slow-phase shard (msg/fast) crosses the chunk threshold the pick
    # pipelines the exchange (paper Sec. 4.2.1).
    choice = at.analytic_choice(16 * MB, 16, 2, cm.TPU_V5E)
    assert choice.strategy == "hier_rd"
    assert choice.rd_chunks > 1
    # tiny messages never chunk
    tiny = at.analytic_choice(32 * KB, 16, 4, cm.TPU_V5E)
    assert tiny.rd_chunks == 1


def test_tuner_lookup_caches_and_buckets():
    t = at.AutoTuner(cm.TPU_V5E)
    a = t.choose(100 * KB, 16, 4)
    b = t.choose(100 * KB + 1, 16, 4)  # same pow2 bucket
    assert a == b
    assert len(t.table) == 1
    t.choose(100 * KB, 16, 2)  # different topology -> new entry
    assert len(t.table) == 2


def test_measurement_refinement_overrides_analytic():
    t = at.AutoTuner(cm.TPU_V5E)
    assert t.choose(64 * KB, 16, 4).strategy == "hier_rd"
    t.record(64 * KB, 16, 4, "bfloat16", "hier_ring", 1.0e-6)
    t.record(64 * KB, 16, 4, "bfloat16", "hier_rd", 9.0e-6)
    assert t.refine() == 1
    assert t.choose(64 * KB, 16, 4).strategy == "hier_ring"


def test_save_load_roundtrip(tmp_path):
    t = at.AutoTuner(cm.TPU_V5E)
    t.choose(64 * KB, 16, 4)
    t.choose(64 * MB, 16, 4)
    p = os.path.join(tmp_path, "ar_table.json")
    t.save(p)
    doc = json.load(open(p))
    assert doc["net"] == "tpu_v5e" and len(doc["table"]) == 2
    t2 = at.AutoTuner.load(p)
    assert t2.table == t.table


def test_save_load_roundtrip_lossy(tmp_path):
    """An allow_lossy table must survive JSON persistence verbatim: the
    knob itself, every compress_slow entry, and — after load — fresh
    lookups must keep seeding with the lossy rule enabled."""
    t = at.AutoTuner(cm.TPU_V5E, allow_lossy=True)
    sizes = [64 * KB, 1 * MB, 16 * MB, 64 * MB, 256 * MB]
    for s in sizes:
        t.choose(s, 16, 4)
    lossy_keys = {k for k, v in t.table.items() if v.compress_slow}
    assert lossy_keys, "expected the lossy knob to fire at some size"
    assert any(not v.compress_slow for v in t.table.values()), \
        "latency-bound buckets must stay lossless"
    p = os.path.join(tmp_path, "lossy_table.json")
    t.save(p)
    doc = json.load(open(p))
    assert doc["allow_lossy"] is True
    t2 = at.AutoTuner.load(p)
    assert t2.allow_lossy is True
    assert t2.table == t.table
    assert {k for k, v in t2.table.items() if v.compress_slow} == lossy_keys
    # a fresh bucket on the loaded tuner still honors allow_lossy
    probe = 4 * max(sizes)
    assert t2.choose(probe, 16, 4) == at.analytic_choice(
        probe, 16, 4, cm.TPU_V5E, allow_lossy=True)
    # and a lossless tuner never emits compress_slow at any probed size
    t3 = at.AutoTuner(cm.TPU_V5E)
    assert not any(t3.choose(s, 16, 4).compress_slow for s in sizes)


def test_install_and_resolve_roundtrip():
    prev = at.install(at.AutoTuner(cm.TPU_V5E))
    try:
        ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                          ar_strategy="auto")
        r_small = at.resolve(ctx, 64 * KB, 16, 4, "bfloat16")
        r_large = at.resolve(ctx, 64 * MB, 16, 4, "bfloat16")
        assert r_small.ar_strategy != "auto"
        assert r_large.ar_strategy != "auto"
        assert r_small.ar_strategy != r_large.ar_strategy
        # the rest of the ctx is untouched
        assert r_small.tp_fast == ctx.tp_fast
        assert r_small.overlap_matmul == ctx.overlap_matmul
    finally:
        at.install(prev)


def test_install_from_path_env(tmp_path, monkeypatch):
    t = at.AutoTuner(cm.PERLMUTTER)
    t.choose(1 * MB, 4, 8)
    p = os.path.join(tmp_path, "tbl.json")
    t.save(p)
    prev = at.active()
    try:
        monkeypatch.setenv("REPRO_AR_TABLE", p)
        installed = at.install_from_path(None)
        assert installed.net.name == "perlmutter"
    finally:
        at.install(prev)


def test_key_parse_key_roundtrip():
    """_parse_key returns the bucket's upper bound (bucket_bytes), never
    the original message size — and re-keying the parsed tuple must be
    the identity (the invariant refine() relies on)."""
    for msg in (1, 300, 64 * KB, 100 * KB, 100 * KB + 1, 64 * MB):
        key = at._key(msg, 16, 4, "bfloat16")
        bucket_bytes, fast, slow, dtype = at._parse_key(key)
        assert (fast, slow, dtype) == (16, 4, "bfloat16")
        assert bucket_bytes >= min(msg, 256)       # bucket floor is 2**8
        assert bucket_bytes >= msg or msg <= 256
        assert bucket_bytes < 2 * max(msg, 256)    # tight upper bound
        assert at._key(bucket_bytes, fast, slow, dtype) == key
    # exact powers of two are their own bucket bound
    assert at._parse_key(at._key(256 * KB, 8, 2, "float32"))[0] == 256 * KB


def test_refine_chunks_use_bucket_bytes():
    """refine() recomputes rd_chunks from the bucket bound, so a measured
    hier_rd winner at a large bucket pipelines its slow exchange."""
    t = at.AutoTuner(cm.TPU_V5E)
    t.record(16 * MB, 16, 2, "float32", "hier_rd", 1.0e-6)
    t.record(16 * MB, 16, 2, "float32", "hier_ring", 9.0e-6)
    assert t.refine() >= 1
    choice = t.choose(16 * MB, 16, 2, "float32")
    assert choice.strategy == "hier_rd"
    assert choice.rd_chunks == at._rd_chunks_for(
        at._parse_key(at._key(16 * MB, 16, 2, "float32"))[0], 16)
    assert choice.rd_chunks > 1


def test_sp_dispatch_crossover_tpu_v5e():
    """seq_parallel='auto' acceptance: decode-sized messages stay on the
    fused (hierarchical-RD) path, prefill-sized messages decompose into
    RS+AG — on both the bench mesh topology (fast=4, slow=2) and the
    production frame (fast=16, slow=4)."""
    for fast, slow in ((4, 2), (16, 4)):
        assert not at.analytic_sp_choice(16 * KB, fast, slow, cm.TPU_V5E)
        assert at.analytic_sp_choice(1 * MB, fast, slow, cm.TPU_V5E)
        assert at.analytic_sp_choice(16 * MB, fast, slow, cm.TPU_V5E)
    # the fused pick SP is compared against at decode sizes is NVRAR
    assert at.analytic_choice(16 * KB, 16, 4, cm.TPU_V5E).strategy \
        == "hier_rd"
    # no fast axes -> nothing to decompose
    assert not at.analytic_sp_choice(16 * MB, 1, 4, cm.TPU_V5E)
    t = at.predict_sp_times(1 * MB, 16, 4, cm.TPU_V5E)
    assert t["fused"] > 0 and t["rs_ag"] > 0


def test_sp_table_persistence_and_lookup_log(tmp_path):
    t = at.AutoTuner(cm.TPU_V5E)
    assert not t.choose_sp(16 * KB, 4, 2)
    assert t.choose_sp(4 * MB, 4, 2)
    assert t.choose_sp(4 * MB + 1, 4, 2) == t.choose_sp(4 * MB, 4, 2)
    assert len(t.sp_table) == 3          # two buckets + the 4MB+1 bucket
    assert len(t.sp_lookup_buckets()) == len(t.sp_table)
    p = os.path.join(tmp_path, "sp_table.json")
    t.save(p)
    doc = json.load(open(p))
    assert doc["sp_table"] == {k: bool(v) for k, v in t.sp_table.items()}
    t2 = at.AutoTuner.load(p)
    assert t2.sp_table == t.sp_table
    # a persisted entry overrides the analytic seed
    key = at._key(16 * KB, 4, 2, "bfloat16")
    t2.sp_table[key] = True
    assert t2.choose_sp(16 * KB, 4, 2) is True


def test_seq_parallel_mode_validation():
    for mode in ("off", "on", "auto"):
        assert ParallelCtx(seq_parallel=mode).seq_parallel == mode
    with pytest.raises(ValueError):
        ParallelCtx(seq_parallel="maybe")
