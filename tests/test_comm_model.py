"""Tests for the alpha-beta communication models (paper Eqs. 1-6)."""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pure-pytest fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.core import comm_model as cm

KB = 1024


def test_ring_latency_linear_in_devices():
    t8 = cm.t_ring_allreduce(256 * KB, 2, 4, cm.PERLMUTTER)
    t32 = cm.t_ring_allreduce(256 * KB, 8, 4, cm.PERLMUTTER)
    # latency term: 2(NG-1) alpha — grows ~linearly with device count
    assert t32 > 3.0 * t8 * (31 / 7) / 5  # loose linear-growth check
    assert t32 > t8


def test_tree_latency_log_in_nodes():
    t2 = cm.t_tree_allreduce(256 * KB, 2, 4, cm.PERLMUTTER)
    t16 = cm.t_tree_allreduce(256 * KB, 16, 4, cm.PERLMUTTER)
    # alpha_inter term scales with log2(N): 1 -> 4
    lat2 = 2 * math.log2(2) * cm.PERLMUTTER.alpha_inter
    lat16 = 2 * math.log2(16) * cm.PERLMUTTER.alpha_inter
    assert (t16 - t2) == pytest.approx(
        (lat16 - lat2)
        + 2 * (15 / 16 - 1 / 2) * 256 * KB / cm.PERLMUTTER.beta_inter,
        rel=1e-6)


def test_nvrar_beats_ring_and_tree_small_messages():
    """The paper's core claim: in the 128 KB - 2 MB regime across >= 4 nodes,
    NVRAR has lower modelled latency than both NCCL algorithms."""
    for msg in (128 * KB, 256 * KB, 512 * KB, 1024 * KB, 2048 * KB):
        for n_nodes in (4, 8, 16, 32):
            nv = cm.t_nvrar(msg, n_nodes, 4, cm.PERLMUTTER)
            ring = cm.t_ring_allreduce(msg, n_nodes, 4, cm.PERLMUTTER)
            tree = cm.t_tree_allreduce(msg, n_nodes, 4, cm.PERLMUTTER)
            assert nv < ring, (msg, n_nodes)
            assert nv < tree, (msg, n_nodes)


def test_nvrar_speedup_band_matches_paper():
    """Paper: up to 1.9x on Slingshot and 3.5x on InfiniBand for
    256 KB-2 MB.  The idealized alpha-beta model lands in the Slingshot band
    and predicts the IB ceiling of exactly 2x vs an *ideal* tree (G=1 makes
    NVRAR pure RD with half of tree's latency+bandwidth terms); the paper's
    larger measured IB gains are against real NCCL software overheads not in
    the model — see EXPERIMENTS.md §Paper-claims."""
    perl = max(cm.nvrar_speedup(m, n, 4, cm.PERLMUTTER)
               for m in (256 * KB, 512 * KB, 1024 * KB, 2048 * KB)
               for n in (4, 8, 16, 32))
    vista = max(cm.nvrar_speedup(m, n, 1, cm.VISTA)
                for m in (256 * KB, 512 * KB, 1024 * KB, 2048 * KB)
                for n in (4, 8, 16, 32))
    assert 1.8 <= perl <= 4.0, perl
    assert 1.9 <= vista <= 2.1, vista


def test_decode_message_size_example():
    # 70B model, B=8, H=8192 -> 128 KB (paper Sec. 3.5)
    assert cm.decode_allreduce_bytes(8, 8192) == 128 * KB


@given(msg=st.integers(16 * KB, 8 * 1024 * KB),
       n_nodes=st.sampled_from([2, 4, 8, 16, 32]),
       g=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=200, deadline=None)
def test_nvrar_model_properties(msg, n_nodes, g):
    net = cm.PERLMUTTER
    nv = cm.t_nvrar(msg, n_nodes, g, net)
    assert nv > 0
    # monotone in message size
    assert cm.t_nvrar(2 * msg, n_nodes, g, net) > nv
    # halving variant never beats paper model on latency-dominated sizes by
    # more than its bandwidth advantage; both positive
    assert cm.t_nvrar_variant(msg, n_nodes, g, net, inter="halving") > 0
    # full-exchange variant >= paper's optimistic Eq. 4 form
    assert cm.t_nvrar_variant(msg, n_nodes, g, net,
                              inter="full_exchange") >= nv - 1e-12


def test_speedup_table_shape():
    rows = cm.speedup_table(cm.PERLMUTTER, [256 * KB, 1024 * KB],
                            [8, 16, 32])
    assert len(rows) == 6
    assert all(r["speedup"] > 0 for r in rows)
