"""BlockAllocator.truncate under adversarial interleavings.

Two layers of guarantee:

* host side — randomized grow/truncate/preempt/free/defragment sequences
  must keep the free-list/table partition invariants (``check()``), keep
  every slot's table row equal to its owned blocks, and bump ``version``
  exactly when the table mutates (callers skip device uploads otherwise);
* device side — blocks a truncate returns to the pool are immediately
  reused (LIFO) by other slots' growth; the truncating slot's attention
  output must stay bitwise equal to an isolated single-slot run, i.e. a
  neighbour's K/V written into the recycled blocks can never leak back
  (the write-ordering invariant of DESIGN.md §7, here exercised through
  the speculative-rollback path that motivated ``truncate``).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.inference.kv_cache import BlockAllocator, TRASH_BLOCK
from repro.inference.scheduler import Request
from repro.inference.spec import ReplicaSpec, build_replica
from repro.inference.speculative import Drafter
from repro.models.transformer import make_plan, init_params

RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def test_truncate_interleaved_randomized():
    """600 random ops across 4 slots on a deliberately tight pool."""
    rng = np.random.default_rng(7)
    bs, max_blocks = 4, 8
    a = BlockAllocator(n_blocks=21, block_size=bs, slots=4,
                       max_blocks_per_slot=max_blocks)
    tokens = [0, 0, 0, 0]          # logical token coverage per slot
    ops = np.array(["grow", "truncate", "preempt", "free", "defrag"])
    for _ in range(600):
        s = int(rng.integers(4))
        op = str(rng.choice(ops, p=[0.45, 0.25, 0.1, 0.1, 0.1]))
        ver = a.version
        if op == "grow":
            tgt = min(tokens[s] + int(rng.integers(1, 2 * bs + 1)),
                      max_blocks * bs)
            grew = a.blocks_for(tgt) > len(a.owned(s))
            if a.ensure(s, tgt):
                tokens[s] = max(tokens[s], tgt)
                assert (a.version > ver) == grew, (op, s, tgt)
            else:
                assert a.version == ver, "failed ensure mutated the table"
        elif op == "truncate":
            tgt = int(rng.integers(0, tokens[s] + 1))
            own_before = len(a.owned(s))
            keep = a.blocks_for(tgt)
            tail = max(own_before - keep, 0)
            freed = a.truncate(s, tgt)
            assert freed == tail, (freed, tail)
            assert len(a.owned(s)) == own_before - freed
            assert (a.version > ver) == (freed > 0)
            # the released tail is immediately reusable, hottest first
            assert a.free_blocks >= freed
            tokens[s] = min(tokens[s], tgt)
        elif op == "preempt":
            n = len(a.owned(s))
            assert a.preempt(s) == n
            assert (a.table[s] == TRASH_BLOCK).all()
            assert (a.version > ver) == (n > 0)
            tokens[s] = 0
        elif op == "free":
            n = len(a.owned(s))
            assert a.free(s) == n
            assert (a.version > ver) == (n > 0)
            tokens[s] = 0
        else:
            perm = a.defragment()
            if perm is not None:
                assert a.version > ver
                assert sorted(perm.tolist()) == list(range(a.n_blocks))
                assert perm[TRASH_BLOCK] == TRASH_BLOCK
            else:
                assert a.version == ver
        a.check()                  # free list + ownership partition pool
        for sl in range(4):
            # table rows past the owned prefix must be trash (truncated
            # tails may never stay addressable through the table)
            own = a.owned(sl)
            assert (a.table[sl, len(own):] == TRASH_BLOCK).all(), sl
    for sl in range(4):
        a.free(sl)
    a.check()
    assert a.used_blocks == 0 and a.free_blocks == a.n_blocks - 1


def _expect_freed(a: BlockAllocator, blocks) -> int:
    """How many of ``blocks`` dropping ONE slot ref would actually free:
    exactly those this slot holds the last reference to (no other slot,
    no external hold)."""
    return sum(1 for b in blocks
               if a.slot_refs(b) == 1 and a.held_count(b) == 0)


@pytest.mark.parametrize("seed", [7, 11, 13])
def test_share_fork_interleaved_randomized(seed):
    """600 random grow/share/fork/hold/evict/truncate/preempt/free/defrag
    ops across 4 slots on a tight pool: the refcount/free-list/ownership
    partition (``check()``) must hold after every op, one sharer's exit
    must never free or remap a neighbour's blocks, and defragment must
    remap each shared block exactly once across all referencing tables.
    The op trace is printed on failure for replay."""
    rng = np.random.default_rng(seed)
    bs, max_blocks = 4, 8
    a = BlockAllocator(n_blocks=21, block_size=bs, slots=4,
                       max_blocks_per_slot=max_blocks)
    remaps = []
    a.register_remap_hook(lambda m: remaps.append(dict(m)))
    tokens = [0, 0, 0, 0]
    held: list = []                 # our (trie-like) external holds
    ops = np.array(["grow", "share", "fork", "hold", "evict", "truncate",
                    "preempt", "free", "defrag"])
    p = np.array([0.28, 0.12, 0.1, 0.08, 0.08, 0.14, 0.06, 0.06, 0.08])
    trace = []
    try:
        for _ in range(600):
            s = int(rng.integers(4))
            op = str(rng.choice(ops, p=p))
            trace.append((op, s))
            if op == "grow":
                tgt = min(tokens[s] + int(rng.integers(1, 2 * bs + 1)),
                          max_blocks * bs)
                trace[-1] = (op, s, tgt)
                if a.ensure(s, tgt):
                    tokens[s] = max(tokens[s], tgt)
            elif op == "share":
                srcs = [x for x in range(4) if a.owned(x) and x != s]
                if a.owned(s) or not srcs:
                    continue
                src = srcs[int(rng.integers(len(srcs)))]
                k = int(rng.integers(1, len(a.owned(src)) + 1))
                blocks = a.owned(src)[:k]
                trace[-1] = (op, s, src, blocks)
                refs_before = [a.slot_refs(b) for b in blocks]
                a.share(s, blocks)
                assert a.owned(s) == blocks
                for b, r0 in zip(blocks, refs_before):
                    assert a.slot_refs(b) == r0 + 1
                tokens[s] = k * bs
            elif op == "fork":
                if not a.owned(s):
                    continue
                idx = int(rng.integers(len(a.owned(s))))
                b = a.owned(s)[idx]
                trace[-1] = (op, s, idx, b)
                exclusive = a.is_exclusive(s, idx)
                refs0, free0 = a.slot_refs(b), a.free_blocks
                if not exclusive and free0 == 0:
                    with pytest.raises(RuntimeError):
                        a.fork_for_write(s, idx)
                    continue
                r = a.fork_for_write(s, idx)
                if exclusive:
                    assert r is None and a.owned(s)[idx] == b
                else:
                    old, new = r
                    assert old == b and a.owned(s)[idx] == new
                    assert a.slot_refs(b) == refs0 - 1
                    assert a.slot_refs(new) == 1
                    assert a.free_blocks == free0 - 1
            elif op == "hold":
                live = [b for x in range(4) for b in a.owned(x)]
                if not live:
                    continue
                b = live[int(rng.integers(len(live)))]
                trace[-1] = (op, b)
                h0 = a.held_count(b)
                a.hold([b])
                held.append(b)
                assert a.held_count(b) == h0 + 1
            elif op == "evict":
                if not held:
                    continue
                b = held.pop(int(rng.integers(len(held))))
                trace[-1] = (op, b)
                expect = (a.slot_refs(b) == 0 and a.held_count(b) == 1)
                freed = a.release([b])
                assert (freed == [b]) == expect, (freed, expect)
            elif op == "truncate":
                tgt = int(rng.integers(0, tokens[s] + 1))
                trace[-1] = (op, s, tgt)
                keep = a.blocks_for(tgt)
                tail = a.owned(s)[keep:]
                expect = _expect_freed(a, tail)
                free0 = a.free_blocks
                assert a.truncate(s, tgt) == expect
                assert a.free_blocks == free0 + expect
                tokens[s] = min(tokens[s], tgt)
            elif op in ("preempt", "free"):
                own = a.owned(s)
                expect = _expect_freed(a, own)
                neighbours = {x: a.owned(x) for x in range(4) if x != s}
                free0 = a.free_blocks
                n = a.preempt(s) if op == "preempt" else a.free(s)
                assert n == expect, (n, expect)
                assert a.free_blocks == free0 + expect
                assert (a.table[s] == TRASH_BLOCK).all()
                # neighbour safety: a sharer's exit never frees or moves
                # blocks another slot still references
                for x, ob in neighbours.items():
                    assert a.owned(x) == ob, (s, x)
                    for b in ob:
                        assert b not in a._free, (s, x, b)
                tokens[s] = 0
            else:
                pre_owned = {x: a.owned(x) for x in range(4)}
                pre_live = {b for ob in pre_owned.values() for b in ob}
                pre_live |= {b for b in held}
                perm = a.defragment()
                if perm is None:
                    continue
                m = remaps[-1]
                # every live block (shared or not) remapped exactly once,
                # and every referencing table moved through that one entry
                assert set(m) == pre_live | {TRASH_BLOCK}
                live_new = [m[b] for b in pre_live]
                assert len(set(live_new)) == len(live_new), "remap not 1:1"
                for x, ob in pre_owned.items():
                    assert list(a.owned(x)) == [m[b] for b in ob], x
                held = [m[b] for b in held]
            a.check()
    except AssertionError:
        print(f"op trace (seed={seed}, {len(trace)} ops):")
        for t in trace[-50:]:
            print("  ", t)
        raise
    # drain: free every slot and release every hold -> empty pool
    for sl in range(4):
        a.free(sl)
    a.release(held)
    a.check()
    assert a.used_blocks == 0 and a.free_blocks == a.n_blocks - 1


class _JunkDrafter(Drafter):
    """Proposes deliberately wrong tokens: every draft is rejected, so
    every verify step writes a K/V tail that truncate must roll back."""

    def __init__(self, vocab: int):
        super().__init__()
        self.vocab = vocab

    def _propose(self, slot, hist, k):
        last = hist[-1] if hist else 0
        return [(last + 1 + i) % self.vocab for i in range(k)]


def test_truncated_tails_never_leak_across_slots(tiny_lm):
    """Spec decoding with an always-rejected drafter truncates a K/V tail
    on every step while a tight pool forces the freed blocks straight
    into the other slots' growth; tokens must equal isolated references.
    """
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(3)
    protos = [(rng.integers(0, cfg.vocab_size, 9 + 7 * i).astype(np.int32),
               18) for i in range(3)]
    refs = {}
    for i, (p, n) in enumerate(protos):
        s1 = build_replica(RS.replace(slots=1), ap=ap, params=params)
        r = Request(rid=i, prompt=p, max_new=n)
        s1.run([r])
        refs[i] = r.output
    sched = build_replica(
        RS.replace(block_size=4, n_blocks=25, spec_mode="replay",
                   spec_k=4),
        ap=ap, params=params, drafter=_JunkDrafter(cfg.vocab_size))
    done = sched.run([Request(rid=i, prompt=p, max_new=n, arrival_s=0.0)
                      for i, (p, n) in enumerate(protos)])
    m = sched.metrics(done)
    assert m.accepted_tokens == 0, "junk drafts must all be rejected"
    assert m.spec_steps > 0
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], r.output)
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0
