"""BlockAllocator.truncate under adversarial interleavings.

Two layers of guarantee:

* host side — randomized grow/truncate/preempt/free/defragment sequences
  must keep the free-list/table partition invariants (``check()``), keep
  every slot's table row equal to its owned blocks, and bump ``version``
  exactly when the table mutates (callers skip device uploads otherwise);
* device side — blocks a truncate returns to the pool are immediately
  reused (LIFO) by other slots' growth; the truncating slot's attention
  output must stay bitwise equal to an isolated single-slot run, i.e. a
  neighbour's K/V written into the recycled blocks can never leak back
  (the write-ordering invariant of DESIGN.md §7, here exercised through
  the speculative-rollback path that motivated ``truncate``).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.inference.kv_cache import BlockAllocator, TRASH_BLOCK
from repro.inference.scheduler import Request
from repro.inference.spec import ReplicaSpec, build_replica
from repro.inference.speculative import Drafter
from repro.models.transformer import make_plan, init_params

RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def test_truncate_interleaved_randomized():
    """600 random ops across 4 slots on a deliberately tight pool."""
    rng = np.random.default_rng(7)
    bs, max_blocks = 4, 8
    a = BlockAllocator(n_blocks=21, block_size=bs, slots=4,
                       max_blocks_per_slot=max_blocks)
    tokens = [0, 0, 0, 0]          # logical token coverage per slot
    ops = np.array(["grow", "truncate", "preempt", "free", "defrag"])
    for _ in range(600):
        s = int(rng.integers(4))
        op = str(rng.choice(ops, p=[0.45, 0.25, 0.1, 0.1, 0.1]))
        ver = a.version
        if op == "grow":
            tgt = min(tokens[s] + int(rng.integers(1, 2 * bs + 1)),
                      max_blocks * bs)
            grew = a.blocks_for(tgt) > len(a.owned(s))
            if a.ensure(s, tgt):
                tokens[s] = max(tokens[s], tgt)
                assert (a.version > ver) == grew, (op, s, tgt)
            else:
                assert a.version == ver, "failed ensure mutated the table"
        elif op == "truncate":
            tgt = int(rng.integers(0, tokens[s] + 1))
            own_before = len(a.owned(s))
            keep = a.blocks_for(tgt)
            tail = max(own_before - keep, 0)
            freed = a.truncate(s, tgt)
            assert freed == tail, (freed, tail)
            assert len(a.owned(s)) == own_before - freed
            assert (a.version > ver) == (freed > 0)
            # the released tail is immediately reusable, hottest first
            assert a.free_blocks >= freed
            tokens[s] = min(tokens[s], tgt)
        elif op == "preempt":
            n = len(a.owned(s))
            assert a.preempt(s) == n
            assert (a.table[s] == TRASH_BLOCK).all()
            assert (a.version > ver) == (n > 0)
            tokens[s] = 0
        elif op == "free":
            n = len(a.owned(s))
            assert a.free(s) == n
            assert (a.version > ver) == (n > 0)
            tokens[s] = 0
        else:
            perm = a.defragment()
            if perm is not None:
                assert a.version > ver
                assert sorted(perm.tolist()) == list(range(a.n_blocks))
                assert perm[TRASH_BLOCK] == TRASH_BLOCK
            else:
                assert a.version == ver
        a.check()                  # free list + ownership partition pool
        for sl in range(4):
            # table rows past the owned prefix must be trash (truncated
            # tails may never stay addressable through the table)
            own = a.owned(sl)
            assert (a.table[sl, len(own):] == TRASH_BLOCK).all(), sl
    for sl in range(4):
        a.free(sl)
    a.check()
    assert a.used_blocks == 0 and a.free_blocks == a.n_blocks - 1


class _JunkDrafter(Drafter):
    """Proposes deliberately wrong tokens: every draft is rejected, so
    every verify step writes a K/V tail that truncate must roll back."""

    def __init__(self, vocab: int):
        super().__init__()
        self.vocab = vocab

    def _propose(self, slot, hist, k):
        last = hist[-1] if hist else 0
        return [(last + 1 + i) % self.vocab for i in range(k)]


def test_truncated_tails_never_leak_across_slots(tiny_lm):
    """Spec decoding with an always-rejected drafter truncates a K/V tail
    on every step while a tight pool forces the freed blocks straight
    into the other slots' growth; tokens must equal isolated references.
    """
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(3)
    protos = [(rng.integers(0, cfg.vocab_size, 9 + 7 * i).astype(np.int32),
               18) for i in range(3)]
    refs = {}
    for i, (p, n) in enumerate(protos):
        s1 = build_replica(RS.replace(slots=1), ap=ap, params=params)
        r = Request(rid=i, prompt=p, max_new=n)
        s1.run([r])
        refs[i] = r.output
    sched = build_replica(
        RS.replace(block_size=4, n_blocks=25, spec_mode="replay",
                   spec_k=4),
        ap=ap, params=params, drafter=_JunkDrafter(cfg.vocab_size))
    done = sched.run([Request(rid=i, prompt=p, max_new=n, arrival_s=0.0)
                      for i, (p, n) in enumerate(protos)])
    m = sched.metrics(done)
    assert m.accepted_tokens == 0, "junk drafts must all be rejected"
    assert m.spec_steps > 0
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], r.output)
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0
