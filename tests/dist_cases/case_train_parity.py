"""Sharded train step: loss parity with the local model + learning + RD /
int8-RD cross-pod gradient strategies."""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import AxisType, make_mesh
from repro.models import ModelConfig, make_plan, init_params, forward_lm
from repro.models.layers import sharded_xent
from repro.core import LOCAL, ParallelCtx
from repro.parallel.steps import build_train_step
from repro.training import adamw_init

def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=96,
                dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)

key = jax.random.PRNGKey(0)
B, S = 8, 16
tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 96)
lab = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 96)
batch = {"tokens": tok, "labels": lab}

def run(cfg, mesh_shape, axes, ctx, tp, mb, label):
    mesh = make_mesh(mesh_shape, axes, axis_types=(AxisType.Auto,)*len(axes))
    ap = make_plan(cfg, tp)
    params = init_params(key, ap)
    opt = adamw_init(params)
    built = build_train_step(ap, ctx, mesh, microbatches=mb, base_lr=1e-2, warmup=1)
    step = built.jit()
    ap1 = make_plan(cfg, 1)
    p1 = init_params(key, ap1)
    lg, aux, _, _ = forward_lm(p1, tok, ap1, LOCAL)
    ref = float(sharded_xent(lg, lab, LOCAL, ap1.vocab_pad, cfg.vocab_size))
    if cfg.is_moe: ref += cfg.router_aux_coef * float(aux)
    losses = []
    for i in range(4):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    tol = 2e-2 if cfg.is_moe else 2e-3
    assert abs(losses[0] - ref) < tol, (label, losses[0], ref)
    assert losses[-1] < losses[0], (label, losses)
    assert float(m["skipped"]) == 0.0
    print(label, "OK", losses[0], "->", losses[-1])

ctx1 = ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",), ep=("model",), sp=("model",))
run(tiny("dense"), (2, 4), ("data", "model"), ctx1, 4, 2, "dense fsdp+sp+mb2")
ctx2 = ParallelCtx(tp_fast=("model",), dp=("pod", "data"), fsdp=("data",),
                   ep=("model",), sp=("model",), grad_reduce_strategy="rd")
run(tiny("dense"), (2, 2, 2), ("pod", "data", "model"), ctx2, 2, 1, "multipod rd")
ctx3 = ctx2.replace(grad_reduce_strategy="rd_int8")
run(tiny("dense"), (2, 2, 2), ("pod", "data", "model"), ctx3, 2, 1, "multipod rd_int8")
run(tiny("moe", n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0),
    (2, 4), ("data", "model"), ctx1, 4, 2, "moe fsdp+sp")
run(tiny("hybrid", d_inner=128, ssm_state=8), (2, 4), ("data", "model"), ctx1, 4, 1, "hybrid")
run(tiny("ssm", d_model=128, rwkv_head_dim=32, decay_lora=8), (2, 4),
    ("data", "model"), ctx1, 4, 1, "rwkv")
print("train parity OK")
