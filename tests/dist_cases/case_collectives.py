"""8-device validation: every hierarchical all-reduce strategy is exact
(or near-exact for int8) against flat psum."""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core.compat import AxisType, make_mesh, shard_map
from repro.core import (rd_all_reduce, rd_halving_all_reduce,
                        compressed_rd_all_reduce, tp_all_reduce, ParallelCtx)

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,)*2)
rng = np.random.default_rng(0)
x = rng.standard_normal((8, 64)).astype(np.float32)

def run(fn):
    f = shard_map(fn, mesh=mesh, in_specs=P("pod", "model"),
                  out_specs=P("pod", "model"), check_vma=False)
    return np.asarray(jax.jit(f)(x))

ref = run(lambda v: lax.psum(v, ("pod", "model")))
assert np.allclose(run(lambda v: rd_all_reduce(lax.psum(v, "model"), "pod")), ref, rtol=1e-5)
assert np.allclose(run(lambda v: rd_all_reduce(lax.psum(v, "model"), "pod", chunks=4)), ref, rtol=1e-5)
assert np.allclose(run(lambda v: rd_halving_all_reduce(lax.psum(v, "model"), "pod")), ref, rtol=1e-5)
c = run(lambda v: compressed_rd_all_reduce(lax.psum(v, "model"), "pod"))
assert np.abs(c - ref).max() / np.abs(ref).max() < 0.05

for strat in ("hier_rd", "hier_rd_halving", "hier_ring"):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ar_strategy=strat)
    out = run(lambda v: tp_all_reduce(v, ctx, scatter_dim=-1))
    assert np.allclose(out, ref, rtol=1e-5), strat
# 2-fast-axis hierarchy (256-way-TP analogue)
ctx = ParallelCtx(tp_fast=("pod", "model"), ar_strategy="hier_rd")
assert np.allclose(run(lambda v: tp_all_reduce(v, ctx, scatter_dim=-1)), ref, rtol=1e-5)
# non-power-of-two fallback on a 3-wide axis
mesh3 = make_mesh((3,), ("m",), axis_types=(AxisType.Auto,))
f3 = shard_map(lambda v: rd_all_reduce(v, "m"), mesh=mesh3, in_specs=P("m"),
               out_specs=P("m"), check_vma=False)
x3 = rng.standard_normal((6, 4)).astype(np.float32)
g3 = shard_map(lambda v: lax.psum(v, "m"), mesh=mesh3, in_specs=P("m"),
               out_specs=P("m"), check_vma=False)
assert np.allclose(jax.jit(f3)(x3), jax.jit(g3)(x3), rtol=1e-5)
print("collectives OK")

# --- Pallas RD all-reduce kernel (remote-DMA, interpret mode) -------------
from repro.core.compat import tpu_interpret_params
from repro.kernels.rd_allreduce import rd_all_reduce_pallas
interp = tpu_interpret_params()
if interp is None:
    print("pallas rd kernel SKIPPED (installed pallas has no TPU interpret "
          "mode for remote DMA)")
else:
    mesh8 = make_mesh((8,), ("pd",), axis_types=(AxisType.Auto,))
    x8 = rng.standard_normal((8, 300)).astype(np.float32)
    fk = shard_map(lambda v: rd_all_reduce_pallas(v, "pd", n_chunks=4,
                                                  interpret=interp),
                   mesh=mesh8, in_specs=P("pd"), out_specs=P("pd"),
                   check_vma=False)
    gk = shard_map(lambda v: lax.psum(v, "pd"), mesh=mesh8, in_specs=P("pd"),
                   out_specs=P("pd"), check_vma=False)
    assert np.allclose(jax.jit(fk)(x8), jax.jit(gk)(x8), rtol=1e-4,
                       atol=1e-5), "pallas rd kernel"
    for nc in (1, 2, 8):
        fk2 = shard_map(lambda v: rd_all_reduce_pallas(v, "pd", n_chunks=nc,
                                                       interpret=interp),
                        mesh=mesh8, in_specs=P("pd"), out_specs=P("pd"),
                        check_vma=False)
        assert np.allclose(jax.jit(fk2)(x8), jax.jit(gk)(x8), rtol=1e-4,
                           atol=1e-5), f"chunks={nc}"
    print("pallas rd kernel OK")
