"""Mesh-path speculative decoding: on the (2 pod x 4 model) mesh with
ar_strategy="auto" + overlap_matmul + a paged KV cache, greedy ngram spec
decode must reproduce the local dense plain batcher's token streams
request-for-request (the acceptance-criterion parity), keep doing so under
a pool tight enough to force preemption mid-speculation, and the engine's
batched spec generate must match its plain mesh generate bitwise.

The verify pass also exercises the autotuner's per-call-site dispatch on
the k-times-wider AR messages: the same table serves both the 1-token
decode and the (k+1)-token verify shapes in one process.
"""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.scheduler import Request, make_trace
from repro.inference.spec import ReplicaSpec, build_engine, build_replica

cfg = ModelConfig(name="spec-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS, K = 64, 4, 4

# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
RM = RL.replace(tp=8, pods=2, ar_strategy="auto", overlap=True)
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
apN = make_plan(cfg, 8)
pN = init_params(key, apN)


def trace():
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local dense plain reference --------------------------------------------
ref_sched = build_replica(RL, ap=ap1, params=p1)
ref = {r.rid: r.output for r in ref_sched.run(trace())}
assert all(v is not None for v in ref.values())

# -- mesh paged spec batcher: auto AR + overlap + chunked admission ----------
spec_sched = build_replica(RM.replace(block_size=8, admit_mode="chunked",
                                      admit_chunk=16, spec_mode="ngram",
                                      spec_k=K), ap=apN, params=pN)
done = spec_sched.run(trace())
m = spec_sched.metrics(done)
assert m.completed == len(done), m
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: mesh spec tokens diverge from local dense plain"
assert m.spec_steps == m.steps > 0
assert m.drafted_tokens >= K * m.spec_steps
print(f"mesh spec trace parity OK ({m.steps} verify steps, "
      f"acceptance {m.acceptance_rate:.2f}, "
      f"drafter hit rate {m.drafter_hit_rate:.2f})")

# -- tight pool on the mesh: preemption mid-speculation + rollback -----------
tight = build_replica(RM.replace(slots=3, block_size=8, n_blocks=9,
                                 spec_mode="ngram", spec_k=K),
                      ap=apN, params=pN)
rng = np.random.default_rng(5)
long_reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                16).astype(np.int32),
                     max_new=30, arrival_s=0.0) for i in range(3)]
iso = {}
for r in long_reqs:
    s1 = build_replica(RL.replace(slots=1), ap=ap1, params=p1)
    rr = Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
    s1.run([rr])
    iso[r.rid] = rr.output
done_t = tight.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                    for r in long_reqs])
mt = tight.metrics(done_t)
for r in done_t:
    assert np.array_equal(iso[r.rid], r.output), f"rid {r.rid} post-preempt"
assert mt.preemptions > 0, "tight pool should have preempted"
tight.alloc.check()
print(f"mesh spec preemption+rollback OK ({mt.preemptions} preemptions)")

# -- engine: mesh spec generate == mesh plain generate -----------------------
prompts = np.random.default_rng(7).integers(0, cfg.vocab_size, (4, 8))
plain_eng = build_engine(RM.replace(s_max=32), ap=apN, params=pN)
spec_eng = build_engine(RM.replace(s_max=32, spec_mode="ngram", spec_k=K),
                        ap=apN, params=pN)
r_plain = plain_eng.generate(prompts, 12)
r_spec = spec_eng.generate(prompts, 12)
assert np.array_equal(r_plain.new_tokens, r_spec.new_tokens), \
    "mesh engine spec generate diverges from plain generate"
print("mesh engine spec generate parity OK")
print("spec OK")
