"""Mesh-path speculative decoding: on the (2 pod x 4 model) mesh with
ar_strategy="auto" + overlap_matmul + a paged KV cache, greedy ngram spec
decode must reproduce the local dense plain batcher's token streams
request-for-request (the acceptance-criterion parity), keep doing so under
a pool tight enough to force preemption mid-speculation, and the engine's
batched spec generate must match its plain mesh generate bitwise.

The verify pass also exercises the autotuner's per-call-site dispatch on
the k-times-wider AR messages: the same table serves both the 1-token
decode and the (k+1)-token verify shapes in one process.
"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import AxisType, make_mesh
from repro.core import ParallelCtx
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.engine import InferenceEngine
from repro.inference.scheduler import ContinuousBatcher, Request, make_trace

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,) * 2)

cfg = ModelConfig(name="spec-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS, K = 64, 4, 4

ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ar_strategy="auto",
                  overlap_matmul=True, overlap_chunks=4)
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
apN = make_plan(cfg, 8)
pN = init_params(key, apN)


def trace():
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local dense plain reference --------------------------------------------
ref_sched = ContinuousBatcher(ap1, p1, slots=SLOTS, s_max=S_MAX)
ref = {r.rid: r.output for r in ref_sched.run(trace())}
assert all(v is not None for v in ref.values())

# -- mesh paged spec batcher: auto AR + overlap + chunked admission ----------
spec_sched = ContinuousBatcher(apN, pN, slots=SLOTS, s_max=S_MAX, ctx=ctx,
                               mesh=mesh, block_size=8,
                               admit_mode="chunked", admit_chunk=16,
                               spec_mode="ngram", spec_k=K)
done = spec_sched.run(trace())
m = spec_sched.metrics(done)
assert m.completed == len(done), m
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: mesh spec tokens diverge from local dense plain"
assert m.spec_steps == m.steps > 0
assert m.drafted_tokens >= K * m.spec_steps
print(f"mesh spec trace parity OK ({m.steps} verify steps, "
      f"acceptance {m.acceptance_rate:.2f}, "
      f"drafter hit rate {m.drafter_hit_rate:.2f})")

# -- tight pool on the mesh: preemption mid-speculation + rollback -----------
tight = ContinuousBatcher(apN, pN, slots=3, s_max=S_MAX, ctx=ctx, mesh=mesh,
                          block_size=8, n_blocks=9, spec_mode="ngram",
                          spec_k=K)
rng = np.random.default_rng(5)
long_reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                16).astype(np.int32),
                     max_new=30, arrival_s=0.0) for i in range(3)]
iso = {}
for r in long_reqs:
    s1 = ContinuousBatcher(ap1, p1, slots=1, s_max=S_MAX)
    rr = Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
    s1.run([rr])
    iso[r.rid] = rr.output
done_t = tight.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                    for r in long_reqs])
mt = tight.metrics(done_t)
for r in done_t:
    assert np.array_equal(iso[r.rid], r.output), f"rid {r.rid} post-preempt"
assert mt.preemptions > 0, "tight pool should have preempted"
tight.alloc.check()
print(f"mesh spec preemption+rollback OK ({mt.preemptions} preemptions)")

# -- engine: mesh spec generate == mesh plain generate -----------------------
prompts = np.random.default_rng(7).integers(0, cfg.vocab_size, (4, 8))
plain_eng = InferenceEngine(apN, pN, ctx=ctx, mesh=mesh, s_max=32)
spec_eng = InferenceEngine(apN, pN, ctx=ctx, mesh=mesh, s_max=32,
                           spec_mode="ngram", spec_k=K)
r_plain = plain_eng.generate(prompts, 12)
r_spec = spec_eng.generate(prompts, 12)
assert np.array_equal(r_plain.new_tokens, r_spec.new_tokens), \
    "mesh engine spec generate diverges from plain generate"
print("mesh engine spec generate parity OK")
print("spec OK")
