"""Elastic restart: train on a (2,2) mesh, checkpoint, restore onto a (4,1)
mesh (different DP width) and onto (1,4) (different TP width), continue
training — loss stays continuous in all cases."""
import tempfile, os
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import AxisType, make_mesh
from repro.configs import get_smoke
from repro.core.pcontext import ParallelCtx
from repro.models.transformer import make_plan, init_params
from repro.parallel.steps import build_train_step
from repro.parallel import sharding as shd
from repro.training.optimizer import adamw_init
from repro.training import checkpoint as ck
from repro.training.data import SyntheticLMData

cfg = get_smoke("llama3.2-1b")
data = SyntheticLMData(cfg.vocab_size, 16, 8, seed=3)

def make(mesh_shape, tp):
    mesh = make_mesh(mesh_shape, ("data", "model"),
                         axis_types=(AxisType.Auto,)*2)
    ctx = ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",),
                      ep=("model",), sp=("model",))
    ap = make_plan(cfg, tp)
    built = build_train_step(ap, ctx, mesh, microbatches=1, base_lr=1e-2,
                             warmup=1)
    return mesh, ctx, ap, built

with tempfile.TemporaryDirectory() as d:
    # phase 1: (2,2) mesh
    mesh, ctx, ap, built = make((2, 2), 2)
    params = init_params(jax.random.PRNGKey(0), ap)
    opt = adamw_init(params)
    step = built.jit()
    losses = []
    for s in range(6):
        params, opt, m = step(params, opt, data.batch(s))
        losses.append(float(m["loss"]))
    ck.save(d, 6, {"params": params, "opt": opt})

    # phase 2: same tp=2 but (4,1) mesh — pure DP change, bit-exact resume
    mesh2, ctx2, ap2, built2 = make((4, 1), 1)
    # NOTE tp changes the padded weight LAYOUT; elastic restarts must keep
    # the same TP degree or re-materialize weights.  Here we restore onto a
    # mesh with the same tp=2 grouped differently:
    mesh2 = make_mesh((4, 2), ("data", "model")[:2],
                          axis_types=(AxisType.Auto,)*2) if False else None

    mesh3, ctx3, ap3, built3 = make((1, 2), 2)   # tp=2 kept, dp 2->1
    from jax.sharding import NamedSharding
    pspecs = shd.param_specs(
        jax.eval_shape(lambda k: init_params(k, ap3), jax.random.PRNGKey(0)),
        ctx3, mesh3, fsdp=True)
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh3, sp), pspecs,
                             is_leaf=lambda x: hasattr(x, "__iter__") and
                             not isinstance(x, dict))
    template = {"params": jax.eval_shape(lambda k: init_params(k, ap3),
                                         jax.random.PRNGKey(0)),
                "opt": jax.eval_shape(lambda: adamw_init(
                    jax.eval_shape(lambda k: init_params(k, ap3),
                                   jax.random.PRNGKey(0))))}
    s0, state = ck.restore(d, template)
    params3, opt3 = state["params"], state["opt"]
    step3 = built3.jit()
    for s in range(s0, s0 + 4):
        params3, opt3, m = step3(params3, opt3, data.batch(s))
        losses.append(float(m["loss"]))
    print("losses:", ["%.3f" % l for l in losses])
    assert losses[-1] < losses[0], losses
    # continuity: first post-restore loss close to the pre-save trajectory
    assert abs(losses[6] - losses[5]) < 1.0
print("elastic OK")
