"""Sharded prefill+decode produces identical greedy tokens to the local
model, across TP layouts including cross-pod TP with hierarchical RD."""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from repro.core.compat import AxisType, make_mesh
from repro.models import ModelConfig, make_plan, init_params, init_cache, forward_lm, decode_step
from repro.core import LOCAL, ParallelCtx
from repro.parallel.steps import build_decode_step, build_prefill

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,)*2)

def tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=96,
                dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)

key = jax.random.PRNGKey(0)
B, S = 4, 8

def parity(cfg, ctx, tp, label):
    ap1, apN = make_plan(cfg, 1), make_plan(cfg, tp)
    p1, pN = init_params(key, ap1), init_params(key, apN)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    lg1, _, st1, _ = forward_lm(p1, tok, ap1, LOCAL, collect_state=True)
    c1 = init_cache(ap1, B, S + 4)
    if "k" in c1:
        c1["k"] = lax.dynamic_update_slice(c1["k"], st1["k"].astype(c1["k"].dtype), (0,)*5)
        c1["v"] = lax.dynamic_update_slice(c1["v"], st1["v"].astype(c1["v"].dtype), (0,)*5)
    for nm in ("conv", "ssm", "shift_tm", "shift_cm", "wkv"):
        if nm in c1: c1[nm] = st1[nm].astype(c1[nm].dtype)
    nxt1 = jnp.argmax(lg1[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    toks1, pos = [nxt1], jnp.full((B,), S, jnp.int32)
    for i in range(3):
        lg, c1 = decode_step(p1, c1, toks1[-1], pos + i, ap1, LOCAL)
        toks1.append(jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32))
    pre = build_prefill(apN, ctx, mesh, s_max=S + 4)
    dec = build_decode_step(apN, ctx, mesh)
    nxtN, cN = jax.jit(pre.fn)(pN, tok)
    toksN = [nxtN]
    for i in range(3):
        tN, cN = dec.jit()(pN, cN, toksN[-1], pos + i)
        toksN.append(tN)
    for a, b in zip(toks1, toksN):
        assert np.array_equal(np.asarray(a), np.asarray(b)), label
    print(label, "OK")

ctxA = ParallelCtx(tp_fast=("model",), dp=("pod",), ep=("model",), ar_strategy="flat")
ctxB = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ep=("model",), ar_strategy="hier_rd")
ctxC = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ep=("model",), ar_strategy="hier_rd_halving")
parity(tiny("dense"), ctxA, 4, "dense tp4+dp")
parity(tiny("dense"), ctxB, 8, "dense tp8 hier_rd")
parity(tiny("dense"), ctxC, 8, "dense tp8 hier_rd_halving")
parity(tiny("dense", n_heads=5, n_kv_heads=5, qkv_bias=True), ctxB, 8, "mha5 tp8")
parity(tiny("moe", n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=8.0), ctxA, 4, "moe tp4")
parity(tiny("hybrid", d_inner=128, ssm_state=8, sliding_window=4), ctxA, 4, "hybrid tp4")
parity(tiny("ssm", d_model=128, rwkv_head_dim=32, decay_lora=8), ctxA, 4, "rwkv tp4")
parity(tiny("ssm", d_model=128, rwkv_head_dim=16, decay_lora=8), ctxB, 8, "rwkv tp8")
print("decode parity OK")
