"""Disaggregated serving on the mesh path: a (2 pod x 4 model) prefill
pool hands KV off to a 4-way single-pod decode pool — different TP
degrees, so the handoff really reshards between GQA slot layouts — and
the greedy trace must reproduce the local colocated batcher's tokens
request-for-request.  Both pools run ar_strategy="auto" against their own
dispatch tables; the observed table keys must show the prefill pool
dispatching on strictly larger message-size buckets than the decode pool
(the disaggregation payoff the ISSUE/DESIGN §9 claim)."""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica

cfg = ModelConfig(name="disagg-tiny", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 4

# arch is nominal: per-pool plans built from the tiny cfg are passed in
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
DS = RL.replace(disagg=True, prefill_tp=8, prefill_pods=2, decode_tp=4,
                ar_strategy="auto", overlap=True, admit_mode="chunked",
                admit_chunk=16, block_size=8)


def trace():
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local colocated reference ------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref = {r.rid: r.output
       for r in build_replica(RL, ap=ap1, params=p1).run(trace())}
assert all(v is not None for v in ref.values())

# -- prefill 2 pods x 4-way TP -> decode single-pod 4-way TP, own tables -----
ap8 = make_plan(cfg, 8)
p8 = init_params(key, ap8)
ap4 = make_plan(cfg, 4)
p4 = init_params(key, ap4)
coord = build_replica(DS, prefill_ap=ap8, prefill_params=p8,
                      decode_ap=ap4, decode_params=p4)
done = coord.run(trace())
m = coord.metrics(done)
assert m.completed == len(done), m
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: disagg mesh tokens diverge from colocated local"
print(f"disagg mesh parity OK (tp8x2pods prefill -> tp4 decode, "
      f"{m.handoffs} handoffs, {m.transfer_bytes} bytes)")

# -- per-pool AR dispatch: observed table keys, not just analytics ------------
bp, bd = coord.prefill.tuner.lookup_buckets(), \
    coord.decode_tuner.lookup_buckets()
assert bp and bd, (bp, bd)
assert max(bp) > max(bd), \
    f"prefill pool should dispatch on larger AR messages: {bp} vs {bd}"
print(f"per-pool AR dispatch OK (prefill buckets {bp} > decode {bd})")
print("disagg OK")
