"""8-device validation of the overlapped collective-matmul decode primitive
and the autotuned dispatcher:

1. collective_matmul is bit-consistent (dtype tolerance) with
   GEMM-then-tp_all_reduce for all four strategies AND ar_strategy="auto",
   at every chunk count, on the (2 pod x 4 model) mesh;
2. the attention-spec form ("bsqh,qhd->bsd") matches the unfused einsum;
3. rd_all_reduce chunked-path edge cases: payload not divisible by chunks,
   chunks > payload, non-power-of-two axis fallback;
4. the sequence-parallel reduce-scatter variant matches tp_reduce_scatter;
5. an end-to-end decode parity run: overlap_matmul=True + ar_strategy="auto"
   produces the exact greedy tokens of the plain flat path.
"""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core.compat import AxisType, make_mesh, shard_map
from repro.core import (collective_matmul, collective_matmul_reduce_scatter,
                        rd_all_reduce, tp_all_reduce, tp_reduce_scatter,
                        ParallelCtx, autotune)

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,) * 2)
rng = np.random.default_rng(0)

B, S, F, D = 2, 3, 32, 64   # F: sharded contraction dim, D: output features
x = rng.standard_normal((B, S, F)).astype(np.float32)
w = rng.standard_normal((F, D)).astype(np.float32)
in_specs = (P(None, None, ("pod", "model")), P(("pod", "model"), None))


def run(fn, out_specs=P()):
    f = shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    return np.asarray(jax.jit(f)(x, w))


ref = np.einsum("bsf,fd->bsd", x, w)
for strat in ("flat", "hier_ring", "hier_rd", "hier_rd_halving", "auto"):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy=strat)
    base = run(lambda xs, ws: tp_all_reduce(
        jnp.einsum("bsf,fd->bsd", xs, ws), ctx, scatter_dim=-1))
    np.testing.assert_allclose(base, ref, rtol=1e-4, atol=1e-4)
    for k in (1, 2, 4, 8):
        ovr = run(lambda xs, ws: collective_matmul(
            xs, ws, ctx.replace(overlap_matmul=True, overlap_chunks=k)))
        np.testing.assert_allclose(ovr, base, rtol=1e-5, atol=1e-5), \
            (strat, k)
    print(f"collective_matmul parity [{strat}] OK")

# --- attention-spec form ---------------------------------------------------
Q, hd = 8, 16
o8 = rng.standard_normal((B, S, Q, hd)).astype(np.float32)
wo = rng.standard_normal((Q, hd, D)).astype(np.float32)
ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ar_strategy="auto",
                  overlap_matmul=True)
fa = shard_map(
    lambda os_, ws: collective_matmul(os_, ws, ctx, spec="bsqh,qhd->bsd"),
    mesh=mesh, in_specs=(P(None, None, ("pod", "model"), None),
                         P(("pod", "model"), None, None)),
    out_specs=P(), check_vma=False)
np.testing.assert_allclose(np.asarray(jax.jit(fa)(o8, wo)),
                           np.einsum("bsqh,qhd->bsd", o8, wo),
                           rtol=1e-4, atol=1e-4)
print("collective_matmul attention-spec OK")

# --- rd_all_reduce chunked-path edge cases ---------------------------------
mesh8 = make_mesh((8,), ("pd",), axis_types=(AxisType.Auto,))


def run8(fn, xv):
    f = shard_map(fn, mesh=mesh8, in_specs=P("pd"), out_specs=P("pd"),
                  check_vma=False)
    return np.asarray(jax.jit(f)(xv))


x8 = rng.standard_normal((8, 7, 9)).astype(np.float32)   # 63 elems/shard
ref8 = run8(lambda v: lax.psum(v, "pd"), x8)
for chunks in (1, 2, 3, 5, 64, 1000):   # 63 % 3 != 0; 1000 > payload
    got = run8(lambda v: rd_all_reduce(v, "pd", chunks=chunks), x8)
    np.testing.assert_allclose(got, ref8, rtol=1e-5), chunks
print("rd_all_reduce chunk edge cases OK")

# non-power-of-two axis falls back to psum (with chunking requested too)
mesh3 = make_mesh((3,), ("m",), axis_types=(AxisType.Auto,))
x3 = rng.standard_normal((6, 5)).astype(np.float32)
f3 = shard_map(lambda v: rd_all_reduce(v, "m", chunks=4), mesh=mesh3,
               in_specs=P("m"), out_specs=P("m"), check_vma=False)
g3 = shard_map(lambda v: lax.psum(v, "m"), mesh=mesh3, in_specs=P("m"),
               out_specs=P("m"), check_vma=False)
np.testing.assert_allclose(jax.jit(f3)(x3), jax.jit(g3)(x3), rtol=1e-5)
print("rd_all_reduce non-pow2 fallback OK")

# --- sequence-parallel reduce-scatter variant ------------------------------
ctx_sp = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), sp=("model",),
                     ar_strategy="hier_rd")
x_sp = rng.standard_normal((B, 8, F)).astype(np.float32)  # S=8 % 4 == 0


def run_sp(fn):
    f = shard_map(fn, mesh=mesh,
                  in_specs=(P(None, None, ("pod", "model")),
                            P(("pod", "model"), None)),
                  out_specs=P(None, "model", None), check_vma=False)
    return np.asarray(jax.jit(f)(x_sp, w))


rs_base = run_sp(lambda xs, ws: tp_reduce_scatter(
    jnp.einsum("bsf,fd->bsd", xs, ws), ctx_sp, dim=1))
rs_ovr = run_sp(lambda xs, ws: collective_matmul_reduce_scatter(
    xs, ws, ctx_sp.replace(overlap_matmul=True, overlap_chunks=4), dim=1))
np.testing.assert_allclose(rs_ovr, rs_base, rtol=1e-5, atol=1e-5)
print("collective_matmul_reduce_scatter parity OK")

# --- end-to-end: overlapped auto decode == flat decode ---------------------
from repro.models import ModelConfig, make_plan, init_params
from repro.parallel.steps import build_decode_step, build_prefill

cfg = ModelConfig(name="ovl-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
ap = make_plan(cfg, 8)
params = init_params(jax.random.PRNGKey(0), ap)
prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 96)
toks = {}
for name, ctx_d in [
    ("flat", ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                         ep=("model",), ar_strategy="flat")),
    ("auto+overlap", ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                                 ep=("model",), ar_strategy="auto",
                                 overlap_matmul=True, overlap_chunks=4)),
]:
    pre = build_prefill(ap, ctx_d, mesh, s_max=24)
    dec = build_decode_step(ap, ctx_d, mesh)
    nxt, cache = jax.jit(pre.fn)(params, prompts)
    seq = [np.asarray(nxt)]
    pos = jnp.full((4,), 8, jnp.int32)
    for i in range(6):
        nxt, cache = dec.jit()(params, cache, nxt, pos + i)
        seq.append(np.asarray(nxt))
    toks[name] = np.stack(seq)
assert np.array_equal(toks["flat"], toks["auto+overlap"]), \
    "overlapped auto decode must reproduce flat greedy tokens"
print("e2e overlapped auto decode parity OK")

# --- int8 weight dequant under TP x the lossy-knob overlap rule ------------
# quantized decode (dequant_layer inside the scan) with the lossy slow-axis
# exchange enabled: overlap.collective_matmul must fall back to the
# unchunked message (the quantization-group-boundary rule), so greedy
# tokens cannot depend on the overlap knob even in the lossy + weight-quant
# configuration.
from repro.models.transformer import init_cache
from repro.parallel.quant import quantize_params

qparams = quantize_params(params)
qtoks = {}
for name, ov in (("plain", False), ("overlap", True)):
    ctx_q = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ep=("model",),
                        ar_strategy="hier_rd", compress_slow=True,
                        overlap_matmul=ov, overlap_chunks=4)
    dec_q = build_decode_step(ap, ctx_q, mesh, weight_quant=True)
    cache_q = shard_map(lambda: init_cache(ap, 4, 24, local=True),
                        mesh=mesh, in_specs=(),
                        out_specs=dec_q.in_specs[1], check_vma=False)()
    cur = jnp.full((4,), 7, jnp.int32)
    seq = []
    for i in range(6):
        cur, cache_q = dec_q.jit()(qparams, cache_q,
                                   cur, jnp.full((4,), i, jnp.int32))
        seq.append(np.asarray(cur))
    qtoks[name] = np.stack(seq)
assert np.array_equal(qtoks["plain"], qtoks["overlap"]), \
    "lossy compress_slow + weight-quant decode must not depend on overlap"
print("weight-quant dequant under TP x lossy overlap rule OK")

# --- fused Pallas GEMM+RD kernel (interpret mode; gated on support) --------
from repro.core.compat import tpu_interpret_params
interp = tpu_interpret_params()
if interp is None:
    print("fused pallas collective matmul SKIPPED (installed pallas has no "
          "TPU interpret mode for remote DMA)")
else:
    from repro.kernels.rd_allreduce.fused_matmul import (
        collective_matmul_pallas)
    ctx_k = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                        ar_strategy="hier_rd")
    fkm = shard_map(
        lambda xs, ws: collective_matmul_pallas(
            xs, ws, ctx_k, spec="bsf,fd->bsd", chunks=2, interpret=interp),
        mesh=mesh, in_specs=in_specs, out_specs=P(), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(fkm)(x, w)), ref,
                               rtol=1e-4, atol=1e-4)
    print("fused pallas collective matmul OK")
print("overlap+autotune OK")
