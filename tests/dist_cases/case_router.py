"""Router over a dp-sharded fleet: 8 host devices carved into 2 replicas
of tp=4 each (disjoint contiguous device groups), round_robin placement.
Fleet tokens must match the local dense reference request-for-request
(fleet == N independent singles), both replicas must receive traffic, and
the merged fleet metrics must account for every request exactly once."""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.router import Router
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica
from repro.parallel.topology import replica_device_groups

cfg = ModelConfig(name="router-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 3
# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
RM = RL.replace(tp=4, ar_strategy="auto", block_size=8,
                admit_mode="chunked", admit_chunk=16)


def trace(seed=4):
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=seed)


# -- local dense reference ---------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref_sched = build_replica(RL, ap=ap1, params=p1)
ref = {r.rid: r.output for r in ref_sched.run(trace())}
assert all(v is not None for v in ref.values())

# -- 2 replicas x tp4 over disjoint device groups ----------------------------
groups = replica_device_groups(2, 4)
assert len(groups) == 2 and all(len(g) == 4 for g in groups)
assert not set(d.id for d in groups[0]) & set(d.id for d in groups[1])
ap4 = make_plan(cfg, 4)
p4 = init_params(key, ap4)
fleet = Router([build_replica(RM, ap=ap4, params=p4, devices=g, replica_id=i)
                for i, g in enumerate(groups)], policy="round_robin")
done = fleet.run(trace())
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: fleet tokens diverge from local dense"
assert fleet.placements == [5, 5], fleet.placements
assert all(p > 0 for p in fleet.placements), "a replica got no traffic"
m = fleet.metrics(done)
assert m.fleet.completed == len(ref), m.fleet.completed
assert sum(p.completed for p in m.per_replica) == len(ref)
assert m.replicas == 2 and m.policy == "round_robin"
print(f"fleet parity OK (placements {fleet.placements}, "
      f"imbalance {m.load_imbalance:.2f})")

# -- both replicas are live engines on their own disjoint meshes -------------
r0, r1 = fleet.replicas
assert r0 is not r1 and r0.mesh is not r1.mesh
assert not (set(d.id for d in r0.mesh.devices.flat)
            & set(d.id for d in r1.mesh.devices.flat))
print("router OK")
