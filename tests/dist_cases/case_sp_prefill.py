"""Sequence-parallel prefill on the (2 pod x 4 model) mesh (DESIGN.md §10).

Parity bar: with ``seq_parallel="on"`` the residual stream is
sequence-sharded between sublayers (RS+AG replace the fused per-residual
all-reduce) and the greedy trace must still equal the local dense
batcher's tokens bitwise — through full and chunked admission into a
paged cache, combined with ar_strategy="auto" + overlap_matmul, and
through the disaggregated prefill pool's tp=8x2pods -> tp=1 handoff.
Structure bar: the SP admission executable must actually lower with
reduce-scatter collectives where the fused flat path lowers a single
all-reduce per residual.
"""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica

cfg = ModelConfig(name="sp-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 3

# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
RM = RL.replace(tp=8, pods=2, block_size=8)


def trace():
    return make_trace(8, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local dense reference ---------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref = {r.rid: r.output for r in
       build_replica(RL, ap=ap1, params=p1).run(trace())}
assert all(v is not None for v in ref.values())

apN = make_plan(cfg, 8)
pN = init_params(key, apN)

# -- structural check: SP lowers reduce-scatters, fused flat does not --------
tok = jnp.zeros((1, 16), jnp.int32)
pos = jnp.arange(16, dtype=jnp.int32)[None]
hlo = {}
for sp_mode in ("off", "on"):
    sched = build_replica(RM.replace(seq_parallel=sp_mode,
                                     admit_mode="chunked", admit_chunk=16),
                          ap=apN, params=pN)
    hlo[sp_mode] = sched._admit_chunked.lower(
        pN, sched.cache, tok, pos, jnp.int32(0), jnp.int32(15),
        jax.random.PRNGKey(0)).as_text(dialect="hlo")
assert "reduce-scatter" not in hlo["off"], \
    "fused flat admission should lower plain all-reduces"
assert "reduce-scatter" in hlo["on"], \
    "SP admission should lower sequence-dim reduce-scatters"
print("SP lowering structure OK (reduce-scatter only under seq_parallel)")

# -- parity: forced SP, flat strategy, full + chunked admission, paged -------
for admit_kw in (dict(admit_mode="full"),
                 dict(admit_mode="chunked", admit_chunk=16)):
    sched = build_replica(RM.replace(seq_parallel="on", **admit_kw),
                          ap=apN, params=pN)
    for r in sched.run(trace()):
        assert np.array_equal(ref[r.rid], r.output), \
            f"rid {r.rid}: SP {admit_kw['admit_mode']} tokens diverge"
    print(f"SP parity OK ({admit_kw['admit_mode']} admission)")

# -- parity: SP + autotuned AR + overlapped collective-matmul ----------------
sched = build_replica(RM.replace(seq_parallel="on", ar_strategy="auto",
                                 overlap=True, admit_mode="chunked",
                                 admit_chunk=16), ap=apN, params=pN)
for r in sched.run(trace()):
    assert np.array_equal(ref[r.rid], r.output), f"rid {r.rid} (auto+ov)"
print("SP + auto + overlap parity OK")

# -- parity: disaggregated prefill pool under SP (mesh pool -> local decode) -
coord = build_replica(
    RL.replace(disagg=True, prefill_tp=8, prefill_pods=2, decode_tp=1,
               ar_strategy="auto", seq_parallel="on", block_size=8,
               prefill_block_size=0),
    prefill_ap=apN, prefill_params=pN, decode_ap=ap1, decode_params=p1)
done = coord.run(trace())
for r in done:
    assert np.array_equal(ref[r.rid], r.output), f"rid {r.rid} (disagg SP)"
m = coord.metrics(done)
assert m.completed == len(done)
print(f"disagg SP prefill pool parity OK ({m.handoffs} handoffs)")

print("sp prefill OK")
