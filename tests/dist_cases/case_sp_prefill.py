"""Sequence-parallel prefill on the (2 pod x 4 model) mesh (DESIGN.md §10).

Parity bar: with ``seq_parallel="on"`` the residual stream is
sequence-sharded between sublayers (RS+AG replace the fused per-residual
all-reduce) and the greedy trace must still equal the local dense
batcher's tokens bitwise — through full and chunked admission into a
paged cache, combined with ar_strategy="auto" + overlap_matmul, and
through the disaggregated prefill pool's tp=8x2pods -> tp=1 handoff.
Structure bar: the SP admission executable must actually lower with
reduce-scatter collectives where the fused flat path lowers a single
all-reduce per residual.
"""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import AxisType, make_mesh
from repro.core import ParallelCtx
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.disagg import DisaggCoordinator, PrefillPool, pool_tuner
from repro.inference.scheduler import ContinuousBatcher, make_trace

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,) * 2)
cfg = ModelConfig(name="sp-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 3


def trace():
    return make_trace(8, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local dense reference ---------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref = {r.rid: r.output for r in
       ContinuousBatcher(ap1, p1, slots=SLOTS, s_max=S_MAX).run(trace())}
assert all(v is not None for v in ref.values())

apN = make_plan(cfg, 8)
pN = init_params(key, apN)

# -- structural check: SP lowers reduce-scatters, fused flat does not --------
tok = jnp.zeros((1, 16), jnp.int32)
pos = jnp.arange(16, dtype=jnp.int32)[None]
hlo = {}
for sp_mode in ("off", "on"):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="flat", seq_parallel=sp_mode)
    sched = ContinuousBatcher(apN, pN, slots=SLOTS, s_max=S_MAX, ctx=ctx,
                              mesh=mesh, block_size=8,
                              admit_mode="chunked", admit_chunk=16)
    hlo[sp_mode] = sched._admit_chunked.lower(
        pN, sched.cache, tok, pos, jnp.int32(0), jnp.int32(15),
        jax.random.PRNGKey(0)).as_text(dialect="hlo")
assert "reduce-scatter" not in hlo["off"], \
    "fused flat admission should lower plain all-reduces"
assert "reduce-scatter" in hlo["on"], \
    "SP admission should lower sequence-dim reduce-scatters"
print("SP lowering structure OK (reduce-scatter only under seq_parallel)")

# -- parity: forced SP, flat strategy, full + chunked admission, paged -------
for admit_kw in (dict(admit_mode="full"),
                 dict(admit_mode="chunked", admit_chunk=16)):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="flat", seq_parallel="on")
    sched = ContinuousBatcher(apN, pN, slots=SLOTS, s_max=S_MAX, ctx=ctx,
                              mesh=mesh, block_size=8, **admit_kw)
    for r in sched.run(trace()):
        assert np.array_equal(ref[r.rid], r.output), \
            f"rid {r.rid}: SP {admit_kw['admit_mode']} tokens diverge"
    print(f"SP parity OK ({admit_kw['admit_mode']} admission)")

# -- parity: SP + autotuned AR + overlapped collective-matmul ----------------
ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",), ar_strategy="auto",
                  overlap_matmul=True, overlap_chunks=4, seq_parallel="on")
sched = ContinuousBatcher(apN, pN, slots=SLOTS, s_max=S_MAX, ctx=ctx,
                          mesh=mesh, block_size=8, admit_mode="chunked",
                          admit_chunk=16)
for r in sched.run(trace()):
    assert np.array_equal(ref[r.rid], r.output), f"rid {r.rid} (auto+ov)"
print("SP + auto + overlap parity OK")

# -- parity: disaggregated prefill pool under SP (mesh pool -> local decode) -
ctx_p = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                    ar_strategy="auto", seq_parallel="on")
tuner_p = pool_tuner(None)
pool = PrefillPool(apN, pN, s_max=S_MAX, ctx=ctx_p, mesh=mesh,
                   ar_table=tuner_p)
tuner_d = pool_tuner(None)
decode = ContinuousBatcher(ap1, p1, slots=SLOTS, s_max=S_MAX,
                           block_size=8, ar_table=tuner_d)
coord = DisaggCoordinator(pool, decode, decode_tuner=tuner_d)
done = coord.run(trace())
for r in done:
    assert np.array_equal(ref[r.rid], r.output), f"rid {r.rid} (disagg SP)"
m = coord.metrics(done)
assert m.completed == len(done)
print(f"disagg SP prefill pool parity OK ({m.handoffs} handoffs)")

print("sp prefill OK")
