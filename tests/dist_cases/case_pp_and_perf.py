"""Hybrid parallelism (GPipe PP x TP) parity + perf-feature parity on 8
simulated devices: quantized all-gather, SP prefill, cross-pod int8 RD."""
import numpy as np, jax, jax.numpy as jnp
from repro.core.compat import AxisType, make_mesh
from repro.models import ModelConfig, make_plan, init_params, forward_lm
from repro.core import LOCAL, ParallelCtx
from repro.parallel.pp import build_pp_forward
from repro.parallel.steps import build_prefill, build_decode_step

cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=64, n_heads=4,
                  n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=96,
                  dtype=jnp.float32)
mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,)*2)
tok = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 96)

# --- PP x TP (the paper's HP scheme) vs local ------------------------------
ctx = ParallelCtx(tp_fast=("model",), ep=("model",))
ap = make_plan(cfg, 4)
params = init_params(jax.random.PRNGKey(0), ap)
fn, _ = build_pp_forward(ap, ctx, mesh, stage_axis="pod", microbatches=4)
logits_pp = np.asarray(jax.jit(fn)(params, tok), np.float32)
ap1 = make_plan(cfg, 1)
p1 = init_params(jax.random.PRNGKey(0), ap1)
ref = np.asarray(forward_lm(p1, tok, ap1, LOCAL)[0], np.float32)
assert np.abs(logits_pp - ref).max() / np.abs(ref).max() < 2e-3
print("pp_parity OK")

# --- SP prefill + quantized AG + int8 KV + ring, all at once ---------------
cfgs = ModelConfig(name="s", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                   vocab_size=96, sliding_window=8, dtype=jnp.float32)
aps = make_plan(cfgs, 4)
ps = init_params(jax.random.PRNGKey(0), aps)
base_ctx = ParallelCtx(tp_fast=("model",), dp=("pod",), ep=("model",),
                       sp=("model",))
toks = {}
for name, ctx2, kw in [
    ("plain", base_ctx, {}),
    ("sp", base_ctx, {"sp": True}),
    ("q8ag", base_ctx.replace(quant_ag=True), {}),
]:
    pre = build_prefill(aps, ctx2, mesh, s_max=32, **kw)
    nxt, cache = jax.jit(pre.fn)(ps, tok)
    dec = build_decode_step(aps, ctx2, mesh)
    seq = [np.asarray(nxt)]
    pos = jnp.full((8,), 16, jnp.int32)
    for i in range(4):
        nxt, cache = dec.jit()(ps, cache, nxt, pos + i)
        seq.append(np.asarray(nxt))
    toks[name] = np.stack(seq)
assert np.array_equal(toks["plain"], toks["sp"]), "sp prefill parity"
# quant_ag is intentionally lossy (int8 + per-128 scales): require a high
# greedy-token agreement rate rather than bit equality
q8_match = np.mean(toks["plain"] == toks["q8ag"])
assert q8_match >= 0.8, f"quant_ag match rate {q8_match}"
print(f"sp parity exact; quant_ag match rate {q8_match:.2f} OK")

# --- int8 KV + ring-window decode vs bf16 full cache -----------------------
ctx3 = base_ctx
for variant, kw in [("kv_int8", {"kv_quant": True}),
                    ("ring", {"window_cache": True})]:
    pre = build_prefill(aps, ctx3, mesh, s_max=32)
    dec_ref = build_decode_step(aps, ctx3, mesh)
    dec_var = build_decode_step(aps, ctx3, mesh, **kw)
    # both decode from scratch (pos 0..) so ring/prefill seeding isn't needed
    from repro.models.transformer import init_cache
    from repro.parallel import sharding as shd
    cache_r = init_cache(aps, 8, 32, local=False)
    cache_v = init_cache(aps, 8, 32, local=False, **{
        "kv_quant": kw.get("kv_quant", False),
        "window_cache": kw.get("window_cache", False)})
    cur_r = cur_v = jnp.arange(8, dtype=jnp.int32)
    outs_r, outs_v = [], []
    for i in range(10):
        lr, cache_r = dec_ref.fn(ps, cache_r, cur_r, jnp.full((8,), i, jnp.int32))
        lv, cache_v = dec_var.fn(ps, cache_v, cur_v, jnp.full((8,), i, jnp.int32))
        cur_r, cur_v = lr, lv
        outs_r.append(np.asarray(lr)); outs_v.append(np.asarray(lv))
    match = np.mean(np.stack(outs_r) == np.stack(outs_v))
    thresh = 1.0 if variant == "ring" else 0.8  # int8 may flip rare ties
    assert match >= thresh, (variant, match)
    print(f"{variant} decode token match rate: {match:.2f} OK")

# --- int8 WEIGHTS decode parity --------------------------------------------
from repro.parallel.quant import quantize_params
dec_w = build_decode_step(aps, ctx3, mesh, weight_quant=True)
qparams = quantize_params(ps)
from repro.models.transformer import init_cache as _ic
c_r = _ic(aps, 8, 32, local=False)
c_w = _ic(aps, 8, 32, local=False)
dec_r2 = build_decode_step(aps, ctx3, mesh)
cur_r = cur_w = jnp.arange(8, dtype=jnp.int32)
m = t = 0
for i in range(8):
    cur_r, c_r = dec_r2.fn(ps, c_r, cur_r, jnp.full((8,), i, jnp.int32))
    cur_w, c_w = dec_w.fn(qparams, c_w, cur_w, jnp.full((8,), i, jnp.int32))
    m += int(np.sum(np.asarray(cur_r) == np.asarray(cur_w))); t += 8
assert m / t >= 0.8, f"weight-quant match {m}/{t}"
print(f"weight_quant decode match {m}/{t} OK")
print("pp+perf case OK")
