"""8-device validation of the quantized all-reduce strategies (ar_quant):
wire exactness, overlapped-matmul chunk invariance, error-feedback decode
parity against the fp strategy, and the serve stack end-to-end."""
import numpy as np, jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core.compat import AxisType, make_mesh, shard_map
from repro.core import ParallelCtx, tp_all_reduce
from repro.core import hierarchical as hier
from repro.core import overlap as ov

mesh = make_mesh((2, 4), ("pod", "model"), axis_types=(AxisType.Auto,) * 2)
rng = np.random.default_rng(0)


def run(fn, x):
    f = shard_map(fn, mesh=mesh, in_specs=P("pod", "model"),
                  out_specs=P("pod", "model"), check_vma=False)
    return np.asarray(jax.jit(f)(x))


# -- A: quantized AR is replicated-exact and close to the fp sum -------------
x = rng.standard_normal((8, 1024)).astype(np.float32)
ref = run(lambda v: lax.psum(v, ("pod", "model")), x)
for quant, tol in (("int8", 0.02), ("int4", 0.2)):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="hier_rd", ar_quant=quant)
    out = run(lambda v: tp_all_reduce(v, ctx, scatter_dim=-1), x)
    # every rank must hold the SAME dequantized sum (the RS+RD+AG pipeline
    # computes one result and replicates it — no per-rank rounding drift).
    # out_specs retiles the per-rank (4, 256) local results into (8, 1024):
    # rank (i, j) owns block [4i:4i+4, 256j:256j+256].
    per_rank = out.reshape(2, 4, 4, 256).transpose(0, 2, 1, 3).reshape(
        8, 4, 256)
    assert np.all(per_rank == per_rank[:1]), f"{quant}: ranks disagree"
    rel = np.abs(out - ref).max() / np.abs(ref).max()
    assert rel < tol, (quant, rel)
print("quant AR exactness OK")

# -- B: overlapped collective-matmul is bitwise chunk-invariant --------------
# d_out = 4096 keeps every chunk step (4096/4 = 1024) a multiple of
# group_cap * n_tp (int8: 128*8, int4: 64*8) -> the chunked path is taken
B_, D, DO = 4, 256, 4096
xs = rng.standard_normal((B_, 1, D)).astype(np.float32)
w = (rng.standard_normal((D, DO)) * 0.05).astype(np.float32)
for quant in ("int8", "int4"):
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="hier_rd", ar_quant=quant,
                      overlap_matmul=True)

    def mm(chunks):
        def f(xv, wv):
            ef0 = jnp.zeros((B_, 1, DO), jnp.float32)
            y, ef = ov.collective_matmul(xv, wv, ctx, spec="bsd,df->bsf",
                                         chunks=chunks, ef=ef0)
            return y, ef
        g = shard_map(f, mesh=mesh,
                      in_specs=(P(None, None, ("pod", "model")),
                                P(("pod", "model"), None)),
                      out_specs=(P(None, None, None), P(None, None, None)),
                      check_vma=False)
        return jax.jit(g)(xs, w)

    (y1, e1), (y4, e4) = mm(1), mm(4)
    assert np.array_equal(np.asarray(y1), np.asarray(y4)), \
        f"{quant}: chunked output diverges from unchunked"
    assert np.array_equal(np.asarray(e1), np.asarray(e4)), \
        f"{quant}: chunked EF diverges from unchunked"
    assert np.abs(np.asarray(e1)).max() > 0, f"{quant}: EF never captured"
print("overlap chunk invariance OK")

# -- C: decode parity + bounded logit divergence (EF on) ---------------------
from repro.models import ModelConfig, make_plan, init_params
from repro.parallel.steps import build_cache_init, build_decode_step

cfg = ModelConfig(name="quant-tiny", family="dense", n_layers=2, d_model=128,
                  n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
                  vocab_size=96, dtype=jnp.float32)
ap = make_plan(cfg, 8)
params = init_params(jax.random.PRNGKey(0), ap)
S, WARM, GREEDY = 4, 8, 8
prompt = rng.integers(0, cfg.vocab_size, (S, WARM)).astype(np.int32)


def decode_run(quant, force=None):
    """Teacher-forced decode: the prompt for WARM steps, then ``force``
    (the fp run's greedy stream) so the quant run scores the SAME token
    trajectory — isolating per-step logit divergence from compounding
    stream divergence."""
    ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                      ar_strategy="hier_rd", ar_quant=quant)
    cache = build_cache_init(ap, ctx, mesh, slots=S, s_max=64).jit()()
    step = build_decode_step(ap, ctx, mesh, sample=False).jit()
    toks, logits_hist = [], []
    cur = jnp.asarray(prompt[:, 0])
    for t in range(WARM + GREEDY):
        pos = jnp.full((S,), t, jnp.int32)
        logits, cache = step(params, cache, cur, pos)
        nxt = jnp.argmax(logits[:, :cfg.vocab_size], axis=-1).astype(
            jnp.int32)
        logits_hist.append(np.asarray(logits, np.float32))
        toks.append(np.asarray(nxt))
        if t + 1 < WARM:
            cur = jnp.asarray(prompt[:, t + 1])
        else:
            cur = jnp.asarray(force[t]) if force is not None else nxt
    return np.stack(toks), np.stack(logits_hist)


tok_fp, log_fp = decode_run("none")
scale = np.abs(log_fp).max()
for quant, rtol, min_agree in (("int8", 0.08, 60), ("int4", 0.6, 20)):
    tok_q, log_q = decode_run(quant, force=tok_fp)
    rel = np.abs(log_q - log_fp).max() / scale
    assert rel < rtol, (quant, rel)
    # greedy argmax tracks the fp strategy on most positions; int8+EF is
    # near-exact, int4's coarser wire flips more low-margin argmaxes but
    # EF keeps the divergence bounded (no drift blowup)
    agree = int((tok_q == tok_fp).sum())
    assert agree >= min_agree, (quant, agree, tok_q.size)
    print(f"decode parity OK [{quant}]: rel logit div {rel:.3f}, "
          f"argmax agreement {agree}/{tok_q.size}")

# -- D: serve stack end-to-end with ar_quant=auto ----------------------------
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica

# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=64, tp=8, pods=2,
                 ar_strategy="auto")
reqs = lambda: make_trace(6, mean_in=8, mean_out=5, rate=3.0,
                          vocab=cfg.vocab_size, seed=2)
ref_done = {r.rid: r.output for r in
            build_replica(RS, ap=ap, params=params).run(reqs())}
done = build_replica(RS.replace(ar_quant="auto"), ap=ap,
                     params=params).run(reqs())
assert all(r.output is not None for r in done)
# one-token decode messages sit far below the quant crossover, so the
# autotuner resolves these call sites to the fp strategy -> exact parity
for r in done:
    assert np.array_equal(ref_done[r.rid], r.output), \
        f"rid {r.rid}: ar_quant=auto diverges from fp at decode sizes"
print("serve auto-quant OK")

print("quant_ar OK")
