"""Fault-injected disaggregated serving on the mesh path: the same
(2 pod x 4 model) prefill pool -> 4-way decode pool pair as
case_disagg.py, but run under a nonzero deterministic FaultPlan —
handoff drops (retried with backoff), in-flight bundle corruption
(caught by the KVBundle checksum, recovered by re-prefill), prefill
stalls, and NaN poked into live KV (quarantined + recomputed).  The
robustness invariant must hold on the real sharded path, not just the
single-device one: every non-shed request's greedy tokens are
bitwise-identical to a local fault-free colocated reference, shed
requests always carry a shed_reason, and the coordinator terminates."""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.faults import FaultInjector, FaultPlan
from repro.inference.scheduler import make_trace
from repro.inference.spec import ReplicaSpec, build_replica

cfg = ModelConfig(name="faults-tiny", family="dense", n_layers=2,
                  d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                  d_ff=128, vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 4

# arch is nominal: per-pool plans built from the tiny cfg are passed in
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
DS = RL.replace(disagg=True, prefill_tp=8, prefill_pods=2, decode_tp=4,
                ar_strategy="auto", overlap=True, admit_mode="chunked",
                admit_chunk=16, block_size=8)


def trace():
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local colocated fault-free reference ------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref = {r.rid: r.output
       for r in build_replica(RL, ap=ap1, params=p1).run(trace())}
assert all(v is not None for v in ref.values())

# -- fault plan: every decode+handoff fault kind lit at once -----------------
plan = FaultPlan(seed=7, handoff_drop=0.2, handoff_corrupt=0.1,
                 prefill_stall=0.1, nan_logits=0.05)
inj = FaultInjector(plan)

# -- prefill 2 pods x 4-way TP -> decode 4-way TP, one shared injector -------
ap8 = make_plan(cfg, 8)
p8 = init_params(key, ap8)
ap4 = make_plan(cfg, 4)
p4 = init_params(key, ap4)
coord = build_replica(DS, prefill_ap=ap8, prefill_params=p8,
                      decode_ap=ap4, decode_params=p4, injector=inj)
done = coord.run(trace())
m = coord.metrics(done)
assert m.completed + m.shed_requests == len(done), m
shed = [r for r in done if r.output is None]
for r in shed:
    assert r.shed_reason, f"rid {r.rid} lost without a shed_reason"
for r in done:
    if r.output is not None:
        assert np.array_equal(ref[r.rid], r.output), \
            f"rid {r.rid}: tokens diverge from fault-free local reference"
# the plan really fired: drops forced retries on the sharded handoff path
assert inj.counts["handoff_drop"] > 0, inj.counts
assert m.handoff_retries > 0, m
print(f"fault parity OK ({m.completed} survived, {m.shed_requests} shed, "
      f"{m.handoff_retries} retries, {m.handoff_reprefills} reprefills, "
      f"{m.decode_pool['quarantines']} quarantines)")
print("faults OK")
