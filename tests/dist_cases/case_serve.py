"""Mesh-path continuous batching: trace replay on the (2 pod x 4 model)
mesh with ar_strategy="auto" + overlap_matmul + a paged KV cache must
reproduce the local dense batcher's greedy tokens request-for-request, and
keep doing so under a block pool tight enough to force preemption."""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.scheduler import Request, make_trace
from repro.inference.spec import ReplicaSpec, build_replica

cfg = ModelConfig(name="serve-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 4
# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
RM = RL.replace(tp=8, pods=2, ar_strategy="auto", overlap=True)


def trace():
    return make_trace(10, mean_in=10, mean_out=6, rate=3.0,
                      vocab=cfg.vocab_size, seed=4)


# -- local dense reference ---------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref_sched = build_replica(RL, ap=ap1, params=p1)
ref = {r.rid: r.output for r in ref_sched.run(trace())}
assert all(v is not None for v in ref.values())

# -- mesh paged batcher: auto AR + overlapped collective-matmul --------------
apN = make_plan(cfg, 8)
pN = init_params(key, apN)
mesh_sched = build_replica(RM.replace(block_size=8, admit_mode="chunked",
                                      admit_chunk=16), ap=apN, params=pN)
done = mesh_sched.run(trace())
m = mesh_sched.metrics(done)
assert m.completed == len(done), m
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: mesh paged tokens diverge from local dense"
assert m.peak_kv_tokens < SLOTS * S_MAX, \
    (m.peak_kv_tokens, SLOTS * S_MAX)
print(f"mesh paged trace parity OK (peak {m.peak_kv_tokens} of "
      f"{SLOTS * S_MAX} dense tokens, util {m.cache_utilization:.2f})")

# -- tight pool on the mesh: preemption + still-correct tokens ---------------
tight = build_replica(RM.replace(slots=3, block_size=8, n_blocks=9,
                                 admit_mode="chunked", admit_chunk=16),
                      ap=apN, params=pN)
rng = np.random.default_rng(5)
long_reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                16).astype(np.int32),
                     max_new=30, arrival_s=0.0) for i in range(3)]
iso = {}
for r in long_reqs:
    s1 = build_replica(RL.replace(slots=1), ap=ap1, params=p1)
    rr = Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
    s1.run([rr])
    iso[r.rid] = rr.output
done_t = tight.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                    for r in long_reqs])
mt = tight.metrics(done_t)
for r in done_t:
    assert np.array_equal(iso[r.rid], r.output), f"rid {r.rid} post-preempt"
assert mt.preemptions > 0, "tight pool should have preempted"
print(f"mesh preemption OK ({mt.preemptions} preemptions, "
      f"tokens exact after recompute)")
print("serve OK")
