"""Mesh-path prefix cache: a shared-prefix trace on the (2 pod x 4 model)
mesh with prefix_cache="on" must reproduce the local dense batcher's
greedy tokens request-for-request while actually splicing blocks
(prefix_tokens_saved > 0) — shared physical KV blocks are read through
every device's shard of the paged cache, so a splice that was only
almost-right shows up as token divergence here even when the 1-device
run passes."""
import numpy as np, jax, jax.numpy as jnp
from repro.models import ModelConfig, make_plan, init_params
from repro.inference.scheduler import Request, make_prefix_trace
from repro.inference.spec import ReplicaSpec, build_replica

cfg = ModelConfig(name="prefix-tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=96, dtype=jnp.float32)
key = jax.random.PRNGKey(0)
S_MAX, SLOTS = 64, 4
# arch is nominal: ap/params built from the tiny cfg are passed explicitly
RL = ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX)
RM = RL.replace(tp=8, pods=2, ar_strategy="auto", overlap=True,
                block_size=8, admit_mode="chunked", admit_chunk=16)


def trace():
    return make_prefix_trace(10, prefix_len=32, shared_frac=0.7,
                             mean_in=10, mean_out=6, rate=3.0,
                             vocab=cfg.vocab_size, seed=4,
                             clip_len=S_MAX - 1)


# -- local dense reference ---------------------------------------------------
ap1 = make_plan(cfg, 1)
p1 = init_params(key, ap1)
ref_sched = build_replica(RL, ap=ap1, params=p1)
ref = {r.rid: r.output for r in ref_sched.run(trace())}
assert all(v is not None for v in ref.values())

# -- mesh paged batcher with the prefix trie on ------------------------------
apN = make_plan(cfg, 8)
pN = init_params(key, apN)
mesh_sched = build_replica(RM.replace(prefix_cache="on"), ap=apN, params=pN)
done = mesh_sched.run(trace())
m = mesh_sched.metrics(done)
assert m.completed == len(done), m
assert m.prefix_hits > 0 and m.prefix_tokens_saved > 0, \
    (m.prefix_hits, m.prefix_tokens_saved)
for r in done:
    assert np.array_equal(ref[r.rid], r.output), \
        f"rid {r.rid}: mesh spliced tokens diverge from local dense"
mesh_sched.alloc.check()
print(f"mesh prefix parity OK ({m.prefix_hits}/{m.prefix_lookups} hits, "
      f"{m.prefix_tokens_saved} prompt tokens spliced)")

# -- warm re-run: the trie persists, every shared admission must hit ---------
done2 = mesh_sched.run(trace())
m2 = mesh_sched.metrics(done2)
assert m2.prefix_hits >= m.prefix_hits, (m2.prefix_hits, m.prefix_hits)
for r in done2:
    assert np.array_equal(ref[r.rid], r.output), f"rid {r.rid} warm re-run"
print(f"mesh warm re-run OK ({m2.prefix_hits}/{m2.prefix_lookups} hits)")
print("prefix OK")
