"""Property tests for the GQA head-padding planner (universal TP
shardability)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pure-pytest fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.models.common import plan_gqa
from repro.configs import ARCH_IDS, get_config


def _check_plan(plan, n_q, n_kv, tp):
    q_map = np.asarray(plan.q_map).reshape(tp, plan.u, plan.g)
    kv_map = np.asarray(plan.kv_map).reshape(tp, plan.u)
    # every live q head appears exactly once
    live = q_map[q_map >= 0]
    assert sorted(live.tolist()) == list(range(n_q))
    # group consistency: every live q slot's kv slot holds its original kv
    q_per_kv = n_q // n_kv
    for d in range(tp):
        for u in range(plan.u):
            for g in range(plan.g):
                q = q_map[d, u, g]
                if q >= 0:
                    assert kv_map[d, u] == q // q_per_kv, (d, u, g)
    # dead kv slots serve no live q heads
    for d in range(tp):
        for u in range(plan.u):
            if kv_map[d, u] < 0:
                assert (q_map[d, u] < 0).all()
    assert plan.flops_overhead >= 1.0
    assert plan.q_slots % tp == 0 and plan.kv_slots % tp == 0


@given(st.integers(1, 16), st.integers(1, 8), st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=300, deadline=None)
def test_plan_random(q_per_kv, n_kv, tp):
    n_q = q_per_kv * n_kv
    plan = plan_gqa(n_q, n_kv, tp)
    _check_plan(plan, n_q, n_kv, tp)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("tp", [8, 16, 256])
def test_plan_assigned_archs(arch, tp):
    cfg = get_config(arch)
    if cfg.attn_free:
        pytest.skip("attention-free")
    plan = plan_gqa(cfg.n_heads, cfg.n_kv_heads, tp)
    _check_plan(plan, cfg.n_heads, cfg.n_kv_heads, tp)
    # padding overhead stays sane for the production TP=16
    if tp == 16:
        assert plan.flops_overhead <= 1.5, (arch, plan.flops_overhead)


def test_no_padding_when_divisible():
    plan = plan_gqa(96, 8, 16)  # mistral-large
    assert plan.flops_overhead == 1.0
    plan = plan_gqa(32, 8, 16)  # llama3.2 / pixtral
    assert plan.flops_overhead == 1.0


def test_hymba_case():
    plan = plan_gqa(25, 5, 16)
    assert plan.q_slots == 32 and plan.flops_overhead == pytest.approx(1.28)


def test_qwen15_mha_case():
    plan = plan_gqa(40, 40, 16)
    assert plan.q_slots == 48  # 20% dead-slot overhead, mapping identity
