"""Disaggregated prefill/decode serving: KV handoff round-trips across
layouts, coordinator correctness (bitwise parity vs colocated paged
serving, incl. speculative decode on the decode pool), and queue behavior
under decode-pool OOM/preemption."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.common import plan_gqa
from repro.models.transformer import make_plan, init_params
from repro.inference.disagg import PrefillPool
from repro.inference.kv_cache import (KVBundle, export_slot, heads_to_slots,
                                      slots_to_heads)
from repro.inference.scheduler import Request, make_trace
from repro.inference.spec import (ReplicaSpec, build_engine,
                                  build_prefill_pool, build_replica)

# the one construction path (DESIGN.md §13): every batcher/pool/
# coordinator below is built from a spec, never from raw kwargs
RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96)
DS = RS.replace(disagg=True)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _trace(cfg, n=10, seed=4, mean_out=6, rate=3.0):
    return make_trace(n, mean_in=10, mean_out=mean_out, rate=rate,
                      vocab=cfg.vocab_size, seed=seed)


# ---------------------------------------------------------------------------
# KV bundle layout round-trips (host-side reshard machinery)
# ---------------------------------------------------------------------------


def test_bundle_roundtrip_across_tp_layouts():
    """Canonical -> slot layout -> canonical is the identity for every TP
    degree, including layouts that replicate kv heads across slots."""
    rng = np.random.default_rng(0)
    L, T, n_q, n_kv, hd = 2, 7, 4, 2, 16
    canon = rng.standard_normal((L, T, n_kv, hd)).astype(np.float32)
    for tp in (1, 2, 4):
        plan = plan_gqa(n_q, n_kv, tp)
        expanded = heads_to_slots(canon, plan.kv_map)
        assert expanded.shape == (L, T, plan.kv_slots, hd)
        # every slot owning head h carries exactly head h's values
        for s, h in enumerate(plan.kv_map):
            expect = canon[:, :, h] if h >= 0 else 0.0
            np.testing.assert_array_equal(expanded[:, :, s], expect)
        back = slots_to_heads(expanded, plan.kv_map)
        np.testing.assert_array_equal(back, canon)
    # cross-layout: pack from tp=4's layout, expand into tp=2's
    p4, p2 = plan_gqa(n_q, n_kv, 4), plan_gqa(n_q, n_kv, 2)
    via4 = slots_to_heads(heads_to_slots(canon, p4.kv_map), p4.kv_map)
    np.testing.assert_array_equal(heads_to_slots(via4, p2.kv_map),
                                  heads_to_slots(canon, p2.kv_map))


def test_export_slot_dense_vs_paged_and_trash_isolation(tiny_lm):
    """Exporting a slot from a dense cache and from a paged cache after
    identical admissions yields identical bundles; freeing a neighbour
    slot (whose table rows revert to the trash block) must not disturb
    the export, and exporting a freed slot is rejected."""
    cfg, ap, params = tiny_lm
    prompt = np.random.default_rng(7).integers(
        0, cfg.vocab_size, 12).astype(np.int32)
    other = np.random.default_rng(8).integers(
        0, cfg.vocab_size, 20).astype(np.int32)
    kv_map = ap.gqa.kv_map

    def admit_two(**kw):
        sched = build_replica(RS.replace(**kw), ap=ap, params=params)
        # admit directly (no decode steps): slot 0 = prompt, slot 1 = other
        sched._wall0 = 0.0
        assert sched._admit(0, Request(rid=0, prompt=prompt, max_new=4), 0.0)
        assert sched._admit(1, Request(rid=1, prompt=other, max_new=4), 0.0)
        return sched

    dense = admit_two()
    paged = admit_two(block_size=8)
    b_dense = export_slot(dense.cache, 0, len(prompt), kv_map)
    row = paged.alloc.table[0]
    b_paged = export_slot(paged.cache, 0, len(prompt), kv_map,
                          table_row=row)
    assert b_dense.k.shape == (cfg.n_layers, len(prompt), cfg.n_kv_heads,
                               cfg.head_dim)
    np.testing.assert_array_equal(b_dense.k, b_paged.k)
    np.testing.assert_array_equal(b_dense.v, b_paged.v)
    # free the neighbour: slot 0's blocks and export must be untouched
    paged.alloc.free(1)
    b_after = export_slot(paged.cache, 0, len(prompt), kv_map,
                          table_row=paged.alloc.table[0])
    np.testing.assert_array_equal(b_paged.k, b_after.k)
    # a freed slot's table row is all-trash: export refuses to read it
    with pytest.raises(AssertionError):
        export_slot(paged.cache, 1, len(other), kv_map,
                    table_row=paged.alloc.table[1])


def test_prefill_pool_full_vs_chunked_bundles(tiny_lm):
    """The prefill-only step (full) and the chunked-admission export
    produce identical bundles and first tokens, dense or paged pool."""
    cfg, ap, params = tiny_lm
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, 23).astype(np.int32)
    req = Request(rid=0, prompt=prompt, max_new=8)
    full = build_prefill_pool(RS, ap=ap, params=params)
    tok_f, b_f = full.prefill(req)
    for kw in (dict(), dict(block_size=8)):
        chunked = build_prefill_pool(
            RS.replace(admit_mode="chunked", admit_chunk=16, **kw),
            ap=ap, params=params)
        tok_c, b_c = chunked.prefill(req)
        assert tok_f == tok_c
        np.testing.assert_array_equal(b_f.k, b_c.k)
        np.testing.assert_array_equal(b_f.v, b_c.v)
    assert b_f.n_tokens == 23 and b_f.nbytes > 0


# ---------------------------------------------------------------------------
# coordinator: bitwise parity vs colocated serving
# ---------------------------------------------------------------------------


def _colocated(cfg, ap, params, reqs, **kw):
    sched = build_replica(RS.replace(**kw), ap=ap, params=params)
    return {r.rid: r.output for r in sched.run(reqs)}


def _disagg(cfg, ap, params, reqs, spec=DS):
    coord = build_replica(spec, ap=ap, params=params)
    done = coord.run(reqs)
    assert all(r.output is not None for r in done)
    return {r.rid: r.output for r in done}, coord


def test_disagg_trace_bitwise_equals_colocated(tiny_lm):
    """Disaggregated greedy serve of the smoke trace == colocated paged
    serve, request for request, for full and chunked prefill pools."""
    cfg, ap, params = tiny_lm
    ref = _colocated(cfg, ap, params, _trace(cfg), block_size=8)
    for spec in (DS.replace(block_size=8, prefill_block_size=0),
                 DS.replace(block_size=8, admit_mode="chunked",
                            admit_chunk=16)):
        got, _ = _disagg(cfg, ap, params, _trace(cfg), spec)
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])


def test_disagg_spec_decode_parity(tiny_lm):
    """Speculative decoding on the decode pool preserves the bitwise
    greedy stream through the handoff."""
    cfg, ap, params = tiny_lm
    ref = _colocated(cfg, ap, params, _trace(cfg), block_size=8)
    reqs = _trace(cfg)
    got, coord = _disagg(cfg, ap, params, reqs,
                         DS.replace(block_size=8, prefill_block_size=0,
                                    spec_mode="ngram", spec_k=4))
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])
    m = coord.metrics(reqs)
    assert m.completed == len(reqs)
    assert m.decode_pool["spec_steps"] > 0
    assert m.ttft_steps_p50 >= 1.0


def test_disagg_dense_decode_pool(tiny_lm):
    """block_size=0 (dense) decode pool takes the same handoff path."""
    cfg, ap, params = tiny_lm
    ref = _colocated(cfg, ap, params, _trace(cfg, n=6))
    got, _ = _disagg(cfg, ap, params, _trace(cfg, n=6))
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])


def test_disagg_sampled_trace_token_identical_to_colocated(tiny_lm):
    """temperature > 0: every request samples from its own stateless key
    chain (scheduler.request_sampling_key) whose base key rides
    KVBundle.rng, so the sampled disagg stream is token-identical to
    colocated paged serving — for full and chunked prefill pools (the PR 5
    fix for the per-pool-RNG divergence gap)."""
    cfg, ap, params = tiny_lm
    kw = dict(temperature=1.5, top_k=20, seed=0)
    ref = _colocated(cfg, ap, params, _trace(cfg), block_size=8, **kw)
    for spec in (DS.replace(block_size=8, prefill_block_size=0, **kw),
                 DS.replace(block_size=8, admit_mode="chunked",
                            admit_chunk=16, **kw)):
        got, coord = _disagg(cfg, ap, params, _trace(cfg), spec)
        for rid in ref:
            np.testing.assert_array_equal(ref[rid], got[rid])
    # and the stream actually sampled (differs from the greedy trace)
    greedy = _colocated(cfg, ap, params, _trace(cfg), block_size=8)
    assert any(not np.array_equal(greedy[rid], ref[rid]) for rid in ref)


def test_disagg_sampled_survives_preemption(tiny_lm):
    """Sampled disagg parity must hold through decode-pool preemption:
    the recompute re-prefills with the same (seed, rid) chain, so the
    resampled tokens are the originals."""
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(5)
    kw = dict(temperature=1.5, top_k=20, seed=0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        16).astype(np.int32),
                    max_new=40, arrival_s=0.0) for i in range(3)]

    def clone():
        return [Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                        arrival_s=0.0) for r in reqs]

    # isolated single-slot references (never preempted)
    iso = {}
    for r in clone():
        sched = build_replica(RS.replace(slots=1, **kw),
                              ap=ap, params=params)
        sched.run([r])
        iso[r.rid] = r.output
    coord = build_replica(
        DS.replace(block_size=8, prefill_block_size=0, n_blocks=13, **kw),
        ap=ap, params=params)
    done = coord.run(clone())
    m = coord.metrics(done)
    assert m.preemptions > 0, "pool sized to force preemption"
    for r in done:
        np.testing.assert_array_equal(iso[r.rid], r.output)


# ---------------------------------------------------------------------------
# coordinator: queue behavior under decode-pool OOM / preemption
# ---------------------------------------------------------------------------


def test_disagg_decode_oom_reprefills_and_stays_exact(tiny_lm):
    """A decode pool too small for three concurrent long decodes preempts;
    the coordinator routes evicted contexts back through the prefill pool
    (handoffs > requests) and the final tokens are undisturbed."""
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(5)
    protos = [(rng.integers(0, cfg.vocab_size, 16).astype(np.int32), 40)
              for _ in range(3)]
    eng = build_engine(RS, ap=ap, params=params)
    ref = {i: eng.generate(p[None], n).new_tokens[0]
           for i, (p, n) in enumerate(protos)}
    reqs = [Request(rid=i, prompt=p, max_new=n, arrival_s=0.0)
            for i, (p, n) in enumerate(protos)]
    coord = build_replica(
        DS.replace(block_size=8, prefill_block_size=0, n_blocks=13),
        ap=ap, params=params)
    decode = coord.decode
    done = coord.run(reqs)
    m = coord.metrics(done)
    assert m.preemptions > 0
    assert m.handoffs > len(reqs), \
        "preempted contexts must re-prefill (fresh handoff each time)"
    assert m.peak_ready_depth >= 1   # bundles queued while the pool was full
    for r in done:
        np.testing.assert_array_equal(ref[r.rid], r.output)
    decode.alloc.check()
    assert decode.alloc.used_blocks == 0


def test_admit_prefilled_rejects_when_pool_full(tiny_lm):
    """admit_prefilled returns False (no state change) when the paged pool
    cannot hold the bundle, and the bundle admits cleanly later."""
    cfg, ap, params = tiny_lm
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, 24).astype(np.int32)
    pool = build_prefill_pool(RS, ap=ap, params=params)
    tok, bundle = pool.prefill(Request(rid=0, prompt=prompt, max_new=4))
    # 13 blocks of 8 = 12 usable; slot 1 hogs 9, leaving 3 < the 4 needed
    decode = build_replica(RS.replace(slots=2, block_size=8, n_blocks=13),
                           ap=ap, params=params)
    decode._wall0 = 0.0
    assert decode.alloc.ensure(1, 72)
    req = Request(rid=0, prompt=prompt, max_new=4)
    assert not decode.admit_prefilled(0, req, bundle, tok, 0.0)
    assert decode.active[0] is None and not decode.active_mask[0]
    decode.alloc.free(1)
    assert decode.admit_prefilled(0, req, bundle, tok, 0.0)
    assert decode.positions[0] == len(prompt)
    assert decode.outputs[0] == [tok]


# ---------------------------------------------------------------------------
# metrics: per-pool attribution + AR operating points
# ---------------------------------------------------------------------------


def test_disagg_metrics_attribution_and_ar_buckets(tiny_lm):
    cfg, ap, params = tiny_lm
    reqs = _trace(cfg)
    _, coord = _disagg(cfg, ap, params, reqs,
                       DS.replace(block_size=8, prefill_block_size=0))
    m = coord.metrics(reqs)
    assert m.completed == m.requests == len(reqs)
    assert m.handoffs == len(reqs) and m.transfer_bytes > 0
    # TTFT decomposes into prefill + transfer components
    assert m.ttft_steps_p50 == pytest.approx(
        m.prefill_steps_p50 + m.transfer_steps_p50, abs=2.0)
    assert m.tpot_steps_p50 >= 0.9
    # the disaggregation payoff: the pools key the AR table on different
    # message-size buckets (prompt-sized vs token-sized messages)
    assert m.prefill_ar_bucket > m.decode_ar_bucket
    d = m.to_dict()
    assert d["prefill_pool"]["prefills"] == len(reqs)
    assert d["decode_pool"]["completed"] == len(reqs)


def test_disagg_rejects_non_dense(tiny_lm):
    cfg = get_smoke("rwkv6-7b")
    ap = make_plan(cfg, 1)
    with pytest.raises(ValueError):
        PrefillPool(ap, None, s_max=96)
