"""PrefixCache radix-trie properties against a brute-force model.

The trie (``inference/prefix_cache.py``) keys physical KV blocks by the
``block_size``-token groups they cover.  A dict mapping every inserted
group-path to its first-published block is an obvious-but-slow spec for
the same structure: longest-prefix ``match`` must return exactly the
model's blocks for the longest resident chain, and ``insert`` must keep
first-published blocks on duplicates.  Randomized insert/match streams
(hypothesis when installed, seeded fallback otherwise) check the two
agree op-for-op while the backing allocator's refcount/hold partition
(``check()``) stays intact.

Deterministic tests pin down the eviction contract separately: LRU
order follows the clock, leaf-first draining, and — the safety property
admission relies on — a node whose block a live slot still references
is never evicted, no matter the pressure.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.inference.kv_cache import BlockAllocator
from repro.inference.prefix_cache import PrefixCache

BS = 4


def _mk(n_blocks=256, capacity=None, slots=2):
    a = BlockAllocator(n_blocks=n_blocks, block_size=BS, slots=slots,
                       max_blocks_per_slot=8)
    return a, PrefixCache(a, capacity=capacity)


def _groups(tokens):
    n = len(tokens) // BS
    return tuple(tuple(int(t) for t in tokens[i * BS:(i + 1) * BS])
                 for i in range(n))


def _publish(a, pc, tokens):
    """Prefill-and-publish like the batcher: allocate blocks through a
    slot, insert, then free the slot (holds keep published blocks)."""
    n = len(tokens) // BS
    if n == 0 or not a.ensure(0, n * BS):
        return None
    blocks = list(a.owned(0))[:n]
    pc.insert(tokens, blocks)
    a.free(0)
    return blocks


@given(st.integers(0, 10 ** 6), st.sampled_from([2, 3]))
@settings(max_examples=30, deadline=None)
def test_match_insert_vs_brute_force(seed, vocab):
    """40-op random insert/match streams over a tiny vocabulary (to force
    shared prefixes) must agree with the dict-of-prefixes model exactly,
    and never violate the allocator partition."""
    rng = np.random.default_rng(seed)
    a, pc = _mk()
    model = {}                       # group-path tuple -> first block
    for _ in range(40):
        tokens = rng.integers(0, vocab, int(rng.integers(0, 4 * BS + 3)))
        g = _groups(tokens)
        if rng.random() < 0.5:
            blocks = _publish(a, pc, tokens)
            if blocks is None:
                continue
            for i in range(1, len(g) + 1):
                model.setdefault(g[:i], blocks[i - 1])
        else:
            got = pc.match(tokens)
            want = []
            for i in range(1, len(g) + 1):
                if g[:i] not in model:
                    break
                want.append(model[g[:i]])
            assert got == want, (got, want)
        a.check()
        assert pc.held_blocks == len(model)
        for b in model.values():
            assert a.held_count(b) >= 1
    # full drain: every model chain must still match end-to-end
    for path, _ in sorted(model.items(), key=lambda kv: len(kv[0])):
        flat = [t for grp in path for t in grp]
        assert pc.match(flat) == [model[path[:i + 1]]
                                  for i in range(len(path))]


def test_insert_keeps_first_published_block():
    a, pc = _mk()
    toks = list(range(2 * BS))
    b1 = _publish(a, pc, toks)
    b2_candidate_owner = a.ensure(1, 2 * BS)
    assert b2_candidate_owner
    dup = list(a.owned(1))[:2]
    assert pc.insert(toks, dup) == 0, "duplicate groups must pin nothing"
    a.free(1)
    assert pc.match(toks) == b1
    a.check()


def test_lru_eviction_order_follows_clock():
    """Three disjoint chains published in order; capacity pressure must
    evict the least-recently matched chain first, leaf before parent."""
    a, pc = _mk(capacity=None)
    chains = {k: [k * 50 + t for t in range(2 * BS)] for k in range(3)}
    for k in range(3):
        _publish(a, pc, chains[k])
    pc.match(chains[0])              # refresh chain 0: 1 is now coldest
    assert pc.held_blocks == 6
    freed = pc.reclaim(2)
    assert freed == 2 and pc.evictions == 2
    assert pc.match(chains[1]) == [], "coldest chain evicted first"
    assert len(pc.match(chains[0])) == 2
    assert len(pc.match(chains[2])) == 2
    a.check()


def test_capacity_evicts_on_insert():
    a, pc = _mk(capacity=2)
    _publish(a, pc, [1 + t for t in range(2 * BS)])
    _publish(a, pc, [100 + t for t in range(2 * BS)])
    assert pc.held_blocks == 2, "insert past capacity must evict LRU"
    assert pc.evictions == 2
    a.check()
    assert a.used_blocks == pc.held_blocks


def test_lru_never_evicts_block_with_live_slot_refs():
    """The safety property: a sharer's blocks stay resident under any
    reclaim pressure; only unreferenced nodes drain."""
    a, pc = _mk()
    shared = [7] * (2 * BS)
    blocks = _publish(a, pc, shared)
    a.share(1, blocks)               # a live request maps the chain
    _publish(a, pc, [200 + t for t in range(2 * BS)])
    freed = pc.reclaim(10 ** 9)      # unbounded pressure
    assert freed == 2, "only the unreferenced chain may drain"
    assert pc.match(shared) == blocks
    for b in blocks:
        assert a.held_count(b) == 1 and a.slot_refs(b) == 1
    a.check()
    # once the sharer exits, the same pressure drains the rest
    a.free(1)
    assert pc.reclaim(10 ** 9) == 2
    assert pc.held_blocks == 0
    a.check()
    assert a.used_blocks == 0


def test_interior_node_unevictable_until_subtree_drains():
    a, pc = _mk()
    toks = list(range(3 * BS))
    blocks = _publish(a, pc, toks)
    a.share(1, blocks[2:])           # pin only the deepest node
    assert pc.reclaim(10 ** 9) == 0, ("parents of a referenced leaf must "
                                      "survive (path must stay walkable)")
    assert pc.match(toks) == blocks
    a.free(1)
    assert pc.reclaim(10 ** 9) == 3
    a.check()


def test_invalidate_block_drops_subtree():
    a, pc = _mk()
    toks = list(range(3 * BS))
    blocks = _publish(a, pc, toks)
    assert pc.invalidate_block(blocks[1]) == 2, "node + its child"
    assert pc.match(toks) == blocks[:1]
    assert pc.invalidate_block(blocks[1]) == 0, "idempotent on non-resident"
    a.check()


def test_remap_survives_defragment():
    """Defragmenting the allocator must leave every chain matchable at
    the remapped physical blocks (the registered remap hook)."""
    a, pc = _mk()
    junk = a.ensure(1, 3 * BS)       # fragment the pool
    assert junk
    toks = [300 + t for t in range(2 * BS)]
    old = _publish(a, pc, toks)
    a.free(1)
    perm = a.defragment()
    assert perm is not None
    new = pc.match(toks)
    assert len(new) == 2 and new != old
    assert [int(perm[b]) for b in new] == old, "perm[new] = old"
    for b in new:
        assert a.held_count(b) == 1
    a.check()
