"""Fault-tolerance: checkpoint atomicity/roundtrip, resume-exactness, data
determinism, preemption handling, NaN skipping."""
import os
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ck
from repro.training.data import SyntheticLMData
from repro.launch.train import run_training


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
                        "b": jnp.ones((4,), jnp.float32)},
             "opt": {"step": jnp.int32(7)}}
    d = str(tmp_path / "ck")
    ck.save(d, 7, state)
    assert ck.latest_step(d) == 7
    step, restored = ck.restore(d, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path / "ck")
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, state, keep=2)
    kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    assert ck.latest_step(d) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ck.save(d, 1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ck.restore(d, {"x": jax.ShapeDtypeStruct((3, 3), jnp.float32)})


def test_async_checkpointer(tmp_path):
    d = str(tmp_path / "ck")
    saver = ck.AsyncCheckpointer(d)
    saver.save(3, {"x": jnp.full((4,), 3.0)})
    saver.wait()
    assert ck.latest_step(d) == 3


def test_data_determinism_and_resharding():
    d = SyntheticLMData(97, 32, 8, seed=1)
    b1 = d.batch(5)
    b2 = d.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(6)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # host sharding covers the global batch disjointly & deterministically
    d2 = SyntheticLMData(97, 32, 8, seed=1, n_hosts=2, host_id=0)
    d3 = d2.reshard(2, 1)
    assert d2.batch(5)["tokens"].shape[0] == 4
    assert not np.array_equal(d2.batch(5)["tokens"], d3.batch(5)["tokens"])


def test_resume_bit_exact(tmp_path):
    """Train 10 steps straight vs 5 + checkpoint + resume 5: identical."""
    d = str(tmp_path / "ck")
    full = run_training("llama3.2-1b", steps=10, global_batch=4, seq_len=16,
                        microbatches=1, log_every=100)
    part = run_training("llama3.2-1b", steps=5, global_batch=4, seq_len=16,
                        microbatches=1, ckpt_dir=d, ckpt_every=5,
                        log_every=100)
    resumed = run_training("llama3.2-1b", steps=10, global_batch=4,
                           seq_len=16, microbatches=1, ckpt_dir=d,
                           log_every=100)
    assert resumed["history"][0]["step"] == 5
    l_full = [h["loss"] for h in full["history"][5:]]
    l_res = [h["loss"] for h in resumed["history"]]
    np.testing.assert_allclose(l_full, l_res, rtol=2e-4, atol=2e-4)


def test_preemption_checkpoint(tmp_path):
    """SIGTERM mid-run -> checkpoint written at the interrupted step."""
    d = str(tmp_path / "ck")

    def fire():
        time.sleep(1.5)
        signal.raise_signal(signal.SIGTERM)

    t = threading.Thread(target=fire)
    t.start()
    out = run_training("llama3.2-1b", steps=100000, global_batch=4,
                       seq_len=16, microbatches=1, ckpt_dir=d,
                       ckpt_every=10**9, log_every=10**9)
    t.join()
    assert out["preempted"]
    assert ck.latest_step(d) == out["stopped_at"]


def test_nan_gradient_skipped():
    """A poisoned batch must not destroy the parameters."""
    from repro.configs import get_smoke
    from repro.core.pcontext import ParallelCtx
    from repro.models.transformer import make_plan, init_params
    from repro.parallel.steps import build_train_step
    from repro.training.optimizer import adamw_init
    from repro.launch.mesh import make_test_mesh

    cfg = get_smoke("llama3.2-1b")
    mesh = make_test_mesh((1, 1), ("data", "model"))
    ctx = ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",),
                      ep=("model",), sp=("model",))
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    # poison one weight so the forward produces inf -> NaN loss/grads
    params["blocks"]["mlp"]["wg"] = params["blocks"]["mlp"]["wg"].at[0].set(
        jnp.inf)
    opt = adamw_init(params)
    built = build_train_step(ap, ctx, mesh, microbatches=1, base_lr=1e-2,
                             warmup=0)
    tok = jnp.zeros((2, 8), jnp.int32)
    # snapshot before the step: the builder donates params for in-place
    # updates, so the originals are deleted afterwards
    w_before = np.asarray(params["blocks"]["mlp"]["wd"], np.float32)
    p2, o2, m = built.jit()(params, opt, {"tokens": tok, "labels": tok})
    assert float(m["skipped"]) == 1.0
    # params unchanged (update skipped)
    w_after = np.asarray(p2["blocks"]["mlp"]["wd"], np.float32)
    np.testing.assert_array_equal(w_before, w_after)
