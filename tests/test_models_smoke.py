"""Per-architecture smoke tests (task-mandated): instantiate the REDUCED
config of the same family and run one forward + one train step + one decode
step on CPU, asserting output shapes and no NaNs.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see launch/dryrun.py and tests/test_dryrun_cells.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke, get_config
from repro.core import LOCAL
from repro.models import (make_plan, init_params, init_cache, forward_lm,
                          decode_step)
from repro.models.layers import sharded_xent
from repro.training import adamw_init
from repro.parallel.steps import build_train_step
from repro.core.pcontext import ParallelCtx
from repro.launch.mesh import make_test_mesh

B, S = 2, 16


def _extras(cfg, key):
    kw = {}
    if cfg.family == "encdec":
        kw["frame_embeds"] = jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    return kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_decode(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    ap = make_plan(cfg, 1)
    params = init_params(key, ap)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    logits, aux, _, _ = forward_lm(params, tok, ap, LOCAL,
                                   **_extras(cfg, key))
    assert logits.shape == (B, S, ap.vocab_pad)
    lo = np.asarray(logits, np.float32)
    assert np.isfinite(lo).all(), f"{arch}: non-finite logits"

    if cfg.family == "encdec":
        # decode needs enc cache seeding — covered by cache-consistency test
        cache = init_cache(ap, B, S + 4)
        assert "enc_k" in cache
        return
    cache = init_cache(ap, B, S + 4)
    lg, cache2 = decode_step(params, cache, jnp.array([1, 2]),
                             jnp.array([0, 0]), ap, LOCAL)
    assert lg.shape == (B, ap.vocab_pad)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One real optimizer step on the 1x1 mesh via the production builder."""
    cfg = get_smoke(arch)
    mesh = make_test_mesh((1, 1), ("data", "model"))
    ctx = ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",),
                      ep=("model",), sp=("model",))
    ap = make_plan(cfg, 1)
    key = jax.random.PRNGKey(0)
    params = init_params(key, ap)
    opt = adamw_init(params)
    built = build_train_step(ap, ctx, mesh, microbatches=2, base_lr=1e-2,
                             warmup=1,
                             frame_embeds=cfg.family == "encdec",
                             patch_embeds=cfg.family == "vlm")
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    ex = _extras(cfg, key)
    if "frame_embeds" in ex:
        batch["frames"] = ex["frame_embeds"]
    if "patch_embeds" in ex:
        batch["patches"] = ex["patch_embeds"]
    step = built.jit()
    p1, o1, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert float(m["skipped"]) == 0.0
    # params actually changed on the second (post-warmup) step
    p2, o2, m2 = step(p1, o1, batch)
    leaf0 = jax.tree.leaves(params)[1]
    leaf2 = jax.tree.leaves(p2)[1]
    assert float(m2["loss"]) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_counts(arch):
    """The FULL configs match their published parameter-count class."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "hymba-1.5b": (1.0e9, 2.3e9),
        "dbrx-132b": (110e9, 145e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "whisper-medium": (0.6e9, 0.95e9),
        "rwkv6-7b": (5.5e9, 9e9),
        "pixtral-12b": (10e9, 14e9),
        "qwen1.5-32b": (28e9, 36e9),
        "mistral-large-123b": (110e9, 130e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "llama3.2-1b": (1.0e9, 1.6e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n / 1e9)
    if cfg.is_moe:
        na = cfg.active_param_count()
        assert na < n
        if arch == "qwen3-moe-30b-a3b":
            assert 2e9 < na < 4.5e9, na / 1e9  # "a3b" = ~3B active
