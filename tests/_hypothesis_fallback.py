"""Pure-pytest stand-in for the subset of hypothesis this suite uses.

The real hypothesis (see requirements-dev.txt) is preferred; when it is not
installed, property tests degrade to a fixed number of seeded pseudo-random
draws instead of erroring out at collection.  Usage in a test module:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, st

Only the strategies this suite uses are implemented: ``integers`` and
``sampled_from``.  Draws are deterministic (seeded per-test by the function
name) so failures are reproducible.
"""
from __future__ import annotations

import functools
import inspect
import random

FALLBACK_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class st:  # mirrors `hypothesis.strategies` for the names used here
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: rng.choice(options))


def given(*garg_strategies, **gkw_strategies):
    """Run the wrapped test over FALLBACK_EXAMPLES seeded draws, always
    including the boundary-ish first draw of each strategy's range."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(fn.__name__)
            for _ in range(FALLBACK_EXAMPLES):
                pos = tuple(s.example(rng) for s in garg_strategies)
                kw = {k: s.example(rng) for k, s in gkw_strategies.items()}
                fn(*args, *pos, **kw, **kwargs)

        # Hide the original parameters from pytest's fixture resolution
        # (the strategies supply them, not fixtures).
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def settings(*_args, **_kwargs):  # accepted and ignored in the fallback
    return lambda fn: fn


__all__ = ["given", "settings", "st", "FALLBACK_EXAMPLES"]
