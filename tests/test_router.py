"""Multi-replica router + ServeSpec config API tests.

Four layers of guarantee:

* placement policies are pure functions over :class:`ReplicaLoad`
  snapshots — unit-tested on synthetic queue states with no engine;
* the spec API round-trips (``from_json(to_json()) == spec``), rejects
  unknown keys, and rejects every known-bad field combination with an
  error that names the offending spec fields — identically at CLI parse
  time and in the factories;
* a ``round_robin`` fleet is token-identical per request to N standalone
  replicas each fed its own arrival-index subset (fleet == N independent
  singles), including under a fault plan (per-replica injector seeds);
* ``ServeMetrics``/``DisaggMetrics`` merge losslessly: fleet percentiles
  are recomputed from retained samples, counters are summed.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import make_plan, init_params
from repro.inference.router import (POLICIES, ReplicaLoad, Router,
                                    place_least_queue, place_round_robin,
                                    place_ttft_aware, prefill_cost_model)
from repro.inference.scheduler import Request, ServeMetrics, make_trace
from repro.inference.spec import (ReplicaSpec, ServeSpec, SpecError,
                                  build_replica, make_injector)

RS = ReplicaSpec(arch="llama3.2-1b", slots=2, s_max=96)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _copy(reqs):
    return [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                    arrival_s=r.arrival_s) for r in reqs]


# ---------------------------------------------------------------------------
# placement policies on synthetic load snapshots (no engine)
# ---------------------------------------------------------------------------


def _load(queue=0, active=0, slots=2, est_q=0.0, est_a=0.0, q_tokens=0,
          remaining=0):
    return ReplicaLoad(queue_depth=queue, queued_prompt_tokens=q_tokens,
                       active=active, slots=slots, active_remaining=remaining,
                       est_queue_cost=est_q, est_active_cost=est_a)


def test_round_robin_ignores_load():
    loads = [_load(queue=9), _load(), _load()]
    assert [place_round_robin(loads, rr) for rr in range(5)] == \
        [0, 1, 2, 0, 1]


def test_least_queue_counts_queued_and_active():
    # replica 0: 2 queued; replica 1: 1 queued + 2 active; replica 2 idle
    loads = [_load(queue=2), _load(queue=1, active=2), _load()]
    assert place_least_queue(loads, 0) == 2
    # deterministic tie-break: lowest index
    assert place_least_queue([_load(), _load()], 7) == 0


def test_ttft_aware_prefers_cheapest_queue():
    # replica 0 queues one huge prompt, replica 1 queues three tiny ones:
    # least_queue picks 1's count... ttft_aware picks the cheaper queue 1
    loads = [_load(queue=1, est_q=500.0), _load(queue=3, est_q=30.0)]
    assert place_ttft_aware(loads, 0) == 1
    assert place_least_queue(loads, 0) == 0


def test_ttft_aware_counts_active_drain_only_when_saturated():
    # both queues empty; replica 0 has a free slot, replica 1 is saturated
    # with long decodes -> its drain cost counts and 0 wins
    loads = [_load(active=1, slots=2, est_a=100.0),
             _load(active=2, slots=2, est_a=100.0)]
    assert place_ttft_aware(loads, 0) == 0
    # two idle replicas look identical -> queue-depth tie-break -> index 0
    assert place_ttft_aware([_load(), _load()], 3) == 0


def test_prefill_cost_model_monotone_and_tp_aware():
    c1 = prefill_cost_model(RS)
    # below the chip's GEMM tile floor (128) the compute term is flat at
    # tp=1; past it cost is strictly monotone in the prompt
    assert 0.0 < c1(8) == c1(64) <= c1(128) < c1(512) < c1(2048)
    # with tp > 1 the per-layer AR term scales with the raw message, so
    # cost is strictly monotone even under the tile floor
    c8 = prefill_cost_model(RS.replace(tp=8, pods=2))
    assert 0.0 < c8(8) < c8(64) < c8(512)
    # disagg replicas cost prefill at the *prefill* pool's layout
    cd = prefill_cost_model(RS.replace(disagg=True, prefill_tp=8,
                                       prefill_pods=2, decode_tp=1))
    assert cd(64) == pytest.approx(c8(64))


def test_router_constructor_rejects():
    with pytest.raises(ValueError, match="at least one"):
        Router([])
    with pytest.raises(ValueError, match="unknown router policy"):
        Router([object()], policy="fastest")
    class _Coord:
        decode = None
    with pytest.raises(ValueError, match="heterogeneous"):
        Router([object(), _Coord()])


# ---------------------------------------------------------------------------
# ServeSpec: JSON round-trip, unknown keys, combo validation
# ---------------------------------------------------------------------------


ROUND_TRIP_SPECS = [
    ServeSpec(replica=RS),
    ServeSpec(replica=RS.replace(block_size=8, n_blocks=13, kv_quant=False,
                                 admit_mode="chunked", admit_chunk=16)),
    ServeSpec(replica=RS.replace(tp=8, pods=2, ar_strategy="auto",
                                 overlap=True, seq_parallel="auto",
                                 ar_quant="auto")),
    ServeSpec(replica=RS.replace(spec_mode="ngram", spec_k=6,
                                 spec_adaptive=True)),
    ServeSpec(replica=RS.replace(fault_plan="nan_logits=0.1,seed=3",
                                 deadline_ms=12.0)),
    ServeSpec(replica=RS.replace(disagg=True, prefill_tp=2, decode_tp=2,
                                 prefill_block_size=0, block_size=8,
                                 max_ready=3, prefill_per_step=4)),
    ServeSpec(replica=RS, replicas=4, router_policy="ttft_aware"),
    ServeSpec(replica=RS.replace(temperature=1.5, top_k=20, seed=9),
              mode="batch"),
]


@pytest.mark.parametrize("spec", ROUND_TRIP_SPECS,
                         ids=lambda s: f"{s.mode}-r{s.replicas}")
def test_spec_json_round_trip(spec):
    assert ServeSpec.from_json(spec.to_json()) == spec


def test_spec_json_rejects_unknown_keys():
    d = json.loads(ServeSpec(replica=RS).to_json())
    d["replica"]["blok_size"] = 8          # typo'd replica field
    with pytest.raises(SpecError, match="blok_size"):
        ServeSpec.from_json(json.dumps(d))
    d = json.loads(ServeSpec(replica=RS).to_json())
    d["router_polcy"] = "round_robin"      # typo'd deployment field
    with pytest.raises(SpecError, match="router_polcy"):
        ServeSpec.from_json(json.dumps(d))
    with pytest.raises(SpecError, match="replica"):
        ServeSpec.from_json("{}")
    with pytest.raises(SpecError, match="object"):
        ServeSpec.from_json("[1, 2]")


# (replica replace kwargs, mode, fragment the error must name)
BAD_COMBOS = [
    (dict(arch="llama-999t"), "trace", "arch"),
    (dict(ar_strategy="warp"), "trace", "ar_strategy"),
    (dict(seq_parallel="maybe"), "trace", "seq_parallel"),
    (dict(ar_quant="int2"), "trace", "ar_quant"),
    (dict(admit_mode="eager"), "trace", "admit_mode"),
    (dict(spec_mode="psychic"), "trace", "spec_mode"),
    (dict(slots=0), "trace", "slots"),
    (dict(tp=0), "trace", "tp"),
    (dict(tp=6, pods=4), "trace", "divisible"),
    (dict(admit_mode="chunked", admit_chunk=28), "trace", "admit_chunk"),
    (dict(spec_mode="ngram", spec_k=0), "trace", "spec_k"),
    (dict(ar_quant="auto"), "trace", "ar_strategy"),
    (dict(kv_quant=True, admit_mode="chunked"), "trace", "chunked"),
    (dict(kv_quant=True, block_size=8), "trace", "block_size"),
    (dict(kv_quant=True, spec_mode="ngram"), "trace", "spec_mode"),
    (dict(kv_quant=True, disagg=True), "trace", "disagg"),
    (dict(spec_adaptive=True), "batch", "trace-mode only"),
    (dict(fault_plan="oom=0.1"), "batch", "trace-mode only"),
    (dict(deadline_ms=5.0), "batch", "trace-mode only"),
    (dict(disagg=True), "batch", "trace-mode only"),
    (dict(kv_quant=True), "batch", "trace-mode only"),
    (dict(block_size=8, tp=8), "batch", "local-path"),
    (dict(prefix_cache="maybe"), "trace", "prefix_cache"),
    (dict(prefix_cache="on"), "trace", "paged"),
    (dict(prefix_cache="on", block_size=8, kv_quant=True), "trace",
     "prefix_cache is incompatible with kv_quant"),
    (dict(prefix_cache="on", block_size=8, disagg=True), "trace",
     "prefix_cache is incompatible with disagg"),
    (dict(prefix_cache="on", block_size=8, admit_chunk=12), "trace",
     "prefix_cache"),
    (dict(prefix_cache="on", block_size=8, admit_chunk=0), "trace",
     "prefix_cache"),
    (dict(prefix_cache="on", block_size=8, prefix_capacity=0), "trace",
     "prefix_capacity"),
    (dict(prefix_cache="on", block_size=8, arch="hymba-1.5b"), "trace",
     "dense"),
    (dict(prefix_cache="on", block_size=8), "batch", "trace-mode only"),
    (dict(disagg=True, prefill_tp=0), "trace", "prefill_tp"),
    (dict(disagg=True, prefill_tp=6, prefill_pods=4), "trace", "divisible"),
    (dict(disagg=True, decode_tp=6, decode_pods=4), "trace", "divisible"),
    (dict(disagg=True, max_reprefills=-1), "trace", "max_reprefills"),
]


@pytest.mark.parametrize("kw,mode,frag", BAD_COMBOS,
                         ids=[f"{sorted(kw)[0]}-{m}" for kw, m, _ in
                              BAD_COMBOS])
def test_validate_rejects_bad_combos(kw, mode, frag):
    with pytest.raises(SpecError, match=frag):
        RS.replace(**kw).validate(mode=mode)
    # the deployment-level validate rejects identically
    with pytest.raises(SpecError, match=frag):
        ServeSpec(replica=RS.replace(**kw), mode=mode).validate()


def test_deployment_validate_rejects():
    with pytest.raises(SpecError, match="replicas"):
        ServeSpec(replica=RS, replicas=0).validate()
    with pytest.raises(SpecError, match="router_policy"):
        ServeSpec(replica=RS, router_policy="fastest").validate()
    with pytest.raises(SpecError, match="trace-mode only"):
        ServeSpec(replica=RS, replicas=2, mode="batch").validate()


def test_cli_rejects_like_validate():
    """The CLI is a thin shell over ServeSpec.from_args -> validate: a
    bad combo exits with the same field-naming message."""
    from repro.launch.serve import build_parser, main
    base = ["--arch", "llama3.2-1b", "--smoke"]
    for argv, frag in (
            (["--mode", "batch", "--fault-plan", "oom=0.1"],
             "trace-mode only"),
            (["--mode", "trace", "--kv-quant", "--block-size", "8"],
             "block_size"),
            (["--mode", "trace", "--ar-quant", "auto"], "ar_strategy"),
            (["--mode", "trace", "--prefix-cache", "on"], "paged"),
            (["--mode", "trace", "--prefix-cache", "on", "--block-size",
              "8", "--kv-quant"], "prefix_cache"),
            (["--mode", "trace", "--prefix-cache", "on", "--block-size",
              "8", "--arch", "hymba-1.5b"], "dense"),
            (["--mode", "trace", "--admit-mode", "chunked", "--s-max",
              "100", "--admit-chunk", "32"], "admit_chunk")):
        with pytest.raises(SystemExit, match=frag):
            main(base + argv)
    # every parseable combination round-trips through JSON (main asserts
    # this on each invocation; spot-check the parser defaults here)
    ns = build_parser().parse_args(base)
    spec = ServeSpec.from_args(ns)
    assert ServeSpec.from_json(spec.to_json()) == spec


def test_build_replica_validates_first():
    with pytest.raises(SpecError, match="admit_chunk"):
        build_replica(RS.replace(admit_mode="chunked", admit_chunk=28))


def test_make_injector_decorrelates_replicas():
    spec = RS.replace(fault_plan="nan_logits=0.2,seed=3")
    inj0, inj1 = make_injector(spec, 0), make_injector(spec, 1)
    assert inj0.plan.seed == 3
    assert inj1.plan.seed == 3 + 7919
    assert make_injector(RS, 1) is None    # no plan -> no injector


# ---------------------------------------------------------------------------
# fleet == N independent singles (token parity), policies end-to-end
# ---------------------------------------------------------------------------


def _fleet_parity(ap, params, vocab, *, fault_plan=None):
    spec = RS if fault_plan is None else RS.replace(fault_plan=fault_plan)
    reqs = make_trace(8, mean_in=10, mean_out=6, rate=4.0, vocab=vocab,
                      seed=2)
    fleet = Router([build_replica(spec, ap=ap, params=params, replica_id=i)
                    for i in range(2)], policy="round_robin")
    done = fleet.run(_copy(reqs))
    by_arrival = sorted(reqs, key=lambda r: r.arrival_s)
    for i in range(2):
        solo = build_replica(spec, ap=ap, params=params, replica_id=i)
        sub = _copy([r for k, r in enumerate(by_arrival) if k % 2 == i])
        solo_done = {r.rid: r for r in solo.run(sub)}
        routed = [r for r in done if r.rid in solo_done]
        assert len(routed) == len(sub)
        for r in routed:
            s = solo_done[r.rid]
            if s.output is None:
                assert r.output is None and r.shed_reason == s.shed_reason
            else:
                np.testing.assert_array_equal(r.output, s.output)
    return fleet, done


def test_fleet_round_robin_token_parity(tiny_lm):
    cfg, ap, params = tiny_lm
    fleet, done = _fleet_parity(ap, params, cfg.vocab_size)
    assert fleet.placements == [4, 4]
    m = fleet.metrics(done)
    assert m.fleet.completed == 8
    assert m.load_imbalance == 1.0
    assert [p.completed for p in m.per_replica] == [4, 4]
    d = m.to_dict()
    assert d["policy"] == "round_robin" and d["replicas"] == 2


def test_fleet_fault_isolation_parity(tiny_lm):
    """Fleet under a fault plan == standalone replicas with the same
    per-replica derived injectors: one replica's deterministic fault
    schedule never leaks onto another's requests."""
    cfg, ap, params = tiny_lm
    _fleet_parity(ap, params, cfg.vocab_size,
                  fault_plan="nan_logits=0.3,seed=5")


def test_policies_complete_bursty_trace(tiny_lm):
    cfg, ap, params = tiny_lm
    reqs = make_trace(10, mean_in=10, mean_out=6, rate=8.0,
                      vocab=cfg.vocab_size, seed=3)
    for policy in ("least_queue", "ttft_aware"):
        fleet = Router([build_replica(RS, ap=ap, params=params)
                        for _ in range(2)], policy=policy,
                       cost_fn=prefill_cost_model(RS))
        done = fleet.run(_copy(reqs))
        m = fleet.metrics(done)
        assert m.fleet.completed == len(reqs), policy
        assert all(p > 0 for p in fleet.placements), \
            f"{policy}: a replica got no traffic {fleet.placements}"


# ---------------------------------------------------------------------------
# lossless metrics merge
# ---------------------------------------------------------------------------


def test_serve_metrics_merge_lossless(tiny_lm):
    cfg, ap, params = tiny_lm
    parts = []
    for seed in (2, 3):
        sched = build_replica(RS, ap=ap, params=params)
        done = sched.run(make_trace(5, mean_in=10, mean_out=6, rate=3.0,
                                    vocab=cfg.vocab_size, seed=seed))
        parts.append(sched.metrics(done))
    fleet = ServeMetrics.merge(parts)
    ttft = [s for m in parts for s in m.ttft_steps_samples]
    tpot = [s for m in parts for s in m.tpot_steps_samples]
    assert len(ttft) == 10
    assert fleet.completed == sum(m.completed for m in parts) == 10
    assert fleet.total_new_tokens == sum(m.total_new_tokens for m in parts)
    # exact percentiles over the pooled samples — not averaged p99s
    assert fleet.ttft_steps_p99 == pytest.approx(
        float(np.percentile(np.asarray(ttft, np.float64), 99)))
    assert fleet.tpot_steps_p50 == pytest.approx(
        float(np.percentile(np.asarray(tpot, np.float64), 50)))
    # merge keeps the samples, so a merge of merges is still lossless
    again = ServeMetrics.merge([fleet])
    assert again.ttft_steps_p99 == fleet.ttft_steps_p99
    assert sorted(again.ttft_steps_samples) == sorted(ttft)
    # samples never leak into bench JSON rows
    assert "ttft_steps_samples" not in fleet.to_dict()
    with pytest.raises(ValueError):
        ServeMetrics.merge([])
