"""Prefix-cache serving: bitwise parity and cross-slot isolation.

The correctness bar (DESIGN.md §14): with ``prefix_cache="on"`` a
shared-prefix trace must produce **token-bitwise-identical** outputs to
the same trace with the cache off, while actually splicing blocks
(``prefix_tokens_saved > 0`` — a cache that never hits proves nothing).
Isolation is the half that breaks silently: a sharer's truncate /
preempt / speculative rollback / NaN quarantine must never mutate or
free a block another slot (or the trie) still references, which the
copy-on-write paths (`fork_for_write`, exclusive-only scrub) guarantee.
Every test closes by re-checking the allocator partition.
"""
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.inference.faults import FaultInjector, FaultPlan
from repro.inference.scheduler import Request, make_prefix_trace
from repro.inference.spec import ReplicaSpec, build_replica
from repro.inference.speculative import Drafter
from repro.models.transformer import make_plan, init_params

import jax

RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96, block_size=8,
                 admit_mode="chunked", admit_chunk=16)
RP = RS.replace(prefix_cache="on")


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def _trace(cfg, n=10, seed=0, shared_frac=0.7, mean_out=8):
    return make_prefix_trace(n, prefix_len=32, shared_frac=shared_frac,
                             mean_in=12, mean_out=mean_out, rate=2.0,
                             vocab=cfg.vocab_size, seed=seed, clip_len=95)


def _outputs(sched, reqs):
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    return {r.rid: r.output for r in done}, sched.metrics(done)


def _isolated_refs(cfg, ap, params, reqs):
    refs = {}
    for r in reqs:
        s1 = build_replica(RS.replace(slots=1), ap=ap, params=params)
        rr = Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
        s1.run([rr])
        refs[r.rid] = rr.output
    return refs


def test_shared_prefix_trace_bitwise_parity(tiny_lm):
    """The headline guarantee: prefix on == prefix off, token for token,
    with real splicing happening underneath."""
    cfg, ap, params = tiny_lm
    off, m_off = _outputs(build_replica(RS, ap=ap, params=params),
                          _trace(cfg))
    on_sched = build_replica(RP, ap=ap, params=params)
    on, m_on = _outputs(on_sched, _trace(cfg))
    assert m_on.prefix_hits > 0 and m_on.prefix_tokens_saved > 0
    assert m_on.prefix_hit_rate == pytest.approx(
        m_on.prefix_hits / m_on.prefix_lookups)
    assert m_off.prefix_lookups == 0, "off means off"
    for rid in off:
        np.testing.assert_array_equal(off[rid], on[rid])
    on_sched.alloc.check()
    # slots drained, but the trie's holds legitimately outlive the run
    assert on_sched.alloc.used_blocks == on_sched.prefix.held_blocks


def test_full_admit_mode_parity_with_prefix(tiny_lm):
    """prefix_cache="on" forces chunked executables for the spliced
    suffix even under admit_mode="full"; tokens must not change."""
    cfg, ap, params = tiny_lm
    off, _ = _outputs(build_replica(RS.replace(admit_mode="full"),
                                    ap=ap, params=params), _trace(cfg))
    on, m = _outputs(build_replica(RP.replace(admit_mode="full"),
                                   ap=ap, params=params), _trace(cfg))
    assert m.prefix_tokens_saved > 0
    for rid in off:
        np.testing.assert_array_equal(off[rid], on[rid])


def test_tight_pool_preemption_with_prefix(tiny_lm):
    """A pool tight enough to preempt live requests must first reclaim
    cold trie nodes, and recompute preempted work bitwise-exactly even
    when the re-admitted prompt hits the (surviving) cache."""
    cfg, ap, params = tiny_lm
    reqs = _trace(cfg, mean_out=16)
    refs = _isolated_refs(cfg, ap, params, reqs)
    tight = build_replica(RP.replace(n_blocks=15), ap=ap, params=params)
    done = tight.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                              arrival_s=r.arrival_s) for r in reqs])
    m = tight.metrics(done)
    assert m.preemptions > 0, "pool not tight enough — not a test"
    assert m.prefix_hits > 0
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], r.output), r.rid
    tight.alloc.check()


class _JunkDrafter(Drafter):
    """Always-rejected drafts: every verify step writes a divergent K/V
    tail into the drafting slot that rollback must fully retract."""

    def __init__(self, vocab: int):
        super().__init__()
        self.vocab = vocab

    def _propose(self, slot, hist, k):
        last = hist[-1] if hist else 0
        return [(last + 1 + i) % self.vocab for i in range(k)]


def test_spec_rollback_never_leaks_into_sharers(tiny_lm):
    """Adversarial isolation: speculative rollback truncates tails on
    slots whose prompt blocks are shared through the trie.  The rollback
    must drop only the drafting slot's references — sharers' attention
    over the same physical blocks stays bitwise-identical to isolated
    runs."""
    cfg, ap, params = tiny_lm
    reqs = _trace(cfg, seed=1, shared_frac=0.8)
    refs = _isolated_refs(cfg, ap, params, reqs)
    sched = build_replica(RP.replace(spec_mode="replay", spec_k=4),
                          ap=ap, params=params,
                          drafter=_JunkDrafter(cfg.vocab_size))
    done = sched.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                              arrival_s=r.arrival_s) for r in reqs])
    m = sched.metrics(done)
    # near-zero, not zero: (last+1) % vocab can collide with the greedy
    # token by chance — every other verify pass still rolls a tail back
    assert m.acceptance_rate < 0.1, "junk drafts must be almost all rejected"
    assert m.spec_steps > 0 and m.prefix_hits > 0
    for r in done:
        np.testing.assert_array_equal(refs[r.rid], r.output), r.rid
    sched.alloc.check()


def test_poison_forks_shared_blocks_before_writing(tiny_lm):
    """NaN injection targeting a position inside a shared/held block
    must copy-on-write fork it first: the quarantined slot recomputes
    exactly, and the sharers (and later cache hits on the same prefix)
    never observe the poison."""
    cfg, ap, params = tiny_lm
    ref, _ = _outputs(build_replica(RS, ap=ap, params=params),
                      _trace(cfg, shared_frac=0.9))
    inj = FaultInjector(FaultPlan(seed=7, nan_logits=0.08))
    sched = build_replica(RP, ap=ap, params=params, injector=inj)
    got, m = _outputs(sched, _trace(cfg, shared_frac=0.9))
    assert m.quarantines > 0, "no quarantine fired — not a test"
    assert m.prefix_hits > 0
    for rid in ref:
        np.testing.assert_array_equal(ref[rid], got[rid])
    sched.alloc.check()


def test_trie_survives_runs_and_readmission_hits(tiny_lm):
    """The trie persists across `run()` calls (per-run counters reset):
    replaying the same trace must hit on every shared admission and save
    at least as many tokens as the cold run."""
    cfg, ap, params = tiny_lm
    sched = build_replica(RP, ap=ap, params=params)
    cold, m_cold = _outputs(sched, _trace(cfg))
    warm, m_warm = _outputs(sched, _trace(cfg))
    assert m_warm.prefix_hits >= m_cold.prefix_hits
    assert m_warm.prefix_tokens_saved >= m_cold.prefix_tokens_saved
    assert m_warm.prefix_hit_rate >= m_cold.prefix_hit_rate
    for rid in cold:
        np.testing.assert_array_equal(cold[rid], warm[rid])
    sched.alloc.check()


def test_capacity_cap_still_exact(tiny_lm):
    """A one-block capacity forces constant LRU churn; hits may vanish
    but correctness may not."""
    cfg, ap, params = tiny_lm
    off, _ = _outputs(build_replica(RS, ap=ap, params=params), _trace(cfg))
    sched = build_replica(RP.replace(prefix_capacity=1),
                          ap=ap, params=params)
    on, _ = _outputs(sched, _trace(cfg))
    assert sched.prefix.evictions > 0, "capacity never binding"
    # live sharers legitimately pin nodes past the soft cap mid-run;
    # once the slots drain the overflow is evictable again
    sched.prefix.reclaim(max(sched.prefix.held_blocks - 1, 0))
    assert sched.prefix.held_blocks <= 1
    for rid in off:
        np.testing.assert_array_equal(off[rid], on[rid])
    sched.alloc.check()
