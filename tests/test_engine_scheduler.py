"""Inference engine + continuous-batching scheduler behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import make_plan, init_params
from repro.inference.engine import InferenceEngine
from repro.inference.scheduler import ContinuousBatcher, Request, make_trace


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def test_engine_generate_matches_stepwise(tiny_lm):
    cfg, ap, params = tiny_lm
    eng = InferenceEngine(ap, params, s_max=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 12))
    res = eng.generate(prompts, 8)
    assert res.new_tokens.shape == (3, 8)
    assert res.tokens.shape == (3, 20)
    # greedy determinism
    res2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(res.new_tokens, res2.new_tokens)


def test_scheduler_completes_and_matches_engine(tiny_lm):
    cfg, ap, params = tiny_lm
    # one request through the scheduler == plain engine generation
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, 12)
    sched = ContinuousBatcher(ap, params, slots=2, s_max=64)
    reqs = [Request(rid=0, prompt=prompt.astype(np.int32), max_new=6)]
    done = sched.run(reqs)
    eng = InferenceEngine(ap, params, s_max=64)
    res = eng.generate(prompt[None], 6)
    np.testing.assert_array_equal(done[0].output, res.new_tokens[0])


def test_scheduler_trace_no_drops(tiny_lm):
    cfg, ap, params = tiny_lm
    sched = ContinuousBatcher(ap, params, slots=3, s_max=96)
    reqs = make_trace(9, mean_in=10, mean_out=6, rate=4.0,
                      vocab=cfg.vocab_size, seed=2)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    assert all(len(r.output) == r.max_new or len(r.output) > 0
               for r in done)
    # FCFS-ish: first arrival starts no later than last arrival
    assert done[0].first_token_s <= done[-1].first_token_s


def test_scheduler_interleaves_different_lengths(tiny_lm):
    cfg, ap, params = tiny_lm
    sched = ContinuousBatcher(ap, params, slots=2, s_max=96)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               8 + 8 * (i % 2)).astype(np.int32),
                    max_new=3 + 2 * (i % 3), arrival_s=0.0)
            for i in range(5)]
    done = sched.run(reqs)
    for r in done:
        assert r.output is not None and len(r.output) == r.max_new
