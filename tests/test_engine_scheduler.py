"""Inference engine + continuous-batching scheduler behaviour tests,
including the paged KV-cache subsystem (block-table parity vs the dense
layout, allocator invariants, preemption/resume correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import make_plan, init_params
from repro.inference.kv_cache import BlockAllocator, TRASH_BLOCK
from repro.inference.scheduler import Request, make_trace
from repro.inference.spec import ReplicaSpec, build_engine, build_replica

RS = ReplicaSpec(arch="llama3.2-1b", slots=3, s_max=96)


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    return cfg, ap, params


def test_engine_generate_matches_stepwise(tiny_lm):
    cfg, ap, params = tiny_lm
    eng = build_engine(RS.replace(s_max=64), ap=ap, params=params)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 12))
    res = eng.generate(prompts, 8)
    assert res.new_tokens.shape == (3, 8)
    assert res.tokens.shape == (3, 20)
    # greedy determinism
    res2 = eng.generate(prompts, 8)
    np.testing.assert_array_equal(res.new_tokens, res2.new_tokens)


def test_scheduler_completes_and_matches_engine(tiny_lm):
    cfg, ap, params = tiny_lm
    # one request through the scheduler == plain engine generation
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, 12)
    sched = build_replica(RS.replace(slots=2, s_max=64), ap=ap,
                          params=params)
    reqs = [Request(rid=0, prompt=prompt.astype(np.int32), max_new=6)]
    done = sched.run(reqs)
    eng = build_engine(RS.replace(s_max=64), ap=ap, params=params)
    res = eng.generate(prompt[None], 6)
    np.testing.assert_array_equal(done[0].output, res.new_tokens[0])


def test_scheduler_trace_no_drops(tiny_lm):
    cfg, ap, params = tiny_lm
    sched = build_replica(RS, ap=ap, params=params)
    reqs = make_trace(9, mean_in=10, mean_out=6, rate=4.0,
                      vocab=cfg.vocab_size, seed=2)
    done = sched.run(reqs)
    assert all(r.output is not None for r in done)
    assert all(len(r.output) == r.max_new or len(r.output) > 0
               for r in done)
    # FCFS-ish: first arrival starts no later than last arrival
    assert done[0].first_token_s <= done[-1].first_token_s


def test_scheduler_interleaves_different_lengths(tiny_lm):
    cfg, ap, params = tiny_lm
    sched = build_replica(RS.replace(slots=2), ap=ap, params=params)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                               8 + 8 * (i % 2)).astype(np.int32),
                    max_new=3 + 2 * (i % 3), arrival_s=0.0)
            for i in range(5)]
    done = sched.run(reqs)
    for r in done:
        assert r.output is not None and len(r.output) == r.max_new


# ---------------------------------------------------------------------------
# paged KV cache: parity, allocator invariants, preemption
# ---------------------------------------------------------------------------


def _trace_outputs(ap, params, vocab, *, n=8, mean_out=6, rate=4.0,
                   seed=2, **kw):
    sched = build_replica(RS.replace(**kw), ap=ap, params=params)
    reqs = make_trace(n, mean_in=10, mean_out=mean_out, rate=rate,
                      vocab=vocab, seed=seed)
    done = sched.run(reqs)
    metrics = sched.metrics(done)
    assert metrics.completed == len(reqs)
    return {r.rid: r.output for r in done}, metrics


def test_paged_trace_matches_dense(tiny_lm):
    """Block-table cache produces identical greedy tokens to the dense
    layout on a ragged multi-request trace, and a strictly smaller peak
    footprint."""
    cfg, ap, params = tiny_lm
    dense, md = _trace_outputs(ap, params, cfg.vocab_size)
    paged, mp = _trace_outputs(ap, params, cfg.vocab_size, block_size=8)
    for rid in dense:
        np.testing.assert_array_equal(dense[rid], paged[rid])
    assert mp.peak_kv_tokens < md.peak_kv_tokens
    assert mp.cache_stats["preemptions"] == 0


def test_chunked_admission_matches_full(tiny_lm):
    """Chunked-prefill admission (fixed executable) == per-length full
    prefill admission, dense and paged."""
    cfg, ap, params = tiny_lm
    full, _ = _trace_outputs(ap, params, cfg.vocab_size)
    for bs in (0, 8):
        chunked, _ = _trace_outputs(ap, params, cfg.vocab_size,
                                    block_size=bs, admit_mode="chunked",
                                    admit_chunk=16)
        for rid in full:
            np.testing.assert_array_equal(full[rid], chunked[rid])


def test_chunked_admission_pad_to_capacity(tiny_lm):
    """A prompt whose padded chunk tail reaches the logical capacity must
    not corrupt live K/V (pads route to the trash block on the paged
    path), and invalid s_max/admit_chunk geometry is rejected."""
    cfg, ap, params = tiny_lm
    prompt = np.random.default_rng(9).integers(
        0, cfg.vocab_size, 79).astype(np.int32)  # pads to 96 == s_max

    def run(**kw):
        sched = build_replica(RS.replace(slots=2, **kw), ap=ap,
                              params=params)
        r = Request(rid=0, prompt=prompt, max_new=6)
        sched.run([r])
        return r.output

    ref = run()
    for kw in (dict(admit_mode="chunked", admit_chunk=32),
               dict(admit_mode="chunked", admit_chunk=32, block_size=16),
               dict(admit_mode="chunked", admit_chunk=16, block_size=8)):
        np.testing.assert_array_equal(ref, run(**kw))
    with pytest.raises(ValueError):
        build_replica(RS.replace(slots=2, s_max=80, admit_mode="chunked",
                                 admit_chunk=32), ap=ap, params=params)


def test_engine_paged_generate_matches_dense(tiny_lm):
    cfg, ap, params = tiny_lm
    prompts = np.random.default_rng(4).integers(0, cfg.vocab_size, (3, 12))
    res_d = build_engine(RS.replace(s_max=64), ap=ap,
                         params=params).generate(prompts, 8)
    res_p = build_engine(RS.replace(s_max=64, block_size=16), ap=ap,
                         params=params).generate(prompts, 8)
    np.testing.assert_array_equal(res_d.new_tokens, res_p.new_tokens)


def test_block_allocator_invariants():
    a = BlockAllocator(n_blocks=9, block_size=4, slots=3,
                       max_blocks_per_slot=4)
    assert a.ensure(0, 5)          # 2 blocks
    assert a.ensure(1, 9)          # 3 blocks
    a.check()
    assert a.used_blocks == 5 and a.free_blocks == 3
    # growth must be atomic: failing ensure leaves state untouched
    assert not a.ensure(2, 16)     # needs 4, only 3 free
    a.check()
    assert a.used_blocks == 5
    assert a.ensure(2, 9)
    assert a.free_blocks == 0
    # idempotent ensure (already covered)
    assert a.ensure(0, 5) and a.used_blocks == 8
    # free -> blocks come back, table row reverts to trash
    freed = a.free(1)
    assert freed == 3 and a.free_blocks == 3
    assert (a.table[1] == TRASH_BLOCK).all()
    a.check()
    # freed blocks are reused
    assert a.ensure(0, 16)
    a.check()
    st = a.stats()
    assert st.peak_used_blocks == 8
    assert st.used_blocks == 7   # slot0 grew 2->4, slot1's 3 were freed
    with pytest.raises(ValueError):
        a.ensure(2, 17)            # > max_blocks_per_slot capacity
    with pytest.raises(ValueError):
        BlockAllocator(n_blocks=4, block_size=4, slots=2,
                       max_blocks_per_slot=4)  # cannot hold one request


def test_block_allocator_defragment_preserves_logical_view():
    a = BlockAllocator(n_blocks=12, block_size=2, slots=3,
                       max_blocks_per_slot=4)
    rng = np.random.default_rng(0)
    phys = rng.standard_normal((12, 2))
    a.ensure(0, 6)
    a.ensure(1, 8)
    a.ensure(2, 4)
    a.free(1)                       # punch a hole -> fragmentation
    a.ensure(2, 8)                  # reuses freed blocks out of order
    a.check()
    def logical(slot, n):
        return np.concatenate([phys[b] for b in a.table[slot][:n]])
    before = {0: logical(0, 3), 2: logical(2, 4)}
    perm = a.defragment()
    a.check()
    assert perm is not None
    phys = phys[perm]
    # live blocks are now packed at the lowest indices
    live = sorted(b for own in (a.owned(0), a.owned(2)) for b in own)
    assert live == list(range(1, len(live) + 1))
    np.testing.assert_array_equal(before[0], logical(0, 3))
    np.testing.assert_array_equal(before[2], logical(2, 4))
    # a second defrag is a no-op
    assert a.defragment() is None


def test_preemption_resume_correctness(tiny_lm):
    """A pool too small for three concurrent long decodes must preempt,
    requeue, recompute — and still emit exactly the undisturbed tokens."""
    cfg, ap, params = tiny_lm
    rng = np.random.default_rng(5)
    protos = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                 16).astype(np.int32),
                      max_new=40, arrival_s=0.0) for i in range(3)]
    eng = build_engine(RS, ap=ap, params=params)
    ref = {r.rid: eng.generate(r.prompt[None], r.max_new).new_tokens[0]
           for r in protos}
    sched = build_replica(RS.replace(block_size=8, n_blocks=13), ap=ap,
                          params=params)
    done = sched.run([Request(rid=r.rid, prompt=r.prompt,
                              max_new=r.max_new) for r in protos])
    m = sched.metrics(done)
    assert m.preemptions > 0
    assert sum(r.preempted for r in done) == m.preemptions
    for r in done:
        np.testing.assert_array_equal(ref[r.rid], r.output)
    sched.alloc.check()
    assert sched.alloc.used_blocks == 0  # everything released at drain


def test_scheduler_defragment_mid_run(tiny_lm):
    """Defragmenting the live pool between steps must not change tokens."""
    cfg, ap, params = tiny_lm

    def defrag_every_step(sched):
        inner = sched.step
        def step(now):
            sched.defragment()
            inner(now)
        sched.step = step
        return sched

    # two trace shapes -> two fragmentation patterns under defrag
    for trace_kw in (dict(), dict(n=6, mean_out=8, rate=3.0, seed=6)):
        ref, _ = _trace_outputs(ap, params, cfg.vocab_size, **trace_kw)
        sched = defrag_every_step(build_replica(
            RS.replace(block_size=8), ap=ap, params=params))
        reqs = make_trace(trace_kw.get("n", 8), mean_in=10,
                          mean_out=trace_kw.get("mean_out", 6),
                          rate=trace_kw.get("rate", 4.0),
                          vocab=cfg.vocab_size,
                          seed=trace_kw.get("seed", 2))
        done = sched.run(reqs)
        for r in done:
            np.testing.assert_array_equal(ref[r.rid], r.output)
        assert sched.alloc.defrags > 0


def test_sampled_serving(tiny_lm):
    """temperature/top_k are honored on-device: deterministic under a seed,
    different across seeds, and max_new=1 returns exactly one token."""
    cfg, ap, params = tiny_lm

    def run(seed):
        sched = build_replica(RS.replace(slots=2, temperature=1.5,
                                         top_k=20, seed=seed),
                              ap=ap, params=params)
        reqs = [Request(rid=i, prompt=np.arange(8, dtype=np.int32) + i,
                        max_new=(1 if i == 0 else 12), arrival_s=0.0)
                for i in range(3)]
        return {r.rid: r.output for r in sched.run(reqs)}

    a1, a2, b = run(0), run(0), run(1)
    assert len(a1[0]) == 1
    for rid in a1:
        np.testing.assert_array_equal(a1[rid], a2[rid])
    assert any(not np.array_equal(a1[rid], b[rid]) for rid in a1), \
        "different seeds should sample different continuations"


def test_trace_metrics_sane(tiny_lm):
    cfg, ap, params = tiny_lm
    sched = build_replica(RS.replace(block_size=8), ap=ap, params=params)
    reqs = make_trace(8, mean_in=10, mean_out=6, rate=4.0,
                      vocab=cfg.vocab_size, seed=2)
    done = sched.run(reqs)
    m = sched.metrics(done)
    assert m.completed == 8 and m.total_new_tokens > 0
    assert m.ttft_steps_p50 >= 1.0
    assert m.ttft_steps_p99 >= m.ttft_steps_p50
    assert 0.9 <= m.tpot_steps_p50  # ~1 step/token when never starved
    assert m.throughput_tok_s > 0 and m.wall_s > 0
    assert 0.0 < m.cache_utilization <= 1.0
    assert m.peak_kv_tokens <= m.kv_capacity_tokens
    d = m.to_dict()
    assert d["cache_stats"]["block_size"] == 8
