"""Multi-device (8 simulated host devices) system tests, run in
subprocesses so the main pytest process keeps its single-device view."""
import pytest


@pytest.mark.dist
def test_collective_strategies(dist_runner):
    out = dist_runner("case_collectives.py")
    assert "collectives OK" in out


@pytest.mark.dist
def test_overlap_autotune(dist_runner):
    out = dist_runner("case_overlap_autotune.py")
    assert "overlap+autotune OK" in out


@pytest.mark.dist
def test_decode_parity(dist_runner):
    out = dist_runner("case_decode_parity.py")
    assert "decode parity OK" in out


@pytest.mark.dist
def test_serve_trace_parity(dist_runner):
    out = dist_runner("case_serve.py")
    assert "serve OK" in out


@pytest.mark.dist
def test_prefix(dist_runner):
    out = dist_runner("case_prefix.py")
    assert "prefix OK" in out


@pytest.mark.dist
def test_spec_decode_parity(dist_runner):
    out = dist_runner("case_spec.py")
    assert "spec OK" in out


@pytest.mark.dist
def test_disagg_mesh_parity(dist_runner):
    out = dist_runner("case_disagg.py")
    assert "disagg OK" in out


@pytest.mark.dist
def test_sp_prefill_parity(dist_runner):
    out = dist_runner("case_sp_prefill.py")
    assert "sp prefill OK" in out


@pytest.mark.dist
def test_train_parity(dist_runner):
    out = dist_runner("case_train_parity.py")
    assert "train parity OK" in out


@pytest.mark.dist
def test_elastic_restart(dist_runner):
    out = dist_runner("case_elastic.py")
    assert "elastic OK" in out


@pytest.mark.dist
def test_faults_injected(dist_runner):
    out = dist_runner("case_faults.py")
    assert "faults OK" in out


@pytest.mark.dist
def test_quant_allreduce(dist_runner):
    out = dist_runner("case_quant_ar.py")
    assert "quant_ar OK" in out


@pytest.mark.dist
def test_router_fleet(dist_runner):
    out = dist_runner("case_router.py")
    assert "router OK" in out
