"""Test configuration.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
single real CPU device.  Multi-device tests run in subprocesses (see
tests/dist_cases/) with --xla_force_host_platform_device_count set there.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "dist: multi-device subprocess tests (8 host devices)")


def run_dist_case(script_name: str, n_devices: int = 8,
                  timeout: int = 900) -> str:
    """Run a tests/dist_cases/<script> in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    path = os.path.join(REPO, "tests", "dist_cases", script_name)
    proc = subprocess.run([sys.executable, path], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"{script_name} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def dist_runner():
    return run_dist_case
