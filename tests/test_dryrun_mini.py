"""Mini-mesh dry-run: the full cell-building machinery (input_specs,
builders, shardings) compiles representative cells on an 8-device
(2,2,2) pod/data/model mesh — the in-suite proxy for the 512-chip sweep
recorded in EXPERIMENTS.md §Dry-run."""
import pytest


CASES = [
    ("llama3.2-1b", "train_4k", {}),
    ("llama3.2-1b", "decode_32k", {}),
    ("qwen3-moe-30b-a3b", "decode_32k", {}),
    ("rwkv6-7b", "long_500k", {}),
    ("llama3.2-1b", "decode_32k",
     {"cross": True, "strategy": "hier_rd"}),
]


@pytest.mark.dist
@pytest.mark.parametrize("arch,shape,opt", CASES,
                         ids=[f"{a}-{s}{'-x' if o else ''}"
                              for a, s, o in CASES])
def test_mini_dryrun_cell(dist_runner, arch, shape, opt):
    script = f"""
import jax
from repro.core.compat import AxisType, make_mesh
from repro.launch.input_specs import build_cell
from repro.launch.hlo_analysis import summarize_compiled
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)
cell = build_cell({arch!r}, {shape!r}, mesh,
                  ar_strategy={opt.get("strategy", "flat")!r},
                  cross_pod_tp={opt.get("cross", False)!r})
lowered = cell.lower()
compiled = lowered.compile()
s = summarize_compiled(compiled, mesh, lowered=lowered)
assert s["flops"] > 0
print("MINI-DRYRUN-OK", s["dcn_bytes"], s["ici_bytes"])
"""
    import os, subprocess, sys
    from tests.conftest import SRC
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MINI-DRYRUN-OK" in proc.stdout
