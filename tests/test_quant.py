"""Unit tests for the serving-quantization module (int8 weights)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.transformer import make_plan, init_params
from repro.parallel.quant import quantize_params, quantize_blocks, dequant_layer


@pytest.fixture(scope="module")
def params():
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    return init_params(jax.random.PRNGKey(0), ap)


def test_quantize_roundtrip_error_bound(params):
    q = quantize_params(params)
    deq = dequant_layer(q["blocks"])
    for (path, a), b in zip(
            jax.tree_util.tree_flatten_with_path(params["blocks"])[0],
            jax.tree.leaves(deq)):
        af = np.asarray(a, np.float32)
        bf = np.asarray(b, np.float32)
        if af.shape != bf.shape:
            continue
        # per-channel absmax/127 error bound (+ bf16 scale rounding slack)
        bound = np.abs(af).max() / 127.0 * 1.1 + 1e-6
        assert np.abs(af - bf).max() <= bound, path


def test_quantize_skips_small_leaves(params):
    q = quantize_blocks(params["blocks"])
    # norms stay bf16 leaves, matrices become {'q','s'}
    assert not isinstance(q["ln1"]["w"], dict)
    assert set(q["attn"]["wq"]) == {"q", "s"}
    assert q["attn"]["wq"]["q"].dtype == jnp.int8
    # embed/head untouched by quantize_params
    qp = quantize_params(params)
    assert qp["embed"]["tok"].dtype == params["embed"]["tok"].dtype


def test_quantized_tree_eval_shape_stable(params):
    """input_specs relies on eval_shape(quantize_params) being allocation-
    free and structure-stable."""
    t = jax.eval_shape(quantize_params, params)
    q = quantize_params(params)
    assert jax.tree.structure(t) == jax.tree.structure(q)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(q)):
        assert a.shape == b.shape and a.dtype == b.dtype
