"""Property tests for the quantized-collective pack/unpack layer
(kernels.rd_allreduce.quant) and its error-feedback contract — the
single-device half of the ar_quant test matrix (device-exact collective
behavior lives in tests/dist_cases/case_quant_ar.py)."""
import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pure-pytest fallback (requirements-dev.txt)
    from _hypothesis_fallback import given, settings, st

from repro.kernels.rd_allreduce import quant as q


def _roundtrip(x, bits, group):
    packed, scales = q.quantize_pack(jnp.asarray(x), bits, group)
    return np.asarray(q.unpack_dequant(packed, scales, bits, group),
                      np.float32), np.asarray(scales, np.float32)


@given(st.sampled_from([8, 4]), st.sampled_from([64, 128, 256, 384]),
       st.integers(0, 50))
@settings(max_examples=60, deadline=None)
def test_roundtrip_error_bound(bits, d, seed):
    """|x - deq(Q(x))| <= step/2 + the bf16-scale storage error.

    The exact bound: with f32 scale s and stored bf16 scale s_b, the error
    is at most 0.51*s (rounding) + qmax*|s - s_b| (scale storage) per
    element of the group.
    """
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((3, d)) * rng.uniform(1e-3, 1e3)).astype(
        np.float32)
    group = q.group_for(d, bits)
    out, s_b = _roundtrip(x, bits, group)
    g = x.reshape(3, d // group, group)
    s_f = np.maximum(np.abs(g).max(-1) / q.QMAX[bits], 1e-30)
    bound = 0.51 * s_f + q.QMAX[bits] * np.abs(s_f - s_b)
    err = np.abs(out.reshape(g.shape) - g)
    assert np.all(err <= bound[..., None] + 1e-12), \
        (bits, d, err.max(), bound.min())


@given(st.sampled_from([8, 4]), st.integers(-6, 6), st.integers(0, 20))
@settings(max_examples=40, deadline=None)
def test_scale_invariance_power_of_two(bits, e, seed):
    """Scaling the input by 2^e scales the round-trip output by exactly
    2^e: pow2 factors move only the (exactly-representable) exponent of
    the bf16 scale, so the int payload is bit-identical."""
    rng = np.random.default_rng(seed)
    d = 128
    x = rng.standard_normal((2, d)).astype(np.float32)
    group = q.group_for(d, bits)
    p1, s1 = q.quantize_pack(jnp.asarray(x), bits, group)
    p2, s2 = q.quantize_pack(jnp.asarray(x * 2.0 ** e), bits, group)
    assert np.array_equal(np.asarray(p1), np.asarray(p2)), (bits, e)
    out1, _ = _roundtrip(x, bits, group)
    out2, _ = _roundtrip(x * 2.0 ** e, bits, group)
    np.testing.assert_array_equal(out2, out1 * 2.0 ** e)


def test_int4_saturation_safe():
    """A huge outlier sets the scale; every other value quantizes toward
    zero but nothing wraps: all decoded magnitudes stay <= qmax*scale and
    the outlier itself is reproduced to within half a step."""
    x = np.ones((1, 64), np.float32)
    x[0, 7] = 1000.0
    out, s = _roundtrip(x, 4, 64)
    assert np.all(np.abs(out) <= 7 * s.max() * 1.01)
    assert abs(out[0, 7] - 1000.0) <= s.max()          # outlier survives
    assert np.all(out[0, :7] >= 0.0)                   # no sign wraparound
    # exact grid points round-trip exactly (scale is a power of two here)
    grid = (np.arange(-7, 8, dtype=np.float32) * 0.5)[None, :]
    grid = np.pad(grid, ((0, 0), (0, 1)))              # int4 needs even D
    out_g, _ = _roundtrip(grid, 4, q.group_for(16, 4))
    np.testing.assert_allclose(out_g, grid, atol=2e-3)


@given(st.sampled_from([3, 5, 7, 9, 21, 129]), st.integers(0, 20))
@settings(max_examples=30, deadline=None)
def test_odd_length_tail_group1(d, seed):
    """Odd trailing dims degrade to group=1 (per-element scales): still a
    valid layout for int8 and exact up to bf16 scale storage."""
    assert q.group_for(d, 8) == 1
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((2, d)).astype(np.float32)
    out, s_b = _roundtrip(x, 8, 1)
    bound = 0.51 * np.abs(x) / 127 + 127 * np.abs(
        np.abs(x) / 127 - s_b.reshape(x.shape))
    assert np.all(np.abs(out - x) <= bound + 1e-9)


def test_group_for_divides_and_caps():
    for d in (1, 2, 6, 48, 64, 96, 128, 384, 1024, 4096):
        for bits in (8, 4):
            g = q.group_for(d, bits)
            assert d % g == 0 and g <= q.GROUP_CAP[bits]
            assert g & (g - 1) == 0                    # power of two


def test_nan_inf_poison_exactly_their_group():
    """A non-finite value poisons its OWN group's scale (so dequant is
    non-finite there and the serving quarantine fires) and leaves every
    other group bit-exact — no masking, no silent laundering."""
    d, group = 256, 128
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, d)).astype(np.float32)
    for bad in (np.nan, np.inf, -np.inf):
        xb = x.copy()
        xb[0, 3] = bad                                  # group 0
        out, _ = _roundtrip(xb, 8, group)
        assert not np.isfinite(out[0, :group]).all(), bad
        clean, _ = _roundtrip(x, 8, group)
        np.testing.assert_array_equal(out[0, group:], clean[0, group:])


def test_error_feedback_drains_on_constant_input():
    """The EF recurrence e' = (v+e) - deq(Q(v+e)) on a CONSTANT message:
    the residual stays bounded by one quantization step and the running
    mean of the emitted values converges to the true value — the property
    that makes int4 decode usable (DESIGN.md §12)."""
    d, bits = 128, 4
    group = q.group_for(d, bits)
    rng = np.random.default_rng(1)
    v = rng.standard_normal((1, d)).astype(np.float32)
    e = np.zeros_like(v)
    emitted = []
    step = np.abs(v).max() / q.QMAX[bits]
    for _ in range(64):
        msg = v + e
        out, _ = _roundtrip(msg, bits, group)
        e = msg - out
        emitted.append(out)
        assert np.abs(e).max() <= 1.1 * step            # never accumulates
    mean = np.mean(emitted, axis=0)
    assert np.abs(mean - v).max() <= 0.05 * step + 0.02 * np.abs(v).max()


def test_overlap_chunk_alignment_predicate():
    """_quant_chunk_ok gates the chunked overlapped matmul: chunking is
    taken only when both the full output dim and the per-chunk step are
    multiples of group_cap * n_scatter (identical absolute feature windows
    chunked or not -> bitwise chunk-invariance)."""
    from repro.core.overlap import _quant_chunk_ok
    assert _quant_chunk_ok(1024, 4, 2, 8)       # 1024 % 256, 256 % 256
    assert not _quant_chunk_ok(960, 4, 2, 8)    # 960 % 256 != 0
    assert not _quant_chunk_ok(1024, 8, 2, 8)   # step 128 % 256 != 0
    assert _quant_chunk_ok(512, 4, 2, 4)        # int4 cap 64: 128-aligned
    assert not _quant_chunk_ok(512, 4, 8, 8)    # cap*8=1024 > 512


def test_seed_cache_quantized_splice():
    """Admitting a prefilled request into an int8 KV cache must quantize
    the fp states (payload + per-(pos, head) scales), not raw-cast them:
    the spliced rows dequantize back to the states within one step, and
    other slots stay untouched."""
    import jax
    from repro.models import ModelConfig, make_plan, init_params, \
        init_cache, seed_cache

    cfg = ModelConfig(name="kv8", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=64, dtype=jnp.float32)
    ap = make_plan(cfg, 1)
    cache = init_cache(ap, 3, 32, local=True, kv_quant=True)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    rng = np.random.default_rng(0)
    S = 5
    u, hd = cache["k"].shape[3], cache["k"].shape[4]
    states = {nm: jnp.asarray(rng.standard_normal((cfg.n_layers, 1, S,
                                                   u, hd)), jnp.float32)
              for nm in ("k", "v")}
    out = seed_cache(cache, states, slot=1)
    for nm in ("k", "v"):
        deq = (np.asarray(out[nm][:, 1, :S], np.float32)
               * np.asarray(out[nm + "_scale"][:, 1, :S],
                            np.float32)[..., None])
        ref = np.asarray(states[nm][:, 0])
        # half-step rounding + bf16 scale storage (127 * s * 2^-9 ~ 0.25s)
        step = np.abs(ref).max(-1, keepdims=True) / 127.0
        assert np.all(np.abs(deq - ref) <= 0.8 * step + 1e-6), nm
        assert np.asarray(out[nm][:, 0]).max() == 0   # other slots clean
        assert np.asarray(out[nm][:, 2]).max() == 0
