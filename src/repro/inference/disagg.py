"""Disaggregated prefill/decode serving: two pools, two operating points.

Prefill and decode stress the network in opposite ways (paper Sec. 3.5 and
the communication characterizations in PAPERS.md): prefill is compute-bound
with *large* per-layer all-reduce messages (prompt-length x d_model), while
decode is latency-bound on *small* per-token all-reduces — exactly the
128 KB-2 MB regime where the paper's strategy choice (hierarchical RD vs
ring) matters most.  A colocated deployment forces one mesh layout and one
``ar_table`` operating point onto both phases; this module splits them:

* a :class:`PrefillPool` runs prompt prefills only (its own ``tp``/pods
  mesh, its own AR dispatch table) and emits each finished context as a
  layout-neutral :class:`~repro.inference.kv_cache.KVBundle` plus the
  already-sampled first token;
* a decode-side :class:`~repro.inference.scheduler.ContinuousBatcher`
  (again its own mesh + table) imports bundles via
  ``ContinuousBatcher.admit_prefilled`` — resharding between the pools'
  GQA slot layouts happens in the bundle pack/unpack
  (``kv_cache.slots_to_heads`` / ``heads_to_slots``), so the pools' TP
  degrees are fully independent;
* the :class:`DisaggCoordinator` is the router in between: it admits
  arrivals to the prefill pool, moves completed contexts (KV bundle +
  first token + position state) across the handoff queue into free decode
  slots, routes decode-pool preemptions *back* to the prefill pool for
  recompute, and tracks queue depths / transfer bytes / per-pool AR
  message-size buckets.

Correctness bar (enforced by tests/test_disagg.py and
benchmarks/bench_disagg.py): a disaggregated greedy trace is **bitwise
equal** to the colocated paged serve of the same trace, including with
speculative decoding enabled on the decode pool — a slot's greedy tokens
depend only on its own prompt and KV, and the handoff round-trips KV
without dtype conversion.  Sampled (temperature > 0) plain-decode traces
are **token-identical** too: every request samples from its own stateless
key chain (``scheduler.request_sampling_key``), whose base key travels
with the context in ``KVBundle.rng`` (PR 5 closed the per-pool-RNG gap).

Scheduling model: the coordinator shares the batcher's logical step clock
(1.0 per tick).  Each tick the prefill pool processes up to
``prefill_per_step`` queued prompts, the handoff queue drains into free
decode slots, and the decode pool runs one (plain or spec-verify) step.
TTFT is attributed to the prefill pool + transfer wait; TPOT to the
decode pool (DESIGN.md §9).

Known gaps: dense (attention-only) families only — recurrent state
handoff is not implemented (same restriction as chunked prefill / spec
decode); *speculative* sampled streams still draw their accept/resample
randomness from the step-level rng, so spec + temperature > 0 is
seed-deterministic but not colocated-identical (plain sampled decode is);
the handoff moves bundles through host memory (one device round-trip),
standing in for a NIC/ICI transport.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import autotune
from ..core.pcontext import ParallelCtx, LOCAL
from ..parallel.steps import (build_admit_chunk_step, build_cache_init,
                              build_prefill_only_step)
from .faults import FaultInjector
from .kv_cache import (BundleIntegrityError, KVBundle, export_slot,
                       slots_to_heads)
from .scheduler import (ContinuousBatcher, Request, _percentile,
                        request_sampling_key, run_chunked_prefill)


def pool_tuner(ar_table) -> autotune.AutoTuner:
    """Resolve a pool-private dispatch table: an AutoTuner instance or a
    path resolves via :func:`autotune.tuner_for`; None seeds a fresh
    analytic table instead of sharing the process-wide one.  Each pool
    owning its tuner is what makes per-pool AR dispatch observable (the
    tuner records the message-size buckets its pool keyed on) — which is
    also why a missing table path is an error here rather than the
    colocated builders' silent fallback: falling back to the shared
    process-wide tuner would merge both pools' lookup logs."""
    if isinstance(ar_table, str) and not os.path.exists(ar_table):
        raise FileNotFoundError(f"pool ar_table not found: {ar_table!r}")
    if ar_table is not None:
        return autotune.tuner_for(ar_table)
    base = autotune.active()
    return autotune.AutoTuner(base.net, allow_lossy=base.allow_lossy)


class PrefillPool:
    """Prefill-only serving pool: prompt in, (first token, KVBundle) out.

    ``admit_mode="full"`` runs one ``build_prefill_only_step`` executable
    per distinct prompt length and packs the bundle straight from the
    returned states; ``"chunked"`` feeds the prompt through the fixed-size
    chunked-prefill executables into a private 1-slot cache (recompile-
    free; ``block_size`` > 0 exercises the paged write path) and exports
    from the cache.  Both paths produce identical bundles.
    """

    def __init__(self, ap, params, *, s_max: int, ctx: ParallelCtx = LOCAL,
                 mesh=None, ar_table=None, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, scan_layers: bool = True,
                 fsdp_serve: bool = False, admit_mode: str = "full",
                 admit_chunk: int = 32, block_size: int = 0):
        self.ap, self.cfg, self.params = ap, ap.cfg, params
        if self.cfg.family != "dense":
            raise ValueError("disaggregated serving supports attention-"
                             f"only dense families, not "
                             f"{self.cfg.family!r}")
        if admit_mode not in ("full", "chunked"):
            raise ValueError(f"unknown admit_mode {admit_mode!r}")
        if admit_mode == "chunked" and s_max % admit_chunk:
            raise ValueError(f"s_max={s_max} must be a multiple of "
                             f"admit_chunk={admit_chunk}")
        self.s_max = s_max
        self.ctx = ctx
        self.mesh = mesh
        self.temperature = temperature
        self.top_k = top_k
        self.admit_mode = admit_mode
        self.admit_chunk = admit_chunk
        self.block_size = block_size
        self.tuner = pool_tuner(ar_table)
        self.seed = seed
        self._rng = jax.random.PRNGKey(seed)
        self._step_kw = dict(scan_layers=scan_layers,
                             fsdp_serve=fsdp_serve,
                             temperature=temperature, top_k=top_k,
                             ar_table=self.tuner)
        self._full_fns: Dict[int, Any] = {}    # prompt_len -> jitted fn
        self.cache = None
        if admit_mode == "chunked":
            # private 1-slot cache; n_blocks=None -> identity block table
            # at full capacity, so no allocator is needed (one request at
            # a time, overwritten in place)
            geo = dict(slots=1, s_max=s_max, block_size=block_size,
                       n_blocks=None, fsdp_serve=fsdp_serve)
            self.cache = build_cache_init(ap, ctx, mesh, **geo).jit()()
            kw = dict(self._step_kw)
            kw.update(slots=1, s_max=s_max, block_size=block_size,
                      n_blocks=None)
            self._chunk_final = build_admit_chunk_step(
                ap, ctx, mesh, chunk=admit_chunk, **kw).jit()
            self._chunk_mid = build_admit_chunk_step(
                ap, ctx, mesh, chunk=admit_chunk, sample=False, **kw).jit()
            if block_size > 0:
                self._table_row = 1 + np.arange(s_max // block_size,
                                                dtype=np.int32)
        # trace-scoped stats
        self.prefills = 0
        self.prompt_tokens = 0
        self.wall_s = 0.0
        self.analytic_buckets: set = set()

    def _full_fn(self, prompt_len: int):
        fn = self._full_fns.get(prompt_len)
        if fn is None:
            fn = build_prefill_only_step(self.ap, self.ctx, self.mesh,
                                         prompt_len=prompt_len,
                                         **self._step_kw).jit()
            self._full_fns[prompt_len] = fn
        return fn

    def prefill(self, req: Request) -> Tuple[int, KVBundle]:
        """Run one request's prompt; return (first token, KV bundle)."""
        S = int(req.prompt.shape[0])
        if S + 1 > self.s_max:
            raise ValueError(f"prompt len {S} + 1 exceeds s_max="
                             f"{self.s_max}")
        t0 = time.perf_counter()
        kv_map = self.ap.gqa.kv_map
        # the request's sampling chain: first token is fold_in(base, 0);
        # the base key rides the bundle so the decode pool continues the
        # exact chain (sampled disagg == colocated, token for token)
        base = request_sampling_key(self.seed, req.rid)
        first = jax.random.fold_in(base, 0)
        if self.admit_mode == "full":
            tok, k, v = self._full_fn(S)(
                self.params, jnp.asarray(req.prompt[None]), first)
            bundle = KVBundle(k=slots_to_heads(np.asarray(k)[:, 0], kv_map),
                              v=slots_to_heads(np.asarray(v)[:, 0], kv_map))
        else:
            tok, self.cache = run_chunked_prefill(
                self.params, self.cache, req.prompt, 0, self.admit_chunk,
                self._chunk_mid, self._chunk_final, self._rng, first)
            row = self._table_row[:] if self.block_size > 0 else None
            bundle = export_slot(self.cache, 0, S, kv_map, table_row=row)
        # seal: the checksum rides the handoff so splice-time verification
        # catches in-flight corruption (admit_prefilled calls verify())
        bundle.rng = np.asarray(base, np.uint32)
        bundle.seal()
        self.prefills += 1
        self.prompt_tokens += S
        self.wall_s += time.perf_counter() - t0
        # the per-layer AR message of this prefill: (1, S, D) for the
        # full-prompt pass, (1, admit_chunk, D) per chunk on the chunked
        # path (pads included — chunks are fixed-size)
        msg_tokens = S if self.admit_mode == "full" else self.admit_chunk
        itemsize = jnp.dtype(self.cfg.dtype).itemsize
        self.analytic_buckets.add(
            autotune.bucket_of(msg_tokens * self.cfg.d_model * itemsize))
        return int(np.asarray(tok)[0]), bundle

    def reset_stats(self) -> None:
        self.prefills = 0
        self.prompt_tokens = 0
        self.wall_s = 0.0
        self.analytic_buckets = set()

    def stats(self) -> Dict[str, Any]:
        return {
            "prefills": self.prefills,
            "prompt_tokens": self.prompt_tokens,
            "wall_s": self.wall_s,
            "mean_prompt_len": self.prompt_tokens / self.prefills
            if self.prefills else 0.0,
            "ar_buckets_analytic": sorted(self.analytic_buckets),
            "ar_buckets_dispatched": self.tuner.lookup_buckets(),
        }


@dataclasses.dataclass
class _Handoff:
    """One prefilled context in the handoff queue, with transfer-retry
    state: ``attempts`` counts failed transfer attempts of *this* bundle
    (the retry cap), ``next_try`` is the backoff horizon (logical steps),
    ``prefill_no`` identifies which prefill of the request produced the
    payload (corruption is a property of the payload, so it is keyed
    here — a corrupt bundle stays corrupt across retries)."""
    req: Request
    tok: int
    bundle: KVBundle
    prefill_no: int
    attempts: int = 0
    next_try: float = 0.0


@dataclasses.dataclass
class DisaggMetrics:
    """Disaggregated trace-replay metrics with per-pool attribution.

    TTFT decomposes into the prefill-pool component (queueing wait +
    prefill tick) and the transfer component (handoff-queue wait until a
    decode slot took the bundle); TPOT is purely the decode pool's
    cadence.  ``*_ar_bucket`` report each pool's all-reduce operating
    point as the max log2 message-size bucket it keyed (observed tuner
    lookups on a mesh with ``ar_strategy="auto"``; the analytic bucket of
    the pool's per-layer message otherwise) — the disaggregation payoff is
    ``prefill_ar_bucket > decode_ar_bucket``: each pool's table serves a
    different regime of the paper's strategy crossover.
    """
    requests: int
    completed: int
    total_new_tokens: int
    steps: int
    wall_s: float
    throughput_tok_s: float
    ttft_steps_p50: float
    ttft_steps_p99: float
    prefill_steps_p50: float     # TTFT component: wait + prefill tick
    transfer_steps_p50: float    # TTFT component: handoff-queue wait
    tpot_steps_p50: float
    tpot_steps_p99: float
    preemptions: int
    handoffs: int
    transfer_bytes: int
    peak_ready_depth: int        # bundles waiting for a decode slot
    peak_pending_depth: int      # prompts waiting for the prefill pool
    prefill_ar_bucket: int
    decode_ar_bucket: int
    prefill_pool: Dict[str, Any]
    decode_pool: Dict[str, Any]
    # robustness (DESIGN.md §11; zeros on a fault-free run):
    # * ``handoff_drops`` / ``handoff_retries`` — transfer attempts lost
    #   to injected drops / retries scheduled with backoff.
    # * ``handoff_corrupt`` — corrupt bundles *detected* (checksum
    #   mismatch at splice time) and routed to re-prefill.
    # * ``handoff_reprefills`` — contexts recomputed from the prompt
    #   after exhausting transfer retries or failing verification.
    # * ``shed_requests`` — never-admitted requests dropped on deadline
    #   expiry or after ``max_reprefills`` (always reported).
    # * ``backpressure_steps`` — ticks the prefill pool was blocked by a
    #   full handoff queue (``ready_cap``) with prompts still pending.
    # * ``prefill_stall_steps`` / ``decode_stall_steps`` — ticks a pool
    #   was frozen by an injected stall.
    handoff_drops: int = 0
    handoff_retries: int = 0
    handoff_corrupt: int = 0
    handoff_reprefills: int = 0
    shed_requests: int = 0
    backpressure_steps: int = 0
    prefill_stall_steps: int = 0
    decode_stall_steps: int = 0
    ready_cap: int = 0
    # Retained per-request latency samples (logical steps) for lossless
    # fleet aggregation — see ``ServeMetrics.merge``.  Excluded from
    # ``to_dict`` so bench JSON rows stay scalar-only.
    ttft_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)
    prefill_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)
    transfer_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)
    tpot_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)

    SAMPLE_FIELDS = ("ttft_steps_samples", "prefill_steps_samples",
                     "transfer_steps_samples", "tpot_steps_samples")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for k in self.SAMPLE_FIELDS:
            d.pop(k, None)
        return d

    @classmethod
    def merge(cls, parts: List["DisaggMetrics"]) -> "DisaggMetrics":
        """Lossless fleet aggregation over per-replica disagg metrics:
        counters/totals summed, percentiles recomputed from retained
        samples, ``steps``/``wall_s`` max (lockstep logical clock), queue
        peaks max (worst replica — per-replica queues peak at different
        ticks, so summing would overstate), AR buckets max, per-pool
        stat dicts dropped (per-replica detail stays with the router)."""
        if not parts:
            raise ValueError("merge() needs at least one DisaggMetrics")
        ttft = [s for m in parts for s in m.ttft_steps_samples]
        pre = [s for m in parts for s in m.prefill_steps_samples]
        xfer = [s for m in parts for s in m.transfer_steps_samples]
        tpot = [s for m in parts for s in m.tpot_steps_samples]
        wall = max(m.wall_s for m in parts)
        total_new = sum(m.total_new_tokens for m in parts)
        return cls(
            requests=sum(m.requests for m in parts),
            completed=sum(m.completed for m in parts),
            total_new_tokens=total_new,
            steps=max(m.steps for m in parts), wall_s=wall,
            throughput_tok_s=total_new / wall if wall > 0 else 0.0,
            ttft_steps_p50=_percentile(ttft, 50),
            ttft_steps_p99=_percentile(ttft, 99),
            prefill_steps_p50=_percentile(pre, 50),
            transfer_steps_p50=_percentile(xfer, 50),
            tpot_steps_p50=_percentile(tpot, 50),
            tpot_steps_p99=_percentile(tpot, 99),
            preemptions=sum(m.preemptions for m in parts),
            handoffs=sum(m.handoffs for m in parts),
            transfer_bytes=sum(m.transfer_bytes for m in parts),
            peak_ready_depth=max(m.peak_ready_depth for m in parts),
            peak_pending_depth=max(m.peak_pending_depth for m in parts),
            prefill_ar_bucket=max(m.prefill_ar_bucket for m in parts),
            decode_ar_bucket=max(m.decode_ar_bucket for m in parts),
            prefill_pool={}, decode_pool={},
            handoff_drops=sum(m.handoff_drops for m in parts),
            handoff_retries=sum(m.handoff_retries for m in parts),
            handoff_corrupt=sum(m.handoff_corrupt for m in parts),
            handoff_reprefills=sum(m.handoff_reprefills for m in parts),
            shed_requests=sum(m.shed_requests for m in parts),
            backpressure_steps=sum(m.backpressure_steps for m in parts),
            prefill_stall_steps=sum(m.prefill_stall_steps for m in parts),
            decode_stall_steps=sum(m.decode_stall_steps for m in parts),
            ready_cap=max(m.ready_cap for m in parts),
            ttft_steps_samples=ttft, prefill_steps_samples=pre,
            transfer_steps_samples=xfer, tpot_steps_samples=tpot)


class DisaggCoordinator:
    """Router between a :class:`PrefillPool` and a decode-side
    :class:`ContinuousBatcher` (see module docstring for the tick model).

    ``decode`` must have been built with ``ar_table=<its own tuner>``
    (see :func:`pool_tuner`) for per-pool dispatch attribution; pass that
    tuner as ``decode_tuner`` so metrics can report its observed buckets.
    """

    def __init__(self, prefill: PrefillPool, decode: ContinuousBatcher, *,
                 prefill_per_step: int = 1,
                 decode_tuner: Optional[autotune.AutoTuner] = None,
                 injector: Optional[FaultInjector] = None,
                 max_handoff_retries: int = 3, retry_backoff: float = 1.0,
                 max_ready: Optional[int] = None, max_reprefills: int = 2,
                 deadline_s: Optional[float] = None):
        """Robustness knobs (DESIGN.md §11, all with fault-free-neutral
        defaults): a transfer attempt lost to an injected drop is retried
        after ``retry_backoff * attempts`` steps, up to
        ``max_handoff_retries`` retries; beyond that (or on a splice-time
        checksum mismatch) the context is *re-prefilled* from the prompt,
        up to ``max_reprefills`` times, after which the request is shed
        with reason ``"handoff_failed"`` — every path terminates.
        ``max_ready`` bounds the handoff queue (default
        ``max(2 * decode.slots, 8)``): a full queue backpressures the
        prefill pool instead of growing without bound.  ``deadline_s``
        is the default TTFT deadline in logical steps (per-request
        deadlines tighten it); expired never-admitted requests are shed
        with reason ``"deadline"``."""
        if prefill.cfg.name != decode.cfg.name:
            raise ValueError(f"pool configs differ: {prefill.cfg.name!r} "
                             f"vs {decode.cfg.name!r}")
        if decode.cfg.family != "dense":
            raise ValueError("disaggregated serving supports dense "
                             f"families only, not {decode.cfg.family!r}")
        if prefill.s_max > decode.s_max:
            # fail fast: a prompt the prefill pool accepts must always
            # fit the decode pool (handoff needs T + 1 <= decode s_max)
            raise ValueError(f"prefill s_max={prefill.s_max} exceeds "
                             f"decode s_max={decode.s_max}; oversized "
                             f"prefills could never hand off")
        if max_handoff_retries < 0 or max_reprefills < 0:
            raise ValueError("retry/re-prefill caps must be >= 0")
        self.prefill = prefill
        self.decode = decode
        self.prefill_per_step = prefill_per_step
        self.decode_tuner = decode_tuner
        self.injector = injector
        self.max_handoff_retries = max_handoff_retries
        self.retry_backoff = retry_backoff
        self.max_ready = max_ready if max_ready is not None \
            else max(2 * decode.slots, 8)
        self.max_reprefills = max_reprefills
        self.deadline_s = deadline_s
        self._records: Dict[int, Dict[str, float]] = {}
        self.transfer_bytes = 0
        self.handoffs = 0
        self.peak_ready = 0
        self.peak_pending = 0
        self._wall = 0.0
        # robustness counters (reset per run)
        self.handoff_drops = 0
        self.handoff_retries = 0
        self.handoff_corrupt = 0
        self.handoff_reprefills = 0
        self.backpressure_steps = 0
        self.prefill_stall_steps = 0
        self.decode_stall_steps = 0
        self._shed: List[Request] = []
        self._reprefills: Dict[int, int] = {}   # rid -> re-prefill count
        # cross-tick queue state (reset by begin_run): an external driver
        # (run(), or inference.router.Router) owns the pending-prompt
        # list and passes it per tick; the handoff queue and the
        # per-request attempt/prefill counters live here
        self._ready: List[_Handoff] = []   # awaiting a decode slot
        self._attempt_no: Dict[int, int] = {}  # rid -> transfer attempts
        self._prefill_no: Dict[int, int] = {}  # rid -> prefills, ever

    def _shed_req(self, req: Request, now: float, reason: str) -> None:
        """Drop a never-admitted request, *reporting* it (shed_reason /
        metrics counter) — shedding is load control, not silent loss."""
        req.shed_step = int(now)
        req.shed_reason = reason
        self._shed.append(req)

    def _deadline(self, req: Request) -> float:
        d = req.deadline_s
        if self.deadline_s is not None:
            d = min(d, self.deadline_s)
        return d

    def _reprefill_or_shed(self, h: _Handoff, pending: List[Request],
                           now: float, reason: str) -> None:
        """Escalation ladder after a handoff gave up (retries exhausted or
        payload corrupt): recompute the context from the prompt (front of
        the prefill queue, preserving age order), bounded by
        ``max_reprefills``; beyond that, shed.  The re-prefill replays the
        request's sampling chain, so a recovered request's tokens are
        bitwise-identical to the fault-free trace."""
        n = self._reprefills.get(h.req.rid, 0)
        if n >= self.max_reprefills:
            self._shed_req(h.req, now, reason)
            return
        self._reprefills[h.req.rid] = n + 1
        self.handoff_reprefills += 1
        pending.insert(0, h.req)

    def begin_run(self) -> None:
        """Reset all per-run state (records, counters, queues, pool
        stats) ahead of a trace replay — called by :meth:`run`, and by an
        external driver (``inference.router.Router``) before it starts
        ticking this coordinator directly."""
        self._records = {}
        self.transfer_bytes = 0
        self.handoffs = 0
        self.peak_ready = 0
        self.peak_pending = 0
        self.handoff_drops = self.handoff_retries = 0
        self.handoff_corrupt = self.handoff_reprefills = 0
        self.backpressure_steps = 0
        self.prefill_stall_steps = self.decode_stall_steps = 0
        self._shed = []
        self._reprefills = {}
        self._ready = []
        self._attempt_no = {}
        self._prefill_no = {}
        self.decode.reset_run_stats()
        self.prefill.reset_stats()

    def _tick_pre(self, pending: List[Request], now: float) -> None:
        """Tick phases ahead of the decode step: deadline sheds, the
        prefill phase (stall-checked even when there is nothing to
        prefill — a stall is a property of the tick, not the queue), and
        the handoff drain into free decode slots.  ``pending`` is the
        externally-owned prompt queue, mutated in place."""
        inj = self.injector
        decode = self.decode
        ready = self._ready
        # deadline shedding: never-admitted requests only (a preempted
        # decode context already emitted its first token — protected)
        for r in [r for r in pending
                  if now - r.arrival_s > self._deadline(r)]:
            self._shed_req(r, now, "deadline")
            pending.remove(r)
        for h in [h for h in ready
                  if now - h.req.arrival_s > self._deadline(h.req)]:
            self._shed_req(h.req, now, "deadline")
            ready.remove(h)
        if inj is not None and inj.prefill_stalled(now):
            self.prefill_stall_steps += 1
        else:
            for _ in range(self.prefill_per_step):
                if not pending:
                    break
                if len(ready) >= self.max_ready:
                    # bounded handoff queue: hold the prompt instead
                    # of growing ready without bound
                    self.backpressure_steps += 1
                    break
                req = pending.pop(0)
                n = self._prefill_no.get(req.rid, 0)
                self._prefill_no[req.rid] = n + 1
                tok, bundle = self.prefill.prefill(req)
                if inj is not None and \
                        inj.corrupt_handoff(req.rid, n):
                    FaultInjector.corrupt_bundle(bundle)
                rec = self._records.setdefault(
                    req.rid, {"arrival": req.arrival_s})
                rec["prefill_step"] = now
                self.handoffs += 1
                self.transfer_bytes += bundle.nbytes
                ready.append(_Handoff(req, tok, bundle, prefill_no=n))
        # handoff queue -> free decode slots, FIFO among *due* entries
        # (retry backoff defers an entry without starving the rest);
        # a bundle that does not fit the paged pool right now stays
        # queued (head-of-line: admitting out of order would starve
        # the oldest context)
        for s in range(decode.slots):
            if decode.active[s] is not None:
                continue
            h = next((h for h in ready if h.next_try <= now), None)
            if h is None:
                continue
            a = self._attempt_no.get(h.req.rid, 0)
            self._attempt_no[h.req.rid] = a + 1
            if inj is not None and inj.drop_handoff(h.req.rid, a):
                # transfer attempt lost in flight
                self.handoff_drops += 1
                h.attempts += 1
                if h.attempts > self.max_handoff_retries:
                    ready.remove(h)
                    self._reprefill_or_shed(h, pending, now,
                                            "handoff_failed")
                else:
                    self.handoff_retries += 1
                    h.next_try = now + self.retry_backoff * h.attempts
                continue
            try:
                ok = decode.admit_prefilled(s, h.req, h.bundle,
                                            h.tok, now)
            except BundleIntegrityError:
                # splice-time checksum mismatch: the payload itself is
                # bad — retrying the same bundle can never succeed
                self.handoff_corrupt += 1
                ready.remove(h)
                self._reprefill_or_shed(h, pending, now,
                                        "handoff_corrupt")
                continue
            if ok:
                ready.remove(h)
                self._records[h.req.rid]["handoff_step"] = now
        self.peak_ready = max(self.peak_ready, len(ready))
        self.peak_pending = max(self.peak_pending, len(pending))

    def _tick_decode(self, pending: List[Request], now: float) -> None:
        """Decode phase of one tick: one decode-pool step (unless
        stalled), then reroute decode-pool preemptions back to the front
        of the prompt queue for recompute."""
        inj = self.injector
        decode = self.decode
        if inj is not None and inj.decode_stalled(now):
            self.decode_stall_steps += 1
        else:
            decode.step(now)
        # a preempted decode context lost its KV: route it back to the
        # prefill pool for recompute (front of queue, preserving the
        # eviction order — the colocated batcher's requeue-first rule)
        if decode._requeue:
            pending[:0] = decode._requeue
            decode._requeue.clear()

    def tick(self, arrived: List[Request], now: float) -> None:
        """One full logical tick on an externally-owned prompt queue —
        the ``ContinuousBatcher.tick`` contract, so a router drives a
        colocated batcher and a disagg coordinator identically.  (The
        trailing drained tick is harmless: both phases no-op on empty
        queues, matching the batcher's no-op ``step``.)"""
        self._tick_pre(arrived, now)
        self._tick_decode(arrived, now)

    def drained(self, arrived: List[Request]) -> bool:
        """No queued, in-flight, or active work left for this replica."""
        return not arrived and not self._ready \
            and all(a is None for a in self.decode.active)

    def run(self, requests: List[Request],
            max_steps: int = 100000) -> List[Request]:
        """Replay a trace (same contract as ``ContinuousBatcher.run``).

        Per tick: arrivals queue for prefill; deadline-expired
        never-admitted requests are shed; the prefill pool (unless stalled
        or backpressured by a full handoff queue) prefills up to
        ``prefill_per_step`` prompts; free decode slots admit the oldest
        *due* handoff (entries inside their retry-backoff window are
        skipped, capacity rejects keep head-of-line order); the decode
        pool (unless stalled) runs one step.  Failed or corrupt handoffs
        walk the retry → re-prefill → shed ladder (bounded at every rung,
        so ``run`` terminates at any fault rate)."""
        waiting = sorted(requests, key=lambda r: r.arrival_s)
        qi = 0
        now = 0.0
        pending: List[Request] = []   # awaiting prefill
        self.begin_run()
        wall0 = time.perf_counter()
        for _ in range(max_steps):
            while qi < len(waiting) and waiting[qi].arrival_s <= now:
                pending.append(waiting[qi])
                qi += 1
            self._tick_pre(pending, now)
            if qi >= len(waiting) and self.drained(pending):
                break
            self._tick_decode(pending, now)
            now += 1.0
        self._wall = time.perf_counter() - wall0
        decode = self.decode
        decode._wall_run = self._wall
        return requests

    # -- metrics -------------------------------------------------------------

    def _decode_bucket(self) -> int:
        """Decode pool's AR operating point: observed tuner lookups when
        available, else the analytic per-layer message bucket (all slots
        x 1 token x d_model; x (k+1) under speculative verify)."""
        if self.decode_tuner is not None:
            seen = self.decode_tuner.lookup_buckets()
            if seen:
                return max(seen)
        cfg = self.decode.cfg
        tokens = self.decode.slots
        if self.decode.spec_mode:
            tokens *= self.decode.spec_k + 1
        itemsize = jnp.dtype(cfg.dtype).itemsize
        return autotune.bucket_of(tokens * cfg.d_model * itemsize)

    def _prefill_bucket(self) -> int:
        seen = self.prefill.tuner.lookup_buckets()
        if seen:
            return max(seen)
        return max(self.prefill.analytic_buckets, default=0)

    def metrics(self, requests: List[Request]) -> DisaggMetrics:
        dm = self.decode.metrics(requests)   # TPOT / cache / spec fields
        done = [r for r in requests if r.output is not None]
        pre, xfer, ttft = [], [], []
        for r in done:
            rec = self._records.get(r.rid)
            if rec is None or "handoff_step" not in rec:
                continue
            p = max(rec["prefill_step"] - rec["arrival"], 0.0) + 1.0
            t = rec["handoff_step"] - rec["prefill_step"]
            pre.append(p)
            xfer.append(t)
            ttft.append(p + t)
        return DisaggMetrics(
            requests=len(requests), completed=len(done),
            total_new_tokens=dm.total_new_tokens, steps=dm.steps,
            wall_s=self._wall,
            throughput_tok_s=dm.total_new_tokens / self._wall
            if self._wall > 0 else 0.0,
            ttft_steps_p50=_percentile(ttft, 50),
            ttft_steps_p99=_percentile(ttft, 99),
            prefill_steps_p50=_percentile(pre, 50),
            transfer_steps_p50=_percentile(xfer, 50),
            tpot_steps_p50=dm.tpot_steps_p50,
            tpot_steps_p99=dm.tpot_steps_p99,
            preemptions=dm.preemptions,
            handoffs=self.handoffs,
            transfer_bytes=self.transfer_bytes,
            peak_ready_depth=self.peak_ready,
            peak_pending_depth=self.peak_pending,
            prefill_ar_bucket=self._prefill_bucket(),
            decode_ar_bucket=self._decode_bucket(),
            prefill_pool=self.prefill.stats(),
            decode_pool=dm.to_dict(),
            handoff_drops=self.handoff_drops,
            handoff_retries=self.handoff_retries,
            handoff_corrupt=self.handoff_corrupt,
            handoff_reprefills=self.handoff_reprefills,
            shed_requests=len(self._shed),
            backpressure_steps=self.backpressure_steps,
            prefill_stall_steps=self.prefill_stall_steps,
            decode_stall_steps=self.decode_stall_steps,
            ready_cap=self.max_ready,
            ttft_steps_samples=ttft, prefill_steps_samples=pre,
            transfer_steps_samples=xfer,
            tpot_steps_samples=list(dm.tpot_steps_samples))


__all__ = ["PrefillPool", "DisaggCoordinator", "DisaggMetrics",
           "pool_tuner"]
