"""Paged KV-cache subsystem: host-side block-table management.

The decode cache is the memory bottleneck of continuous batching: a dense
``(slots, s_max)`` layout reserves worst-case sequence length for every slot,
while real traces (lognormal lengths, the paper's Sec. 5.2.3 regime) leave
most of it untouched.  The paged layout carves the cache into fixed-size
blocks of ``block_size`` tokens and maps each slot's *logical* positions to
*physical* blocks through a per-slot block table — the vLLM PagedAttention
scheme, realized here on the JAX side as a gather/scatter through an int32
table so the same jitted decode step serves any mapping.

Split of responsibilities:

* this module (host side): the :class:`BlockAllocator` — free-list
  accounting, per-slot logical->physical tables, on-demand growth,
  eviction (preemption), defragmentation, and utilization stats.  Pure
  numpy; never traced.
* ``models/transformer.py`` + ``models/layers.py`` (device side): the cache
  pytree carries the table as an int32 leaf (``cache["block_tbl"]``) and the
  decode/prefill steps gather K/V through it (see
  ``layers.attention_decode`` / ``attention_chunk_step``).

Invariants this module (and everything downstream) relies on:

* **block-0-trash**: physical block 0 is reserved as the *trash block*:
  the table rows of freed or never-admitted slots point at it, so the
  (fixed-shape, whole-batch) decode step can keep scattering the stale
  slots' K/V writes somewhere harmless without any masking in the hot
  path.  Trash contents are never read — the attention mask only exposes
  positions ``<= pos`` of *active* slots, whose tables never contain
  block 0.
* **write-ordering**: freed / truncated / preempted blocks may hold stale
  K/V when they return to the free list.  That is safe because a block is
  only re-read through some slot's table after that slot has overwritten
  every position its attention mask exposes (DESIGN.md §7) — the same
  invariant that makes chunk-padding and inactive-slot writes harmless.

This module also owns the **KV handoff format** for disaggregated
prefill/decode serving (DESIGN.md §9): :class:`KVBundle` is a dense
``(L, T, n_kv, head_dim)`` snapshot of one request's cache in *canonical
real-head* layout — per-pool GQA slot layouts (which replicate/pad kv
heads differently per TP degree) are packed via :func:`slots_to_heads` on
export and re-expanded via :func:`heads_to_slots` on import, so a bundle
produced by a ``tp=8`` prefill pool splices bit-exactly into a ``tp=2``
decode pool.

Known gaps: paging covers the self-attention K/V only (recurrent /
encoder states stay dense per-slot), and a paged mesh cache cannot shard
slots over dp axes — run one batcher per data-parallel replica.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

TRASH_BLOCK = 0


class BundleIntegrityError(RuntimeError):
    """A KV handoff bundle failed its content checksum at splice time —
    the payload was damaged in flight.  The consumer must treat the
    bundle as lost (retry the transfer or re-prefill); splicing it would
    silently corrupt the request's downstream tokens."""


@dataclasses.dataclass
class CacheStats:
    """Point-in-time utilization snapshot (also the bench JSON payload)."""
    n_blocks: int            # physical blocks incl. trash
    block_size: int
    used_blocks: int         # currently owned by live slots
    peak_used_blocks: int    # high-water mark since construction
    used_tokens: int         # positions actually occupied (<= used*bs)
    preemptions: int
    allocations: int
    defrags: int

    @property
    def utilization(self) -> float:
        """Occupied tokens / reserved token capacity of the used blocks."""
        cap = self.used_blocks * self.block_size
        return self.used_tokens / cap if cap else 0.0

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["utilization"] = self.utilization
        return d


class BlockAllocator:
    """Free-list block allocator + per-slot block tables.

    ``n_blocks`` counts *all* physical blocks including the reserved trash
    block, matching the leading dim of the device-side cache, so a cache
    built with ``init_cache(..., block_size=bs, n_blocks=n)`` pairs with
    ``BlockAllocator(n, bs, slots, max_blocks)`` verbatim.
    """

    def __init__(self, n_blocks: int, block_size: int, slots: int,
                 max_blocks_per_slot: int):
        if block_size <= 0:
            raise ValueError("block_size must be > 0 for a paged cache")
        if n_blocks < max_blocks_per_slot + 1:
            raise ValueError(
                f"n_blocks={n_blocks} cannot hold one full-length request "
                f"({max_blocks_per_slot} blocks) plus the trash block")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks = max_blocks_per_slot
        # LIFO free list (reuse hot blocks first); block 0 is never free.
        self._free: List[int] = list(range(n_blocks - 1, TRASH_BLOCK, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._tokens = np.zeros((slots,), np.int64)  # occupied positions
        self.table = np.full((slots, max_blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self.peak_used_blocks = 0
        self.preemptions = 0
        self.allocations = 0
        self.defrags = 0
        # bumped on every table mutation; lets callers skip device uploads
        self.version = 0

    # -- queries -----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def owned(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned[slot])

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)  # ceil div

    def can_allocate(self, slot: int, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens) - len(self._owned[slot])
        return need <= len(self._free)

    def needs_growth(self, slot: int, n_tokens: int) -> bool:
        """Would covering [0, n_tokens) require new blocks for ``slot``?
        (The question an injected allocator-OOM burst gates on: growth
        that is not actually needed can never fail.)"""
        return self.blocks_for(n_tokens) > len(self._owned[slot])

    def stats(self) -> CacheStats:
        return CacheStats(
            n_blocks=self.n_blocks, block_size=self.block_size,
            used_blocks=self.used_blocks,
            peak_used_blocks=self.peak_used_blocks,
            used_tokens=int(self._tokens.sum()),
            preemptions=self.preemptions, allocations=self.allocations,
            defrags=self.defrags)

    # -- allocate / free ---------------------------------------------------

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to cover logical positions [0, n_tokens).

        Returns False (no state change) when the free list cannot cover the
        growth — the scheduler then preempts somebody and retries.
        """
        need_total = self.blocks_for(n_tokens)
        if need_total > self.max_blocks:
            raise ValueError(
                f"request needs {need_total} blocks > max_blocks_per_slot="
                f"{self.max_blocks} (s_max too small)")
        own = self._owned[slot]
        grow = need_total - len(own)
        if grow > len(self._free):
            return False
        for _ in range(max(grow, 0)):
            b = self._free.pop()
            self.table[slot, len(own)] = b
            own.append(b)
            self.allocations += 1
            self.version += 1
        self._tokens[slot] = max(self._tokens[slot], n_tokens)
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks)
        return True

    def reset_stats(self) -> None:
        """Zero the trace-scoped counters (peak/preemptions/allocations/
        defrags) so a fresh replay reports its own numbers; current
        ownership is untouched."""
        self.peak_used_blocks = self.used_blocks
        self.preemptions = 0
        self.allocations = 0
        self.defrags = 0

    def note_usage(self, slot: int, n_tokens: int) -> None:
        """Record occupied positions that did not require growth (writes
        inside an already-allocated block) so utilization stats stay exact
        between block-boundary ``ensure`` calls."""
        assert self.blocks_for(n_tokens) <= len(self._owned[slot]) or \
            n_tokens == 0, (slot, n_tokens)
        self._tokens[slot] = max(self._tokens[slot], n_tokens)

    def free(self, slot: int) -> int:
        """Release every block of ``slot``; its table row reverts to trash.
        Returns the number of blocks released."""
        own = self._owned[slot]
        n = len(own)
        # LIFO: freed blocks go back on top, most recently used first.
        self._free.extend(reversed(own))
        own.clear()
        self.table[slot, :] = TRASH_BLOCK
        self._tokens[slot] = 0
        if n:
            self.version += 1
        return n

    def preempt(self, slot: int) -> int:
        """Evict ``slot`` (count it as a preemption) and return its blocks."""
        self.preemptions += 1
        return self.free(slot)

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Roll ``slot`` back so it covers exactly logical positions
        [0, n_tokens) — the speculative-decode rejection rollback: blocks
        that only held rejected draft K/V go straight back on the free
        list.  Returns the number of blocks released.

        Freed blocks may contain stale K/V; that is safe for the same
        write-ordering reason preemption-freed blocks are (DESIGN.md §7):
        a block is only re-read through some slot's table after that slot
        has overwritten every position the attention mask exposes.
        """
        keep = self.blocks_for(n_tokens)
        own = self._owned[slot]
        tail = own[keep:]
        if tail:
            del own[keep:]
            # LIFO: rejected-tail blocks are the hottest, reuse them first.
            self._free.extend(reversed(tail))
            self.table[slot, keep:] = TRASH_BLOCK
            self.version += 1
        self._tokens[slot] = min(int(self._tokens[slot]), n_tokens)
        return len(tail)

    # -- defragmentation ---------------------------------------------------

    def defragment(self) -> Optional[np.ndarray]:
        """Compact live blocks into the lowest physical indices.

        Returns ``perm`` (n_blocks,) int32 with ``perm[new] = old`` — apply
        ``cache_k = cache_k[:, perm]`` (and same for v) on device, in the
        same transaction as uploading the rewritten ``self.table``.  Returns
        None when already compact (no device work needed).
        """
        live = [b for own in self._owned for b in own]
        if sorted(live) == list(range(1, len(live) + 1)):
            return None
        old_to_new = {TRASH_BLOCK: TRASH_BLOCK}
        nxt = 1
        perm = np.empty((self.n_blocks,), np.int32)
        perm[TRASH_BLOCK] = TRASH_BLOCK
        for own in self._owned:
            for i, b in enumerate(own):
                old_to_new[b] = nxt
                perm[nxt] = b
                nxt += 1
        # leftover physical indices map from the remaining old blocks
        rest = [b for b in range(1, self.n_blocks) if b not in old_to_new]
        for new, old in zip(range(nxt, self.n_blocks), rest):
            perm[new] = old
        for s, own in enumerate(self._owned):
            self._owned[s] = [old_to_new[b] for b in own]
            for i, b in enumerate(self._owned[s]):
                self.table[s, i] = b
        self._free = list(range(self.n_blocks - 1, nxt - 1, -1))
        self.defrags += 1
        self.version += 1
        return perm

    # -- invariant checking (tests / debug) --------------------------------

    def check(self) -> None:
        """Assert the free list + ownership exactly partition the pool."""
        owned = [b for own in self._owned for b in own]
        assert TRASH_BLOCK not in owned, "trash block allocated"
        assert TRASH_BLOCK not in self._free, "trash block on free list"
        all_b = sorted(owned + self._free)
        assert all_b == list(range(1, self.n_blocks)), \
            f"pool leak/dup: {len(owned)} owned + {len(self._free)} free"
        for s, own in enumerate(self._owned):
            got = list(self.table[s, :len(own)])
            assert got == own, f"slot {s} table mismatch"
            assert (self.table[s, len(own):] == TRASH_BLOCK).all(), \
                f"slot {s} stale table tail"


def paged_geometry(s_max: int, block_size: int) -> int:
    """max_blocks_per_slot for a given logical capacity (s_max must divide
    evenly so the gathered logical cache is exactly (slots, s_max))."""
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} not a multiple of "
                         f"block_size={block_size}")
    return s_max // block_size


# ---------------------------------------------------------------------------
# KV handoff bundles (disaggregated prefill/decode serving)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVBundle:
    """One request's KV cache in the canonical (layout-neutral) form.

    ``k`` / ``v``: ``(L, T, n_kv, head_dim)`` arrays holding logical
    positions ``[0, T)`` with one entry per *real* kv head — GQA slot
    padding/replication removed (:func:`slots_to_heads`).  This is the
    wire format of the prefill->decode handoff: independent of the source
    pool's TP degree, block size, or slot index, so either pool can use
    any mesh layout.  Dtype is the cache dtype (no conversion — bitwise
    round-trips).

    ``rng``: the request's per-slot sampling-chain base key ((2,) uint32;
    token ``t`` is drawn with ``fold_in(rng, t)`` — see
    ``scheduler.request_sampling_key``).  Carrying it through the handoff
    is what makes sampled (temperature > 0) disaggregated streams
    token-identical to colocated serving: the decode pool continues the
    exact chain the prefill pool sampled the first token from.  ``None``
    for producers that never sample (e.g. raw :func:`export_slot`).
    ``checksum``: cheap crc32 content checksum over the K/V payload plus
    its shape/dtype, set by :meth:`seal` at the producer and verified by
    :meth:`verify` at splice time (``ContinuousBatcher.admit_prefilled``)
    — the end-to-end integrity check of the handoff transport.  ``None``
    means unsealed (producers predating the robustness layer); verify is
    then a no-op, so raw :func:`export_slot` bundles keep working.
    """
    k: np.ndarray
    v: np.ndarray
    rng: Optional[np.ndarray] = None
    checksum: Optional[int] = None

    def __post_init__(self):
        assert self.k.shape == self.v.shape and self.k.ndim == 4, \
            (self.k.shape, self.v.shape)

    @property
    def n_tokens(self) -> int:
        return self.k.shape[1]

    @property
    def nbytes(self) -> int:
        """Transfer size of the handoff payload (K/V only; the 8-byte
        sampling key rides in the control plane)."""
        return int(self.k.nbytes + self.v.nbytes)

    # -- integrity ---------------------------------------------------------

    def _digest(self) -> int:
        h = zlib.crc32(repr((self.k.shape, str(self.k.dtype))).encode())
        h = zlib.crc32(np.ascontiguousarray(self.k).tobytes(), h)
        h = zlib.crc32(np.ascontiguousarray(self.v).tobytes(), h)
        return h

    def seal(self) -> "KVBundle":
        """Stamp the content checksum (producer side); returns self."""
        self.checksum = self._digest()
        return self

    def verify(self) -> None:
        """Raise :class:`BundleIntegrityError` when the payload does not
        match the sealed checksum; no-op on unsealed bundles."""
        if self.checksum is not None and self._digest() != self.checksum:
            raise BundleIntegrityError(
                f"KV bundle payload corrupt ({self.n_tokens} tokens, "
                f"{self.nbytes} bytes): checksum mismatch")


def slots_to_heads(arr: np.ndarray, kv_map) -> np.ndarray:
    """Pack a GQA slot layout down to real kv heads.

    ``arr``: ``(L, T, kv_slots, hd)``; ``kv_map``: per-slot original kv
    head index or -1 (``GQAPlan.kv_map``, global layout).  Returns
    ``(L, T, n_kv, hd)`` taking each head's first owning slot — replicated
    slots hold identical values (replicated weights), dead slots are
    dropped.
    """
    kv_map = np.asarray(kv_map)
    n_kv = int(kv_map.max()) + 1
    first = np.full((n_kv,), -1, np.int64)
    for s, h in enumerate(kv_map):
        if h >= 0 and first[h] < 0:
            first[h] = s
    assert (first >= 0).all(), f"kv_map covers only {first} of {n_kv} heads"
    return np.ascontiguousarray(arr[:, :, first])


def heads_to_slots(arr: np.ndarray, kv_map) -> np.ndarray:
    """Expand canonical real-head KV back into a GQA slot layout.

    Inverse of :func:`slots_to_heads` for the *target* pool's
    ``GQAPlan.kv_map``: replicated heads are duplicated into every slot
    that owns them, dead slots are zero — exactly what a direct prefill
    under the target layout would have written (dead-slot weights are
    zero, so their K/V are zero).
    """
    kv_map = np.asarray(kv_map)
    out = np.array(arr[:, :, np.maximum(kv_map, 0)])
    out[:, :, kv_map < 0] = 0
    return out


def export_slot(cache, slot: int, n_tokens: int, kv_map,
                table_row=None) -> KVBundle:
    """Pack one slot's live KV out of a (device) cache into a bundle.

    ``cache``: the batcher's cache pytree (dense or paged, local or the
    global view of a mesh cache).  ``table_row``: the slot's physical
    block row (``BlockAllocator.table[slot]`` — or the identity table row
    for an allocator-free paged cache); required iff the cache is paged.
    Only blocks/rows owned by ``slot`` are read, so trash-block contents
    and other slots' K/V can never leak into the bundle.
    """
    T = int(n_tokens)
    if "block_tbl" in cache:
        assert table_row is not None, "paged export needs the slot's row"
        bs = cache["k"].shape[2]
        nb = -(-T // bs)
        rows = np.asarray(table_row[:nb], np.int32)
        assert TRASH_BLOCK not in rows, "exporting an unowned (trash) block"
        def pull(phys):
            L, _, _, u, hd = phys.shape
            gathered = phys[:, rows]                     # (L, nb, bs, u, hd)
            return np.asarray(gathered).reshape(L, nb * bs, u, hd)[:, :T]
        k, v = pull(cache["k"]), pull(cache["v"])
    else:
        k = np.asarray(cache["k"][:, slot, :T])
        v = np.asarray(cache["v"][:, slot, :T])
    return KVBundle(k=slots_to_heads(k, kv_map),
                    v=slots_to_heads(v, kv_map))


__all__ = ["BlockAllocator", "BundleIntegrityError", "CacheStats",
           "KVBundle", "paged_geometry", "export_slot", "slots_to_heads",
           "heads_to_slots", "TRASH_BLOCK"]
