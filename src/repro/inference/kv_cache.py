"""Paged KV-cache subsystem: host-side block-table management.

The decode cache is the memory bottleneck of continuous batching: a dense
``(slots, s_max)`` layout reserves worst-case sequence length for every slot,
while real traces (lognormal lengths, the paper's Sec. 5.2.3 regime) leave
most of it untouched.  The paged layout carves the cache into fixed-size
blocks of ``block_size`` tokens and maps each slot's *logical* positions to
*physical* blocks through a per-slot block table — the vLLM PagedAttention
scheme, realized here on the JAX side as a gather/scatter through an int32
table so the same jitted decode step serves any mapping.

Split of responsibilities:

* this module (host side): the :class:`BlockAllocator` — free-list
  accounting, per-slot logical->physical tables, on-demand growth,
  eviction (preemption), defragmentation, and utilization stats.  Pure
  numpy; never traced.
* ``models/transformer.py`` + ``models/layers.py`` (device side): the cache
  pytree carries the table as an int32 leaf (``cache["block_tbl"]``) and the
  decode/prefill steps gather K/V through it (see
  ``layers.attention_decode`` / ``attention_chunk_step``).

Invariants this module (and everything downstream) relies on:

* **block-0-trash**: physical block 0 is reserved as the *trash block*:
  the table rows of freed or never-admitted slots point at it, so the
  (fixed-shape, whole-batch) decode step can keep scattering the stale
  slots' K/V writes somewhere harmless without any masking in the hot
  path.  Trash contents are never read — the attention mask only exposes
  positions ``<= pos`` of *active* slots, whose tables never contain
  block 0.
* **write-ordering**: freed / truncated / preempted blocks may hold stale
  K/V when they return to the free list.  That is safe because a block is
  only re-read through some slot's table after that slot has overwritten
  every position its attention mask exposes (DESIGN.md §7) — the same
  invariant that makes chunk-padding and inactive-slot writes harmless.
* **refcounted sharing (copy-on-write, DESIGN.md §14)**: a physical
  block may appear in several slots' tables at once (shared prompt
  prefix) and/or be *held* externally (the prefix trie).  A block
  returns to the free list only when its slot refcount **and** its hold
  count both reach zero, so ``free``/``preempt``/``truncate`` of one
  sharer can never recycle a block a neighbour still reads.  A slot must
  never write a block it does not own exclusively — writers call
  :meth:`BlockAllocator.fork_for_write` first, which swaps a private
  copy into that slot's table (the caller copies the device contents in
  the same transaction).

This module also owns the **KV handoff format** for disaggregated
prefill/decode serving (DESIGN.md §9): :class:`KVBundle` is a dense
``(L, T, n_kv, head_dim)`` snapshot of one request's cache in *canonical
real-head* layout — per-pool GQA slot layouts (which replicate/pad kv
heads differently per TP degree) are packed via :func:`slots_to_heads` on
export and re-expanded via :func:`heads_to_slots` on import, so a bundle
produced by a ``tp=8`` prefill pool splices bit-exactly into a ``tp=2``
decode pool.

Known gaps: paging covers the self-attention K/V only (recurrent /
encoder states stay dense per-slot), and a paged mesh cache cannot shard
slots over dp axes — run one batcher per data-parallel replica.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

TRASH_BLOCK = 0


class BundleIntegrityError(RuntimeError):
    """A KV handoff bundle failed its content checksum at splice time —
    the payload was damaged in flight.  The consumer must treat the
    bundle as lost (retry the transfer or re-prefill); splicing it would
    silently corrupt the request's downstream tokens."""


@dataclasses.dataclass
class CacheStats:
    """Point-in-time utilization snapshot (also the bench JSON payload)."""
    n_blocks: int            # physical blocks incl. trash
    block_size: int
    used_blocks: int         # currently owned by live slots
    peak_used_blocks: int    # high-water mark since construction
    used_tokens: int         # positions actually occupied (<= used*bs)
    preemptions: int
    allocations: int
    defrags: int

    @property
    def utilization(self) -> float:
        """Occupied tokens / reserved token capacity of the used blocks."""
        cap = self.used_blocks * self.block_size
        return self.used_tokens / cap if cap else 0.0

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        d["utilization"] = self.utilization
        return d


class BlockAllocator:
    """Free-list block allocator + per-slot block tables.

    ``n_blocks`` counts *all* physical blocks including the reserved trash
    block, matching the leading dim of the device-side cache, so a cache
    built with ``init_cache(..., block_size=bs, n_blocks=n)`` pairs with
    ``BlockAllocator(n, bs, slots, max_blocks)`` verbatim.
    """

    def __init__(self, n_blocks: int, block_size: int, slots: int,
                 max_blocks_per_slot: int):
        if block_size <= 0:
            raise ValueError("block_size must be > 0 for a paged cache")
        if n_blocks < max_blocks_per_slot + 1:
            raise ValueError(
                f"n_blocks={n_blocks} cannot hold one full-length request "
                f"({max_blocks_per_slot} blocks) plus the trash block")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks = max_blocks_per_slot
        # LIFO free list (reuse hot blocks first); block 0 is never free.
        self._free: List[int] = list(range(n_blocks - 1, TRASH_BLOCK, -1))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        # per-block slot refcount: how many slot tables reference b.  A
        # freshly allocated block has ref 1; share() raises it.
        self._ref = np.zeros((n_blocks,), np.int64)
        # external holds (prefix-trie pins): block -> hold count.  Held
        # blocks stay off the free list even with zero slot refs.
        self._held: Dict[int, int] = {}
        # called with {old: new} on every defragment (trie remap et al.)
        self._remap_hooks: List = []
        self._tokens = np.zeros((slots,), np.int64)  # occupied positions
        self.table = np.full((slots, max_blocks_per_slot), TRASH_BLOCK,
                             np.int32)
        self.peak_used_blocks = 0
        self.preemptions = 0
        self.allocations = 0
        self.defrags = 0
        # bumped on every table mutation; lets callers skip device uploads
        self.version = 0

    # -- queries -----------------------------------------------------------

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.n_blocks - 1) - len(self._free)

    def owned(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._owned[slot])

    def blocks_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)  # ceil div

    def can_allocate(self, slot: int, n_tokens: int) -> bool:
        need = self.blocks_for(n_tokens) - len(self._owned[slot])
        return need <= len(self._free)

    def needs_growth(self, slot: int, n_tokens: int) -> bool:
        """Would covering [0, n_tokens) require new blocks for ``slot``?
        (The question an injected allocator-OOM burst gates on: growth
        that is not actually needed can never fail.)"""
        return self.blocks_for(n_tokens) > len(self._owned[slot])

    def slot_refs(self, block: int) -> int:
        """How many slot tables reference ``block`` (0 for free blocks)."""
        return int(self._ref[block])

    def held_count(self, block: int) -> int:
        """External (trie) hold count on ``block``."""
        return self._held.get(block, 0)

    def is_exclusive(self, slot: int, idx: int) -> bool:
        """True iff ``slot`` may write its ``idx``-th block in place:
        exactly one slot ref (this slot's) and no external holds."""
        b = self._owned[slot][idx]
        return int(self._ref[b]) == 1 and b not in self._held

    def stats(self) -> CacheStats:
        return CacheStats(
            n_blocks=self.n_blocks, block_size=self.block_size,
            used_blocks=self.used_blocks,
            peak_used_blocks=self.peak_used_blocks,
            used_tokens=int(self._tokens.sum()),
            preemptions=self.preemptions, allocations=self.allocations,
            defrags=self.defrags)

    # -- allocate / free ---------------------------------------------------

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to cover logical positions [0, n_tokens).

        Returns False (no state change) when the free list cannot cover the
        growth — the scheduler then preempts somebody and retries.
        """
        need_total = self.blocks_for(n_tokens)
        if need_total > self.max_blocks:
            raise ValueError(
                f"request needs {need_total} blocks > max_blocks_per_slot="
                f"{self.max_blocks} (s_max too small)")
        own = self._owned[slot]
        grow = need_total - len(own)
        if grow > len(self._free):
            return False
        for _ in range(max(grow, 0)):
            b = self._free.pop()
            self._ref[b] = 1
            self.table[slot, len(own)] = b
            own.append(b)
            self.allocations += 1
            self.version += 1
        self._tokens[slot] = max(self._tokens[slot], n_tokens)
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks)
        return True

    # -- sharing (copy-on-write) -------------------------------------------

    def share(self, slot: int, blocks) -> None:
        """Point an *empty* ``slot``'s table at existing live blocks.

        The prefix-splice primitive: an admitted request whose prompt
        matched ``len(blocks)`` trie blocks takes a reference on each —
        the blocks become the slot's leading table entries, and ``ensure``
        then grows only the private suffix.  Each shared block's refcount
        rises by one; nothing is copied.  The slot must own nothing (a
        fresh admission) and every block must be live (slot-referenced or
        held) — a free-list block has undefined K/V.
        """
        own = self._owned[slot]
        assert not own, f"share() into non-empty slot {slot}"
        blocks = list(blocks)
        if len(blocks) > self.max_blocks:
            raise ValueError(f"sharing {len(blocks)} blocks > max_blocks="
                             f"{self.max_blocks}")
        for b in blocks:
            assert b != TRASH_BLOCK, "sharing the trash block"
            assert self._ref[b] > 0 or b in self._held, \
                f"sharing dead block {b}"
        for i, b in enumerate(blocks):
            self._ref[b] += 1
            self.table[slot, i] = b
            own.append(b)
            self.version += 1

    def fork_for_write(self, slot: int, idx: int) -> Optional[Tuple[int, int]]:
        """Give ``slot`` a private copy of its ``idx``-th block.

        Returns ``None`` when the block is already exclusive (write in
        place).  Otherwise pops a free block, moves this slot's reference
        onto it, and returns ``(old_phys, new_phys)`` — the caller MUST
        copy the device K/V ``old -> new`` before any divergent write, in
        the same transaction as the table upload.  Raises RuntimeError
        when the free list is empty (callers reclaim trie holds first, or
        skip the write).
        """
        own = self._owned[slot]
        b = own[idx]
        if self._ref[b] == 1 and b not in self._held:
            return None
        if not self._free:
            raise RuntimeError(
                f"fork_for_write: no free block to copy shared block {b}")
        new = self._free.pop()
        self._ref[b] -= 1
        self._ref[new] = 1
        own[idx] = new
        self.table[slot, idx] = new
        self.allocations += 1
        self.version += 1
        self.peak_used_blocks = max(self.peak_used_blocks, self.used_blocks)
        return (b, new)

    def hold(self, blocks) -> None:
        """Take an external (trie) hold on each block: it stays off the
        free list even when every slot releases it.  Blocks must be live
        or just-released by the caller in the same transaction."""
        for b in blocks:
            assert b != TRASH_BLOCK, "holding the trash block"
            assert b not in self._free, f"holding free block {b}"
            self._held[b] = self._held.get(b, 0) + 1

    def release(self, blocks) -> List[int]:
        """Drop one external hold per block; blocks whose refcount and
        hold count both hit zero go back on the free list.  Returns the
        blocks actually freed (the trie's eviction bookkeeping)."""
        freed: List[int] = []
        for b in blocks:
            n = self._held[b] - 1
            if n:
                self._held[b] = n
            else:
                del self._held[b]
                if self._ref[b] == 0:
                    self._free.append(b)
                    freed.append(b)
        return freed

    def register_remap_hook(self, fn) -> None:
        """``fn(old_to_new: Dict[int, int])`` is invoked on every
        defragment so external block indices (the trie's) stay valid."""
        self._remap_hooks.append(fn)

    def reset_stats(self) -> None:
        """Zero the trace-scoped counters (peak/preemptions/allocations/
        defrags) so a fresh replay reports its own numbers; current
        ownership is untouched."""
        self.peak_used_blocks = self.used_blocks
        self.preemptions = 0
        self.allocations = 0
        self.defrags = 0

    def note_usage(self, slot: int, n_tokens: int) -> None:
        """Record occupied positions that did not require growth (writes
        inside an already-allocated block) so utilization stats stay exact
        between block-boundary ``ensure`` calls."""
        assert self.blocks_for(n_tokens) <= len(self._owned[slot]) or \
            n_tokens == 0, (slot, n_tokens)
        self._tokens[slot] = max(self._tokens[slot], n_tokens)

    def _drop_ref(self, block: int) -> bool:
        """Drop one slot reference; True iff the block went back on the
        free list (refcount and hold count both zero)."""
        self._ref[block] -= 1
        assert self._ref[block] >= 0, f"refcount underflow on {block}"
        if self._ref[block] == 0 and block not in self._held:
            self._free.append(block)
            return True
        return False

    def free(self, slot: int) -> int:
        """Drop ``slot``'s reference on every block it holds; its table
        row reverts to trash.  Blocks shared with another slot or held by
        the trie survive — returns the number actually released to the
        free list."""
        own = self._owned[slot]
        n = 0
        # LIFO: freed blocks go back on top, most recently used first.
        for b in reversed(own):
            n += self._drop_ref(b)
        if own:
            self.version += 1
        own.clear()
        self.table[slot, :] = TRASH_BLOCK
        self._tokens[slot] = 0
        return n

    def preempt(self, slot: int) -> int:
        """Evict ``slot`` (count it as a preemption) and return its blocks."""
        self.preemptions += 1
        return self.free(slot)

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Roll ``slot`` back so it covers exactly logical positions
        [0, n_tokens) — the speculative-decode rejection rollback: blocks
        that only held rejected draft K/V go straight back on the free
        list.  Returns the number of blocks released.

        Freed blocks may contain stale K/V; that is safe for the same
        write-ordering reason preemption-freed blocks are (DESIGN.md §7):
        a block is only re-read through some slot's table after that slot
        has overwritten every position the attention mask exposes.
        """
        keep = self.blocks_for(n_tokens)
        own = self._owned[slot]
        tail = own[keep:]
        n = 0
        if tail:
            del own[keep:]
            # LIFO: rejected-tail blocks are the hottest, reuse them first.
            for b in reversed(tail):
                n += self._drop_ref(b)
            self.table[slot, keep:] = TRASH_BLOCK
            self.version += 1
        self._tokens[slot] = min(int(self._tokens[slot]), n_tokens)
        return n

    # -- defragmentation ---------------------------------------------------

    def defragment(self) -> Optional[np.ndarray]:
        """Compact live blocks into the lowest physical indices.

        Returns ``perm`` (n_blocks,) int32 with ``perm[new] = old`` — apply
        ``cache_k = cache_k[:, perm]`` (and same for v) on device, in the
        same transaction as uploading the rewritten ``self.table``.  Returns
        None when already compact (no device work needed).
        """
        # Live = every block some table or hold still references; a block
        # shared by k slots (or slot+trie) is live ONCE — it gets exactly
        # one new index and every referencing table maps through it.
        live: List[int] = []
        seen = set()
        for own in self._owned:
            for b in own:
                if b not in seen:
                    seen.add(b)
                    live.append(b)
        for b in sorted(self._held):       # held-only blocks (no slot ref)
            if b not in seen:
                seen.add(b)
                live.append(b)
        if sorted(live) == list(range(1, len(live) + 1)):
            return None
        old_to_new = {TRASH_BLOCK: TRASH_BLOCK}
        perm = np.empty((self.n_blocks,), np.int32)
        perm[TRASH_BLOCK] = TRASH_BLOCK
        nxt = 1
        for b in live:
            old_to_new[b] = nxt
            perm[nxt] = b
            nxt += 1
        # leftover physical indices map from the remaining old blocks
        rest = [b for b in range(1, self.n_blocks) if b not in old_to_new]
        for new, old in zip(range(nxt, self.n_blocks), rest):
            perm[new] = old
        for s, own in enumerate(self._owned):
            self._owned[s] = [old_to_new[b] for b in own]
            for i, b in enumerate(self._owned[s]):
                self.table[s, i] = b
        new_ref = np.zeros_like(self._ref)
        for old, new in old_to_new.items():
            new_ref[new] = self._ref[old]
        self._ref = new_ref
        self._held = {old_to_new[b]: c for b, c in self._held.items()}
        self._free = list(range(self.n_blocks - 1, nxt - 1, -1))
        self.defrags += 1
        self.version += 1
        for fn in self._remap_hooks:
            fn(old_to_new)
        return perm

    # -- invariant checking (tests / debug) --------------------------------

    def check(self) -> None:
        """Assert refcounts, holds, and the free list exactly partition
        the pool: every block 1..n-1 is either live (slot refcount ==
        its table occurrences, and/or positively held) or appears on the
        free list exactly once — never both, never neither."""
        owned = [b for own in self._owned for b in own]
        assert TRASH_BLOCK not in owned, "trash block allocated"
        assert TRASH_BLOCK not in self._free, "trash block on free list"
        assert TRASH_BLOCK not in self._held, "trash block held"
        assert self._ref[TRASH_BLOCK] == 0, "trash block refcounted"
        # refcount[b] == number of slot tables referencing b
        counts = np.zeros((self.n_blocks,), np.int64)
        for b in owned:
            counts[b] += 1
        assert (counts == self._ref).all(), \
            f"refcount drift: {np.flatnonzero(counts != self._ref)}"
        for b, c in self._held.items():
            assert c > 0, f"zero hold entry for {b}"
        free_set = set(self._free)
        assert len(free_set) == len(self._free), "duplicate free blocks"
        expect_free = {b for b in range(1, self.n_blocks)
                       if counts[b] == 0 and b not in self._held}
        assert free_set == expect_free, (
            f"free-list drift: leaked={sorted(expect_free - free_set)} "
            f"premature={sorted(free_set - expect_free)}")
        for s, own in enumerate(self._owned):
            got = list(self.table[s, :len(own)])
            assert got == own, f"slot {s} table mismatch"
            assert (self.table[s, len(own):] == TRASH_BLOCK).all(), \
                f"slot {s} stale table tail"


def paged_geometry(s_max: int, block_size: int) -> int:
    """max_blocks_per_slot for a given logical capacity (s_max must divide
    evenly so the gathered logical cache is exactly (slots, s_max))."""
    if s_max % block_size:
        raise ValueError(f"s_max={s_max} not a multiple of "
                         f"block_size={block_size}")
    return s_max // block_size


# ---------------------------------------------------------------------------
# KV handoff bundles (disaggregated prefill/decode serving)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVBundle:
    """One request's KV cache in the canonical (layout-neutral) form.

    ``k`` / ``v``: ``(L, T, n_kv, head_dim)`` arrays holding logical
    positions ``[0, T)`` with one entry per *real* kv head — GQA slot
    padding/replication removed (:func:`slots_to_heads`).  This is the
    wire format of the prefill->decode handoff: independent of the source
    pool's TP degree, block size, or slot index, so either pool can use
    any mesh layout.  Dtype is the cache dtype (no conversion — bitwise
    round-trips).

    ``rng``: the request's per-slot sampling-chain base key ((2,) uint32;
    token ``t`` is drawn with ``fold_in(rng, t)`` — see
    ``scheduler.request_sampling_key``).  Carrying it through the handoff
    is what makes sampled (temperature > 0) disaggregated streams
    token-identical to colocated serving: the decode pool continues the
    exact chain the prefill pool sampled the first token from.  ``None``
    for producers that never sample (e.g. raw :func:`export_slot`).
    ``checksum``: cheap crc32 content checksum over the K/V payload plus
    its shape/dtype, set by :meth:`seal` at the producer and verified by
    :meth:`verify` at splice time (``ContinuousBatcher.admit_prefilled``)
    — the end-to-end integrity check of the handoff transport.  ``None``
    means unsealed (producers predating the robustness layer); verify is
    then a no-op, so raw :func:`export_slot` bundles keep working.
    """
    k: np.ndarray
    v: np.ndarray
    rng: Optional[np.ndarray] = None
    checksum: Optional[int] = None

    def __post_init__(self):
        assert self.k.shape == self.v.shape and self.k.ndim == 4, \
            (self.k.shape, self.v.shape)

    @property
    def n_tokens(self) -> int:
        return self.k.shape[1]

    @property
    def nbytes(self) -> int:
        """Transfer size of the handoff payload (K/V only; the 8-byte
        sampling key rides in the control plane)."""
        return int(self.k.nbytes + self.v.nbytes)

    # -- integrity ---------------------------------------------------------

    def _digest(self) -> int:
        h = zlib.crc32(repr((self.k.shape, str(self.k.dtype))).encode())
        h = zlib.crc32(np.ascontiguousarray(self.k).tobytes(), h)
        h = zlib.crc32(np.ascontiguousarray(self.v).tobytes(), h)
        return h

    def seal(self) -> "KVBundle":
        """Stamp the content checksum (producer side); returns self."""
        self.checksum = self._digest()
        return self

    def verify(self) -> None:
        """Raise :class:`BundleIntegrityError` when the payload does not
        match the sealed checksum; no-op on unsealed bundles."""
        if self.checksum is not None and self._digest() != self.checksum:
            raise BundleIntegrityError(
                f"KV bundle payload corrupt ({self.n_tokens} tokens, "
                f"{self.nbytes} bytes): checksum mismatch")


def slots_to_heads(arr: np.ndarray, kv_map) -> np.ndarray:
    """Pack a GQA slot layout down to real kv heads.

    ``arr``: ``(L, T, kv_slots, hd)``; ``kv_map``: per-slot original kv
    head index or -1 (``GQAPlan.kv_map``, global layout).  Returns
    ``(L, T, n_kv, hd)`` taking each head's first owning slot — replicated
    slots hold identical values (replicated weights), dead slots are
    dropped.
    """
    kv_map = np.asarray(kv_map)
    n_kv = int(kv_map.max()) + 1
    first = np.full((n_kv,), -1, np.int64)
    for s, h in enumerate(kv_map):
        if h >= 0 and first[h] < 0:
            first[h] = s
    assert (first >= 0).all(), f"kv_map covers only {first} of {n_kv} heads"
    return np.ascontiguousarray(arr[:, :, first])


def heads_to_slots(arr: np.ndarray, kv_map) -> np.ndarray:
    """Expand canonical real-head KV back into a GQA slot layout.

    Inverse of :func:`slots_to_heads` for the *target* pool's
    ``GQAPlan.kv_map``: replicated heads are duplicated into every slot
    that owns them, dead slots are zero — exactly what a direct prefill
    under the target layout would have written (dead-slot weights are
    zero, so their K/V are zero).
    """
    kv_map = np.asarray(kv_map)
    out = np.array(arr[:, :, np.maximum(kv_map, 0)])
    out[:, :, kv_map < 0] = 0
    return out


def export_slot(cache, slot: int, n_tokens: int, kv_map,
                table_row=None) -> KVBundle:
    """Pack one slot's live KV out of a (device) cache into a bundle.

    ``cache``: the batcher's cache pytree (dense or paged, local or the
    global view of a mesh cache).  ``table_row``: the slot's physical
    block row (``BlockAllocator.table[slot]`` — or the identity table row
    for an allocator-free paged cache); required iff the cache is paged.
    Only blocks/rows owned by ``slot`` are read, so trash-block contents
    and other slots' K/V can never leak into the bundle.
    """
    T = int(n_tokens)
    if "block_tbl" in cache:
        assert table_row is not None, "paged export needs the slot's row"
        bs = cache["k"].shape[2]
        nb = -(-T // bs)
        rows = np.asarray(table_row[:nb], np.int32)
        assert TRASH_BLOCK not in rows, "exporting an unowned (trash) block"
        def pull(phys):
            L, _, _, u, hd = phys.shape
            gathered = phys[:, rows]                     # (L, nb, bs, u, hd)
            return np.asarray(gathered).reshape(L, nb * bs, u, hd)[:, :T]
        k, v = pull(cache["k"]), pull(cache["v"])
    else:
        k = np.asarray(cache["k"][:, slot, :T])
        v = np.asarray(cache["v"][:, slot, :T])
    return KVBundle(k=slots_to_heads(k, kv_map),
                    v=slots_to_heads(v, kv_map))


__all__ = ["BlockAllocator", "BundleIntegrityError", "CacheStats",
           "KVBundle", "paged_geometry", "export_slot", "slots_to_heads",
           "heads_to_slots", "TRASH_BLOCK"]
