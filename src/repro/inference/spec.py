"""The unified serving configuration API: ``ReplicaSpec`` / ``ServeSpec``.

Before this module, a serving deployment was ~25 keyword arguments
threaded in parallel through ``ContinuousBatcher.__init__``,
``PrefillPool``, ``DisaggCoordinator``, ``InferenceEngine`` and every
driver/test/benchmark that built one — and the incompatible-combo
rejections lived twice (CLI parse time and builder layer), drifting
apart.  This module makes the deployment a *value*:

* :class:`ReplicaSpec` — one self-contained serving replica: model,
  mesh layout (tp/pods or per-pool layouts under ``disagg``), AR knobs,
  KV layout, admission, sampling, speculation, robustness.  Frozen,
  hashable, JSON round-trippable.
* :class:`ServeSpec` — a deployment: ``mode`` (batch | trace), the
  replica template, the replica count, and the router placement policy.
* :meth:`ServeSpec.validate` — the single home of combo validation.
  The CLI, the factories below, and router-constructed replicas all
  call it, so every layer rejects identically, naming spec fields.
* :func:`build_replica` — the one factory that turns a ``ReplicaSpec``
  into a live ``ContinuousBatcher`` (colocated) or ``DisaggCoordinator``
  (``disagg=True``), used by ``launch.serve``, ``inference.router``,
  tests and benchmarks alike.  ``build_engine`` / ``build_prefill_pool``
  cover the batch engine and direct pool construction.

Serializability is the point: a router can ship a spec to construct a
replica, a bench can log the exact deployment next to its numbers, and
``ServeSpec.from_json(spec.to_json()) == spec`` holds for every CLI
combination (asserted in CI).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from ..configs import ARCH_IDS
from ..core.pcontext import AR_STRATEGIES, AR_QUANT_MODES, SEQ_PARALLEL_MODES

ROUTER_POLICIES = ("round_robin", "least_queue", "ttft_aware")

ADMIT_MODES = ("full", "chunked")
SPEC_MODES = (None, "ngram", "draft", "replay")
SERVE_MODES = ("batch", "trace")
PREFIX_MODES = ("off", "on")


class SpecError(ValueError):
    """An invalid ``ServeSpec``/``ReplicaSpec`` field combination.

    Raised by :meth:`ServeSpec.validate` — the same exception at CLI
    parse time, in the factories, and for router-constructed replicas.
    """


@dataclasses.dataclass(frozen=True)
class ReplicaSpec:
    """One self-contained serving replica (see module docstring)."""
    arch: str
    smoke: bool = True
    # -- mesh layout ------------------------------------------------------
    tp: int = 1
    pods: int = 1
    # -- all-reduce knobs (paper Sec. 4; DESIGN.md §3/§10/§12) ------------
    ar_strategy: str = "flat"
    ar_table: Optional[str] = None      # persisted autotune table path
    overlap: bool = False
    seq_parallel: str = "off"
    ar_quant: str = "none"
    # -- KV layout / admission -------------------------------------------
    slots: int = 4
    s_max: int = 128
    block_size: int = 0
    n_blocks: Optional[int] = None
    kv_quant: bool = False
    admit_mode: str = "full"
    admit_chunk: int = 32
    # -- prefix sharing (DESIGN.md §14) -----------------------------------
    prefix_cache: str = "off"
    prefix_capacity: Optional[int] = None   # max trie-pinned blocks
    # -- sampling ---------------------------------------------------------
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    # -- step-builder knobs -----------------------------------------------
    scan_layers: bool = True
    fsdp_serve: bool = False
    # -- speculative decoding (DESIGN.md §8) ------------------------------
    spec_mode: Optional[str] = None
    spec_k: int = 4
    spec_adaptive: bool = False
    draft_arch: str = "llama3.2-1b"
    spec_autodisable_after: int = 0
    # -- robustness (DESIGN.md §11) ---------------------------------------
    fault_plan: Optional[str] = None    # 'k=v,...' string or JSON path
    deadline_ms: Optional[float] = None  # 1 logical step = 1 ms
    # -- disaggregated prefill/decode pools (DESIGN.md §9) ----------------
    disagg: bool = False
    prefill_tp: int = 1
    prefill_pods: int = 1
    decode_tp: int = 1
    decode_pods: int = 1
    prefill_ar_table: Optional[str] = None
    decode_ar_table: Optional[str] = None
    # pool KV layout override: None = inherit ``block_size``; 0 forces a
    # dense pool in front of a paged decode pool (the bundles are layout
    # independent, so any combination hands off)
    prefill_block_size: Optional[int] = None
    prefill_per_step: int = 1
    max_handoff_retries: int = 3
    retry_backoff: float = 1.0
    max_ready: Optional[int] = None
    max_reprefills: int = 2

    # -- derived ----------------------------------------------------------

    @property
    def device_need(self) -> int:
        """Devices one replica of this spec occupies (its mesh carve
        width): the TP degree, or the wider pool under ``disagg`` (the
        pools run sequentially per tick and may share the group)."""
        return max(self.prefill_tp, self.decode_tp) if self.disagg \
            else self.tp

    def validate(self, mode: str = "trace") -> "ReplicaSpec":
        """Reject invalid field combinations (raises :class:`SpecError`).

        ``mode`` is the deployment mode the replica will serve under —
        several combos are trace-mode only.  Returns ``self`` so call
        sites can chain ``spec.validate().…``.
        """
        def bad(msg: str) -> None:
            raise SpecError(msg)

        if mode not in SERVE_MODES:
            bad(f"unknown mode={mode!r} (one of {SERVE_MODES})")
        if self.arch not in ARCH_IDS:
            bad(f"unknown arch={self.arch!r}")
        if self.ar_strategy not in AR_STRATEGIES:
            bad(f"unknown ar_strategy={self.ar_strategy!r}")
        if self.seq_parallel not in SEQ_PARALLEL_MODES:
            bad(f"unknown seq_parallel={self.seq_parallel!r}")
        if self.ar_quant not in AR_QUANT_MODES:
            bad(f"unknown ar_quant={self.ar_quant!r}")
        if self.admit_mode not in ADMIT_MODES:
            bad(f"unknown admit_mode={self.admit_mode!r}")
        if self.spec_mode not in SPEC_MODES:
            bad(f"unknown spec_mode={self.spec_mode!r}")
        if self.slots < 1:
            bad(f"slots={self.slots} must be >= 1")
        if self.tp < 1 or self.pods < 1:
            bad(f"tp={self.tp}/pods={self.pods} must be >= 1")
        if self.tp % self.pods:
            bad(f"tp={self.tp} not divisible by pods={self.pods}")
        if self.admit_mode == "chunked" and self.s_max % self.admit_chunk:
            bad(f"s_max={self.s_max} must be a multiple of "
                f"admit_chunk={self.admit_chunk}")
        if self.spec_mode and self.spec_k < 1:
            bad(f"spec_k must be >= 1, got spec_k={self.spec_k}")
        if self.ar_quant == "auto" and self.ar_strategy != "auto":
            bad("ar_quant='auto' rides the per-call-site autotuner: it "
                "requires --ar-strategy auto / ar_strategy='auto' (got "
                f"ar_strategy={self.ar_strategy!r})")
        if mode == "batch":
            if self.spec_adaptive:
                bad("spec_adaptive is trace-mode only (the batch engine "
                    "runs a fixed spec_k)")
            if self.fault_plan or self.deadline_ms is not None:
                bad("fault_plan/deadline_ms are trace-mode only (the "
                    "batch engine has no recovery machinery)")
            if self.disagg:
                bad("disagg is trace-mode only")
            if self.kv_quant:
                bad("kv_quant is trace-mode only (the batch engine's "
                    "prefill builds an fp cache)")
            if self.block_size and self.tp > 1:
                bad("block_size with mode='batch' is local-path only "
                    "(use mode='trace' for mesh-path paging)")
            if self.prefix_cache != "off":
                bad("prefix_cache is trace-mode only (admission-time "
                    "prefix splicing; the batch engine prefills once)")
        if self.prefix_cache not in PREFIX_MODES:
            bad(f"unknown prefix_cache={self.prefix_cache!r} (one of "
                f"{PREFIX_MODES})")
        if self.prefix_cache == "on":
            # ordered before the kv_quant block so a prefix_cache +
            # kv_quant combo is rejected naming prefix_cache (the field
            # the user just added)
            if not self.block_size:
                bad("prefix_cache='on' needs the paged KV layout: set "
                    f"block_size > 0 (got block_size={self.block_size}) "
                    "— prefix sharing is per physical block")
            if self.kv_quant:
                bad("prefix_cache is incompatible with kv_quant (the "
                    "int8 cache is dense-layout, full-admission only)")
            if self.disagg:
                bad("prefix_cache is incompatible with disagg: the "
                    "decode pool admits via KV handoff, not prompts "
                    "(colocated trace serving only)")
            if self.admit_chunk < 1 or self.admit_chunk % self.block_size:
                bad(f"admit_chunk={self.admit_chunk} must be a positive "
                    f"multiple of block_size={self.block_size} for "
                    "prefix_cache (a spliced prefix must end on a chunk "
                    "boundary)")
            if self.s_max % self.admit_chunk:
                bad(f"s_max={self.s_max} must be a multiple of "
                    f"admit_chunk={self.admit_chunk} for prefix_cache "
                    "(hits prefill their suffix through the chunked "
                    "executables)")
            if self.prefix_capacity is not None \
                    and self.prefix_capacity < 1:
                bad(f"prefix_capacity={self.prefix_capacity} must be "
                    ">= 1 (or None for pool-bounded)")
            from ..configs import get_smoke
            if get_smoke(self.arch).family != "dense":
                bad("prefix_cache rides the chunked suffix-prefill path: "
                    "dense (attention-only) families only, not "
                    f"arch={self.arch!r}")
        if self.kv_quant:
            if self.admit_mode == "chunked":
                bad("kv_quant is incompatible with admit_mode='chunked': "
                    "chunked prefill cannot re-read the int8 cache "
                    "mid-prompt (use admit_mode='full')")
            if self.block_size:
                bad("kv_quant is incompatible with block_size > 0 (paged "
                    "KV blocks are not scale-grouped); drop one of the "
                    "two")
            if self.spec_mode:
                bad("kv_quant is incompatible with spec_mode: the verify "
                    "pass rides chunked prefill over the int8 cache")
            if self.disagg:
                bad("kv_quant is incompatible with disagg: the KV "
                    "handoff ships fp states between pools")
        if self.disagg:
            if self.prefill_tp < 1 or self.decode_tp < 1:
                bad(f"prefill_tp={self.prefill_tp}/decode_tp="
                    f"{self.decode_tp} must be >= 1")
            if self.prefill_tp % self.prefill_pods:
                bad(f"prefill_tp={self.prefill_tp} not divisible by "
                    f"prefill_pods={self.prefill_pods}")
            if self.decode_tp % self.decode_pods:
                bad(f"decode_tp={self.decode_tp} not divisible by "
                    f"decode_pods={self.decode_pods}")
            if self.max_handoff_retries < 0 or self.max_reprefills < 0:
                bad("max_handoff_retries/max_reprefills must be >= 0")
        return self

    def replace(self, **kw) -> "ReplicaSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """A deployment: mode + replica template + fleet shape."""
    replica: ReplicaSpec
    mode: str = "trace"
    replicas: int = 1
    router_policy: str = "round_robin"

    def validate(self) -> "ServeSpec":
        """The single home of combo validation (CLI parse time, the
        factories, and router replica construction all call this)."""
        if self.replicas < 1:
            raise SpecError(f"replicas={self.replicas} must be >= 1")
        if self.router_policy not in ROUTER_POLICIES:
            raise SpecError(f"unknown router_policy="
                            f"{self.router_policy!r} (one of "
                            f"{ROUTER_POLICIES})")
        if self.replicas > 1 and self.mode != "trace":
            raise SpecError("replicas > 1 is trace-mode only (the router "
                            "tier replays a request trace)")
        self.replica.validate(mode=self.mode)
        return self

    def replace(self, **kw) -> "ServeSpec":
        return dataclasses.replace(self, **kw)

    # -- CLI / JSON -------------------------------------------------------

    @classmethod
    def from_args(cls, ns) -> "ServeSpec":
        """Build (and validate) a spec from a ``launch.serve`` argparse
        namespace.  CLI sentinel values are normalized here — the spec
        stores canonical forms (``spec_mode=None``, ``ar_quant='none'``)."""
        spec_mode = None if ns.spec_mode in (None, "none") else ns.spec_mode
        ar_quant = "none" if ns.ar_quant == "off" else ns.ar_quant
        replica = ReplicaSpec(
            arch=ns.arch, smoke=ns.smoke, tp=ns.tp, pods=ns.pods,
            ar_strategy=ns.ar_strategy, ar_table=ns.ar_table,
            overlap=ns.overlap, seq_parallel=ns.seq_parallel,
            ar_quant=ar_quant, slots=ns.slots, s_max=ns.s_max,
            block_size=ns.block_size, n_blocks=ns.n_blocks,
            kv_quant=ns.kv_quant, admit_mode=ns.admit_mode,
            admit_chunk=ns.admit_chunk, prefix_cache=ns.prefix_cache,
            prefix_capacity=ns.prefix_capacity, temperature=ns.temperature,
            top_k=ns.top_k, seed=ns.seed, spec_mode=spec_mode,
            spec_k=ns.spec_k, spec_adaptive=ns.spec_adaptive,
            draft_arch=ns.draft_arch, fault_plan=ns.fault_plan,
            deadline_ms=ns.deadline_ms, disagg=ns.disagg,
            prefill_tp=ns.prefill_tp, prefill_pods=ns.prefill_pods,
            decode_tp=ns.decode_tp, decode_pods=ns.decode_pods,
            prefill_ar_table=ns.prefill_ar_table,
            decode_ar_table=ns.decode_ar_table,
            prefill_per_step=ns.prefill_per_step)
        return cls(replica=replica, mode=ns.mode,
                   replicas=getattr(ns, "replicas", 1),
                   router_policy=getattr(ns, "router_policy",
                                         "round_robin")).validate()

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["replica"] = dataclasses.asdict(self.replica)
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "ServeSpec":
        """Inverse of :meth:`to_json`; unknown keys are an error (a
        mistyped field silently reverting to a default is exactly the
        config bug specs exist to prevent)."""
        d = json.loads(s)
        if not isinstance(d, dict):
            raise SpecError(f"spec JSON must be an object, got "
                            f"{type(d).__name__}")
        rd = d.pop("replica", None)
        if rd is None:
            raise SpecError("spec JSON is missing the 'replica' object")
        known_r = {f.name for f in dataclasses.fields(ReplicaSpec)}
        unknown = sorted(set(rd) - known_r)
        if unknown:
            raise SpecError(f"unknown ReplicaSpec field(s): {unknown}")
        known_s = {f.name for f in dataclasses.fields(cls)} - {"replica"}
        unknown = sorted(set(d) - known_s)
        if unknown:
            raise SpecError(f"unknown ServeSpec field(s): {unknown}")
        return cls(replica=ReplicaSpec(**rd), **d).validate()


# ---------------------------------------------------------------------------
# factories: the one construction path for every deployment shape
# ---------------------------------------------------------------------------


def _plan(spec: ReplicaSpec, tp: int):
    from ..configs import get_config, get_smoke
    from ..models.transformer import make_plan
    cfg = get_smoke(spec.arch) if spec.smoke else get_config(spec.arch)
    return make_plan(cfg, tp)


def _init_params(spec: ReplicaSpec, ap):
    import jax
    from ..models.transformer import init_params
    return init_params(jax.random.PRNGKey(spec.seed), ap)


def make_injector(spec: ReplicaSpec, replica_id: int = 0):
    """``spec.fault_plan`` -> :class:`FaultInjector` (None when absent).

    ``replica_id`` is folded into the plan seed so a fleet built from one
    template gets *independent* deterministic fault schedules per replica
    — one replica's drops/stalls never mirror onto another's requests
    (the per-replica fault-isolation contract, tested in
    tests/test_router.py)."""
    if spec.fault_plan is None:
        return None
    from .faults import FaultInjector, FaultPlan
    plan = FaultPlan.parse(spec.fault_plan)
    if replica_id:
        plan = dataclasses.replace(plan, seed=plan.seed + 7919 * replica_id)
    return FaultInjector(plan)


def build_engine(spec: ReplicaSpec, *, ap=None, params=None, drafter=None):
    """``ReplicaSpec`` -> :class:`InferenceEngine` (the batch path)."""
    from .engine import InferenceEngine
    from ..parallel.topology import mesh_and_ctx
    spec.validate(mode="batch")
    mesh, ctx, tp = mesh_and_ctx(
        spec.tp, spec.pods, ar_strategy=spec.ar_strategy,
        overlap=spec.overlap, seq_parallel=spec.seq_parallel,
        ar_quant=spec.ar_quant)
    if ap is None:
        ap = _plan(spec, tp)
    if params is None:
        params = _init_params(spec, ap)
    return InferenceEngine(
        ap, params, ctx=ctx, mesh=mesh, s_max=spec.s_max,
        fsdp_serve=spec.fsdp_serve, scan_layers=spec.scan_layers,
        temperature=spec.temperature, top_k=spec.top_k, seed=spec.seed,
        block_size=spec.block_size, ar_table=spec.ar_table,
        spec_mode=spec.spec_mode, spec_k=spec.spec_k,
        draft_arch=spec.draft_arch, drafter=drafter)


def build_prefill_pool(spec: ReplicaSpec, *, ap=None, params=None,
                       ar_table=None, devices=None):
    """``ReplicaSpec`` -> :class:`PrefillPool` on the spec's *prefill*
    layout (``prefill_tp``/``prefill_pods``; ``seq_parallel`` shapes the
    prefill pool only).  ``ar_table`` overrides ``spec.prefill_ar_table``
    (e.g. an already-resolved :func:`pool_tuner`)."""
    from .disagg import PrefillPool, pool_tuner
    from ..parallel.topology import mesh_and_ctx
    spec.validate(mode="trace")
    mesh, ctx, tp = mesh_and_ctx(
        spec.prefill_tp, spec.prefill_pods, ar_strategy=spec.ar_strategy,
        overlap=spec.overlap, seq_parallel=spec.seq_parallel,
        ar_quant=spec.ar_quant,
        devices=None if devices is None else devices[:spec.prefill_tp])
    if ap is None:
        ap = _plan(spec, tp)
    if params is None:
        params = _init_params(spec, ap)
    if ar_table is None:
        ar_table = pool_tuner(spec.prefill_ar_table or spec.ar_table)
    return PrefillPool(
        ap, params, s_max=spec.s_max, ctx=ctx, mesh=mesh,
        ar_table=ar_table, temperature=spec.temperature, top_k=spec.top_k,
        seed=spec.seed, scan_layers=spec.scan_layers,
        fsdp_serve=spec.fsdp_serve, admit_mode=spec.admit_mode,
        admit_chunk=spec.admit_chunk,
        block_size=spec.block_size if spec.prefill_block_size is None
        else spec.prefill_block_size)


def _build_batcher(spec: ReplicaSpec, *, ap, params, drafter, injector,
                   devices, ar_table, seq_parallel, deadline):
    from .scheduler import ContinuousBatcher
    from ..parallel.topology import mesh_and_ctx
    mesh, ctx, tp = mesh_and_ctx(
        spec.tp, spec.pods, ar_strategy=spec.ar_strategy,
        overlap=spec.overlap, seq_parallel=seq_parallel,
        ar_quant=spec.ar_quant, devices=devices)
    if ap is None:
        ap = _plan(spec, tp)
    if params is None:
        params = _init_params(spec, ap)
    return ContinuousBatcher(
        ap, params, slots=spec.slots, s_max=spec.s_max, ctx=ctx, mesh=mesh,
        block_size=spec.block_size, n_blocks=spec.n_blocks,
        kv_quant=spec.kv_quant, ar_table=ar_table,
        temperature=spec.temperature, top_k=spec.top_k, seed=spec.seed,
        scan_layers=spec.scan_layers, fsdp_serve=spec.fsdp_serve,
        admit_mode=spec.admit_mode, admit_chunk=spec.admit_chunk,
        spec_mode=spec.spec_mode, spec_k=spec.spec_k,
        spec_adaptive=spec.spec_adaptive, draft_arch=spec.draft_arch,
        drafter=drafter, injector=injector, deadline_s=deadline,
        spec_autodisable_after=spec.spec_autodisable_after,
        prefix_cache=spec.prefix_cache,
        prefix_capacity=spec.prefix_capacity)


def build_replica(spec: ReplicaSpec, *, ap=None, params=None, drafter=None,
                  injector=None, devices=None, replica_id: int = 0,
                  prefill_ap=None, prefill_params=None,
                  decode_ap=None, decode_params=None):
    """The one replica factory: ``ReplicaSpec`` ->
    :class:`ContinuousBatcher` (colocated) or :class:`DisaggCoordinator`
    (``spec.disagg``).  Validates first, so a router-constructed replica
    rejects exactly like the CLI.

    ``ap``/``params`` short-circuit plan/weight construction (tests and
    fleets share one weight init; params from ``PRNGKey(spec.seed)``
    otherwise, so sharing is the default behavior anyway).  ``devices``
    restricts the replica's mesh(es) to a disjoint device group (see
    ``parallel.topology.replica_device_groups``).  ``injector`` overrides
    the one :func:`make_injector` derives from ``spec.fault_plan`` +
    ``replica_id``.  ``prefill_ap``/``decode_ap`` (+ ``*_params``) give a
    disagg replica with *heterogeneous* pool TP degrees caller-built
    plans per pool — the dist cases feed both pools one tiny non-registry
    model this way.
    """
    spec.validate(mode="trace")
    if injector is None:
        injector = make_injector(spec, replica_id)
    if not spec.disagg:
        return _build_batcher(
            spec, ap=ap, params=params, drafter=drafter, injector=injector,
            devices=devices, ar_table=spec.ar_table,
            seq_parallel=spec.seq_parallel, deadline=spec.deadline_ms)
    # -- disaggregated replica: prefill pool + decode batcher + coordinator
    from .disagg import DisaggCoordinator, pool_tuner
    tuner_p = pool_tuner(spec.prefill_ar_table or spec.ar_table)
    tuner_d = pool_tuner(spec.decode_ar_table or spec.ar_table)
    # caller-supplied ap/params are honored only when both pools share one
    # TP layout (the common local-test shape); otherwise each pool gets
    # its own plan + params from PRNGKey(spec.seed) — same weights, each
    # pool's layout (the run_disagg contract) — unless the caller passed
    # explicit per-pool plans
    shared = spec.prefill_tp == spec.decode_tp
    if prefill_ap is None:
        prefill_ap = ap if shared else None
        prefill_params = params if shared else None
    pool = build_prefill_pool(
        spec, ap=prefill_ap, params=prefill_params,
        ar_table=tuner_p, devices=devices)
    # the decode pool admits via handoff splice, never from prompts —
    # force full-admission executables, fused (non-SP) residuals
    decode_spec = spec.replace(tp=spec.decode_tp, pods=spec.decode_pods,
                               admit_mode="full")
    if decode_ap is None:
        decode_ap = ap if shared else None
        decode_params = pool.params if shared else None
    decode = _build_batcher(
        decode_spec, ap=decode_ap, params=decode_params, drafter=drafter,
        injector=injector,
        devices=None if devices is None else devices[:spec.decode_tp],
        ar_table=tuner_d, seq_parallel="off", deadline=None)
    return DisaggCoordinator(
        pool, decode, prefill_per_step=spec.prefill_per_step,
        decode_tuner=tuner_d, injector=injector,
        max_handoff_retries=spec.max_handoff_retries,
        retry_backoff=spec.retry_backoff, max_ready=spec.max_ready,
        max_reprefills=spec.max_reprefills, deadline_s=spec.deadline_ms)


__all__ = ["ReplicaSpec", "ServeSpec", "SpecError", "ROUTER_POLICIES",
           "PREFIX_MODES", "build_replica", "build_engine",
           "build_prefill_pool", "make_injector"]
