"""Event-driven cluster simulator for multi-node inference performance.

Reproduces the paper's performance study quantitatively on CPU: per-step
times are built from analytic per-device FLOP/byte counts (roofline:
max(compute, memory)) plus collective times from the alpha-beta models in
:mod:`repro.core.comm_model`.  Three modelling choices carry the paper's
findings:

* **Decode GEMM tile floor** (Table 4): GEMM time uses M_eff = max(M, 128)
  — shrinking the token dimension below the MXU/SM tile yields no speedup,
  which is why PP cannot reduce decode matmul time (Obs. 2) while TP's
  K-split can.
* **TP all-reduce per layer**: 2 x AR(B x H) in decode (Sec. 3.5's message
  sizes) priced by the NCCL-best / NVRAR models (Obs. 3 / Sec. 4).
* **Pipeline bubbles**: HP latency uses the (m + p - 1)/m GPipe factor for
  prefill and per-token stage serialization for decode.

Used by benchmarks/bench_scaling.py (Figs. 1-2), bench_breakdown.py
(Figs. 3/8), bench_e2e.py (Fig. 7), bench_trace.py (Figs. 9/18) and
bench_moe.py (Fig. 10).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import comm_model as cm
from ..models.common import ModelConfig


# ---------------------------------------------------------------------------
# Hardware
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    flops_bf16: float      # FLOP/s
    hbm_bw: float          # B/s
    hbm_cap: float         # bytes
    gemm_tile_m: int = 128  # M below this yields no GEMM speedup (Table 4)
    efficiency: float = 0.55  # sustained fraction of peak for big GEMMs


A100 = ChipSpec("A100-80G", 312e12, 2.0e12, 80e9)
GH200 = ChipSpec("GH200", 989e12, 4.0e12, 96e9)
V5E = ChipSpec("TPUv5e", 197e12, 0.819e12, 16e9)

CHIP_FOR_NET = {"perlmutter": A100, "vista": GH200, "tpu_v5e": V5E}


# ---------------------------------------------------------------------------
# Analytic per-device costs
# ---------------------------------------------------------------------------


def _layer_gemm_flops(cfg: ModelConfig, m_tokens: int, tile_m: int) -> float:
    """Per-layer projection GEMM flops for M tokens with the tile-floor
    effect applied (M_eff)."""
    m_eff = max(m_tokens, tile_m)
    d, hd = cfg.d_model, cfg.head_dim
    qkvo = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
        + cfg.n_heads * hd * d
    if cfg.is_moe:
        ff = 3 * d * cfg.d_ff_expert * cfg.top_k
    else:
        ff = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    return 2.0 * m_eff * (qkvo + ff)


def _layer_attn_flops(cfg: ModelConfig, m_tokens: int, ctx: int) -> float:
    return 4.0 * m_tokens * ctx * cfg.n_heads * cfg.head_dim


def _layer_param_bytes(cfg: ModelConfig, dtype_bytes: int = 2,
                       active_only: bool = True) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    n = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
    if cfg.is_moe:
        n += 3 * d * cfg.d_ff_expert * (cfg.top_k if active_only
                                        else cfg.n_experts)
    else:
        n += (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    return n * dtype_bytes


def _kv_bytes_per_token_ctx(cfg: ModelConfig, ctx: int,
                            dtype_bytes: int = 2) -> float:
    return 2.0 * ctx * cfg.n_kv_heads * cfg.head_dim * dtype_bytes


# ---------------------------------------------------------------------------
# Collective timing
# ---------------------------------------------------------------------------


def ar_time(msg_bytes: float, *, algo: str, n_nodes: int, g: int,
            net: cm.NetworkSpec) -> float:
    if n_nodes * g <= 1:
        return 0.0
    if n_nodes <= 1:
        # intra-node ring all-reduce over NVLink/ICI
        t = 2 * (g - 1) * net.alpha_intra \
            + 2 * (g - 1) / g * msg_bytes / net.beta_intra
        if algo.startswith("nvrar"):
            # NVRAR degenerates to RS+AG with 3-phase launch overhead
            # (matches the paper's single-node slowdowns, Fig. 6)
            t += 2 * net.alpha_intra
        return t
    if algo == "nccl":
        return cm.nccl_model_best(msg_bytes, n_nodes, g, net)[1]
    if algo == "ring":
        return cm.t_ring_allreduce(msg_bytes, n_nodes, g, net)
    if algo == "tree":
        return cm.t_tree_allreduce(msg_bytes, n_nodes, g, net)
    if algo == "nvrar":
        return cm.t_nvrar(msg_bytes, n_nodes, g, net)
    if algo == "nvrar_halving":
        return cm.t_nvrar_variant(msg_bytes, n_nodes, g, net,
                                  inter="halving")
    raise ValueError(algo)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepBreakdown:
    matmul: float = 0.0
    other: float = 0.0
    comm: float = 0.0
    idle: float = 0.0

    @property
    def total(self) -> float:
        return self.matmul + self.other + self.comm + self.idle

    def add(self, o: "StepBreakdown"):
        self.matmul += o.matmul
        self.other += o.other
        self.comm += o.comm
        self.idle += o.idle


@dataclasses.dataclass
class ClusterSim:
    cfg: ModelConfig
    chip: ChipSpec
    net: cm.NetworkSpec
    n_gpus: int
    scheme: str = "tp"            # "tp" | "hp"
    ar_algo: str = "nccl"         # nccl | ring | tree | nvrar | nvrar_halving
    microbatches: int = 4         # HP prefill microbatching
    straggler_delay: float = 0.0  # per-AR extra latency from one slow node

    def __post_init__(self):
        g = self.net.gpus_per_node
        self.n_nodes = max(1, self.n_gpus // g)
        self.g = min(self.n_gpus, g)
        if self.scheme == "tp":
            self.tp = self.n_gpus
            self.pp = 1
        else:                       # HP: TP within node, PP across nodes
            self.tp = self.g
            self.pp = self.n_nodes

    # -- one forward pass over m tokens with context ctx (per layer group) --
    def _step_time(self, m_tokens: int, ctx: int, *, phase: str,
                   layers: int, with_ar: bool) -> StepBreakdown:
        cfg, chip = self.cfg, self.chip
        bd = StepBreakdown()
        eff = chip.flops_bf16 * chip.efficiency
        gemm_f = _layer_gemm_flops(cfg, m_tokens, chip.gemm_tile_m) / self.tp
        attn_f = _layer_attn_flops(cfg, m_tokens, ctx) / self.tp
        # memory: weights stream once per step; decode adds the KV read
        w_bytes = _layer_param_bytes(cfg) / self.tp
        kv_b = 0.0
        if phase == "decode":
            kv_b = m_tokens * _kv_bytes_per_token_ctx(cfg, ctx) / self.tp
        t_gemm = max(gemm_f / eff, w_bytes / chip.hbm_bw)
        t_attn = max(attn_f / eff, kv_b / chip.hbm_bw)
        bd.matmul += layers * t_gemm
        bd.other += layers * t_attn
        if with_ar and self.tp > 1:
            # 2 ARs per layer on (m_tokens x d_model) bf16
            msg = m_tokens * cfg.d_model * 2
            tp_nodes = max(1, self.tp // self.g)
            t_ar = ar_time(msg, algo=self.ar_algo, n_nodes=tp_nodes,
                           g=min(self.tp, self.g), net=self.net)
            t_ar += self.straggler_delay
            bd.comm += layers * 2 * t_ar
        return bd

    # -- public: one full-model forward ------------------------------------
    def prefill_time(self, batch: int, prompt_len: int) -> StepBreakdown:
        cfg = self.cfg
        m = batch * prompt_len
        if self.pp == 1:
            return self._step_time(m, prompt_len, phase="prefill",
                                   layers=cfg.n_layers, with_ar=True)
        # GPipe: m microbatches through pp stages
        mb = max(1, self.microbatches)
        stage = self._step_time(m // mb, prompt_len, phase="prefill",
                                layers=cfg.n_layers // self.pp,
                                with_ar=True)
        factor = (mb + self.pp - 1) / mb
        out = StepBreakdown(matmul=stage.matmul * mb,
                            other=stage.other * mb,
                            comm=stage.comm * mb)
        # bubble shows up as idle
        out.idle = stage.total * mb * (factor - 1.0)
        # p2p activation sends between stages
        act = (m // mb) * cfg.d_model * 2
        out.comm += (self.pp - 1) * (self.net.alpha_inter
                                     + act / self.net.beta_inter) * mb
        return out

    def decode_step_time(self, batch: int, ctx: int) -> StepBreakdown:
        cfg = self.cfg
        if self.pp == 1:
            return self._step_time(batch, ctx, phase="decode",
                                   layers=cfg.n_layers, with_ar=True)
        # PP decode: the token must traverse all stages serially; splitting
        # the batch into microbatches cannot shrink the tile-floored GEMMs.
        mb = min(self.microbatches, max(1, batch))
        stage = self._step_time(max(1, batch // mb), ctx, phase="decode",
                                layers=cfg.n_layers // self.pp,
                                with_ar=True)
        steps = mb + self.pp - 1
        out = StepBreakdown(matmul=stage.matmul * mb,
                            other=stage.other * mb,
                            comm=stage.comm * mb)
        out.idle = stage.total * (steps - mb)
        act = max(1, batch // mb) * cfg.d_model * 2
        out.comm += (self.pp - 1) * (self.net.alpha_inter
                                     + act / self.net.beta_inter) * mb
        return out


def simulate_batch_latency(cfg: ModelConfig, chip: ChipSpec,
                           net: cm.NetworkSpec, n_gpus: int, *,
                           scheme: str, ar_algo: str,
                           prompt_len: int, decode_len: int,
                           n_prompts: int,
                           straggler_delay: float = 0.0
                           ) -> Tuple[float, StepBreakdown]:
    """Time-to-completion of one batch (paper's batched-inference metric)."""
    sim = ClusterSim(cfg, chip, net, n_gpus, scheme=scheme,
                     ar_algo=ar_algo, straggler_delay=straggler_delay)
    total = StepBreakdown()
    total.add(sim.prefill_time(n_prompts, prompt_len))
    for t in range(decode_len):
        total.add(sim.decode_step_time(n_prompts, prompt_len + t))
    return total.total, total


def simulate_trace(cfg: ModelConfig, chip: ChipSpec, net: cm.NetworkSpec,
                   n_gpus: int, *, scheme: str, ar_algo: str,
                   arrivals: np.ndarray, in_lens: np.ndarray,
                   out_lens: np.ndarray, concurrency: int) -> Dict[str, float]:
    """Continuous-batching trace replay at step granularity (Fig. 9/18).

    Mixed prefill+decode steps: arrivals are admitted into free slots (up to
    ``concurrency``); each engine step advances every active request by one
    token, plus prefill cost for newly admitted ones.
    """
    sim = ClusterSim(cfg, chip, net, n_gpus, scheme=scheme, ar_algo=ar_algo)
    n = len(arrivals)
    order = np.argsort(arrivals)
    arrivals, in_lens, out_lens = (arrivals[order], in_lens[order],
                                   out_lens[order])
    now = 0.0
    qi = 0
    active: List[List[float]] = []   # [remaining, ctx]
    done_tokens = 0.0
    finish_time = 0.0
    while qi < n or active:
        # admit
        while qi < n and arrivals[qi] <= now and len(active) < concurrency:
            t_pref, _ = (sim.prefill_time(1, int(in_lens[qi])).total, None)
            now += t_pref
            active.append([float(out_lens[qi]), float(in_lens[qi])])
            qi += 1
        if not active:
            now = max(now, arrivals[qi] if qi < n else now)
            if qi < n and arrivals[qi] > now:
                now = arrivals[qi]
            continue
        b = len(active)
        ctx = int(np.mean([a[1] for a in active]))
        now += sim.decode_step_time(b, ctx).total
        done_tokens += b
        for a in active:
            a[0] -= 1
            a[1] += 1
        newly = [a for a in active if a[0] <= 0]
        if newly:
            finish_time = now
        active = [a for a in active if a[0] > 0]
    total_out = float(np.sum(out_lens))
    return {"makespan_s": now, "output_tokens": total_out,
            "throughput_tok_s": total_out / now if now > 0 else 0.0}


__all__ = ["ChipSpec", "A100", "GH200", "V5E", "ClusterSim",
           "StepBreakdown", "simulate_batch_latency", "simulate_trace",
           "ar_time"]
