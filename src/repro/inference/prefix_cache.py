"""Token-prefix radix trie over paged KV blocks (DESIGN.md §14).

At fleet scale most traffic shares long system/tool prompts, and every
re-prefilled shared token pays the full TP all-reduce tax the paper
fights to shrink — so the highest-leverage optimization is to not prefill
shared tokens at all.  This module keys *physical KV blocks* by the
prompt token IDs they cover: admission looks up the longest previously
prefilled prefix of the incoming prompt, splices those blocks into the
new slot's table via :meth:`BlockAllocator.share` (copy-on-write
refcounts, ``kv_cache.py``), and chunk-prefills only the suffix.

Layout: one trie node per KV block.  A node's edge key is the exact
``block_size``-token group it covers, so a root-to-node path spells a
prompt prefix of ``depth * block_size`` tokens and stores the physical
block for each group.  Lookup is a dict-walk per block group — O(S/bs)
with no per-token scanning, which is the point of block (radix)
granularity over a per-token trie.

Pinning: every resident node takes one external *hold* on its block
(:meth:`BlockAllocator.hold`), keeping it off the free list after the
admitting slot exits.  Eviction is LRU over nodes whose block has **zero
slot references** — a node some live slot still maps is never evicted
(its hold must outlive the sharer; dropping it early would let a later
``free`` recycle a block mid-read).  Eviction is leaf-first: interior
nodes become evictable once their subtree is gone, so a cold chain
drains from the tail — and it runs *only synchronously inside admission
or growth* (``capacity`` overflow after publish, or
:meth:`reclaim` under allocation pressure), never on a background
clock: between batcher steps the block/table state is frozen, which is
what keeps device table uploads transactional (DESIGN.md §14).

Determinism: greedy prefill is a pure function of the prompt tokens, so
any block previously prefilled for token group ``g`` holds bit-identical
K/V to what re-prefilling ``g`` at the same positions would write —
splicing is exact, not approximate.  That also means duplicate blocks
for the same group (two concurrent misses) are merely wasted capacity,
never a correctness hazard; ``insert`` keeps the first-published block.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .kv_cache import BlockAllocator


class _Node:
    """One KV block's worth of prompt tokens (an edge in the radix trie)."""
    __slots__ = ("key", "block", "parent", "children", "clock")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.clock = 0


class PrefixCache:
    """Radix trie of published prompt blocks, pinned via allocator holds.

    ``capacity``: max resident (held) blocks; inserts that push past it
    trigger LRU eviction of unreferenced nodes.  ``None`` = bounded only
    by the physical pool (reclaim under pressure still applies).
    Registers itself as a defragment remap hook on construction, so node
    block indices stay valid across :meth:`BlockAllocator.defragment`.
    """

    def __init__(self, alloc: BlockAllocator,
                 capacity: Optional[int] = None):
        self.alloc = alloc
        self.block_size = alloc.block_size
        self.capacity = capacity
        self._root = _Node((), -1, None)
        self._by_block: Dict[int, _Node] = {}
        self._clock = 0
        self.evictions = 0
        alloc.register_remap_hook(self.remap)

    # -- queries -----------------------------------------------------------

    @property
    def held_blocks(self) -> int:
        return len(self._by_block)

    @property
    def nodes(self) -> int:
        return len(self._by_block)

    def _groups(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n = len(tokens) // bs
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n)]

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Longest-prefix lookup: the physical blocks covering the most
        leading *complete* block groups of ``tokens`` already resident.
        Refreshes the LRU clock along the matched path."""
        self._clock += 1
        node = self._root
        blocks: List[int] = []
        for key in self._groups(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.clock = self._clock
            blocks.append(child.block)
            node = child
        return blocks

    # -- publication -------------------------------------------------------

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Publish a prefilled prompt's complete block groups.

        ``blocks``: the admitting slot's physical block for each group
        (``alloc.table[slot]`` prefix).  Groups already resident keep
        their first-published block (bit-identical contents — see module
        docstring); new groups take a hold on the slot's block, making
        it survive the slot.  Returns the number of newly pinned blocks.
        May evict LRU unreferenced nodes to stay within ``capacity``.
        """
        self._clock += 1
        node = self._root
        new = 0
        for key, b in zip(self._groups(tokens), blocks):
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(b), node)
                self.alloc.hold([int(b)])
                self._by_block[int(b)] = child
                node.children[key] = child
                new += 1
            child.clock = self._clock
            node = child
        if self.capacity is not None and self.held_blocks > self.capacity:
            self._evict_lru(self.held_blocks - self.capacity)
        return new

    # -- eviction ----------------------------------------------------------

    def _evictable(self) -> List[_Node]:
        """Leaves whose block no slot currently references."""
        return [n for n in self._by_block.values()
                if not n.children and self.alloc.slot_refs(n.block) == 0]

    def _drop(self, node: _Node) -> int:
        """Unlink one leaf and release its hold; returns blocks freed."""
        assert not node.children
        del node.parent.children[node.key]
        del self._by_block[node.block]
        self.evictions += 1
        return len(self.alloc.release([node.block]))

    def _evict_lru(self, n_nodes: int) -> int:
        """Evict up to ``n_nodes`` unreferenced leaves, oldest clock
        first (re-scanning as interior nodes become leaves)."""
        dropped = 0
        while dropped < n_nodes:
            cands = self._evictable()
            if not cands:
                break
            self._drop(min(cands, key=lambda nd: nd.clock))
            dropped += 1
        return dropped

    def reclaim(self, n_blocks: int) -> int:
        """Free at least ``n_blocks`` physical blocks by evicting LRU
        unreferenced nodes; returns blocks actually freed.  Called by the
        batcher under allocation pressure *before* it preempts a live
        request — cold cache beats evicted traffic."""
        freed = 0
        while freed < n_blocks:
            cands = self._evictable()
            if not cands:
                break
            freed += self._drop(min(cands, key=lambda nd: nd.clock))
        return freed

    def invalidate_block(self, phys: int) -> int:
        """Drop the node owning ``phys`` and its whole subtree (a
        poisoned/scrubbed block invalidates every extension of its
        prefix).  No-op if ``phys`` is not resident.  Returns nodes
        dropped."""
        node = self._by_block.get(phys)
        if node is None:
            return 0
        stack, order = [node], []
        while stack:
            n = stack.pop()
            order.append(n)
            stack.extend(n.children.values())
        for n in reversed(order):          # leaves first
            self._drop(n)
        return len(order)

    # -- defragment support ------------------------------------------------

    def remap(self, old_to_new: Dict[int, int]) -> None:
        """Rewrite node block indices after a defragment (allocator remap
        hook) — each resident block moves exactly once."""
        by_block: Dict[int, _Node] = {}
        for b, node in self._by_block.items():
            node.block = old_to_new[b]
            by_block[node.block] = node
        self._by_block = by_block


__all__ = ["PrefixCache"]
