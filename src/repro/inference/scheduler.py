"""Continuous batching over the decode step (Orca-style), plus trace replay.

The scheduler owns a fixed pool of batch slots.  Each engine step decodes all
active slots; freed slots (finished requests) are refilled from the waiting
queue, and refills trigger a slot-local prefill whose KV is spliced into the
shared cache.  Positions are per-slot, so the single decode-step executable
serves ragged batches — the same mechanism the paper's trace evaluation
(Sec. 5.2.3) relies on.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.pcontext import LOCAL
from ..models.transformer import init_cache, forward_lm, decode_step
from ..models import layers as L


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,)
    max_new: int
    arrival_s: float = 0.0
    # filled by the scheduler:
    first_token_s: float = -1.0
    done_s: float = -1.0
    output: Optional[np.ndarray] = None


class ContinuousBatcher:
    """Slot-based continuous batching on the local engine path."""

    def __init__(self, ap, params, *, slots: int = 8, s_max: int = 512):
        self.ap, self.cfg, self.params = ap, ap.cfg, params
        self.slots = slots
        self.s_max = s_max
        self._decode_jit = jax.jit(
            lambda cache, toks, pos: decode_step(self.params, cache, toks,
                                                 pos, self.ap, LOCAL),
            donate_argnums=(0,))
        self._prefill_jit = jax.jit(
            lambda tok: forward_lm(self.params, tok, self.ap, LOCAL,
                                   collect_state=True))
        self.cache = init_cache(ap, slots, s_max)
        self.positions = np.zeros((slots,), np.int32)
        self.remaining = np.zeros((slots,), np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.tokens = np.zeros((slots,), np.int32)
        self.outputs: Dict[int, List[int]] = {}

    # -- slot fill (prefill one request, splice its state into the cache) ---
    def _admit(self, slot: int, req: Request, now: float):
        tok = jnp.asarray(req.prompt[None, :], jnp.int32)
        logits, _, states, _ = self._prefill_jit(tok)
        S = req.prompt.shape[0]
        if "k" in self.cache:
            for nm in ("k", "v"):
                upd = states[nm].astype(self.cache[nm].dtype)  # (L,1,S,U,hd)
                self.cache[nm] = lax.dynamic_update_slice(
                    self.cache[nm], upd, (0, slot, 0, 0, 0))
        for nm in ("conv", "ssm", "shift_tm", "shift_cm", "wkv"):
            if nm in self.cache:
                upd = states[nm].astype(self.cache[nm].dtype)
                idx = (0, slot) + (0,) * (self.cache[nm].ndim - 2)
                self.cache[nm] = lax.dynamic_update_slice(self.cache[nm],
                                                          upd, idx)
        nxt = int(jnp.argmax(
            logits[0, -1, :self.cfg.vocab_size].astype(jnp.float32)))
        self.active[slot] = req
        self.positions[slot] = S
        self.remaining[slot] = req.max_new - 1
        self.tokens[slot] = nxt
        self.outputs[req.rid] = [nxt]
        req.first_token_s = now

    def _release(self, slot: int, now: float):
        req = self.active[slot]
        req.done_s = now
        req.output = np.asarray(self.outputs[req.rid], np.int32)
        self.active[slot] = None
        self.remaining[slot] = 0

    def step(self, now: float):
        """One decode step over all active slots."""
        if not any(a is not None for a in self.active):
            return
        logits, self.cache = self._decode_jit(
            self.cache, jnp.asarray(self.tokens),
            jnp.asarray(self.positions))
        nxt = np.asarray(jnp.argmax(
            logits[:, :self.cfg.vocab_size].astype(jnp.float32), axis=-1),
            np.int32)
        for s in range(self.slots):
            if self.active[s] is None:
                continue
            self.outputs[self.active[s].rid].append(int(nxt[s]))
            self.tokens[s] = nxt[s]
            self.positions[s] += 1
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or \
                    self.positions[s] >= self.s_max - 1:
                self._release(s, now)

    def run(self, requests: List[Request],
            max_steps: int = 100000) -> List[Request]:
        """Replay a trace (requests sorted by arrival) to completion."""
        waiting = sorted(requests, key=lambda r: r.arrival_s)
        qi = 0
        now = 0.0
        for _ in range(max_steps):
            # admit arrivals into free slots
            for s in range(self.slots):
                if self.active[s] is None and qi < len(waiting) and \
                        waiting[qi].arrival_s <= now:
                    self._admit(s, waiting[qi], now)
                    qi += 1
            if qi >= len(waiting) and all(a is None for a in self.active):
                break
            self.step(now)
            now += 1.0  # logical step clock
        return requests


def make_trace(n_requests: int, *, mean_in: int, mean_out: int,
               rate: float, burstiness: float = 2.0, vocab: int = 97,
               seed: int = 0) -> List[Request]:
    """BurstGPT-style synthetic trace: gamma inter-arrivals (shape=1/CV^2 ~
    burstiness), lognormal-ish lengths (paper Appendix C.4.2)."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / burstiness
    gaps = rng.gamma(shape, scale=1.0 / (rate * shape), size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        s_in = max(8, int(rng.lognormal(np.log(mean_in), 0.6)) // 8 * 8)
        s_out = max(1, int(rng.lognormal(np.log(mean_out), 0.6)))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, s_in).astype(np.int32),
            max_new=s_out, arrival_s=float(arrivals[i])))
    return reqs


__all__ = ["ContinuousBatcher", "Request", "make_trace"]
