"""Continuous batching over the decode step (Orca-style), plus trace replay.

The scheduler owns a fixed pool of batch slots.  Each engine step decodes all
active slots; freed slots (finished requests) are refilled from the waiting
queue.  Positions are per-slot, so the single decode-step executable serves
ragged batches — the mechanism the paper's trace evaluation (Sec. 5.2.3)
relies on.

This is the *unified serving stack* over the paged KV-cache subsystem:

* the per-step work (decode + sampling + token/position/remaining update)
  is one jitted executable built by ``parallel.steps.build_serve_step`` —
  with ``mesh=None`` it runs single-device, with a mesh it is the
  shard_map'd production step inheriting ``ar_table`` (``ar_strategy=
  "auto"``) and ``ctx.overlap_matmul``.  The host only reads back the
  emitted tokens and done flags.
* admission is a jitted on-device splice (``build_admit_step`` /
  ``build_admit_chunk_step``), not host ``dynamic_update_slice`` round
  trips.  ``admit_mode="chunked"`` feeds prompts through a fixed-size
  chunked prefill (one executable for all lengths; dense families);
  ``"full"`` runs one prefill executable per distinct prompt length
  (every family).
* with ``block_size > 0`` the KV cache is paged: a host-side
  :class:`~repro.inference.kv_cache.BlockAllocator` grows each slot's
  block list on demand and *preempts* (evicts + requeues) the youngest
  request when the pool runs dry, so a slot count that would overflow a
  dense ``(slots, s_max)`` cache keeps serving.
* with ``spec_mode`` set, the per-step executable becomes the fused
  draft-verify step (``build_spec_verify_step``): slots advance by
  variable accepted lengths, rejected-draft K/V rolls back via
  ``BlockAllocator.truncate``, and the per-layer all-reduce is amortized
  over up to ``spec_k + 1`` tokens per step (DESIGN.md §8).

* for disaggregated serving, :meth:`ContinuousBatcher.admit_prefilled`
  admits a context prefilled by *another pool*: a canonical
  :class:`~repro.inference.kv_cache.KVBundle` is resharded into this
  batcher's GQA slot layout and spliced on device
  (``build_kv_splice_step``); ``inference.disagg.DisaggCoordinator``
  drives the batcher step-by-step in that deployment (DESIGN.md §9).

Scheduling time is a logical step clock (1.0 per engine step) so traces
replay deterministically; wall-clock timestamps are recorded alongside for
TTFT / TPOT reporting (see :class:`ServeMetrics`).

Invariants inherited from the cache layer (see ``kv_cache``): block-0 is
trash (stale-slot writes are routed there, never read), and freed blocks
may hold stale K/V (write-ordering: re-read only after overwrite).  Known
gaps: chunked admission and speculative decoding are dense-family-only
(recurrent states cannot skip pads; MoE routing is load-dependent), and a
paged mesh cache cannot shard slots over dp axes — run one batcher per
data-parallel replica.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.pcontext import ParallelCtx, LOCAL
from ..parallel.steps import (build_admit_chunk_step, build_admit_step,
                              build_cache_init, build_kv_splice_step,
                              build_serve_step, build_spec_verify_step)
from .faults import FaultInjector
from .kv_cache import (BlockAllocator, KVBundle, heads_to_slots,
                       paged_geometry)
from .prefix_cache import PrefixCache
from .speculative import AdaptiveK, Drafter, make_drafter


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,)
    max_new: int
    arrival_s: float = 0.0       # logical (step-clock) arrival
    # TTFT deadline in logical steps from arrival (inf = none); expired
    # never-admitted requests are shed, not served (DESIGN.md §11)
    deadline_s: float = float("inf")
    # filled by the scheduler:
    first_token_s: float = -1.0  # wall-clock, relative to run() start
    done_s: float = -1.0         # wall-clock, relative to run() start
    admit_step: int = -1         # logical step of (last) admission
    done_step: int = -1
    preempted: int = 0           # times evicted and recomputed
    output: Optional[np.ndarray] = None
    # shed bookkeeping: a shed request's output stays None, but it is
    # always *reported* (shed_reason set, counted in metrics) — the
    # never-silently-dropped contract
    shed_step: int = -1
    shed_reason: Optional[str] = None


def _percentile(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else float("nan")


def request_sampling_key(seed: int, rid: int) -> jax.Array:
    """Base of request ``rid``'s per-slot sampling chain.

    Token ``t`` of the request is drawn with ``fold_in(base, t)`` — a
    *stateless* chain keyed on the request, not on the global step
    schedule.  Consequences the suite relies on: sampled streams are
    identical across colocated and disaggregated deployments (the base key
    travels in ``KVBundle.rng``), and a preempted request's recompute
    resamples its original tokens.  temperature=0 paths never consult it.
    """
    return jax.random.fold_in(jax.random.PRNGKey(seed), rid)


def run_chunked_prefill(params, cache, prompt: np.ndarray, slot, chunk: int,
                        mid_fn, final_fn, mid_rng, final_rng,
                        start_chunk: int = 0):
    """Drive a prompt through the chunked-prefill executables into cache
    row ``slot``: intermediate chunks via the logits-free ``mid_fn``
    (``mid_rng`` untouched — nothing samples), the final chunk via
    ``final_fn`` which samples the first token at in-chunk index
    ``(S-1) % chunk``.  Shared by colocated admission
    (:meth:`ContinuousBatcher._admit`) and the disaggregated prefill pool
    — the bitwise-parity guarantee between those deployments depends on
    this being ONE code path.  Returns (first_token_dev, cache).

    ``start_chunk`` skips the leading chunks whose K/V the slot's table
    already maps (a prefix-cache hit, DESIGN.md §14): chunk ``i`` writes
    the same values at the same positions regardless of where the loop
    starts, so a suffix prefill over spliced shared blocks is
    bit-identical to the full one.  ``start_chunk`` must be < n_chunks —
    the final chunk always runs (it samples the first token)."""
    S = int(prompt.shape[0])
    padded = np.zeros((-(-S // chunk) * chunk,), np.int32)
    padded[:S] = prompt
    n_chunks = padded.shape[0] // chunk
    assert 0 <= start_chunk < n_chunks, (start_chunk, n_chunks)
    last = jnp.int32((S - 1) % chunk)
    slot = jnp.int32(slot)
    tok = None
    for i in range(start_chunk, n_chunks):
        x = jnp.asarray(padded[None, i * chunk:(i + 1) * chunk])
        pos = jnp.arange(i * chunk, (i + 1) * chunk,
                         dtype=jnp.int32)[None]
        if i < n_chunks - 1:
            cache = mid_fn(params, cache, x, pos, slot, last, mid_rng)
        else:
            tok, cache = final_fn(params, cache, x, pos, slot, last,
                                  final_rng)
    return tok, cache


@dataclasses.dataclass
class ServeMetrics:
    """Aggregate trace-replay metrics.

    TTFT (time-to-first-token) and TPOT (time-per-output-token) are
    reported in logical *steps* (deterministic: admission wait + prefill
    counts 1 step) and converted to wall seconds via the measured mean
    step time, so the numbers are stable under CI jitter but still carry
    real units.

    Speculative-decoding fields (all zero when ``spec_mode`` is off):

    * ``spec_steps``    — verify passes run (each replaces up to k+1
      sequential decode steps).
    * ``drafted_tokens`` / ``accepted_tokens`` — totals over the trace;
      ``acceptance_rate`` is their ratio (fraction of drafted tokens the
      target model verified — counted at verification, so a request
      terminating mid-run can verify more drafts than it emits).
    * ``accepted_tokens_per_step`` — mean verified drafts per verify
      pass across all slots: the per-step all-reduce amortization factor
      (an upper bound on emitted-tokens-per-step minus the active-slot
      count, tight when no request terminates mid-speculation).
    * ``drafter_hit_rate`` — fraction of ``draft()`` calls where the
      drafter found a real candidate instead of falling back.
    * ``spec_k_mean`` — mean speculation length used (moves under
      ``spec_adaptive``).
    """
    requests: int
    completed: int
    total_new_tokens: int
    steps: int
    wall_s: float
    throughput_tok_s: float
    ttft_steps_p50: float
    ttft_steps_p99: float
    tpot_steps_p50: float
    tpot_steps_p99: float
    ttft_s_p50: float
    ttft_s_p99: float
    tpot_s_p50: float
    tpot_s_p99: float
    preemptions: int
    peak_kv_tokens: int          # high-water cache footprint, in tokens
    kv_capacity_tokens: int      # reserved footprint of the layout
    cache_utilization: float     # occupied / reserved at peak-usage basis
    cache_stats: Optional[Dict[str, Any]] = None
    # speculative decoding (see class docstring; zeros when disabled)
    spec_steps: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    acceptance_rate: float = 0.0
    accepted_tokens_per_step: float = 0.0
    drafter_hit_rate: float = 0.0
    spec_k_mean: float = 0.0
    # robustness (DESIGN.md §11; zeros on a fault-free run):
    # * ``quarantines``       — slots evicted on non-finite logits and
    #   recomputed through the preemption path (exact replay).
    # * ``injected_oom``      — growths denied by an injected OOM burst
    #   (the growing slot is evicted + requeued, not its neighbours).
    # * ``shed_requests``     — deadline-expired requests dropped *before*
    #   admission; reported (``shed_reason``), never silently lost.
    # * ``spec_autodisables`` — slots whose speculative decoding was
    #   degraded to plain decode (verify fault or acceptance collapse).
    # * ``straggler_steps``   — steps carrying an injected wall-clock
    #   delay (logical clock untouched: latency noise, not token change).
    # * ``wasted_tokens``     — tokens decoded then discarded by an
    #   eviction (preemption / OOM / quarantine) and re-decoded from
    #   scratch; ``total / (total + wasted)`` is the useful-work goodput
    #   fraction ``benchmarks/bench_faults.py`` gates on.
    quarantines: int = 0
    injected_oom: int = 0
    shed_requests: int = 0
    spec_autodisables: int = 0
    straggler_steps: int = 0
    wasted_tokens: int = 0
    # prefix cache (DESIGN.md §14; zeros when ``prefix_cache="off"``):
    # * ``prefix_lookups``      — admissions that consulted the trie.
    # * ``prefix_hits``         — admissions that spliced >= 1 chunk.
    # * ``prefix_tokens_saved`` — prompt tokens never re-prefilled (each
    #   skipped an entire chunk of per-layer TP all-reduces).
    # * ``prefix_hit_rate``     — hits / lookups.
    prefix_lookups: int = 0
    prefix_hits: int = 0
    prefix_tokens_saved: int = 0
    prefix_hit_rate: float = 0.0
    # Retained per-request latency samples (logical steps) so a fleet
    # aggregation can recompute exact percentiles instead of averaging
    # per-replica p99s (see :meth:`merge`).  Excluded from ``to_dict`` —
    # bench JSON rows stay scalar-only.
    ttft_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)
    tpot_steps_samples: List[float] = dataclasses.field(
        default_factory=list, repr=False)

    SAMPLE_FIELDS = ("ttft_steps_samples", "tpot_steps_samples")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for k in self.SAMPLE_FIELDS:
            d.pop(k, None)
        return d

    @classmethod
    def merge(cls, parts: List["ServeMetrics"]) -> "ServeMetrics":
        """Lossless fleet aggregation over per-replica metrics.

        Counters and token totals are summed; TTFT/TPOT percentiles are
        **recomputed from the retained samples** (a mean/max of
        per-replica p99s is not a fleet p99 — the average-of-averages
        bug this method exists to avoid); ``steps``/``wall_s`` take the
        max because replicas advance in lockstep on one shared logical
        clock; footprints sum (a fleet reserves every replica's cache);
        ratio fields are recomputed from the summed numerators and
        denominators.  ``cache_stats`` is dropped (per-replica detail —
        the router keeps the unmerged parts alongside)."""
        if not parts:
            raise ValueError("merge() needs at least one ServeMetrics")
        ttft = [s for m in parts for s in m.ttft_steps_samples]
        tpot = [s for m in parts for s in m.tpot_steps_samples]
        steps = max(m.steps for m in parts)
        wall = max(m.wall_s for m in parts)
        step_s = wall / steps if steps else 0.0
        total_new = sum(m.total_new_tokens for m in parts)
        drafted = sum(m.drafted_tokens for m in parts)
        accepted = sum(m.accepted_tokens for m in parts)
        spec_steps = sum(m.spec_steps for m in parts)
        peak = sum(m.peak_kv_tokens for m in parts)
        # sum of per-replica occupied peaks over sum of reserved peaks
        occupied = sum(m.cache_utilization * m.peak_kv_tokens
                       for m in parts)
        wsum = lambda f: sum(getattr(m, f) * m.spec_steps for m in parts)
        return cls(
            requests=sum(m.requests for m in parts),
            completed=sum(m.completed for m in parts),
            total_new_tokens=total_new, steps=steps, wall_s=wall,
            throughput_tok_s=total_new / wall if wall > 0 else 0.0,
            ttft_steps_p50=_percentile(ttft, 50),
            ttft_steps_p99=_percentile(ttft, 99),
            tpot_steps_p50=_percentile(tpot, 50),
            tpot_steps_p99=_percentile(tpot, 99),
            ttft_s_p50=_percentile(ttft, 50) * step_s,
            ttft_s_p99=_percentile(ttft, 99) * step_s,
            tpot_s_p50=_percentile(tpot, 50) * step_s,
            tpot_s_p99=_percentile(tpot, 99) * step_s,
            preemptions=sum(m.preemptions for m in parts),
            peak_kv_tokens=peak,
            kv_capacity_tokens=sum(m.kv_capacity_tokens for m in parts),
            cache_utilization=occupied / peak if peak else 0.0,
            cache_stats=None,
            spec_steps=spec_steps,
            drafted_tokens=drafted, accepted_tokens=accepted,
            acceptance_rate=accepted / drafted if drafted else 0.0,
            accepted_tokens_per_step=accepted / spec_steps
            if spec_steps else 0.0,
            drafter_hit_rate=wsum("drafter_hit_rate") / spec_steps
            if spec_steps else 0.0,
            spec_k_mean=wsum("spec_k_mean") / spec_steps
            if spec_steps else 0.0,
            quarantines=sum(m.quarantines for m in parts),
            injected_oom=sum(m.injected_oom for m in parts),
            shed_requests=sum(m.shed_requests for m in parts),
            spec_autodisables=sum(m.spec_autodisables for m in parts),
            straggler_steps=sum(m.straggler_steps for m in parts),
            wasted_tokens=sum(m.wasted_tokens for m in parts),
            prefix_lookups=sum(m.prefix_lookups for m in parts),
            prefix_hits=sum(m.prefix_hits for m in parts),
            prefix_tokens_saved=sum(m.prefix_tokens_saved for m in parts),
            prefix_hit_rate=sum(m.prefix_hits for m in parts) /
            sum(m.prefix_lookups for m in parts)
            if sum(m.prefix_lookups for m in parts) else 0.0,
            ttft_steps_samples=ttft, tpot_steps_samples=tpot)


class ContinuousBatcher:
    """Slot-based continuous batching on the local or mesh engine path."""

    def __init__(self, ap, params, *, slots: int = 8, s_max: int = 512,
                 ctx: ParallelCtx = LOCAL, mesh=None,
                 block_size: int = 0, n_blocks: Optional[int] = None,
                 kv_quant: bool = False,
                 ar_table: Optional[str] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 scan_layers: bool = True, fsdp_serve: bool = False,
                 admit_mode: str = "full", admit_chunk: int = 32,
                 spec_mode: Optional[str] = None, spec_k: int = 4,
                 spec_adaptive: bool = False,
                 draft_arch: str = "llama3.2-1b",
                 drafter: Optional[Drafter] = None,
                 injector: Optional[FaultInjector] = None,
                 deadline_s: Optional[float] = None,
                 spec_autodisable_after: int = 0,
                 prefix_cache: str = "off",
                 prefix_capacity: Optional[int] = None):
        """``spec_mode`` turns on speculative decoding: each engine step
        drafts ``spec_k`` tokens per slot (``"ngram"`` prompt-lookup,
        ``"draft"`` small model from ``configs.registry`` via
        ``draft_arch``, or an injected ``drafter``) and verifies them in
        one fused pass (``build_spec_verify_step``), emitting a variable
        1..spec_k+1 tokens per slot per step.  Greedy spec streams are
        bitwise-identical to plain greedy decode; rejected-draft K/V is
        rolled back via ``BlockAllocator.truncate`` on the paged path.
        ``spec_adaptive`` walks k along {2,4,8}∩[1,spec_k] by acceptance
        rate.  Dense (attention-only) families only.

        Robustness knobs (DESIGN.md §11): ``injector`` is a
        :class:`~repro.inference.faults.FaultInjector` consulted at the
        step hooks (poison/OOM/straggler); ``deadline_s`` is a default
        TTFT deadline in logical steps (per-request ``Request.deadline_s``
        tightens it) — expired never-admitted requests are shed;
        ``spec_autodisable_after`` > 0 degrades a slot to plain decode
        after that many consecutive zero-accept verify passes (0 = off;
        a verify-path fault always disables the slot's speculation)."""
        self.ap, self.cfg, self.params = ap, ap.cfg, params
        self.slots = slots
        self.s_max = s_max
        self.ctx = ctx
        self.mesh = mesh
        self.temperature = temperature
        self.top_k = top_k
        self.seed = seed
        self._rng = jax.random.PRNGKey(seed)
        if admit_mode not in ("full", "chunked"):
            raise ValueError(f"unknown admit_mode {admit_mode!r}")
        if admit_mode == "chunked" and self.cfg.family != "dense":
            raise ValueError("chunked admission needs an attention-only "
                             f"dense family, not {self.cfg.family!r}")
        if admit_mode == "chunked" and s_max % admit_chunk:
            # trailing-chunk pads would reach positions >= s_max; the paged
            # write path routes those to trash, but keep geometry exact
            raise ValueError(f"s_max={s_max} must be a multiple of "
                             f"admit_chunk={admit_chunk}")
        self.admit_mode = admit_mode
        self.admit_chunk = admit_chunk
        self.block_size = block_size
        # paging applies to the self-attention K/V only; attention-free
        # archs (rwkv) have fixed-size recurrent state and stay dense
        self.paged = block_size > 0 and not self.cfg.attn_free
        # -- prefix cache (DESIGN.md §14) -----------------------------------
        if prefix_cache not in ("off", "on"):
            raise ValueError(f"unknown prefix_cache {prefix_cache!r}")
        self.prefix_on = prefix_cache == "on"
        if self.prefix_on:
            if not self.paged:
                raise ValueError("prefix_cache needs the paged KV layout "
                                 "(block_size > 0, attention family) — "
                                 "sharing is per physical block")
            if self.cfg.family != "dense":
                raise ValueError("prefix_cache rides the chunked suffix-"
                                 "prefill path: dense families only, not "
                                 f"{self.cfg.family!r}")
            if kv_quant:
                raise ValueError("prefix_cache is incompatible with "
                                 "kv_quant (int8 cache is dense-layout, "
                                 "full-admission only)")
            if admit_chunk < 1 or admit_chunk % block_size:
                # a spliced prefix must end exactly where a chunk starts
                raise ValueError(f"admit_chunk={admit_chunk} must be a "
                                 f"positive multiple of "
                                 f"block_size={block_size} "
                                 "for prefix_cache")
            if s_max % admit_chunk:
                raise ValueError(f"s_max={s_max} must be a multiple of "
                                 f"admit_chunk={admit_chunk} for "
                                 "prefix_cache")
        if kv_quant:
            # the unsupported combinations all die deep inside jitted code
            # (prefill_chunk / init_cache asserts) — reject them here with
            # the actual reason instead
            if admit_mode == "chunked":
                raise ValueError("kv_quant needs full-prefill admission: "
                                 "chunked prefill cannot re-read the int8 "
                                 "cache mid-prompt")
            if self.paged:
                raise ValueError("kv_quant is incompatible with the paged "
                                 "KV layout (block_size > 0)")
            if spec_mode:
                raise ValueError("kv_quant is incompatible with "
                                 "speculative decoding (the verify pass "
                                 "rides chunked prefill)")
        self.kv_quant = kv_quant
        kw = dict(s_max=s_max, slots=slots, scan_layers=scan_layers,
                  fsdp_serve=fsdp_serve,
                  block_size=block_size if self.paged else 0,
                  kv_quant=kv_quant, n_blocks=n_blocks)
        self.alloc: Optional[BlockAllocator] = None
        if self.paged:
            max_blocks = paged_geometry(s_max, block_size)
            if n_blocks is None:
                kw["n_blocks"] = n_blocks = slots * max_blocks + 1
            self.alloc = BlockAllocator(n_blocks, block_size, slots,
                                        max_blocks)
        sample_kw = dict(temperature=temperature, top_k=top_k)
        self.cache = build_cache_init(
            ap, ctx, mesh, **{k: v for k, v in kw.items()
                              if k != "scan_layers"}).jit()()
        self._serve = build_serve_step(ap, ctx, mesh, ar_table=ar_table,
                                       **sample_kw, **kw).jit()
        self._admit_kw = dict(ar_table=ar_table, **sample_kw, **kw)
        # -- speculative decoding wiring ------------------------------------
        self.spec_mode = spec_mode
        self.spec_k = spec_k
        self.drafter: Optional[Drafter] = None
        self._speck: Optional[AdaptiveK] = None
        self._spec_fns: Dict[int, Any] = {}     # k -> jitted verify step
        self._spec_kw = dict(self._admit_kw)
        self._spec_steps = 0
        self._spec_drafted = 0
        self._spec_accepted = 0
        self._spec_k_sum = 0
        if drafter is not None and not spec_mode:
            raise ValueError("an injected drafter needs spec_mode set "
                             "(got drafter= without spec_mode=)")
        if spec_mode:
            if self.cfg.family != "dense":
                raise ValueError("speculative decoding rides the chunked-"
                                 "prefill verify path: dense families "
                                 f"only, not {self.cfg.family!r}")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            self.drafter = drafter if drafter is not None else \
                make_drafter(spec_mode, draft_arch=draft_arch, seed=seed)
            if spec_adaptive:
                self._speck = AdaptiveK(ks=tuple(sorted(
                    {k2 for k2 in (2, 4, 8) if k2 <= spec_k} | {spec_k})))
        self._admit_full: Dict[int, Any] = {}   # prompt_len -> jitted fn
        self._splice_fns: Dict[int, Any] = {}   # handoff len -> jitted fn
        self._admit_chunked = None
        if admit_mode == "chunked" or self.prefix_on:
            # final chunk samples the first token; intermediate chunks run
            # a logits-free executable (no vocab head / TP gather).  A
            # prefix-cache hit prefills its suffix through these even
            # under admit_mode="full" (chunked == full bitwise, so the
            # hit/miss paths emit identical tokens).
            self._admit_chunked = build_admit_chunk_step(
                ap, ctx, mesh, chunk=admit_chunk, **self._admit_kw).jit()
            self._admit_chunked_mid = build_admit_chunk_step(
                ap, ctx, mesh, chunk=admit_chunk, sample=False,
                **self._admit_kw).jit()
        self.prefix: Optional[PrefixCache] = None
        if self.prefix_on:
            self.prefix = PrefixCache(self.alloc, capacity=prefix_capacity)
        self._prefix_lookups = 0
        self._prefix_hits = 0
        self._prefix_tokens_saved = 0
        if self.paged:
            self.cache["block_tbl"] = jnp.asarray(self.alloc.table)

        # host mirrors of the device-side slot state
        self.positions = np.zeros((slots,), np.int32)
        self.remaining = np.zeros((slots,), np.int32)
        self.tokens = np.zeros((slots,), np.int32)
        self.active_mask = np.zeros((slots,), bool)
        # per-slot sampling chain: base key + tokens sampled so far (slot
        # s's next token draws with fold_in(slot_key[s], sample_idx[s]))
        self.slot_key = np.zeros((slots, 2), np.uint32)
        self.sample_idx = np.zeros((slots,), np.int32)
        self._admit_seq = np.full((slots,), -1, np.int64)  # admission order
        self._seq = 0
        self.active: List[Optional[Request]] = [None] * slots
        self.outputs: Dict[int, List[int]] = {}
        self._state = None       # device state dict (pushed lazily)
        self._dirty = True
        self._table_version = -1
        self.steps_run = 0
        self._wall0 = None
        self._wall_run = 0.0     # wall seconds of the last run(), at drain
        self._peak_occupied = 0  # max sum of live positions, in tokens
        self._requeue: List[Request] = []   # preempted, awaiting re-admission
        # evictions this run, counted at the batcher so the dense layout
        # reports them too (the allocator's counter only exists when
        # paged; the two agree on the paged path — asserted in metrics())
        self._preemptions = 0
        # -- robustness state (DESIGN.md §11) --------------------------------
        self.injector = injector
        self.deadline_s = deadline_s
        self.spec_autodisable_after = spec_autodisable_after
        self._quarantines = 0
        self._injected_oom = 0
        self._straggler_steps = 0
        self._spec_autodisables = 0
        self._shed: List[Request] = []      # shed this run (reported)
        self._wasted_tokens = 0
        self._oom_now = False               # injected burst, this step only
        self._spec_deny: set = set()        # rids degraded to plain decode
        self._spec_zero_acc = np.zeros((slots,), np.int64)
        self._tainted: set = set()          # slots holding injected NaN

    # -- state/device sync ---------------------------------------------------

    def _push_state(self):
        self._state = {"tokens": jnp.asarray(self.tokens),
                       "positions": jnp.asarray(self.positions),
                       "remaining": jnp.asarray(self.remaining),
                       "active": jnp.asarray(self.active_mask),
                       "rng": jnp.asarray(self.slot_key),
                       "sample_idx": jnp.asarray(self.sample_idx)}
        self._dirty = False

    def _sync_table(self):
        if self.alloc.version != self._table_version:
            self.cache["block_tbl"] = jnp.asarray(self.alloc.table)
            self._table_version = self.alloc.version

    def _step_rng(self):
        if self.temperature > 0.0:
            self._rng, r = jax.random.split(self._rng)
            return r
        return self._rng

    # -- admission -----------------------------------------------------------

    def _admit_fn(self, prompt_len: int):
        fn = self._admit_full.get(prompt_len)
        if fn is None:
            fn = build_admit_step(self.ap, self.ctx, self.mesh,
                                  prompt_len=prompt_len,
                                  **self._admit_kw).jit()
            self._admit_full[prompt_len] = fn
        return fn

    def _prefix_match(self, prompt: np.ndarray) -> List[int]:
        """Longest resident prefix of ``prompt``, truncated to what the
        suffix-prefill can actually skip: whole ``admit_chunk`` multiples
        (the spliced prefix must end exactly where a chunk starts) and at
        most ``S-1`` tokens (the final chunk always recomputes — it
        samples the first token).  Returns the physical blocks to splice.
        """
        S = int(prompt.shape[0])
        self._prefix_lookups += 1
        blocks = self.prefix.match(prompt)
        cap = ((S - 1) // self.admit_chunk) * self.admit_chunk
        shared = min(len(blocks) * self.block_size, cap)
        shared = (shared // self.admit_chunk) * self.admit_chunk
        return blocks[:shared // self.block_size]

    def _admit(self, slot: int, req: Request, now: float) -> bool:
        """Prefill one request into ``slot`` (on-device splice).  Returns
        False when the paged pool cannot hold the prompt right now.

        With the prefix cache on, admission first splices the longest
        resident prompt prefix (``share``: refcounts up, zero copies) and
        chunk-prefills only the suffix; after prefill, every fully-
        prompt-covered block is published back to the trie.  Ordering
        matters: the share happens *before* any trie reclaim, so
        allocation pressure can never evict the blocks just matched."""
        S = int(req.prompt.shape[0])
        if S + 1 > self.s_max:
            raise ValueError(f"prompt len {S} + 1 exceeds s_max={self.s_max}")
        shared_blocks: List[int] = []
        if self.prefix is not None:
            shared_blocks = self._prefix_match(req.prompt)
            if shared_blocks:
                self.alloc.share(slot, shared_blocks)
        if self.alloc is not None:
            # +1: the first decode write lands at position S
            if self.prefix is not None \
                    and not self.alloc.can_allocate(slot, S + 1):
                # cold trie blocks before live traffic: reclaim only what
                # the growth is short (matched blocks are slot-referenced
                # now — unevictable)
                need = self.alloc.blocks_for(S + 1) - \
                    len(self.alloc.owned(slot))
                self.prefix.reclaim(need - self.alloc.free_blocks)
            if not self.alloc.ensure(slot, S + 1):
                if shared_blocks:
                    self.alloc.free(slot)   # drop just-taken references
                return False
            self._sync_table()
        base = request_sampling_key(self.seed, req.rid)
        first = jax.random.fold_in(base, 0)   # token 0 of the chain
        if shared_blocks:
            n_shared = len(shared_blocks) * self.block_size
            self._prefix_hits += 1
            self._prefix_tokens_saved += n_shared
            tok, self.cache = run_chunked_prefill(
                self.params, self.cache, req.prompt, slot,
                self.admit_chunk, self._admit_chunked_mid,
                self._admit_chunked, self._rng, first,
                start_chunk=n_shared // self.admit_chunk)
        elif self.admit_mode == "chunked":
            tok, self.cache = run_chunked_prefill(
                self.params, self.cache, req.prompt, slot,
                self.admit_chunk, self._admit_chunked_mid,
                self._admit_chunked, self._rng, first)
        else:
            tok, self.cache = self._admit_fn(S)(
                self.params, self.cache, jnp.asarray(req.prompt[None]),
                jnp.int32(slot), first)
        if self.prefix is not None:
            # publish before activation (max_new == 1 releases the slot
            # inside _activate): every block whose positions are all
            # prompt is pinned — decode writes land at >= S, never here
            n_pub = S // self.block_size
            if n_pub:
                self.prefix.insert(req.prompt,
                                   self.alloc.owned(slot)[:n_pub])
        self._activate(slot, req, int(np.asarray(tok)[0]), S, now,
                       rng_key=base)
        return True

    def _activate(self, slot: int, req: Request, nxt: int, S: int,
                  now: float, rng_key=None) -> None:
        """Post-admission bookkeeping shared by local prefill admission and
        disaggregated handoff admission: the slot holds ``req`` at position
        ``S`` with first token ``nxt`` already emitted (sampled as token 0
        of the request's chain).  ``rng_key`` is that chain's base key —
        a handoff passes the bundle's; None recomputes from (seed, rid)."""
        if rng_key is None:
            rng_key = request_sampling_key(self.seed, req.rid)
        self.slot_key[slot] = np.asarray(rng_key, np.uint32)
        self.sample_idx[slot] = 1
        self.active[slot] = req
        self.positions[slot] = S
        self.remaining[slot] = req.max_new - 1
        self.tokens[slot] = nxt
        self.active_mask[slot] = True
        if self.drafter is not None:
            self.drafter.reset(slot, list(req.prompt) + [nxt])
        self._spec_zero_acc[slot] = 0   # collapse streak is per-occupant
        self._admit_seq[slot] = self._seq
        self._seq += 1
        self.outputs[req.rid] = [nxt]
        req.admit_step = int(now)
        req.first_token_s = time.perf_counter() - self._wall0
        self._dirty = True
        if self.remaining[slot] == 0:   # max_new == 1: prefill token only
            self._release(slot, now)

    def _splice_fn(self, n_tokens: int):
        fn = self._splice_fns.get(n_tokens)
        if fn is None:
            kw = {k: v for k, v in self._admit_kw.items()
                  if k in ("s_max", "slots", "block_size", "n_blocks",
                           "fsdp_serve")}
            fn = build_kv_splice_step(self.ap, self.ctx, self.mesh,
                                      n_tokens=n_tokens, **kw).jit()
            self._splice_fns[n_tokens] = fn
        return fn

    def admit_prefilled(self, slot: int, req: Request, bundle: KVBundle,
                        first_token: int, now: float) -> bool:
        """Disaggregated handoff admission: splice an imported KV bundle
        (canonical real-head layout, from another pool's prefill) into
        ``slot`` and activate the request with its already-sampled first
        token.  Returns False (no state change) when the paged pool cannot
        hold the context right now — the coordinator keeps it queued.
        Raises :class:`~repro.inference.kv_cache.BundleIntegrityError`
        (before any state change) when a sealed bundle's checksum does not
        match — in-flight corruption; the coordinator re-prefills."""
        bundle.verify()
        S = bundle.n_tokens
        if S + 1 > self.s_max:
            raise ValueError(f"handoff len {S} + 1 exceeds s_max="
                             f"{self.s_max}")
        if self.alloc is not None:
            # +1: the first decode write lands at position S
            if not self.alloc.ensure(slot, S + 1):
                return False
            self._sync_table()
        k = heads_to_slots(bundle.k, self.ap.gqa.kv_map)[:, None]
        v = heads_to_slots(bundle.v, self.ap.gqa.kv_map)[:, None]
        self.cache = self._splice_fn(S)(
            self.cache, jnp.asarray(k), jnp.asarray(v), jnp.int32(slot))
        # continue the *prefill pool's* sampling chain (bundle.rng); a
        # greedy-only producer leaves it None and _activate recomputes
        self._activate(slot, req, int(first_token), S, now,
                       rng_key=bundle.rng)
        return True

    def _release(self, slot: int, now: float):
        req = self.active[slot]
        req.done_s = time.perf_counter() - self._wall0
        req.done_step = int(now)
        req.output = np.asarray(self.outputs[req.rid], np.int32)
        self.active[slot] = None
        self.active_mask[slot] = False
        self.remaining[slot] = 0
        self.sample_idx[slot] = 0
        self._admit_seq[slot] = -1
        if self.alloc is not None:
            self.alloc.free(slot)
            self._sync_table()
        self._dirty = True

    # -- preemption / eviction ----------------------------------------------

    def _evict(self, slot: int) -> None:
        """Evict ``slot``'s request and requeue it for recompute-from-
        scratch — shared by capacity preemption, injected-OOM bursts and
        non-finite-logits quarantine.  The recompute replays the request's
        stateless sampling chain, so its final tokens are bitwise-identical
        to an uneventful run (``request_sampling_key``)."""
        if slot in self._tainted:
            self._scrub_slot(slot)
        req = self.active[slot]
        req.preempted += 1
        self._preemptions += 1
        self._wasted_tokens += len(self.outputs[req.rid])
        del self.outputs[req.rid]
        self.active[slot] = None
        self.active_mask[slot] = False
        self.remaining[slot] = 0
        self._admit_seq[slot] = -1
        if self.alloc is not None:
            self.alloc.preempt(slot)
            self._sync_table()
        self._requeue.append(req)
        self._dirty = True

    def _preempt_youngest(self) -> bool:
        """Evict the most recently admitted active request (vLLM-style
        last-come-first-preempted), requeue it for recompute-from-scratch.
        Returns False when nothing is evictable."""
        live = [s for s in range(self.slots) if self.active_mask[s]]
        if not live:
            return False
        self._evict(max(live, key=lambda s: self._admit_seq[s]))
        return True

    def _quarantine(self, slot: int) -> None:
        """Non-finite logits in ``slot``: its emitted token this step is
        garbage, so drop the step's output for the slot, evict it, and let
        the requeue path recompute the request exactly (same machinery as
        capacity preemption).  Under spec decode the slot's speculation is
        also permanently degraded — a drafter feeding on poisoned history
        is not trusted again."""
        self._quarantines += 1
        if self.spec_mode:
            rid = self.active[slot].rid
            if rid not in self._spec_deny:
                self._spec_deny.add(rid)
                self._spec_autodisables += 1
        self._evict(slot)

    def _poison_slot(self, slot: int) -> None:
        """Injected fault: poke NaN into the slot's most recently written
        K position, so the *device* step produces non-finite logits and
        the ``finite`` guard must catch them.  Safe by the write-ordering
        invariant: after quarantine + re-admission, every position up to
        the new write frontier is overwritten before it is read again."""
        p = max(int(self.positions[slot]) - 1, 0)
        nan = jnp.asarray(jnp.nan, self.cache["k"].dtype)
        if self.paged:
            bidx = p // self.block_size
            if not self.alloc.is_exclusive(slot, bidx):
                # shared/held block (a prompt block the trie or a sharer
                # still reads): copy-on-write before the divergent poke —
                # fault injection must corrupt only its victim.  If no
                # free block exists even after trie reclaim, skip the
                # injection entirely (never corrupt a neighbour).
                if not self.alloc.free_blocks and self.prefix is not None:
                    self.prefix.reclaim(1)
                if not self.alloc.free_blocks:
                    return
                old, new = self.alloc.fork_for_write(slot, bidx)
                self.cache["k"] = self.cache["k"].at[:, new].set(
                    self.cache["k"][:, old])
                self.cache["v"] = self.cache["v"].at[:, new].set(
                    self.cache["v"][:, old])
                self._sync_table()
            phys = int(self.alloc.table[slot, bidx])
            off = p % self.block_size
            self.cache["k"] = self.cache["k"].at[:, phys, off].set(nan)
        else:
            self.cache["k"] = self.cache["k"].at[:, slot, p].set(nan)
        self._tainted.add(slot)

    def _scrub_slot(self, slot: int) -> None:
        """Zero a tainted slot's K/V storage before its blocks are freed.

        The poisoned step itself writes NaN K *and* V at the then-current
        position in every layer past the first (projections of the NaN
        hidden state), and a masked attention lane still contributes
        ``0 * NaN = NaN`` through the V-weighted sum — so without this
        scrub a freed contaminated block re-poisons its next occupant at
        positions past that occupant's write frontier.  Zeros are safe on
        both sides of the mask: masked lanes contribute exactly 0 and
        unmasked positions are rewritten before they are read (the same
        write-ordering invariant recompute-from-scratch relies on)."""
        if self.paged:
            # exclusively-owned blocks only: a slot never writes a shared
            # or trie-held block (fork-before-write, _poison_slot), so
            # contamination is confined to its private blocks — and a
            # shared block holds live sharers' clean prompt K/V that a
            # zero-fill would destroy mid-read.
            blocks = [b for i, b in enumerate(self.alloc.owned(slot))
                      if self.alloc.is_exclusive(slot, i)]
            if blocks:
                idx = jnp.asarray(blocks, jnp.int32)
                self.cache["k"] = self.cache["k"].at[:, idx].set(0)
                self.cache["v"] = self.cache["v"].at[:, idx].set(0)
        else:
            self.cache["k"] = self.cache["k"].at[:, slot].set(0)
            self.cache["v"] = self.cache["v"].at[:, slot].set(0)
        self._tainted.discard(slot)

    def _ensure_growth(self, slot: int,
                       n_tokens: Optional[int] = None) -> None:
        """Pre-step invariant: blocks cover the next write position (or an
        explicit ``n_tokens`` target — the spec verify chunk's whole write
        range).  On OOM, preempt youngest-first until the growth fits (the
        growing slot itself may be the victim).  Under an injected OOM
        burst any *real* growth is denied instead: the growing slot itself
        is evicted (surgical — neighbours keep their blocks) and recomputed
        once the burst passes."""
        if n_tokens is None:
            n_tokens = int(self.positions[slot]) + 1
        if self._oom_now and self.alloc.needs_growth(slot, n_tokens):
            self._injected_oom += 1
            self._evict(slot)
            return
        while not self.alloc.ensure(slot, n_tokens):
            if self.prefix is not None:
                # cold trie blocks go before live traffic: evict LRU
                # unreferenced nodes first, preempt a request only when
                # the trie has nothing left to give
                need = self.alloc.blocks_for(n_tokens) - \
                    len(self.alloc.owned(slot))
                if self.prefix.reclaim(need - self.alloc.free_blocks) > 0:
                    continue
            victim_ok = self._preempt_youngest()
            if not self.active_mask[slot]:
                return  # we evicted ourselves
            if not victim_ok:
                raise RuntimeError(
                    "paged KV pool cannot hold a single request; "
                    "raise n_blocks")
        self._sync_table()

    def _pre_step_faults(self, now: float) -> None:
        """Consult the injector's decode-path hooks for this step: arm the
        OOM burst (read by ``_ensure_growth``), poison any chosen live
        slots, and apply the straggler's wall-clock delay (logical clock
        untouched)."""
        inj = self.injector
        self._oom_now = False
        if inj is None:
            return
        self._oom_now = inj.oom_burst(now)
        for s in range(self.slots):
            if not self.active_mask[s]:
                continue
            req = self.active[s]
            if inj.poison_slot(req.rid, len(self.outputs[req.rid])):
                self._poison_slot(s)
        d = inj.straggle(now)
        if d >= 0.0:
            self._straggler_steps += 1
            if d > 0.0:
                time.sleep(d)

    # -- speculative decoding ------------------------------------------------

    def _spec_fn(self, k: int):
        fn = self._spec_fns.get(k)
        if fn is None:
            fn = build_spec_verify_step(self.ap, self.ctx, self.mesh,
                                        k=k, **self._spec_kw).jit()
            self._spec_fns[k] = fn
        return fn

    def _spec_step(self, now: float):
        """One draft + fused-verify step over all slots.

        Per active slot: draft k tokens, write/score the C = k+1 chunk
        [current token, drafts] in one pass, take the verified prefix plus
        one correction/bonus token (1..k+1 tokens), and truncate the
        rejected tail's blocks back to the pool.  The host slot state is
        authoritative (variable per-slot advance), re-pushed every step.

        Degraded mode: slots whose rid is in ``_spec_deny`` (verify-path
        fault, or ``spec_autodisable_after`` consecutive zero-accept
        passes) draft nothing and take at most the correction token —
        per-slot plain decode riding the same verify executable, still
        emitting exact target-model tokens.  If *every* active slot is
        denied the whole step falls back to the plain executable.
        """
        if not self.active_mask.any():
            return
        self._pre_step_faults(now)
        if not self.active_mask.any():   # faults evicted every slot
            return
        denied = np.array([self.active_mask[s]
                           and self.active[s].rid in self._spec_deny
                           for s in range(self.slots)])
        if denied[self.active_mask].all():
            return self._plain_step(now, faults_done=True)
        k = self._speck.k if self._speck is not None else self.spec_k
        C = k + 1
        drafts = np.zeros((self.slots, k), np.int32)
        for s in range(self.slots):
            if self.active_mask[s] and not denied[s]:
                # clamp: a cross-vocabulary drafter must still propose
                # valid target ids (bad ids would just be rejected anyway)
                drafts[s] = np.clip(self.drafter.draft(s, k), 0,
                                    self.cfg.vocab_size - 1)
        if self.alloc is not None:
            for s in range(self.slots):
                # the verify chunk writes positions [p, p+C); cover them
                # all up front (clamped to capacity: overflow writes are
                # trash-routed on device), preempting youngest on OOM
                if self.active_mask[s]:
                    self._ensure_growth(s, min(int(self.positions[s]) + C,
                                               self.s_max))
        occ = int(self.positions[self.active_mask].sum()) + \
            int(self.active_mask.sum())
        self._peak_occupied = max(self._peak_occupied, occ)
        if self._dirty:
            self._push_state()
        was_active = self.active_mask.copy()
        # the verify step keeps the lean 4-field state (its sampled mode
        # draws from the step-level rng, not the per-slot chains)
        spec_state = {k2: self._state[k2] for k2 in
                      ("tokens", "positions", "remaining", "active")}
        emitted, accepted, finite, self.cache = self._spec_fn(k)(
            self.params, self.cache, spec_state, jnp.asarray(drafts),
            self._step_rng())
        emitted = np.asarray(emitted)
        accepted = np.asarray(accepted)
        finite = np.asarray(finite)
        self.steps_run += 1
        self._spec_steps += 1
        self._spec_k_sum += k
        n_active = acc_sum = 0
        for s in range(self.slots):
            if not was_active[s]:
                continue
            if not finite[s]:
                self._quarantine(s)
                continue
            a = int(accepted[s])
            # cap by the request budget and the cache capacity — exactly
            # where sequential decode would have stopped emitting.  The
            # capacity floor of 1 mirrors the plain step: a slot admitted
            # at position s_max-1 still decodes once (querying/writing the
            # last in-bounds position) before its done check fires.
            take = min(a + 1, int(self.remaining[s]),
                       max(self.s_max - 1 - int(self.positions[s]), 1))
            if denied[s]:
                take = min(take, 1)   # degraded: correction token only
            toks = [int(t) for t in emitted[s, :take]]
            self.outputs[self.active[s].rid].extend(toks)
            self.tokens[s] = toks[-1]
            self.positions[s] += take
            self.remaining[s] -= take
            if not denied[s]:
                self.drafter.observe(s, toks)
                n_active += 1
                acc_sum += a
                self._spec_drafted += k
                self._spec_accepted += a
                if self.spec_autodisable_after > 0:
                    # acceptance collapse: N consecutive all-reject passes
                    # mean drafting is pure overhead for this slot
                    self._spec_zero_acc[s] = 0 if a else \
                        self._spec_zero_acc[s] + 1
                    if self._spec_zero_acc[s] >= \
                            self.spec_autodisable_after:
                        self._spec_deny.add(self.active[s].rid)
                        self._spec_autodisables += 1
            if self.alloc is not None:
                # KV rollback: blocks holding only rejected-draft writes
                # go back to the pool
                self.alloc.truncate(s, int(self.positions[s]))
                self.alloc.note_usage(s, int(self.positions[s]))
            if self.remaining[s] <= 0 \
                    or self.positions[s] >= self.s_max - 1:
                self._release(s, now)
        if self.alloc is not None:
            self._sync_table()
        self._dirty = True  # host state is authoritative under spec
        if self._speck is not None and n_active:
            self._speck.update(acc_sum / n_active, k)

    # -- one engine step -----------------------------------------------------

    def step(self, now: float):
        """One decode step over all slots (no-op when none active)."""
        if self.spec_mode:
            return self._spec_step(now)
        return self._plain_step(now)

    def _plain_step(self, now: float, faults_done: bool = False):
        """One plain decode step (``faults_done``: the spec path already
        ran this step's fault hooks before falling back here)."""
        if not self.active_mask.any():
            return
        if not faults_done:
            self._pre_step_faults(now)
            if not self.active_mask.any():   # faults evicted every slot
                return
        if self.alloc is not None:
            for s in range(self.slots):
                # growth only at block boundaries: next write position is
                # positions[s], covered unless it opens a fresh block
                if self.active_mask[s] \
                        and self.positions[s] % self.block_size == 0:
                    self._ensure_growth(s)
            if not self.active_mask.any():   # OOM burst evicted them all
                return
        occ = int(self.positions[self.active_mask].sum()) + \
            int(self.active_mask.sum())
        self._peak_occupied = max(self._peak_occupied, occ)
        if self._dirty:
            self._push_state()
        was_active = self.active_mask.copy()
        emitted, done, finite, self._state, self.cache = self._serve(
            self.params, self.cache, self._state)
        emitted = np.asarray(emitted)
        done = np.asarray(done)
        finite = np.asarray(finite)
        self.steps_run += 1
        for s in range(self.slots):
            if not was_active[s]:
                continue
            if not finite[s]:
                # drop this step's garbage token and recompute the whole
                # request through the preemption path — exact replay
                self._quarantine(s)
                continue
            self.outputs[self.active[s].rid].append(int(emitted[s]))
            self.tokens[s] = emitted[s]
            self.positions[s] += 1
            self.remaining[s] -= 1
            self.sample_idx[s] += 1
            if self.alloc is not None:
                self.alloc.note_usage(s, int(self.positions[s]))
            if done[s]:
                self._release(s, now)

    # -- trace replay --------------------------------------------------------

    def reset_run_stats(self) -> None:
        """Reset per-run accounting (step counts, spec counters, allocator
        trace stats) so :meth:`metrics` reflects one trace only.  Called by
        :meth:`run` on a drained batcher, and by an external driver
        (``inference.disagg.DisaggCoordinator``) that steps the batcher
        itself; current slot ownership is untouched."""
        self.steps_run = 0
        self._peak_occupied = 0
        self.outputs = {}
        self._spec_steps = self._spec_drafted = 0
        self._spec_accepted = self._spec_k_sum = 0
        self._quarantines = self._injected_oom = 0
        self._straggler_steps = self._spec_autodisables = 0
        self._wasted_tokens = 0
        self._preemptions = 0
        # per-run prefix counters only — the trie itself persists across
        # runs (warm cross-trace reuse is the feature)
        self._prefix_lookups = 0
        self._prefix_hits = 0
        self._prefix_tokens_saved = 0
        self._shed = []
        self._spec_deny = set()
        self._spec_zero_acc[:] = 0
        self._tainted = set()
        if self.injector is not None:
            self.injector.reset_stats()
        if self.drafter is not None:
            self.drafter.calls = self.drafter.hits = 0
        if self.alloc is not None:
            self.alloc.reset_stats()
        self._wall0 = time.perf_counter()

    def _shed_req(self, req: Request, now: float, reason: str) -> None:
        """Drop a never-admitted request, *reporting* it (shed_reason /
        metrics counter) — shedding is load control, not silent loss."""
        req.shed_step = int(now)
        req.shed_reason = reason
        self._shed.append(req)

    def _deadline(self, req: Request) -> float:
        """Effective TTFT deadline (logical steps): the tighter of the
        request's own and the batcher default."""
        d = req.deadline_s
        if self.deadline_s is not None:
            d = min(d, self.deadline_s)
        return d

    def tick(self, arrived: List[Request], now: float) -> None:
        """One logical scheduling tick over an externally-owned queue of
        due arrivals (mutated in place): shed deadline-expired entries,
        admit (preempted requeue first, then arrivals, FCFS), and run one
        engine step.  ``run`` drives this on its private queue; an
        external driver (``inference.router.Router``) owns a per-replica
        queue and calls this directly — one code path, so a routed
        replica schedules exactly like a standalone batcher."""
        expired = [r for r in arrived
                   if now - r.arrival_s > self._deadline(r)]
        for r in expired:
            self._shed_req(r, now, "deadline")
            arrived.remove(r)
        # admit preempted requests first, then due arrivals
        for s in range(self.slots):
            if self.active[s] is not None:
                continue
            if self._requeue:
                if self._admit(s, self._requeue[0], now):
                    self._requeue.pop(0)
                continue
            if arrived:
                if self._admit(s, arrived[0], now):
                    arrived.pop(0)
        self.step(now)

    def drained(self, arrived: List[Request]) -> bool:
        """No queued, requeued, or active work left for this batcher."""
        return not arrived and not self._requeue \
            and all(a is None for a in self.active)

    def run(self, requests: List[Request],
            max_steps: int = 100000) -> List[Request]:
        """Replay a trace (requests sorted by arrival) to completion.

        Deadline shedding: a request still waiting for *first* admission
        past its effective deadline (:meth:`_deadline`) is shed instead of
        served.  Preempted requests are never shed — their first token was
        already promised and the recompute replays it exactly."""
        waiting = sorted(requests, key=lambda r: r.arrival_s)
        qi = 0
        now = 0.0
        arrived: List[Request] = []   # due, never admitted
        if not self.active_mask.any() and not self._requeue:
            # fresh replay on a drained batcher
            self.reset_run_stats()
        self._wall0 = time.perf_counter()
        for _ in range(max_steps):
            while qi < len(waiting) and waiting[qi].arrival_s <= now:
                arrived.append(waiting[qi])
                qi += 1
            if qi >= len(waiting) and self.drained(arrived):
                break
            self.tick(arrived, now)
            now += 1.0  # logical step clock
        self._wall_run = time.perf_counter() - self._wall0
        return requests

    # -- metrics -------------------------------------------------------------

    def defragment(self):
        """Compact the physical block pool (paged only); applies the block
        permutation to the device cache and uploads the rewritten table."""
        if self.alloc is None:
            return
        perm = self.alloc.defragment()
        if perm is None:
            return
        p = jnp.asarray(perm)
        self.cache["k"] = jnp.take(self.cache["k"], p, axis=1)
        self.cache["v"] = jnp.take(self.cache["v"], p, axis=1)
        self._sync_table()

    def metrics(self, requests: List[Request]) -> ServeMetrics:
        done = [r for r in requests if r.output is not None]
        wall = self._wall_run   # captured at run() drain, not call time
        total_new = sum(len(r.output) for r in done)
        step_s = wall / self.steps_run if self.steps_run else 0.0
        # TTFT: queueing wait + the admission (prefill) tick.
        ttft = [max(r.admit_step - r.arrival_s, 0.0) + 1.0 for r in done]
        # TPOT over decode tokens only: a request admitted at step t decodes
        # at steps t..done_step inclusive (admission and the first decode
        # share a logical tick), i.e. done-admit+1 steps for len-1 tokens.
        tpot = [(r.done_step - r.admit_step + 1) / (len(r.output) - 1)
                for r in done if len(r.output) > 1]
        if self.alloc is not None:
            st = self.alloc.stats()
            # peak footprint a right-sized deployment would have to reserve
            peak_tok = st.peak_used_blocks * st.block_size
            cap = (st.n_blocks - 1) * st.block_size
            util = self._peak_occupied / peak_tok if peak_tok else 0.0
            # every eviction goes through _evict -> alloc.preempt, so the
            # two counters can only disagree on a bookkeeping bug
            assert st.preemptions == self._preemptions, \
                (st.preemptions, self._preemptions)
            cache_stats = st.to_dict()
        else:
            # dense reserves worst case up front regardless of occupancy
            peak_tok = cap = self.slots * self.s_max
            util = self._peak_occupied / cap if cap else 0.0
            cache_stats = None
        # counted at the batcher, not the allocator: dense-layout
        # evictions (quarantine / injected OOM) used to report as 0
        preempt = self._preemptions
        return ServeMetrics(
            requests=len(requests), completed=len(done),
            total_new_tokens=total_new, steps=self.steps_run, wall_s=wall,
            throughput_tok_s=total_new / wall if wall > 0 else 0.0,
            ttft_steps_p50=_percentile(ttft, 50),
            ttft_steps_p99=_percentile(ttft, 99),
            tpot_steps_p50=_percentile(tpot, 50),
            tpot_steps_p99=_percentile(tpot, 99),
            ttft_s_p50=_percentile(ttft, 50) * step_s,
            ttft_s_p99=_percentile(ttft, 99) * step_s,
            tpot_s_p50=_percentile(tpot, 50) * step_s,
            tpot_s_p99=_percentile(tpot, 99) * step_s,
            preemptions=preempt, peak_kv_tokens=int(peak_tok),
            kv_capacity_tokens=int(cap), cache_utilization=float(util),
            cache_stats=cache_stats,
            spec_steps=self._spec_steps,
            drafted_tokens=self._spec_drafted,
            accepted_tokens=self._spec_accepted,
            acceptance_rate=self._spec_accepted / self._spec_drafted
            if self._spec_drafted else 0.0,
            accepted_tokens_per_step=self._spec_accepted / self._spec_steps
            if self._spec_steps else 0.0,
            drafter_hit_rate=self.drafter.hit_rate
            if self.drafter is not None else 0.0,
            spec_k_mean=self._spec_k_sum / self._spec_steps
            if self._spec_steps else 0.0,
            quarantines=self._quarantines,
            injected_oom=self._injected_oom,
            shed_requests=len(self._shed),
            spec_autodisables=self._spec_autodisables,
            straggler_steps=self._straggler_steps,
            wasted_tokens=self._wasted_tokens,
            prefix_lookups=self._prefix_lookups,
            prefix_hits=self._prefix_hits,
            prefix_tokens_saved=self._prefix_tokens_saved,
            prefix_hit_rate=self._prefix_hits / self._prefix_lookups
            if self._prefix_lookups else 0.0,
            ttft_steps_samples=ttft, tpot_steps_samples=tpot)


def make_trace(n_requests: int, *, mean_in: int, mean_out: int,
               rate: float, burstiness: float = 2.0, vocab: int = 97,
               seed: int = 0) -> List[Request]:
    """BurstGPT-style synthetic trace: gamma inter-arrivals (shape=1/CV^2 ~
    burstiness), lognormal-ish lengths (paper Appendix C.4.2)."""
    rng = np.random.default_rng(seed)
    shape = 1.0 / burstiness
    gaps = rng.gamma(shape, scale=1.0 / (rate * shape), size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        s_in = max(8, int(rng.lognormal(np.log(mean_in), 0.6)) // 8 * 8)
        s_out = max(1, int(rng.lognormal(np.log(mean_out), 0.6)))
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, s_in).astype(np.int32),
            max_new=s_out, arrival_s=float(arrivals[i])))
    return reqs


def make_prefix_trace(n_requests: int, *, prefix_len: int,
                      shared_frac: float, mean_in: int, mean_out: int,
                      rate: float, burstiness: float = 2.0, vocab: int = 97,
                      seed: int = 0,
                      clip_len: Optional[int] = None) -> List[Request]:
    """A :func:`make_trace` trace where a ``shared_frac`` fraction of
    requests open with one common ``prefix_len``-token system prompt —
    the multi-tenant shared-prefix workload the prefix cache targets.

    Sharing is decided by a fixed-seed per-request uniform draw against
    the threshold, so raising ``shared_frac`` only *adds* shared requests
    (never reshuffles which) — the monotonicity ``bench_prefix`` gates
    on.  ``clip_len`` caps total prompt length (trim the unique tail)
    so prefixed prompts still fit ``s_max - 1``.
    """
    rng = np.random.default_rng(seed + 0x5afe)
    shared = rng.integers(0, vocab, prefix_len).astype(np.int32)
    u = rng.random(n_requests)
    reqs = make_trace(n_requests, mean_in=mean_in, mean_out=mean_out,
                      rate=rate, burstiness=burstiness, vocab=vocab,
                      seed=seed)
    for i, r in enumerate(reqs):
        if u[i] < shared_frac:
            r.prompt = np.concatenate([shared, r.prompt]).astype(np.int32)
        if clip_len is not None and r.prompt.shape[0] > clip_len:
            r.prompt = r.prompt[:clip_len]
    return reqs


__all__ = ["ContinuousBatcher", "Request", "ServeMetrics", "make_trace",
           "make_prefix_trace", "run_chunked_prefill",
           "request_sampling_key"]
