"""YALIS-style batched inference engine (the paper's research vehicle),
in JAX.

Runs in two modes:
  * local  — single device, direct model calls (CPU tests, examples)
  * mesh   — shard_map'd prefill/decode step builders (production path; the
             same builders the dry-run lowers)

``generate`` implements the paper's *batched inference* workload: one batch
of prompts runs to completion (prefill + N decode steps) before the next
batch starts — isolating GPU/TPU execution from scheduler effects, as in the
paper's Sec. 3.2.

Known gaps: the engine's paged cache is local-path only (identity block
table, no allocator — mesh-path paged serving lives in
``inference.scheduler.ContinuousBatcher``), and speculative ``generate``
is dense-family-only with the draft model running local/replicated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.pcontext import ParallelCtx, LOCAL
from ..models.transformer import (ArchPlan, forward_lm, decode_step,
                                  init_cache, seed_cache)
from ..models import layers as L


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # (B, prompt+new)
    new_tokens: np.ndarray       # (B, new)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.new_tokens.size
        return n / self.decode_s if self.decode_s > 0 else float("inf")


class InferenceEngine:
    """Batched generation over a fixed model."""

    def __init__(self, ap: ArchPlan, params, *, ctx: ParallelCtx = LOCAL,
                 mesh=None, s_max: int = 4096, fsdp_serve: bool = False,
                 scan_layers: bool = True, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, block_size: int = 0,
                 ar_table: Optional[str] = None,
                 spec_mode: Optional[str] = None, spec_k: int = 4,
                 draft_arch: str = "llama3.2-1b", drafter=None):
        """``ar_table``: optional path to a persisted all-reduce autotune
        table (see repro.core.autotune); with ``ctx.ar_strategy="auto"`` the
        decode/prefill steps dispatch each all-reduce call site on message
        size against it.  ``ctx.overlap_matmul=True`` additionally pipelines
        the output-projection GEMMs against their all-reduces.
        ``block_size > 0`` selects the paged KV layout on the local path
        (identity block table — the continuous batcher owns allocator-driven
        paging; here paging is exercised for parity).
        ``spec_mode`` ("ngram" | "draft" | "replay", or an injected
        ``drafter``) switches ``generate`` to speculative decoding: per
        step, ``spec_k`` drafted tokens are verified batch-wide in one
        fused pass, each row advancing by its own accepted length.  Greedy
        spec output is bitwise-identical to plain greedy ``generate``.
        Dense families only."""
        self.ap = ap
        self.cfg = ap.cfg
        self.params = params
        self.ctx = ctx
        self.mesh = mesh
        self.s_max = s_max
        self.temperature = temperature
        self.top_k = top_k
        self.block_size = block_size
        if block_size and mesh is not None:
            raise NotImplementedError(
                "paged engine cache is local-path only; use "
                "ContinuousBatcher for mesh-path paged serving")
        self._rng = jax.random.PRNGKey(seed)
        self.spec_mode = spec_mode
        self.spec_k = spec_k
        self._spec = None
        self._drafter = drafter
        self._draft_arch = draft_arch
        self._seed = seed
        if drafter is not None and not spec_mode:
            raise ValueError("an injected drafter needs spec_mode set "
                             "(got drafter= without spec_mode=)")
        if spec_mode:
            if self.cfg.family != "dense":
                raise ValueError("speculative generate supports dense "
                                 f"families only, not {self.cfg.family!r}")
            from ..parallel.steps import build_spec_verify_step
            self._spec = build_spec_verify_step(
                ap, ctx, mesh, k=spec_k, s_max=s_max,
                scan_layers=scan_layers, fsdp_serve=fsdp_serve,
                temperature=temperature, top_k=top_k,
                ar_table=ar_table).jit()
        if mesh is not None:
            from ..parallel.steps import build_decode_step, build_prefill
            self._prefill = jax.jit(build_prefill(
                ap, ctx, mesh, s_max=s_max, scan_layers=scan_layers,
                fsdp_serve=fsdp_serve,
                frame_embeds=self.cfg.family == "encdec",
                patch_embeds=self.cfg.family == "vlm",
                ar_table=ar_table).fn)
            self._decode = build_decode_step(
                ap, ctx, mesh, scan_layers=scan_layers,
                fsdp_serve=fsdp_serve, ar_table=ar_table).jit()
        else:
            self._prefill = None
            self._decode = None
            # jit the local paths (cache donated so decode is in-place)
            self._local_decode_jit = jax.jit(self._local_decode,
                                             donate_argnums=(0,))
            self._local_prefill_jit = jax.jit(
                self._local_prefill, static_argnames=("extra_keys",))

    # -- local-mode primitives ---------------------------------------------

    def _local_prefill(self, tokens, extra=None, extra_keys=()):
        ap, cfg = self.ap, self.cfg
        extra = extra or {}
        B, S = tokens.shape
        logits, _, states, enc = forward_lm(
            self.params, tokens, ap, LOCAL, collect_state=True,
            chunk=1024 if S > 8192 else 0, **extra)
        cache = init_cache(ap, B, self.s_max, block_size=self.block_size)
        enc_kv = None
        if cfg.enc_layers:
            enc_kv = jax.vmap(lambda bp: L.cross_kv(bp["xattn"], enc))(
                self.params["blocks"])
        cache = seed_cache(cache, states, enc_kv=enc_kv)
        nxt = jnp.argmax(
            logits[:, -1, :cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)
        return nxt, cache

    def _local_decode(self, cache, tokens, positions, rng):
        logits, cache = decode_step(self.params, cache, tokens, positions,
                                    self.ap, LOCAL)
        nxt = L.sample_token(logits, rng, temperature=self.temperature,
                             top_k=self.top_k,
                             vocab_real=self.cfg.vocab_size)
        return nxt, cache

    def _make_drafter(self):
        # built once and reused across generate() calls (a draft model's
        # init + jit is not cheap); reset() reseeds per-row histories
        if self._drafter is None:
            from .speculative import make_drafter
            self._drafter = make_drafter(self.spec_mode,
                                         draft_arch=self._draft_arch,
                                         seed=self._seed)
        return self._drafter

    def _step_rng(self):
        if self.temperature > 0.0:
            self._rng, r = jax.random.split(self._rng)
            return r
        return self._rng

    def _generate_spec(self, tokens, max_new_tokens: int,
                       extra) -> GenerationResult:
        """Speculative batched generation: all rows share each fused
        verify pass but advance by their own accepted lengths; rows that
        reach ``max_new_tokens`` go inactive and decode into their own
        row harmlessly (write-ordering invariant) until the batch drains.
        """
        B, S = tokens.shape
        t0 = time.perf_counter()
        if self._prefill is not None:
            nxt, cache = self._prefill(self.params, tokens)
        else:
            nxt, cache = self._local_prefill_jit(tokens, extra)
        nxt = np.asarray(jax.block_until_ready(nxt))
        t1 = time.perf_counter()

        drafter = self._make_drafter()
        prompts_np = np.asarray(tokens)
        outputs = [[int(t)] for t in nxt]
        for b in range(B):
            drafter.reset(b, list(prompts_np[b]) + [int(nxt[b])])
        positions = np.full((B,), S, np.int32)
        remaining = np.full((B,), max_new_tokens - 1, np.int32)
        active = remaining > 0
        cur = nxt.copy()
        k = self.spec_k
        steps = 1  # count prefill's token like the plain path counts steps
        while active.any():
            drafts = np.zeros((B, k), np.int32)
            for b in range(B):
                if active[b]:
                    drafts[b] = np.clip(drafter.draft(b, k), 0,
                                        self.cfg.vocab_size - 1)
            state = {"tokens": jnp.asarray(cur),
                     "positions": jnp.asarray(positions),
                     "remaining": jnp.asarray(remaining),
                     "active": jnp.asarray(active)}
            emitted, accepted, finite, cache = self._spec(
                self.params, cache, state, jnp.asarray(drafts),
                self._step_rng())
            emitted = np.asarray(emitted)
            accepted = np.asarray(accepted)
            # the engine has no quarantine/recompute machinery (that is
            # the batcher's job) — fail loudly instead of emitting garbage
            assert bool(np.asarray(finite)[np.asarray(active)].all()), \
                "non-finite verify logits in batched generate"
            steps += 1
            for b in range(B):
                if not active[b]:
                    continue
                take = min(int(accepted[b]) + 1, int(remaining[b]),
                           self.s_max - 1 - int(positions[b]))
                toks = [int(t) for t in emitted[b, :take]]
                outputs[b].extend(toks)
                drafter.observe(b, toks)
                cur[b] = toks[-1]
                positions[b] += take
                remaining[b] -= take
                if remaining[b] <= 0 or positions[b] >= self.s_max - 1:
                    active[b] = False
        jax.block_until_ready(cache["k"])
        t2 = time.perf_counter()
        new = np.asarray([o[:max_new_tokens] for o in outputs], np.int32)
        return GenerationResult(
            tokens=np.concatenate([prompts_np, new], axis=1),
            new_tokens=new, prefill_s=t1 - t0, decode_s=t2 - t1,
            steps=steps)

    # -- public API ----------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 extra: Optional[Dict[str, Any]] = None) -> GenerationResult:
        """prompts: (B, S) int32 (uniform length; engine-level padding is the
        scheduler's job).  Greedy decoding."""
        extra = extra or {}
        tokens = jnp.asarray(prompts, jnp.int32)
        B, S = tokens.shape
        assert S + max_new_tokens <= self.s_max
        if self._spec is not None:
            return self._generate_spec(tokens, max_new_tokens, extra)
        t0 = time.perf_counter()
        if self._prefill is not None:
            args = [self.params, tokens]
            if self.cfg.family == "encdec":
                args.append(extra["frame_embeds"])
            if self.cfg.family == "vlm":
                args.append(extra["patch_embeds"])
            nxt, cache = self._prefill(*args)
        else:
            nxt, cache = self._local_prefill_jit(tokens, extra)
        nxt = jax.block_until_ready(nxt)
        t1 = time.perf_counter()

        out = [np.asarray(nxt)]
        positions = jnp.full((B,), S, jnp.int32)
        cur = nxt
        for i in range(max_new_tokens - 1):
            if self._decode is not None:
                cur, cache = self._decode(self.params, cache, cur,
                                          positions + i)
            else:
                self._rng, step_rng = jax.random.split(self._rng)
                cur, cache = self._local_decode_jit(cache, cur,
                                                    positions + i, step_rng)
            out.append(np.asarray(cur))
        jax.block_until_ready(cur)
        t2 = time.perf_counter()
        new = np.stack(out, axis=1)
        return GenerationResult(
            tokens=np.concatenate([np.asarray(tokens), new], axis=1),
            new_tokens=new, prefill_s=t1 - t0, decode_s=t2 - t1,
            steps=max_new_tokens)


__all__ = ["InferenceEngine", "GenerationResult"]
