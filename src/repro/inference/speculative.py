"""Speculative decoding: drafters + adaptive speculation-length control.

Multi-node decode is latency-bound on the per-token TP all-reduce: every
decoded token pays one small-message AR per layer (the paper's 128 KB-2 MB
NVRAR regime).  Speculative decoding attacks that bottleneck from the
workload side — a drafter proposes ``k`` cheap tokens and the target model
verifies all of them in ONE fused pass (``parallel.steps.
build_spec_verify_step``), so the per-layer all-reduce is amortized over
``k+1`` tokens and its message widens by the same factor, into the size
region where the autotuner's strategy choice actually matters.

This module is the *host* side: drafters maintain per-slot token histories
and propose continuations; correctness never depends on draft quality —
the verify step's rejection rule guarantees the emitted stream follows the
target model exactly (greedy mode: bitwise-equal to plain decode), a bad
drafter only costs speedup.

Drafters (``make_drafter``):

* ``ngram``   — prompt-lookup / n-gram self-drafting: propose the
  continuation of the most recent earlier occurrence of the current
  suffix (longest n-gram first), falling back to repeating the last
  token.  Zero extra model weights, surprisingly strong on code/prose
  with self-repetition.
* ``draft``   — a small draft model from ``configs.registry`` (its smoke
  config by default) greedily continues a fixed-size window of the
  history.  The draft model always runs on the local/replicated path with
  window-relative positions — it is a *proposal* distribution, so the
  position offset is irrelevant to correctness.
* ``replay``  — oracle drafter that replays precomputed target streams
  (tests / benchmark upper bound: acceptance == 1.0 by construction).

All drafters are deterministic (a delta proposal distribution), which is
what makes the sampled-mode rejection rule in ``_spec_targets`` exact.

Degraded mode (DESIGN.md §11): the batcher can disable speculation
*per slot* at runtime — permanently after a verify-path fault
(non-finite logits quarantine: a drafter fed on poisoned history is not
trusted again), or on acceptance collapse when
``ContinuousBatcher(spec_autodisable_after=N)`` sees N consecutive
zero-accept verify passes.  A denied slot drafts nothing and takes only
the correction token from the shared verify pass — per-slot plain decode
emitting exact target-model tokens; when every active slot is denied the
whole step falls back to the plain executable.  Drafters themselves need
no fault handling: they are proposal distributions, never correctness.

Known gaps: the verify pass rides the chunked-prefill path and is
therefore dense-family-only, and the draft model runs local/replicated
(not mesh-sharded) — it is tiny relative to the target by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

SPEC_MODES = ("ngram", "draft", "replay")


class Drafter:
    """Per-slot draft proposer.  Subclasses implement ``_propose``.

    ``hits``/``calls`` track how often the drafter produced a real
    candidate (vs falling back) — reported as ``drafter_hit_rate`` in
    :class:`~repro.inference.scheduler.ServeMetrics`.
    """

    def __init__(self):
        self._hist: Dict[int, List[int]] = {}
        self.calls = 0
        self.hits = 0

    # -- lifecycle ---------------------------------------------------------

    def reset(self, slot: int, tokens: Sequence[int]) -> None:
        """(Re)seed ``slot``'s history: prompt + tokens emitted so far."""
        self._hist[slot] = [int(t) for t in tokens]

    def observe(self, slot: int, tokens: Sequence[int]) -> None:
        """Append tokens the target model actually emitted for ``slot``."""
        self._hist[slot].extend(int(t) for t in tokens)

    def drop(self, slot: int) -> None:
        self._hist.pop(slot, None)

    @property
    def hit_rate(self) -> float:
        return self.hits / self.calls if self.calls else 0.0

    # -- drafting ----------------------------------------------------------

    def draft(self, slot: int, k: int) -> np.ndarray:
        """Propose ``k`` continuation tokens for ``slot`` (always exactly
        k — the verify executable has a static chunk length)."""
        hist = self._hist[slot]
        self.calls += 1
        cand = self._propose(slot, hist, k)
        if cand:
            self.hits += 1
        out = list(cand[:k])
        fill = out[-1] if out else (hist[-1] if hist else 0)
        out.extend([fill] * (k - len(out)))
        return np.asarray(out, np.int32)

    def _propose(self, slot: int, hist: List[int], k: int) -> List[int]:
        raise NotImplementedError


class NGramDrafter(Drafter):
    """Prompt-lookup decoding: longest-suffix n-gram match in the history.

    For n from ``max_n`` down to 1: find the most recent earlier
    occurrence of the last n tokens and propose what followed it.  The
    lookup is O(max_n) per draft — per slot and per n, a dict maps each
    n-gram to the end positions of its last two occurrences (the final
    one is the current suffix itself), maintained incrementally as tokens
    are observed; this is host code on the serving hot loop.
    """

    def __init__(self, max_n: int = 3, max_hist: int = 4096):
        super().__init__()
        self.max_n = max_n
        self.max_hist = max_hist
        # slot -> per-n ({gram: last end pos}, {gram: previous end pos})
        self._idx: Dict[int, List[tuple]] = {}

    def _register(self, slot: int, end: int) -> None:
        h = self._hist[slot]
        for n in range(1, self.max_n + 1):
            if end >= n:
                last, prev = self._idx[slot][n - 1]
                g = tuple(h[end - n:end])
                if g in last:
                    prev[g] = last[g]
                last[g] = end

    def _rebuild(self, slot: int) -> None:
        self._idx[slot] = [({}, {}) for _ in range(self.max_n)]
        for end in range(1, len(self._hist[slot]) + 1):
            self._register(slot, end)

    def reset(self, slot, tokens):
        super().reset(slot, tokens)
        self._rebuild(slot)

    def drop(self, slot):
        super().drop(slot)
        self._idx.pop(slot, None)

    def observe(self, slot, tokens):
        h = self._hist[slot]
        for t in tokens:
            h.append(int(t))
            self._register(slot, len(h))
        if len(h) > self.max_hist:
            # trim to half so index rebuilds amortize to O(1)/token
            del h[: len(h) - self.max_hist // 2]
            self._rebuild(slot)

    def _propose(self, slot, hist, k):
        L = len(hist)
        for n in range(min(self.max_n, L - 1), 0, -1):
            last, prev = self._idx[slot][n - 1]
            g = tuple(hist[L - n:])
            end = last.get(g)
            if end == L:                 # that's the suffix itself
                end = prev.get(g)
            if end is not None and end < L:
                return hist[end: end + k]
        return []


class ModelDrafter(Drafter):
    """Small draft model proposing greedy continuations of a fixed window.

    The drafting forward pass runs on the local (replicated) path with one
    jitted executable of static shape ``(1, window)``: the last ``window``
    history tokens (left-padded with 0) are re-scored per drafted token.
    O(k * window^2) per draft — negligible next to the target model, and
    free of draft-side KV-cache rollback bookkeeping.  Window-relative
    positions are fine: this is a proposal, not the target distribution.
    """

    def __init__(self, ap, params, *, window: int = 32):
        super().__init__()
        import jax
        import jax.numpy as jnp
        from ..models.transformer import forward_lm
        from ..core.pcontext import LOCAL
        self.ap = ap
        self.window = window
        vocab = ap.cfg.vocab_size

        def last_greedy(toks):
            logits, _, _, _ = forward_lm(params, toks, ap, LOCAL)
            lf = logits[0, -1, :vocab].astype(jnp.float32)
            return jnp.argmax(lf).astype(jnp.int32)

        self._next = jax.jit(last_greedy)

    def _propose(self, slot, hist, k):
        W = self.window
        win = hist[-W:]
        win = [0] * (W - len(win)) + win
        out: List[int] = []
        for _ in range(k):
            out.append(int(self._next(np.asarray(win, np.int32)[None])))
            win = win[1:] + out[-1:]
        return out


class ReplayDrafter(Drafter):
    """Oracle drafter replaying precomputed target streams, keyed by the
    request prompt.  Testing / benchmark upper bound: every draft is the
    token the target will emit, so acceptance is 1.0 and a trace completes
    in ~1/(k+1) of the decode steps."""

    def __init__(self, streams: Dict[Tuple[int, ...], Sequence[int]]):
        super().__init__()
        self.streams = {k: [int(t) for t in v] for k, v in streams.items()}
        self._cursor: Dict[int, Tuple[Tuple[int, ...], int]] = {}

    def reset(self, slot, tokens):
        super().reset(slot, tokens)
        toks = [int(t) for t in tokens]
        # longest prompt key that prefixes the history wins (prompts can
        # share prefixes); cursor = tokens already emitted beyond it
        best = None
        for key in self.streams:
            if len(key) < len(toks) and toks[: len(key)] == list(key) \
                    and (best is None or len(key) > len(best)):
                best = key
        self._cursor[slot] = (best, len(toks) - len(best)) \
            if best is not None else ((), 0)

    def observe(self, slot, tokens):
        super().observe(slot, tokens)
        key, cur = self._cursor.get(slot, ((), 0))
        self._cursor[slot] = (key, cur + len(tokens))

    def draft(self, slot, k):
        self.calls += 1
        key, cur = self._cursor.get(slot, ((), 0))
        stream = self.streams.get(key, [])
        cand = stream[cur: cur + k]
        if cand:
            self.hits += 1
        fill = cand[-1] if cand else (self._hist[slot][-1]
                                      if self._hist.get(slot) else 0)
        cand = list(cand) + [fill] * (k - len(cand))
        return np.asarray(cand, np.int32)


@dataclasses.dataclass
class AdaptiveK:
    """Acceptance-rate-adaptive speculation length.

    Tracks an EWMA of the per-step draft acceptance ratio and walks the
    current k up/down a ladder of candidate lengths: consistently high
    acceptance buys longer speculation (bigger AR messages, fewer steps),
    consistently low acceptance backs off toward plain decode.  Each k is
    its own verify executable, so the ladder is small by design.
    """

    ks: Tuple[int, ...] = (2, 4, 8)
    hi: float = 0.75
    lo: float = 0.30
    ewma: float = 0.5
    _idx: int = 0
    _rate: float = 0.5

    def __post_init__(self):
        self.ks = tuple(sorted(set(int(k) for k in self.ks)))
        if not self.ks or self.ks[0] < 1:
            raise ValueError(f"bad adaptive-k ladder {self.ks}")

    @property
    def k(self) -> int:
        return self.ks[self._idx]

    def update(self, accepted: float, k: int) -> int:
        """Feed one step's mean accepted-draft count at length ``k``;
        returns the k to use next step."""
        self._rate = (1 - self.ewma) * self._rate \
            + self.ewma * (accepted / max(k, 1))
        if self._rate > self.hi and self._idx < len(self.ks) - 1:
            self._idx += 1
            self._rate = 0.5  # re-center after a ladder move
        elif self._rate < self.lo and self._idx > 0:
            self._idx -= 1
            self._rate = 0.5
        return self.k


def make_drafter(mode: str, *, draft_arch: str = "llama3.2-1b",
                 smoke: bool = True, window: int = 32, max_n: int = 3,
                 seed: int = 0,
                 streams: Optional[Dict] = None) -> Drafter:
    """Drafter factory behind the ``--spec-mode`` flag."""
    if mode == "ngram":
        return NGramDrafter(max_n=max_n)
    if mode == "draft":
        import jax
        from ..configs import get_config, get_smoke
        from ..models.transformer import make_plan, init_params
        cfg = get_smoke(draft_arch) if smoke else get_config(draft_arch)
        ap = make_plan(cfg, 1)
        params = init_params(jax.random.PRNGKey(seed), ap)
        return ModelDrafter(ap, params, window=window)
    if mode == "replay":
        return ReplayDrafter(streams or {})
    raise ValueError(f"unknown spec mode {mode!r}; known: {SPEC_MODES}")


__all__ = ["Drafter", "NGramDrafter", "ModelDrafter", "ReplayDrafter",
           "AdaptiveK", "make_drafter", "SPEC_MODES"]
