"""Inference runtime: batched engine, continuous-batching scheduler, trace
replay, and the event-driven cluster simulator used for the paper's
strong-scaling and serving studies."""
from .engine import InferenceEngine, GenerationResult
from .scheduler import ContinuousBatcher, Request
from .simulator import (ChipSpec, A100, GH200, V5E, ClusterSim,
                        simulate_batch_latency, simulate_trace)

__all__ = ["InferenceEngine", "GenerationResult", "ContinuousBatcher",
           "Request", "ChipSpec", "A100", "GH200", "V5E", "ClusterSim",
           "simulate_batch_latency", "simulate_trace"]
