"""Inference runtime: batched engine, continuous-batching scheduler over the
paged KV-cache subsystem, disaggregated prefill/decode pools, trace replay,
and the event-driven cluster simulator used for the paper's strong-scaling
and serving studies."""
from .engine import InferenceEngine, GenerationResult
from .disagg import DisaggCoordinator, DisaggMetrics, PrefillPool
from .kv_cache import (BlockAllocator, CacheStats, KVBundle, export_slot,
                       heads_to_slots, paged_geometry, slots_to_heads)
from .prefix_cache import PrefixCache
from .router import Router, RouterMetrics, ReplicaLoad
from .scheduler import (ContinuousBatcher, Request, ServeMetrics,
                        make_prefix_trace, make_trace)
from .spec import (ReplicaSpec, ServeSpec, SpecError, ROUTER_POLICIES,
                   build_engine, build_prefill_pool, build_replica,
                   make_injector)
from .speculative import (AdaptiveK, Drafter, ModelDrafter, NGramDrafter,
                          ReplayDrafter, make_drafter)
from .simulator import (ChipSpec, A100, GH200, V5E, ClusterSim,
                        simulate_batch_latency, simulate_trace)

__all__ = ["InferenceEngine", "GenerationResult", "ContinuousBatcher",
           "Request", "ServeMetrics", "make_trace", "make_prefix_trace",
           "PrefixCache", "BlockAllocator",
           "CacheStats", "paged_geometry", "ChipSpec", "A100", "GH200",
           "V5E", "ClusterSim", "simulate_batch_latency", "simulate_trace",
           "Drafter", "NGramDrafter", "ModelDrafter", "ReplayDrafter",
           "AdaptiveK", "make_drafter", "DisaggCoordinator",
           "DisaggMetrics", "PrefillPool", "KVBundle", "export_slot",
           "slots_to_heads", "heads_to_slots", "Router", "RouterMetrics",
           "ReplicaLoad", "ReplicaSpec", "ServeSpec", "SpecError",
           "ROUTER_POLICIES", "build_engine", "build_prefill_pool",
           "build_replica", "make_injector"]
