"""Deterministic fault injection for the serving stack (DESIGN.md §11).

The paper's operating point is multi-node (Llama 3.1 405B over
Slingshot/InfiniBand), where transient faults — dropped or corrupted KV
transfers, straggler pools, non-finite activations, allocator pressure
bursts — are routine operating conditions, not exceptional ones.  This
module is the *fault model* side of the robustness layer: a seedable
:class:`FaultPlan` describing per-kind fault rates, and a
:class:`FaultInjector` the serving loops consult at explicit hook points
(never monkeypatching):

* ``ContinuousBatcher.step`` / ``_spec_step`` — ``poison_slot`` (NaN
  injected into a slot's live KV so non-finite logits arise *on device*),
  ``oom_burst`` (the allocator behaves as if the free list ran dry),
  ``straggle`` (wall-clock decode delay; the logical clock is untouched);
* ``DisaggCoordinator.run`` — ``corrupt_handoff`` (bundle payload damaged
  in flight; detected by the :class:`~repro.inference.kv_cache.KVBundle`
  checksum at splice time), ``drop_handoff`` (the transfer attempt is
  lost; retried with backoff), ``prefill_stalled`` / ``decode_stalled``
  (a pool freezes for whole windows of ``stall_steps`` ticks).

Determinism contract: every decision is a pure hash of
``(plan.seed, kind, ids...)`` — no RNG state, no wall clock — so a fault
schedule replays bit-identically, and the event set at rate ``r1`` is a
**subset** of the event set at ``r2 >= r1`` for the same seed/ids (the
decision is ``hash_unit < rate``).  That superset property is what lets
``benchmarks/bench_faults.py`` assert goodput degrades monotonically in
the fault rate.

The recovery obligations on the consumer side (retry/backoff, re-prefill
fallback, quarantine + recompute, deadline shedding) live with the
consumers; the invariant they jointly enforce is: **every non-shed greedy
request's tokens are bitwise-identical to the fault-free trace, and shed
requests are always reported, never silently dropped** (docs/robustness.md).
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Any, Dict, Optional

import numpy as np

# fault kinds an injector counts (stats() keys, in reporting order)
FAULT_KINDS = ("handoff_drop", "handoff_corrupt", "prefill_stall",
               "decode_stall", "straggler", "nan_logits", "oom")


def hash_unit(seed: int, kind: str, *ids: int) -> float:
    """Deterministic uniform draw in [0, 1) from (seed, kind, ids).

    crc32 of the repr — stable across processes and platforms (unlike
    ``hash``), cheap enough for per-step hooks, and stateless so the
    fault schedule is independent of evaluation order.
    """
    h = zlib.crc32(repr((int(seed), kind) + tuple(int(i) for i in ids))
                   .encode())
    return h / 2.0 ** 32


@dataclasses.dataclass
class FaultPlan:
    """Seedable description of a fault workload (all rates in [0, 1]).

    * ``handoff_drop``    — per handoff *transfer attempt* (rid, attempt):
      the attempt is lost; the coordinator retries with backoff.
    * ``handoff_corrupt`` — per *prefill* of a request (rid, prefill#):
      the bundle payload is flipped in flight; the checksum catches it at
      splice time and the coordinator falls back to re-prefill.
    * ``prefill_stall`` / ``decode_stall`` — per window of
      ``stall_steps`` ticks: the pool freezes for the whole window
      (crash-and-recover for N steps).
    * ``straggler``       — per decode step: an artificial wall-clock
      delay of ``straggler_s`` (logical clock untouched — latency noise,
      never a token change).
    * ``nan_logits``      — per (request, progress): a non-finite value
      is poked into the request's live KV once it has emitted that many
      tokens, so the *device* produces non-finite logits and the
      batcher's quarantine guard must catch it.  Keyed on request
      identity + progress (never the wall step) so the event set — and
      the decode work each quarantine destroys — is invariant to
      scheduling shifts; each key fires at most once, so the
      quarantine-recompute replay is not re-poisoned into a livelock.
    * ``oom``             — per step: allocator growth behaves as if the
      free pool ran dry (burst); growing slots are evicted and recomputed.
    """

    seed: int = 0
    handoff_drop: float = 0.0
    handoff_corrupt: float = 0.0
    prefill_stall: float = 0.0
    decode_stall: float = 0.0
    stall_steps: int = 3
    straggler: float = 0.0
    straggler_s: float = 0.0
    nan_logits: float = 0.0
    oom: float = 0.0

    def __post_init__(self):
        for f in ("handoff_drop", "handoff_corrupt", "prefill_stall",
                  "decode_stall", "straggler", "nan_logits", "oom"):
            v = float(getattr(self, f))
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"fault rate {f}={v} outside [0, 1]")
            setattr(self, f, v)
        if int(self.stall_steps) < 1:
            raise ValueError(f"stall_steps must be >= 1, got "
                             f"{self.stall_steps}")
        self.stall_steps = int(self.stall_steps)
        self.seed = int(self.seed)

    @property
    def any_faults(self) -> bool:
        return any(getattr(self, f) > 0.0 for f in
                   ("handoff_drop", "handoff_corrupt", "prefill_stall",
                    "decode_stall", "straggler", "nan_logits", "oom"))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Build a plan from a ``k=v,k=v`` string or a JSON file path
        (the ``--fault-plan`` flag accepts either)."""
        if os.path.exists(spec):
            with open(spec) as f:
                doc = json.load(f)
            if not isinstance(doc, dict):
                raise ValueError(f"fault plan {spec!r} must hold a JSON "
                                 f"object, got {type(doc).__name__}")
            return cls(**doc)
        kw: Dict[str, Any] = {}
        fields = {f.name: f.type for f in dataclasses.fields(cls)}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(f"bad fault-plan entry {part!r} "
                                 f"(want key=value)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k not in fields:
                raise ValueError(f"unknown fault-plan key {k!r}; known: "
                                 f"{sorted(fields)}")
            kw[k] = int(v) if k in ("seed", "stall_steps") else float(v)
        return cls(**kw)


class FaultInjector:
    """Hook-point decisions + injected/observed-event counters.

    One injector instance serves one run; ``reset_stats`` re-arms it for
    a fresh trace (decisions are stateless, so a reset replays the same
    schedule).  ``counts`` tallies decisions that fired; consumers own
    the *recovery* counters (retries, sheds, quarantines) in their
    metrics.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.counts: Dict[str, int] = {k: 0 for k in FAULT_KINDS}
        self._nan_fired: set = set()

    # -- bookkeeping -------------------------------------------------------

    def reset_stats(self) -> None:
        self.counts = {k: 0 for k in FAULT_KINDS}
        self._nan_fired = set()

    def stats(self) -> Dict[str, int]:
        return dict(self.counts)

    def _fire(self, kind: str, rate: float, *ids: int) -> bool:
        if rate <= 0.0:
            return False
        hit = hash_unit(self.plan.seed, kind, *ids) < rate
        if hit:
            self.counts[kind] += 1
        return hit

    # -- handoff-path hooks (DisaggCoordinator) ----------------------------

    def drop_handoff(self, rid: int, attempt: int) -> bool:
        """Lose transfer attempt ``attempt`` of request ``rid``?"""
        return self._fire("handoff_drop", self.plan.handoff_drop,
                          rid, attempt)

    def corrupt_handoff(self, rid: int, prefill_no: int) -> bool:
        """Damage the bundle produced by ``rid``'s ``prefill_no``-th
        prefill?  (Keyed per prefill, not per attempt: the same corrupt
        payload stays corrupt across retries — only a re-prefill can
        produce a clean bundle.)"""
        return self._fire("handoff_corrupt", self.plan.handoff_corrupt,
                          rid, prefill_no)

    def prefill_stalled(self, step: float) -> bool:
        """Is the prefill pool frozen at logical ``step``?  Stalls occupy
        whole windows of ``stall_steps`` ticks (crash for N steps)."""
        return self._fire("prefill_stall", self.plan.prefill_stall,
                          int(step) // self.plan.stall_steps)

    def decode_stalled(self, step: float) -> bool:
        """Is the decode pool frozen at logical ``step``?"""
        return self._fire("decode_stall", self.plan.decode_stall,
                          int(step) // self.plan.stall_steps)

    # -- decode-path hooks (ContinuousBatcher) -----------------------------

    def straggle(self, step: float) -> float:
        """Wall-clock delay (seconds; 0.0 = none) for this decode step."""
        if self._fire("straggler", self.plan.straggler, int(step)):
            return max(self.plan.straggler_s, 0.0)
        return -1.0

    def poison_slot(self, rid: int, emitted: int) -> bool:
        """Poke a non-finite value into request ``rid``'s live KV now
        that it has emitted ``emitted`` tokens?  Fire-once per
        (rid, emitted): the quarantine-recompute replay walks the same
        progress values again and must not be re-poisoned forever."""
        if self.plan.nan_logits <= 0.0:
            return False
        key = (int(rid), int(emitted))
        if key in self._nan_fired:
            return False
        if hash_unit(self.plan.seed, "nan_logits", *key) \
                < self.plan.nan_logits:
            self._nan_fired.add(key)
            self.counts["nan_logits"] += 1
            return True
        return False

    def oom_burst(self, step: float) -> bool:
        """Does allocator growth fail for the whole logical ``step``?"""
        return self._fire("oom", self.plan.oom, int(step))

    # -- payload damage ----------------------------------------------------

    @staticmethod
    def corrupt_bundle(bundle) -> None:
        """Flip one K element of a (sealed) bundle in place — the
        in-flight bit-flip the splice-time checksum must catch.  The
        perturbation is sign+magnitude (not NaN): silent corruption, the
        hard case — only the checksum can see it."""
        k = np.array(bundle.k)   # private copy: never alias a shared ref
        idx = (0,) * k.ndim
        k[idx] = -k[idx] + np.asarray(1.0, dtype=k.dtype)
        bundle.k = k


__all__ = ["FaultPlan", "FaultInjector", "FAULT_KINDS", "hash_unit"]
