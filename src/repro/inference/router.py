"""The multi-replica serving router (DESIGN.md §13).

Single-replica tensor parallelism hits the communication wall the paper
quantifies well before the hardware runs out (Sec. 5's strong-scaling
study): past the AR mitigations in PRs 1/5/7, the remaining throughput
lever is *data parallelism over replicas*.  This module is that tier: a
:class:`Router` load-balances a request trace over N self-contained
replicas — each a :class:`~.scheduler.ContinuousBatcher` or
:class:`~.disagg.DisaggCoordinator` with its own mesh (disjoint device
group), AR table, and KV cache — and owns admission *placement*, while
each replica keeps owning its internal scheduling.

Design invariants:

* **Placement is a pure function of a load snapshot.**  Every policy is
  ``f(loads: List[ReplicaLoad], rr: int) -> int`` over per-replica
  :class:`ReplicaLoad` snapshots, so policies unit-test on synthetic
  queue states with no engine behind them.  Load is measured in *queue
  depth and estimated cost on the logical step clock* — never wall
  clock — so placement is deterministic and a trace replays bit-identically
  across runs and machines (wall time would make placement a function of
  CI jitter).
* **Replica-affine preemption recovery.**  A preempted request re-admits
  through its own replica's requeue (``ContinuousBatcher.tick`` admits
  requeue-first; the disagg coordinator splices decode evictions back
  into its own pending queue).  The router never re-places a preempted
  request — its KV/recompute context and sampling chain live on the
  replica that admitted it.
* **Fleet == N independent singles.**  Replicas never interact, so a
  ``round_robin`` fleet is *token-identical per request* to N standalone
  replicas each fed its own arrival-index subset (asserted in
  tests/test_router.py and tests/dist_cases/case_router.py).
* **Per-replica fault isolation.**  ``build_replica`` folds the replica
  id into the fault-plan seed, so one replica's injected drops/stalls
  never mirror onto another's requests.

The ``ttft_aware`` policy estimates each queued prompt's prefill cost
with the paper's analytic machinery (``core.comm_model`` ring/tree AR
model + chip GEMM roofline from ``inference.simulator``): per-layer
projection flops over the chip's sustained throughput, plus two
all-reduces per layer at the replica's TP layout when tp > 1.  Units are
seconds, but only the *ordering* matters for placement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from .scheduler import Request, ServeMetrics
from .spec import ReplicaSpec, ServeSpec, build_replica

# ---------------------------------------------------------------------------
# Load snapshots and placement policies
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicaLoad:
    """One replica's admission-relevant state at a placement decision.

    All quantities live on the logical step clock / token axis —
    deterministic, replayable, CI-stable (see module docstring).
    """
    queue_depth: int            # due requests queued, not yet admitted
    queued_prompt_tokens: int   # sum of queued prompt lengths
    active: int                 # occupied decode slots (incl. requeue)
    slots: int                  # decode slot capacity
    active_remaining: int       # decode tokens left across active slots
    est_queue_cost: float = 0.0   # est. prefill seconds queued ahead
    est_active_cost: float = 0.0  # est. drain seconds of active decodes


def place_round_robin(loads: Sequence[ReplicaLoad], rr: int) -> int:
    """Arrival index modulo fleet size — the parity-bearing baseline."""
    return rr % len(loads)


def place_least_queue(loads: Sequence[ReplicaLoad], rr: int) -> int:
    """Fewest requests in flight (queued + active); ties to the lowest
    index so placement is deterministic."""
    return min(range(len(loads)),
               key=lambda i: (loads[i].queue_depth + loads[i].active, i))


def place_ttft_aware(loads: Sequence[ReplicaLoad], rr: int) -> int:
    """Smallest estimated wait-to-first-token: the prefill cost of the
    work queued ahead, plus — when every slot is busy — the estimated
    drain cost of the active decodes the arrival must wait behind.
    Queue depth breaks cost ties (two empty replicas look identical)."""
    def key(i: int):
        l = loads[i]
        c = l.est_queue_cost
        if l.slots and l.active >= l.slots:
            c += l.est_active_cost
        return (c, l.queue_depth + l.active, i)
    return min(range(len(loads)), key=key)


POLICIES: Dict[str, Callable[[Sequence[ReplicaLoad], int], int]] = {
    "round_robin": place_round_robin,
    "least_queue": place_least_queue,
    "ttft_aware": place_ttft_aware,
}


# ---------------------------------------------------------------------------
# Analytic prefill cost (comm_model + chip roofline)
# ---------------------------------------------------------------------------


def prefill_cost_model(spec: ReplicaSpec, net=None,
                       chip=None) -> Callable[[int], float]:
    """``spec`` -> ``f(prompt_tokens) -> estimated prefill seconds``.

    Compute term: per-layer projection GEMM flops (tile-floor applied)
    over the chip's sustained bf16 throughput, split ``tp`` ways.  Comm
    term (tp > 1): two all-reduces per layer of the activation message
    ``S * d_model * itemsize`` at the best of the modeled algorithms for
    the replica's (pods x tp/pods) layout.  Deterministic by
    construction — pure arithmetic on the spec.
    """
    from ..configs import get_config, get_smoke
    from ..core.comm_model import NETWORKS, nccl_model_best
    from .simulator import CHIP_FOR_NET, V5E, _layer_gemm_flops
    cfg = get_smoke(spec.arch) if spec.smoke else get_config(spec.arch)
    tp = spec.prefill_tp if spec.disagg else spec.tp
    pods = spec.prefill_pods if spec.disagg else spec.pods
    if net is None:
        net = NETWORKS["tpu_v5e"]
    if chip is None:
        chip = CHIP_FOR_NET.get(net.name, V5E)
    itemsize = 2  # bf16 activations
    def cost(s_tokens: int) -> float:
        flops = cfg.n_layers * _layer_gemm_flops(cfg, s_tokens,
                                                 chip.gemm_tile_m)
        t = flops / (tp * chip.flops_bf16 * chip.efficiency)
        if tp > 1:
            msg = 2.0 * s_tokens * cfg.d_model * itemsize
            _, t_ar = nccl_model_best(msg, pods, tp // pods, net)
            t += cfg.n_layers * t_ar
        return t
    return cost


# ---------------------------------------------------------------------------
# Fleet metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RouterMetrics:
    """Fleet-level aggregation: per-replica metrics plus their lossless
    merge (percentiles recomputed from retained samples — never an
    average of per-replica p99s) and placement accounting."""
    replicas: int
    policy: str
    placements: List[int]          # requests placed per replica
    load_imbalance: float          # max/mean of placements (1.0 = even)
    fleet: Any                     # ServeMetrics | DisaggMetrics merge
    per_replica: List[Any]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "replicas": self.replicas,
            "policy": self.policy,
            "placements": list(self.placements),
            "load_imbalance": self.load_imbalance,
            "fleet": self.fleet.to_dict(),
            "per_replica": [m.to_dict() for m in self.per_replica],
        }


# ---------------------------------------------------------------------------
# The router
# ---------------------------------------------------------------------------


class Router:
    """Front-end tier over N self-contained replicas.

    Drives the fleet on one shared logical step clock: each tick, due
    arrivals are placed (policy over :class:`ReplicaLoad` snapshots)
    onto per-replica queues the router owns, then every replica runs one
    ``tick(queue, now)`` — the same entry point ``run`` uses standalone,
    so a routed replica schedules exactly like a single one.
    """

    def __init__(self, replicas: Sequence[Any], policy: str = "round_robin",
                 cost_fn: Optional[Callable[[int], float]] = None):
        if not replicas:
            raise ValueError("Router needs at least one replica")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r} "
                             f"(one of {tuple(POLICIES)})")
        self.replicas = list(replicas)
        kinds = {hasattr(r, "decode") for r in self.replicas}
        if len(kinds) > 1:
            raise ValueError("heterogeneous fleet: all replicas must be "
                             "batchers or all coordinators")
        self.policy = policy
        # token-count proxy when no spec/cost model: est cost ~ tokens.
        # monotone in prompt length, which is all ttft_aware needs.
        self.cost_fn = cost_fn if cost_fn is not None else float
        self.queues: List[List[Request]] = [[] for _ in self.replicas]
        self.placements = [0] * len(self.replicas)
        self.assigned: List[List[Request]] = [[] for _ in self.replicas]
        self._rr = 0

    @classmethod
    def from_spec(cls, spec: ServeSpec, *, ap=None, params=None) -> "Router":
        """Build the fleet a ``ServeSpec`` describes: N replicas from one
        template, each on its own disjoint contiguous device group, each
        with an independently-seeded fault schedule (``replica_id`` folds
        into the plan seed)."""
        from ..parallel.topology import replica_device_groups
        spec.validate()
        rspec = spec.replica
        groups = replica_device_groups(spec.replicas, rspec.device_need)
        reps = [build_replica(rspec, ap=ap, params=params,
                              devices=groups[i], replica_id=i)
                for i in range(spec.replicas)]
        return cls(reps, policy=spec.router_policy,
                   cost_fn=prefill_cost_model(rspec))

    # -- load snapshot -------------------------------------------------------

    def _load(self, i: int) -> ReplicaLoad:
        rep = self.replicas[i]
        q = self.queues[i]
        dec = rep.decode if hasattr(rep, "decode") else rep
        # in-flight disagg handoffs count as queued-ahead work
        inflight = len(rep._ready) if hasattr(rep, "_ready") else 0
        active = sum(a is not None for a in dec.active) + len(dec._requeue)
        remaining = sum(int(dec.remaining[s])
                        for s, a in enumerate(dec.active) if a is not None)
        q_tokens = sum(len(r.prompt) for r in q)
        est_q = sum(self.cost_fn(len(r.prompt)) for r in q) \
            + inflight * self.cost_fn(1)
        # decode drains ~1 token per active slot per step; cost_fn(1) is
        # the single-token forward estimate for one such step
        steps_to_free = min((int(dec.remaining[s])
                             for s, a in enumerate(dec.active)
                             if a is not None), default=0)
        est_a = steps_to_free * self.cost_fn(1)
        return ReplicaLoad(
            queue_depth=len(q) + inflight, queued_prompt_tokens=q_tokens,
            active=active, slots=dec.slots, active_remaining=remaining,
            est_queue_cost=est_q, est_active_cost=est_a)

    def _place(self, req: Request) -> int:
        loads = [self._load(i) for i in range(len(self.replicas))]
        i = POLICIES[self.policy](loads, self._rr)
        self._rr += 1
        self.placements[i] += 1
        self.queues[i].append(req)
        self.assigned[i].append(req)
        return i

    # -- trace replay --------------------------------------------------------

    def run(self, requests: List[Request],
            max_steps: int = 100000) -> List[Request]:
        """Replay a trace over the fleet (same contract as
        ``ContinuousBatcher.run``): one shared logical clock, placement
        at arrival, every replica ticked every step, drained when every
        queue, requeue, and slot across the fleet is empty."""
        waiting = sorted(requests, key=lambda r: r.arrival_s)
        qi = 0
        now = 0.0
        self.queues = [[] for _ in self.replicas]
        self.placements = [0] * len(self.replicas)
        self.assigned = [[] for _ in self.replicas]
        self._rr = 0
        for rep in self.replicas:
            if hasattr(rep, "begin_run"):
                rep.begin_run()
            else:
                rep.reset_run_stats()
        wall0 = time.perf_counter()
        for _ in range(max_steps):
            while qi < len(waiting) and waiting[qi].arrival_s <= now:
                self._place(waiting[qi])
                qi += 1
            if qi >= len(waiting) and all(
                    rep.drained(q)
                    for rep, q in zip(self.replicas, self.queues)):
                break
            for rep, q in zip(self.replicas, self.queues):
                rep.tick(q, now)
            now += 1.0
        wall = time.perf_counter() - wall0
        # fleet wall: replicas share the loop, so each gets the same wall
        for rep in self.replicas:
            if hasattr(rep, "decode"):
                rep._wall = wall
                rep.decode._wall_run = wall
            else:
                rep._wall_run = wall
        return requests

    # -- metrics -------------------------------------------------------------

    def metrics(self, requests: List[Request]) -> RouterMetrics:
        from .disagg import DisaggMetrics
        per = [rep.metrics(self.assigned[i])
               for i, rep in enumerate(self.replicas)]
        cls = DisaggMetrics if hasattr(self.replicas[0], "decode") \
            else ServeMetrics
        fleet = cls.merge(per)
        mean = sum(self.placements) / len(self.placements)
        imb = max(self.placements) / mean if mean else 0.0
        return RouterMetrics(
            replicas=len(self.replicas), policy=self.policy,
            placements=list(self.placements), load_imbalance=imb,
            fleet=fleet, per_replica=per)


__all__ = ["Router", "RouterMetrics", "ReplicaLoad", "POLICIES",
           "place_round_robin", "place_least_queue", "place_ttft_aware",
           "prefill_cost_model"]
