"""Training substrate: optimizer, data pipeline, checkpointing, fault
tolerance."""
from .optimizer import (adamw_init, adamw_update, cosine_lr,
                        global_grad_norm)

__all__ = ["adamw_init", "adamw_update", "cosine_lr", "global_grad_norm"]
