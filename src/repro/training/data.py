"""Deterministic, resumable synthetic data pipeline.

Stateless by construction: batch ``i`` is a pure function of
(seed, step, host_shard), so resume-after-preemption and elastic re-sharding
need no iterator state — the checkpointed step counter alone restores the
exact data order.  Tokens follow a fixed random first-order Markov chain
(Zipf-ish stationary distribution), which gives the CE loss real learnable
structure for the end-to-end training examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class SyntheticLMData:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    order: int = 1                 # markov order

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse-ish transition table: each token has k likely successors
        k = min(16, v)
        self._succ = rng.integers(0, v, size=(v, k)).astype(np.int32)
        logits = rng.gumbel(size=(v, k)).astype(np.float64)
        p = np.exp(logits - logits.max(1, keepdims=True))
        self._p = (p / p.sum(1, keepdims=True)).astype(np.float64)

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """(tokens, labels) for this host at ``step`` — pure function."""
        rng = np.random.default_rng(
            (self.seed, 0x5EED, step, self.host_id))
        b, s, v = self.host_batch, self.seq_len, self.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        k = self._succ.shape[1]
        choices = rng.random((b, s))
        for t in range(s):
            cum = np.cumsum(self._p[toks[:, t]], axis=1)
            idx = (choices[:, t, None] > cum).sum(1)
            toks[:, t + 1] = self._succ[toks[:, t], np.minimum(idx, k - 1)]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def reshard(self, n_hosts: int, host_id: int) -> "SyntheticLMData":
        """Elastic re-sharding: same stream, new host split."""
        return dataclasses.replace(self, n_hosts=n_hosts, host_id=host_id)


__all__ = ["SyntheticLMData"]
