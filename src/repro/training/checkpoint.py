"""Checkpointing: atomic, async, mesh-agnostic.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``meta.json``; a ``LATEST`` marker
file is renamed into place last, so a crash mid-save can never corrupt the
restore path.  Arrays are stored as full logical arrays keyed by pytree
path, which makes checkpoints *mesh-agnostic*: restore re-shards onto
whatever mesh the restarted job has (elastic scaling).  ``AsyncCheckpointer``
snapshots device arrays to host, then writes on a background thread so the
train loop never blocks on disk.

(On a real multi-host cluster each host would write only its addressable
shards with the same commit protocol; the single-process container makes
every shard addressable, so the full-array path is exact here.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten, DictKey


def _path_key(path) -> str:
    parts = []
    for k in path:
        parts.append(str(k.key) if isinstance(k, DictKey) else str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         keep: int = 3) -> str:
    """Synchronous atomic save.  ``state`` is any pytree of arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = tree_flatten_with_path(state)
    arrays = {_path_key(p): np.asarray(jax.device_get(v))
              for p, v in leaves}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": list(arrays),
                   "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
                   "time": time.time()}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                        # atomic publish
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, template: Any,
            shardings: Any = None, step: Optional[int] = None
            ) -> Tuple[int, Any]:
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    jax.sharding.Sharding — arrays are placed directly onto the (possibly
    different) mesh, which is what makes restarts elastic."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(d, "arrays.npz"))
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    saved_dtypes = meta.get("dtypes", {})
    leaves, treedef = tree_flatten_with_path(template)
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (path, tmpl), shd in zip(leaves, shard_leaves):
        key = _path_key(path)
        arr = data[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"template {tmpl.shape}")
        if arr.dtype.kind == "V":
            # npz round-trips extension dtypes (bfloat16) as raw void bytes
            saved = saved_dtypes.get(key, str(np.dtype(tmpl.dtype)))
            if saved != str(np.dtype(tmpl.dtype)):
                raise ValueError(f"{key}: checkpoint dtype {saved} != "
                                 f"template {np.dtype(tmpl.dtype)}")
            arr = arr.view(tmpl.dtype)
        else:
            arr = arr.astype(tmpl.dtype)
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.device_put(arr))
    return step, tree_unflatten(jax.tree.structure(template), out)


class AsyncCheckpointer:
    """Snapshot-to-host then write-on-thread; at most one pending save."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                  state)

        def work():
            try:
                save(self.ckpt_dir, step, host_state, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err


__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]
