"""AdamW with fully-sharded (ZeRO-style) optimizer state.

The optimizer runs *inside* the shard_map'd train step: every update is
elementwise, so applying it to local parameter shards is exact.  Optimizer
moments are f32 and inherit the parameter sharding specs, which makes the
state ZeRO-sharded for free (each device holds moments only for its shard).

Gradient-norm computation accounts for replication: leaves that are
replicated across some mesh axes contribute their square-sum divided by the
replication factor before the global psum, so every logical element is
counted exactly once.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_grad_norm(grads, repl_factors, all_axes) -> jax.Array:
    """L2 norm of the (sharded) gradient pytree.

    repl_factors: pytree of ints — how many devices hold a copy of each
    local shard (1 for fully sharded leaves).
    """
    def leaf_sq(g, r):
        return jnp.sum(jnp.square(g.astype(jnp.float32))) / r

    sq = sum(jax.tree.leaves(jax.tree.map(leaf_sq, grads, repl_factors)))
    if all_axes:
        sq = lax.psum(sq, all_axes)
    return jnp.sqrt(sq)


def adamw_update(grads, opt_state, params, *, lr, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1,
                 grad_scale=None, skip: Optional[jax.Array] = None
                 ) -> Tuple[Any, Dict[str, Any]]:
    """One AdamW step.  ``skip`` (bool scalar) freezes the update (NaN/inf
    gradient protection) while still advancing nothing."""
    step = opt_state["step"] + jnp.where(
        skip if skip is not None else False, 0, 1)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(b1, jnp.maximum(t, 1.0))
    bc2 = 1.0 - jnp.power(b2, jnp.maximum(t, 1.0))

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        if grad_scale is not None:
            gf = gf * grad_scale
        m_new = b1 * m + (1.0 - b1) * gf
        v_new = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if skip is not None:
            p_new = jnp.where(skip, p, p_new)
            m_new = jnp.where(skip, m, m_new)
            v_new = jnp.where(skip, v, v_new)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def cosine_lr(step, *, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = base_lr * t / max(warmup, 1)
    prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                     * (1.0 + jnp.cos(math.pi * prog)))
    return jnp.where(t < warmup, warm, cos)


__all__ = ["adamw_init", "adamw_update", "cosine_lr", "global_grad_norm"]
