"""Production mesh construction.

``make_production_mesh`` is a *function* (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh is
16x16 = 256 chips (one v5e pod, all-ICI); the multi-pod mesh adds a leading
"pod" axis over DCN: 2 x 16 x 16 = 512 chips.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..core.compat import make_mesh, auto_axis_types
from ..core.pcontext import ParallelCtx, single_pod_ctx, multi_pod_ctx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_test_mesh(shape: Tuple[int, ...] = (2, 4),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Small mesh for multi-host-device tests."""
    return make_mesh(shape, axes, axis_types=auto_axis_types(len(axes)))


def make_ctx(mesh, *, ar_strategy: str = "flat",
             cross_pod_tp: bool = False,
             batch_replicated: bool = False, **kw) -> ParallelCtx:
    """Wire a ParallelCtx for one of the production meshes."""
    multi = "pod" in mesh.axis_names
    ctx = (multi_pod_ctx(ar_strategy=ar_strategy, cross_pod_tp=cross_pod_tp,
                         **kw)
           if multi else single_pod_ctx(ar_strategy=ar_strategy, **kw))
    if batch_replicated:  # long_500k: batch=1 cannot shard over dp
        ctx = ctx.replace(dp=(), fsdp=())
    return ctx


def tp_size(mesh, ctx: ParallelCtx) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in ctx.tp_slow + ctx.tp_fast:
        n *= sizes[a]
    return n


__all__ = ["make_production_mesh", "make_test_mesh", "make_ctx", "tp_size"]
