"""Training driver: ``python -m repro.launch.train --arch llama3.2-1b
--smoke --steps 50``.

Fault tolerance built in:
  * resume-from-latest on start (``--ckpt-dir``), async atomic checkpoints
  * SIGTERM/SIGINT preemption handler: checkpoint synchronously, exit 143
    (cluster schedulers re-queue; restart resumes exactly)
  * NaN/Inf gradient skipping (inside the jitted step)
  * straggler watchdog: per-step wall-clock EMA; steps slower than
    ``--straggler-factor`` x EMA are logged (on a cluster, the hook point
    for drain/replace decisions)
  * elastic restart: checkpoints are mesh-agnostic; restarting on a
    different mesh re-shards automatically (see launch/elastic_demo.py)
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time
from typing import Optional

import jax
import numpy as np

from ..configs import get_config, get_smoke, ARCH_IDS
from ..core.pcontext import ParallelCtx
from ..models.transformer import make_plan, init_params
from ..parallel.steps import build_train_step
from ..parallel import sharding as shd
from ..training.optimizer import adamw_init
from ..training.data import SyntheticLMData
from ..training import checkpoint as ckpt
from .mesh import make_test_mesh, make_production_mesh, make_ctx, tp_size


def run_training(arch: str, *, steps: int = 50, smoke: bool = True,
                 seq_len: int = 64, global_batch: int = 8,
                 microbatches: int = 2, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 25, base_lr: float = 1e-2,
                 mesh=None, ctx: Optional[ParallelCtx] = None,
                 grad_reduce: str = "rd", straggler_factor: float = 3.0,
                 log_every: int = 10, seed: int = 0):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if mesh is None:
        mesh = make_test_mesh((1, 1), ("data", "model"))
    if ctx is None:
        ctx = ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",),
                          ep=("model",), sp=("model",),
                          grad_reduce_strategy=grad_reduce)
    tp = tp_size(mesh, ctx)
    ap = make_plan(cfg, tp)
    built = build_train_step(ap, ctx, mesh, microbatches=microbatches,
                             base_lr=base_lr, warmup=5, total_steps=steps,
                             frame_embeds=cfg.family == "encdec",
                             patch_embeds=cfg.family == "vlm")
    step_fn = built.jit()

    params = init_params(jax.random.PRNGKey(seed), ap)
    opt = adamw_init(params)
    start_step = 0
    saver = None
    if ckpt_dir:
        saver = ckpt.AsyncCheckpointer(ckpt_dir)
        if ckpt.latest_step(ckpt_dir) is not None:
            start_step, state = ckpt.restore(
                ckpt_dir, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

    data = SyntheticLMData(cfg.vocab_size, seq_len, global_batch, seed=seed)
    rng = np.random.default_rng(seed)

    preempted = {"flag": False}

    def on_signal(signum, frame):
        preempted["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        old_handlers[sig] = signal.signal(sig, on_signal)

    history = []
    ema = None
    try:
        for step in range(start_step, steps):
            batch = data.batch(step)
            if cfg.family == "encdec":
                batch["frames"] = rng.standard_normal(
                    (global_batch, cfg.enc_seq, cfg.d_model)).astype(
                        np.float32)
            if cfg.family == "vlm":
                batch["patches"] = rng.standard_normal(
                    (global_batch, cfg.n_patches, cfg.d_model)).astype(
                        np.float32)
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            ema = dt if ema is None else 0.9 * ema + 0.1 * dt
            if dt > straggler_factor * ema and step > start_step + 3:
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(ema {ema:.2f}s)")
            history.append({"step": step, "loss": loss,
                            "grad_norm": float(metrics["grad_norm"]),
                            "skipped": float(metrics["skipped"]),
                            "wall_s": dt})
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
            if saver and (step + 1) % ckpt_every == 0:
                saver.save(step + 1, {"params": params, "opt": opt})
            if preempted["flag"]:
                print("[train] preemption signal: checkpointing + exit")
                if saver:
                    saver.wait()
                if ckpt_dir:
                    ckpt.save(ckpt_dir, step + 1,
                              {"params": params, "opt": opt})
                return {"history": history, "params": params, "opt": opt,
                        "preempted": True, "stopped_at": step + 1}
        if saver:
            saver.save(steps, {"params": params, "opt": opt})
            saver.wait()
    finally:
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return {"history": history, "params": params, "opt": opt,
            "preempted": False, "stopped_at": steps}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--microbatches", type=int, default=2)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=25)
    p.add_argument("--lr", type=float, default=1e-2)
    p.add_argument("--grad-reduce", default="rd",
                   choices=["flat", "rd", "rd_int8"])
    args = p.parse_args(argv)
    out = run_training(args.arch, steps=args.steps, smoke=args.smoke,
                       seq_len=args.seq_len, global_batch=args.global_batch,
                       microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       base_lr=args.lr, grad_reduce=args.grad_reduce)
    print(f"[train] done: final loss "
          f"{out['history'][-1]['loss']:.4f}, preempted={out['preempted']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
