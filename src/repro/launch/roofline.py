import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Roofline analysis per (arch x shape x mesh) — deliverable (g).

Terms (per chip, TPU v5e):
    compute    = HLO_FLOPs / 197 TFLOP/s
    memory     = HLO_bytes / 819 GB/s          (bf16-corrected, see below)
    collective = ICI_bytes / 45 GB/s + DCN_bytes / 6.25 GB/s

Methodology notes (full discussion in EXPERIMENTS.md):
* XLA's ``cost_analysis`` counts a ``lax.scan`` body ONCE, so the sweep
  compiles 1-layer and 2-layer UNROLLED probe variants at identical
  per-device shapes and reconstructs totals linearly:
  total = m(1) + (L-1) * (m(2) - m(1)); training cells scale by the real
  grad-accumulation microbatch count.  Probes disable attention chunking
  (chunk loops would be undercounted the same way).
* The CPU backend upcasts bf16 compute to f32; collective bytes therefore
  come from the *lowered* HLO (logical dtypes), and HLO memory bytes are
  reported raw and bf16-corrected (x0.5 — exact for the bf16-dominated
  inference streams, conservative for f32 gradient traffic).
* MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (inference) per token;
  the ratio to HLO_FLOPs surfaces remat recompute, attention, dead-slot
  padding and dispatch overheads.
"""
import argparse
import dataclasses
import json
import sys
from typing import Dict, Optional

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 4.5e10      # ~50 GB/s/link, one link conservatively
DCN_BW = 6.25e9      # per-chip share of pod-level DCN


def _probe_once(arch, shape_name, mesh, *, n_dec, n_enc, strategy,
                cross_pod_tp, **cell_kw):
    import dataclasses as dc
    import jax
    from ..configs import get_config, cell_plan
    from ..launch.input_specs import build_cell
    from ..launch.hlo_analysis import summarize_compiled
    cfg = get_config(arch)
    plan = cell_plan(arch, shape_name)
    over = {"n_layers": n_dec}
    if cfg.enc_layers:
        over["enc_layers"] = n_enc
    cfg2 = dc.replace(cfg, **over)
    shape = plan.shape
    probe_kw = {}
    if shape.kind == "train" and plan.microbatches > 1:
        # one microbatch at the per-microbatch batch size
        from ..configs.registry import Shape
        shape2 = Shape(shape.name, shape.seq_len,
                       shape.global_batch // plan.microbatches, shape.kind)
        probe_kw["shape_override"] = shape2
    cell = build_cell(arch, shape_name, mesh, ar_strategy=strategy,
                      cross_pod_tp=cross_pod_tp, cfg_override=cfg2,
                      scan_layers=False, probe=True, **probe_kw,
                      **cell_kw)
    lowered = cell.lower()
    compiled = lowered.compile()
    return summarize_compiled(compiled, mesh, lowered=lowered)


def _lin(m1: Dict, m2: Dict, n: int, keys) -> Dict[str, float]:
    out = {}
    for k in keys:
        a, b = float(m1[k]), float(m2[k])
        out[k] = a + (n - 1) * (b - a)
    return out


_KEYS = ("flops", "bytes_accessed", "ici_bytes", "dcn_bytes",
         "wire_ici_bytes", "wire_dcn_bytes")


def roofline_cell(arch: str, shape_name: str, mesh_kind: str, *,
                  strategy: str = "flat", cross_pod_tp: bool = False,
                  dryrun_dir: str = "experiments/dryrun",
                  variant: str = "", **cell_kw) -> Dict:
    from ..configs import get_config, cell_plan, shape_applicable
    from ..launch.mesh import make_production_mesh

    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    cfg = get_config(arch)
    plan = cell_plan(arch, shape_name)
    shape = plan.shape
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(mesh.devices.size)

    # probes: (dec, enc) layer counts
    if cfg.enc_layers:
        m11 = _probe_once(arch, shape_name, mesh, n_dec=1, n_enc=1,
                          strategy=strategy, cross_pod_tp=cross_pod_tp,
                          **cell_kw)
        m21 = _probe_once(arch, shape_name, mesh, n_dec=2, n_enc=1,
                          strategy=strategy, cross_pod_tp=cross_pod_tp,
                          **cell_kw)
        m12 = _probe_once(arch, shape_name, mesh, n_dec=1, n_enc=2,
                          strategy=strategy, cross_pod_tp=cross_pod_tp,
                          **cell_kw)
        tot = {}
        for k in _KEYS:
            a = float(m11[k])
            bd = float(m21[k]) - a
            be = float(m12[k]) - a
            tot[k] = a + (cfg.n_layers - 1) * bd + (cfg.enc_layers - 1) * be
    else:
        m1 = _probe_once(arch, shape_name, mesh, n_dec=1, n_enc=0,
                         strategy=strategy, cross_pod_tp=cross_pod_tp,
                         **cell_kw)
        m2 = _probe_once(arch, shape_name, mesh, n_dec=2, n_enc=0,
                         strategy=strategy, cross_pod_tp=cross_pod_tp,
                         **cell_kw)
        tot = _lin(m1, m2, cfg.n_layers, _KEYS)

    if shape.kind == "train" and plan.microbatches > 1:
        for k in _KEYS:
            tot[k] *= plan.microbatches

    t_compute = tot["flops"] / PEAK_FLOPS
    bytes_bf16 = tot["bytes_accessed"] * 0.5
    t_memory = bytes_bf16 / HBM_BW
    t_coll = tot["wire_ici_bytes"] / ICI_BW + tot["wire_dcn_bytes"] / DCN_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = sum(terms.values())
    frac = terms[dominant] / bound if bound > 0 else 0.0

    # MODEL_FLOPS (useful) per device
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens / n_dev
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens / n_dev
    else:
        model_flops = 2.0 * n_active * shape.global_batch / n_dev

    suggest = {
        "compute_s": "raise MXU utilization: fuse elementwise chains, "
                     "MXU-align tiles, drop dead-slot padding",
        "memory_s": "cut HBM traffic: int8 weights/KV-cache, larger "
                    "batch per weight read, fuse to avoid re-reads",
        "collective_s": "hierarchical RD over the slow axis, int8 "
                        "exchange, overlap AR with compute",
    }[dominant]

    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy, "cross_pod_tp": cross_pod_tp,
           "variant": variant,
           "status": "ok", "n_devices": n_dev,
           "hlo_flops_per_dev": tot["flops"],
           "hlo_bytes_per_dev_raw": tot["bytes_accessed"],
           "hlo_bytes_per_dev_bf16corr": bytes_bf16,
           "ici_bytes_per_dev": tot["ici_bytes"],
           "dcn_bytes_per_dev": tot["dcn_bytes"],
           "wire_ici_bytes_per_dev": tot["wire_ici_bytes"],
           "wire_dcn_bytes_per_dev": tot["wire_dcn_bytes"],
           **terms,
           "dominant": dominant.replace("_s", ""),
           "dominant_frac": frac,
           "model_flops_per_dev": model_flops,
           "useful_flops_ratio": model_flops / max(tot["flops"], 1.0),
           "bound_step_s": bound,
           "move_dominant": suggest}
    # attach memory evidence from the scanned dry-run record if present
    tag = f"{mesh_kind}__{arch}__{shape_name}__flat.json"
    p = os.path.join(dryrun_dir, tag)
    if os.path.exists(p):
        with open(p) as f:
            d = json.load(f)
        rec["peak_bytes_per_device_xla"] = d.get("peak_bytes_per_device")
        rec["argument_bytes_per_device"] = d.get("argument_bytes_per_device")
    return rec


def main(argv=None):
    from ..configs import ARCH_IDS, SHAPES, all_cells
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--strategy", default="flat")
    p.add_argument("--cross-pod-tp", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--out", default="experiments/roofline")
    args = p.parse_args(argv)

    cells = ([(a, s) for a, s, ok, _ in all_cells() if ok]
             if args.all else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        rec = roofline_cell(arch, shape, args.mesh, strategy=args.strategy,
                            cross_pod_tp=args.cross_pod_tp)
        tag = f"{args.mesh}__{arch}__{shape}__{args.strategy}"
        if args.cross_pod_tp:
            tag += "__xpod"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            print(f"{arch:22s} {shape:12s} {args.mesh:6s} "
                  f"C={rec['compute_s']*1e3:8.3f}ms "
                  f"M={rec['memory_s']*1e3:8.3f}ms "
                  f"N={rec['collective_s']*1e3:8.3f}ms "
                  f"dom={rec['dominant']:10s} "
                  f"useful={rec['useful_flops_ratio']:.2f}", flush=True)
        else:
            print(f"{arch:22s} {shape:12s} SKIP ({rec['reason'][:40]})",
                  flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
