import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines, before ANY other import: jax locks the
# device count on first initialization, and the multi-pod dry-run needs 512
# placeholder host devices to build the production mesh.  (Do NOT set this
# globally — smoke tests and benches must see 1 device.)
"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell on the production meshes and record memory/cost/collective evidence.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
        --shape decode_32k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out experiments/dryrun

A cell passes when the lowered module compiles on the 16x16 single-pod mesh
AND the 2x16x16 multi-pod mesh; failures (sharding mismatch, OOM at compile,
unsupported collective) are bugs in the system.
"""
import argparse
import json
import sys
import time
import traceback

import jax


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             strategy: str = "flat", cross_pod_tp: bool = False,
             out_dir=None, verbose: bool = True):
    from ..configs import shape_applicable
    from ..launch.mesh import make_production_mesh
    from ..launch.input_specs import build_cell
    from ..launch.hlo_analysis import summarize_compiled

    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "strategy": strategy, "cross_pod_tp": cross_pod_tp,
           "n_devices": int(mesh.devices.size)}
    try:
        cell = build_cell(arch, shape_name, mesh, ar_strategy=strategy,
                          cross_pod_tp=cross_pod_tp)
        lowered = cell.lower()
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        summary = summarize_compiled(compiled, mesh, lowered=lowered)
        rec.update(summary)
        rec["status"] = "ok"
        rec["fits_16GB"] = summary["peak_bytes_per_device"] < 16e9
        if verbose:
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
    except Exception as e:  # noqa: BLE001 — record and keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
        if verbose:
            traceback.print_exc()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{mesh_kind}__{arch}__{shape_name}__{strategy}"
        if cross_pod_tp:
            tag += "__xpod"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main(argv=None):
    from ..configs import ARCH_IDS, SHAPES, all_cells

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    p.add_argument("--shape", choices=list(SHAPES), default=None)
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="both")
    p.add_argument("--strategy", default="flat",
                   choices=["flat", "hier_ring", "hier_rd",
                            "hier_rd_halving"])
    p.add_argument("--cross-pod-tp", action="store_true",
                   help="TP spans the pod axis (the paper's headline "
                        "multi-node TP scenario)")
    p.add_argument("--all", action="store_true",
                   help="sweep the full 40-cell grid")
    p.add_argument("--out", default="experiments/dryrun")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch, shape, ok, _ in all_cells():
            cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            p.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, strategy=args.strategy,
                           cross_pod_tp=args.cross_pod_tp,
                           out_dir=args.out, verbose=not args.quiet)
            s = rec["status"]
            n_ok += s == "ok"
            n_skip += s == "skipped"
            n_err += s == "error"
            mark = {"ok": "PASS", "skipped": "SKIP", "error": "FAIL"}[s]
            extra = ""
            if s == "ok":
                extra = (f" peak={rec['peak_bytes_per_device']/1e9:.2f}GB"
                         f" fits={rec['fits_16GB']}"
                         f" flops={rec['flops']:.3e}"
                         f" dcn={rec['dcn_bytes']/1e6:.2f}MB"
                         f" ici={rec['ici_bytes']/1e6:.2f}MB"
                         f" ({rec['lower_s']}s/{rec['compile_s']}s)")
            elif s == "error":
                extra = " " + rec["error"][:160]
            print(f"[{mark}] {mk:6s} {arch:22s} {shape:12s}{extra}",
                  flush=True)
    print(f"\ndry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
