"""Serving driver: batched generation, trace-replay continuous batching,
or disaggregated prefill/decode pools (see docs/serving.md for the full
flag reference).

    python -m repro.launch.serve --arch llama3.2-1b --smoke --mode batch
    python -m repro.launch.serve --arch llama3.2-1b --smoke --mode trace \
        --block-size 8 --ar-strategy auto --overlap
    python -m repro.launch.serve --arch llama3.2-1b --mode trace --tp 8 \
        --pods 2 --block-size 8   # under XLA_FLAGS=...device_count=8
    python -m repro.launch.serve --arch llama3.2-1b --mode trace \
        --spec-mode ngram --spec-k 4   # speculative decoding (DESIGN.md §8)
    python -m repro.launch.serve --arch llama3.2-1b --mode trace --disagg \
        --prefill-tp 8 --prefill-pods 2 --decode-tp 4 --block-size 8
        # disaggregated pools (DESIGN.md §9); per-pool mesh + ar_table

Trace mode replays a BurstGPT-style synthetic trace through the
continuous batcher (local path, or the mesh path when --tp > 1) and
reports:

  TTFT   time-to-first-token: queueing wait + prefill, per request
  TPOT   time-per-output-token: decode cadence once generation started

both as p50/p99 in logical engine steps (deterministic) and in wall
seconds (steps x measured mean step time), plus cache utilization and
preemption counts from the paged KV allocator.  With ``--disagg`` the
TTFT is attributed to the prefill pool + handoff transfer, TPOT to the
decode pool, and each pool reports its own all-reduce message-size
buckets.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke, ARCH_IDS
from ..core.pcontext import (ParallelCtx, LOCAL, AR_STRATEGIES,
                             SEQ_PARALLEL_MODES)
from ..models.transformer import make_plan, init_params
from ..inference.engine import InferenceEngine
from ..inference.faults import FaultInjector, FaultPlan
from ..inference.scheduler import ContinuousBatcher, make_trace


def _make_injector(fault_plan):
    """``--fault-plan`` -> FaultInjector (None when absent): a ``k=v,...``
    string or a JSON file path (``FaultPlan.parse``)."""
    if fault_plan is None:
        return None
    return FaultInjector(FaultPlan.parse(fault_plan))


def _check_outcomes(done, injector, deadline_ms):
    """The never-silently-dropped contract: on a fault-free run with no
    deadline every request must complete; under faults/deadlines each
    request either completed or was shed *with a reason*."""
    if injector is None and deadline_ms is None:
        assert all(r.output is not None for r in done), "requests dropped!"
    else:
        lost = [r.rid for r in done
                if r.output is None and r.shed_reason is None]
        assert not lost, f"requests silently dropped: {lost}"


def _print_faults(m, injector, shed):
    """One summary line for the robustness counters (trace modes)."""
    if injector is not None:
        fired = {k: v for k, v in injector.stats().items() if v}
        print(f"[serve]   faults injected: {fired or 'none'}")
    if shed:
        reasons: dict = {}
        for r in shed:
            reasons[r.shed_reason] = reasons.get(r.shed_reason, 0) + 1
        print(f"[serve]   shed {len(shed)} request(s): {reasons}")


def _mesh_and_ctx(tp: int, pods: int, ar_strategy: str, overlap: bool,
                  seq_parallel: str = "off", ar_quant: str = "none"):
    """(mesh, ctx, tp_total) for the requested layout; local when tp == 1."""
    ctx = LOCAL.replace(ar_strategy=ar_strategy, overlap_matmul=overlap,
                        seq_parallel=seq_parallel, ar_quant=ar_quant)
    if tp <= 1:
        return None, ctx, 1
    from ..core.compat import AxisType, make_mesh
    if pods > 1:
        if tp % pods:
            raise SystemExit(f"--tp {tp} not divisible by --pods {pods}")
        mesh = make_mesh((pods, tp // pods), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2)
        ctx = ctx.replace(tp_fast=("model",), tp_slow=("pod",),
                          ep=("model",))
    else:
        mesh = make_mesh((tp,), ("model",), axis_types=(AxisType.Auto,))
        ctx = ctx.replace(tp_fast=("model",), ep=("model",))
    return mesh, ctx, tp


def run_batch(arch: str, *, smoke: bool = True, batch: int = 4,
              prompt_len: int = 16, max_new: int = 16,
              ar_strategy: str = "flat", ar_table=None, overlap: bool = False,
              seq_parallel: str = "off", ar_quant: str = "none",
              temperature: float = 0.0, top_k: int = 0, seed: int = 0,
              tp: int = 1, pods: int = 1, block_size: int = 0,
              spec_mode=None, spec_k: int = 4,
              draft_arch: str = "llama3.2-1b"):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if block_size and tp > 1:
        raise SystemExit("--block-size with --mode batch is local-path "
                         "only (use --mode trace for mesh-path paging)")
    mesh, ctx, tp = _mesh_and_ctx(tp, pods, ar_strategy, overlap,
                                  seq_parallel, ar_quant)
    ap = make_plan(cfg, tp)
    params = init_params(jax.random.PRNGKey(seed), ap)
    s_max = prompt_len + max_new + 8
    if block_size:
        s_max = -(-s_max // block_size) * block_size
    eng = InferenceEngine(ap, params, ctx=ctx, mesh=mesh, s_max=s_max,
                          temperature=temperature, top_k=top_k, seed=seed,
                          block_size=block_size, ar_table=ar_table,
                          spec_mode=spec_mode, spec_k=spec_k,
                          draft_arch=draft_arch)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    extra = {}
    if cfg.family == "encdec":
        extra["frame_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "vlm":
        extra["patch_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)),
            cfg.dtype)
    res = eng.generate(prompts, max_new, extra=extra)
    layout = f"paged(bs={block_size})" if block_size else "dense"
    spec = f" spec={spec_mode}(k={spec_k})" if spec_mode else ""
    print(f"[serve] {arch}: batch {batch} prompt {prompt_len} "
          f"new {max_new} ar={ar_strategy} tp={tp} {layout}{spec} "
          f"| prefill {res.prefill_s*1e3:.0f}ms "
          f"decode {res.decode_s*1e3:.0f}ms "
          f"({res.decode_tokens_per_s:.0f} tok/s, {res.steps} steps)")
    return res


def run_trace(arch: str, *, smoke: bool = True, n_requests: int = 12,
              slots: int = 4, s_max: int = 128, block_size: int = 0,
              n_blocks=None, ar_strategy: str = "flat", ar_table=None,
              overlap: bool = False, seq_parallel: str = "off",
              ar_quant: str = "none", kv_quant: bool = False,
              temperature: float = 0.0,
              top_k: int = 0, seed: int = 0, tp: int = 1, pods: int = 1,
              admit_mode: str = "full", admit_chunk: int = 32,
              mean_in: int = 12, mean_out: int = 10, rate: float = 2.0,
              spec_mode=None, spec_k: int = 4, spec_adaptive: bool = False,
              draft_arch: str = "llama3.2-1b", json_out=None,
              fault_plan=None, deadline_ms=None):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("trace mode supports text-only archs")
    mesh, ctx, tp = _mesh_and_ctx(tp, pods, ar_strategy, overlap,
                                  seq_parallel, ar_quant)
    ap = make_plan(cfg, tp)
    params = init_params(jax.random.PRNGKey(seed), ap)
    injector = _make_injector(fault_plan)
    sched = ContinuousBatcher(
        ap, params, slots=slots, s_max=s_max, ctx=ctx, mesh=mesh,
        block_size=block_size, n_blocks=n_blocks, kv_quant=kv_quant,
        ar_table=ar_table,
        temperature=temperature, top_k=top_k, seed=seed,
        admit_mode=admit_mode, admit_chunk=admit_chunk,
        spec_mode=spec_mode, spec_k=spec_k, spec_adaptive=spec_adaptive,
        draft_arch=draft_arch, injector=injector,
        deadline_s=deadline_ms)   # 1 logical step = 1 ms (deterministic)
    reqs = make_trace(n_requests, mean_in=mean_in, mean_out=mean_out,
                      rate=rate, vocab=cfg.vocab_size, seed=seed)
    done = sched.run(reqs)
    _check_outcomes(done, injector, deadline_ms)
    m = sched.metrics(done)
    layout = f"paged(bs={block_size})" if sched.paged else "dense"
    if kv_quant:
        layout += "+kv8"
    if ar_quant != "none":
        ar_strategy = f"{ar_strategy}/q={ar_quant}"
    print(f"[serve] trace {arch} [{layout} ar={ar_strategy} tp={tp}"
          f"{' overlap' if overlap else ''}]: "
          f"{m.completed}/{m.requests} reqs, {m.total_new_tokens} tokens "
          f"in {m.wall_s:.1f}s ({m.throughput_tok_s:.0f} tok/s, "
          f"slots={slots}, {m.steps} steps)")
    print(f"[serve]   TTFT p50/p99: {m.ttft_steps_p50:.1f}/"
          f"{m.ttft_steps_p99:.1f} steps = {m.ttft_s_p50*1e3:.0f}/"
          f"{m.ttft_s_p99*1e3:.0f} ms | TPOT p50/p99: "
          f"{m.tpot_steps_p50:.2f}/{m.tpot_steps_p99:.2f} steps = "
          f"{m.tpot_s_p50*1e3:.1f}/{m.tpot_s_p99*1e3:.1f} ms")
    print(f"[serve]   KV peak {m.peak_kv_tokens} tokens of "
          f"{m.kv_capacity_tokens} reserved "
          f"(util {m.cache_utilization:.2f}), "
          f"{m.preemptions} preemptions")
    if spec_mode:
        print(f"[serve]   spec[{spec_mode} k_mean={m.spec_k_mean:.1f}"
              f"{' adaptive' if spec_adaptive else ''}]: "
              f"{m.accepted_tokens}/{m.drafted_tokens} drafts accepted "
              f"(rate {m.acceptance_rate:.2f}), "
              f"{m.accepted_tokens_per_step:.2f} accepted/step over "
              f"{m.spec_steps} verify steps, drafter hit rate "
              f"{m.drafter_hit_rate:.2f}")
    if injector is not None or m.shed_requests:
        print(f"[serve]   robustness: {m.quarantines} quarantines, "
              f"{m.injected_oom} injected OOM, {m.straggler_steps} "
              f"straggler steps, {m.spec_autodisables} spec autodisables")
        _print_faults(m, injector, sched._shed)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(m.to_dict(), f, indent=2, default=float)
        print(f"[serve]   metrics -> {json_out}")
    return done, m


def run_disagg(arch: str, *, smoke: bool = True, n_requests: int = 12,
               slots: int = 4, s_max: int = 128, block_size: int = 0,
               n_blocks=None, ar_strategy: str = "flat", ar_table=None,
               overlap: bool = False, seq_parallel: str = "off",
               ar_quant: str = "none",
               prefill_tp: int = 1, prefill_pods: int = 1,
               decode_tp: int = 1, decode_pods: int = 1,
               prefill_ar_table=None, decode_ar_table=None,
               temperature: float = 0.0, top_k: int = 0, seed: int = 0,
               admit_mode: str = "full", admit_chunk: int = 32,
               mean_in: int = 12, mean_out: int = 10, rate: float = 2.0,
               prefill_per_step: int = 1,
               spec_mode=None, spec_k: int = 4, spec_adaptive: bool = False,
               draft_arch: str = "llama3.2-1b", json_out=None,
               fault_plan=None, deadline_ms=None):
    """Disaggregated trace serving: prefill pool + decode pool, each with
    its own mesh layout and AR dispatch table (DESIGN.md §9).
    ``ar_table`` seeds BOTH pools when a per-pool table is not given.
    ``fault_plan`` / ``deadline_ms`` arm the robustness layer: one
    injector drives both the coordinator's handoff hooks and the decode
    batcher's step hooks (DESIGN.md §11; 1 logical step = 1 ms)."""
    from ..inference.disagg import (DisaggCoordinator, PrefillPool,
                                    pool_tuner)
    prefill_ar_table = prefill_ar_table or ar_table
    decode_ar_table = decode_ar_table or ar_table
    cfg = get_smoke(arch) if smoke else get_config(arch)
    # seq_parallel shapes the *prefill* pool's residual layout only; the
    # decode pool stays on the fused path (its one-token and spec-verify
    # messages live in the latency-bound regime — DESIGN.md §10)
    mesh_p, ctx_p, tp_p = _mesh_and_ctx(prefill_tp, prefill_pods,
                                        ar_strategy, overlap, seq_parallel,
                                        ar_quant)
    mesh_d, ctx_d, tp_d = _mesh_and_ctx(decode_tp, decode_pods,
                                        ar_strategy, overlap, "off",
                                        ar_quant)
    # per-pool plans + params: same weights (same key), each pool's layout
    ap_p = make_plan(cfg, tp_p)
    ap_d = make_plan(cfg, tp_d)
    params_p = init_params(jax.random.PRNGKey(seed), ap_p)
    params_d = params_p if tp_d == tp_p \
        else init_params(jax.random.PRNGKey(seed), ap_d)
    tuner_p = pool_tuner(prefill_ar_table)
    tuner_d = pool_tuner(decode_ar_table)
    pool = PrefillPool(ap_p, params_p, s_max=s_max, ctx=ctx_p, mesh=mesh_p,
                       ar_table=tuner_p, temperature=temperature,
                       top_k=top_k, seed=seed, admit_mode=admit_mode,
                       admit_chunk=admit_chunk, block_size=block_size)
    injector = _make_injector(fault_plan)
    decode = ContinuousBatcher(
        ap_d, params_d, slots=slots, s_max=s_max, ctx=ctx_d, mesh=mesh_d,
        block_size=block_size, n_blocks=n_blocks, ar_table=tuner_d,
        temperature=temperature, top_k=top_k, seed=seed,
        spec_mode=spec_mode, spec_k=spec_k, spec_adaptive=spec_adaptive,
        draft_arch=draft_arch, injector=injector)
    coord = DisaggCoordinator(pool, decode, decode_tuner=tuner_d,
                              prefill_per_step=prefill_per_step,
                              injector=injector,
                              deadline_s=deadline_ms)  # 1 step = 1 ms
    reqs = make_trace(n_requests, mean_in=mean_in, mean_out=mean_out,
                      rate=rate, vocab=cfg.vocab_size, seed=seed)
    done = coord.run(reqs)
    _check_outcomes(done, injector, deadline_ms)
    m = coord.metrics(done)
    layout = f"paged(bs={block_size})" if decode.paged else "dense"
    spec = f" spec={spec_mode}(k={spec_k})" if spec_mode else ""
    print(f"[serve] disagg {arch} [{layout} ar={ar_strategy} "
          f"prefill tp={tp_p}x{prefill_pods} decode tp={tp_d}x"
          f"{decode_pods}{spec}]: {m.completed}/{m.requests} reqs, "
          f"{m.total_new_tokens} tokens in {m.wall_s:.1f}s "
          f"({m.throughput_tok_s:.0f} tok/s, {m.steps} decode steps)")
    print(f"[serve]   TTFT p50/p99: {m.ttft_steps_p50:.1f}/"
          f"{m.ttft_steps_p99:.1f} steps "
          f"(prefill {m.prefill_steps_p50:.1f} + transfer "
          f"{m.transfer_steps_p50:.1f} at p50) | TPOT p50/p99: "
          f"{m.tpot_steps_p50:.2f}/{m.tpot_steps_p99:.2f} steps "
          f"[decode pool]")
    print(f"[serve]   handoff: {m.handoffs} bundles, "
          f"{m.transfer_bytes / 1024:.0f} KiB, ready/pending queue peaks "
          f"{m.peak_ready_depth}/{m.peak_pending_depth}, "
          f"{m.preemptions} decode-pool preemptions")
    print(f"[serve]   AR buckets: prefill pool 2^{m.prefill_ar_bucket} "
          f"vs decode pool 2^{m.decode_ar_bucket} "
          f"(prefill {m.prefill_pool['ar_buckets_analytic']} analytic, "
          f"{m.prefill_pool['ar_buckets_dispatched']} dispatched)")
    if injector is not None or m.shed_requests:
        print(f"[serve]   robustness: {m.handoff_drops} drops / "
              f"{m.handoff_retries} retries / {m.handoff_corrupt} corrupt "
              f"/ {m.handoff_reprefills} re-prefills, "
              f"{m.backpressure_steps} backpressure steps "
              f"(ready cap {m.ready_cap}), stalls prefill="
              f"{m.prefill_stall_steps} decode={m.decode_stall_steps}")
        _print_faults(m, injector, coord._shed + decode._shed)
    if json_out:
        with open(json_out, "w") as f:
            json.dump(m.to_dict(), f, indent=2, default=float)
        print(f"[serve]   metrics -> {json_out}")
    return done, m


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI (introspected by tools/check_docs.py: every flag
    added here must be documented in docs/serving.md)."""
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    p.add_argument("--mode", choices=["batch", "trace"], default="batch")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--s-max", type=int, default=128)
    p.add_argument("--block-size", type=int, default=0,
                   help="paged KV block size (0 = dense layout)")
    p.add_argument("--n-blocks", type=int, default=None,
                   help="physical block pool size (default: full capacity)")
    p.add_argument("--ar-strategy", choices=list(AR_STRATEGIES),
                   default="flat")
    p.add_argument("--ar-table", default=None,
                   help="persisted autotune table for --ar-strategy auto")
    p.add_argument("--overlap", action="store_true",
                   help="overlapped collective-matmul decode path")
    p.add_argument("--seq-parallel", choices=list(SEQ_PARALLEL_MODES),
                   default="off",
                   help="sequence-parallel prefill residual layout: "
                        "reduce-scatter + all-gather replace the fused "
                        "per-residual all-reduce (auto = per-call-site "
                        "message-size dispatch; decode is never "
                        "decomposed)")
    p.add_argument("--ar-quant", choices=["off", "int8", "int4", "auto"],
                   default="off",
                   help="quantized all-reduce wire format: int8/int4 "
                        "payloads with per-group scales and error "
                        "feedback on the decode residuals (auto = "
                        "per-call-site pick among off/int8/int4, "
                        "requires --ar-strategy auto)")
    p.add_argument("--kv-quant", action="store_true",
                   help="int8 KV cache with per-(pos, head) scales "
                        "(trace mode, dense layout, full admission, "
                        "no speculation)")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--tp", type=int, default=1,
                   help="tensor-parallel ways (mesh path when > 1)")
    p.add_argument("--pods", type=int, default=1,
                   help="split --tp across this many pods (slow axis)")
    p.add_argument("--admit-mode", choices=["full", "chunked"],
                   default="full")
    p.add_argument("--admit-chunk", type=int, default=32)
    p.add_argument("--rate", type=float, default=2.0)
    p.add_argument("--spec-mode", choices=["none", "ngram", "draft"],
                   default="none",
                   help="speculative decoding drafter (none = off)")
    p.add_argument("--spec-k", type=int, default=4,
                   help="draft tokens verified per fused pass")
    p.add_argument("--spec-adaptive", action="store_true",
                   help="acceptance-rate-adaptive speculation length")
    p.add_argument("--draft-arch", default="llama3.2-1b",
                   help="registry arch for --spec-mode draft")
    p.add_argument("--json", "--metrics-json", dest="json_out",
                   default=None, help="write trace metrics JSON here")
    # -- disaggregated prefill/decode pools (trace mode only) ------------
    p.add_argument("--disagg", action="store_true",
                   help="disaggregated serving: prefill pool + decode "
                        "pool with per-pool mesh layouts and AR tables")
    p.add_argument("--prefill-tp", type=int, default=1,
                   help="prefill-pool tensor-parallel ways (--disagg)")
    p.add_argument("--prefill-pods", type=int, default=1,
                   help="prefill-pool pod split of --prefill-tp")
    p.add_argument("--decode-tp", type=int, default=1,
                   help="decode-pool tensor-parallel ways (--disagg)")
    p.add_argument("--decode-pods", type=int, default=1,
                   help="decode-pool pod split of --decode-tp")
    p.add_argument("--prefill-ar-table", default=None,
                   help="persisted autotune table for the prefill pool")
    p.add_argument("--decode-ar-table", default=None,
                   help="persisted autotune table for the decode pool")
    p.add_argument("--prefill-per-step", type=int, default=1,
                   help="prompts the prefill pool admits per logical step")
    # -- robustness / fault injection (trace modes) ----------------------
    p.add_argument("--fault-plan", default=None,
                   help="deterministic fault plan: 'key=rate,...' string "
                        "or JSON file (see docs/robustness.md); e.g. "
                        "'seed=7,handoff_drop=0.1,nan_logits=0.02'")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="TTFT deadline; 1 logical step = 1 ms, so this "
                        "is a deterministic step budget — expired "
                        "never-admitted requests are shed (reported, "
                        "never silent)")
    return p


def main(argv=None):
    args = build_parser().parse_args(argv)
    spec_mode = None if args.spec_mode == "none" else args.spec_mode
    ar_quant = "none" if args.ar_quant == "off" else args.ar_quant
    if args.mode == "batch" and args.spec_adaptive:
        raise SystemExit("--spec-adaptive is trace-mode only (the engine "
                         "runs a fixed --spec-k)")
    if args.mode == "batch" and (args.fault_plan or
                                 args.deadline_ms is not None):
        raise SystemExit("--fault-plan/--deadline-ms are trace-mode only "
                         "(the batch engine has no recovery machinery)")
    # -- incompatible flag combos: fail at parse time, naming both flags,
    # instead of dying deep inside jitted step construction ---------------
    if ar_quant == "auto" and args.ar_strategy != "auto":
        raise SystemExit("--ar-quant auto rides the per-call-site "
                         "autotuner: it requires --ar-strategy auto "
                         f"(got --ar-strategy {args.ar_strategy})")
    if args.kv_quant:
        if args.mode != "trace":
            raise SystemExit("--kv-quant is trace-mode only (the batch "
                             "engine's prefill builds an fp cache)")
        if args.admit_mode == "chunked":
            raise SystemExit("--kv-quant is incompatible with "
                             "--admit-mode chunked: chunked prefill "
                             "cannot re-read the int8 cache mid-prompt "
                             "(use --admit-mode full)")
        if args.block_size:
            raise SystemExit("--kv-quant is incompatible with "
                             "--block-size (paged KV blocks are not "
                             "scale-grouped); drop one of the two")
        if spec_mode:
            raise SystemExit("--kv-quant is incompatible with "
                             "--spec-mode: the verify pass rides "
                             "chunked prefill over the int8 cache")
        if args.disagg:
            raise SystemExit("--kv-quant is incompatible with --disagg: "
                             "the KV handoff ships fp states between "
                             "pools")
    if args.disagg:
        if args.mode != "trace":
            raise SystemExit("--disagg is trace-mode only")
        run_disagg(args.arch, smoke=args.smoke, n_requests=args.requests,
                   slots=args.slots, s_max=args.s_max,
                   block_size=args.block_size, n_blocks=args.n_blocks,
                   ar_strategy=args.ar_strategy, ar_table=args.ar_table,
                   overlap=args.overlap, seq_parallel=args.seq_parallel,
                   ar_quant=ar_quant,
                   prefill_tp=args.prefill_tp,
                   prefill_pods=args.prefill_pods,
                   decode_tp=args.decode_tp, decode_pods=args.decode_pods,
                   prefill_ar_table=args.prefill_ar_table,
                   decode_ar_table=args.decode_ar_table,
                   temperature=args.temperature, top_k=args.top_k,
                   seed=args.seed, admit_mode=args.admit_mode,
                   admit_chunk=args.admit_chunk, rate=args.rate,
                   prefill_per_step=args.prefill_per_step,
                   spec_mode=spec_mode, spec_k=args.spec_k,
                   spec_adaptive=args.spec_adaptive,
                   draft_arch=args.draft_arch, json_out=args.json_out,
                   fault_plan=args.fault_plan,
                   deadline_ms=args.deadline_ms)
        return 0
    if args.mode == "batch":
        run_batch(args.arch, smoke=args.smoke, batch=args.batch,
                  prompt_len=args.prompt_len, max_new=args.max_new,
                  ar_strategy=args.ar_strategy, ar_table=args.ar_table,
                  overlap=args.overlap, seq_parallel=args.seq_parallel,
                  ar_quant=ar_quant, temperature=args.temperature,
                  top_k=args.top_k, seed=args.seed, tp=args.tp,
                  pods=args.pods, block_size=args.block_size,
                  spec_mode=spec_mode, spec_k=args.spec_k,
                  draft_arch=args.draft_arch)
    else:
        run_trace(args.arch, smoke=args.smoke, n_requests=args.requests,
                  slots=args.slots, s_max=args.s_max,
                  block_size=args.block_size, n_blocks=args.n_blocks,
                  ar_strategy=args.ar_strategy, ar_table=args.ar_table,
                  overlap=args.overlap, seq_parallel=args.seq_parallel,
                  ar_quant=ar_quant, kv_quant=args.kv_quant,
                  temperature=args.temperature,
                  top_k=args.top_k, seed=args.seed, tp=args.tp,
                  pods=args.pods, admit_mode=args.admit_mode,
                  admit_chunk=args.admit_chunk, rate=args.rate,
                  spec_mode=spec_mode, spec_k=args.spec_k,
                  spec_adaptive=args.spec_adaptive,
                  draft_arch=args.draft_arch, json_out=args.json_out,
                  fault_plan=args.fault_plan,
                  deadline_ms=args.deadline_ms)
    return 0


if __name__ == "__main__":
    sys.exit(main())
