"""Serving driver: batched generation, trace-replay continuous batching,
disaggregated prefill/decode pools, or a multi-replica routed fleet (see
docs/serving.md for the full flag reference).

    python -m repro.launch.serve --arch llama3.2-1b --smoke --mode batch
    python -m repro.launch.serve --arch llama3.2-1b --smoke --mode trace \
        --block-size 8 --ar-strategy auto --overlap
    python -m repro.launch.serve --arch llama3.2-1b --mode trace --tp 8 \
        --pods 2 --block-size 8   # under XLA_FLAGS=...device_count=8
    python -m repro.launch.serve --arch llama3.2-1b --mode trace \
        --spec-mode ngram --spec-k 4   # speculative decoding (DESIGN.md §8)
    python -m repro.launch.serve --arch llama3.2-1b --mode trace --disagg \
        --prefill-tp 8 --prefill-pods 2 --decode-tp 4 --block-size 8
        # disaggregated pools (DESIGN.md §9); per-pool mesh + ar_table
    python -m repro.launch.serve --arch llama3.2-1b --mode trace \
        --replicas 2 --tp 4 --router-policy ttft_aware
        # multi-replica router (DESIGN.md §13); disjoint mesh per replica

Every deployment is described by a :class:`~repro.inference.ServeSpec`
(``ServeSpec.from_args``): one validated, JSON-round-trippable value
that the factories (``build_engine`` / ``build_replica``) and the router
construct from — the CLI, tests, and benchmarks share one construction
path and reject invalid combos identically.

Trace mode replays a BurstGPT-style synthetic trace through the
continuous batcher (local path, or the mesh path when --tp > 1) and
reports:

  TTFT   time-to-first-token: queueing wait + prefill, per request
  TPOT   time-per-output-token: decode cadence once generation started

both as p50/p99 in logical engine steps (deterministic) and in wall
seconds (steps x measured mean step time), plus cache utilization and
preemption counts from the paged KV allocator.  With ``--disagg`` the
TTFT is attributed to the prefill pool + handoff transfer, TPOT to the
decode pool, and each pool reports its own all-reduce message-size
buckets.  With ``--replicas N`` the trace is load-balanced over N
self-contained replicas and the report adds placement counts, load
imbalance, and the lossless fleet metric merge.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from ..configs import get_config, get_smoke, ARCH_IDS
from ..core.pcontext import AR_STRATEGIES, SEQ_PARALLEL_MODES
from ..inference.router import Router
from ..inference.scheduler import make_prefix_trace, make_trace
from ..inference.spec import (PREFIX_MODES, ROUTER_POLICIES, ServeSpec,
                              SpecError, build_engine, build_replica)


def _cfg(spec: ServeSpec):
    r = spec.replica
    return get_smoke(r.arch) if r.smoke else get_config(r.arch)


def _check_outcomes(done, injector, deadline_ms):
    """The never-silently-dropped contract: on a fault-free run with no
    deadline every request must complete; under faults/deadlines each
    request either completed or was shed *with a reason*."""
    if injector is None and deadline_ms is None:
        assert all(r.output is not None for r in done), "requests dropped!"
    else:
        lost = [r.rid for r in done
                if r.output is None and r.shed_reason is None]
        assert not lost, f"requests silently dropped: {lost}"


def _print_faults(m, injector, shed):
    """One summary line for the robustness counters (trace modes)."""
    if injector is not None:
        fired = {k: v for k, v in injector.stats().items() if v}
        print(f"[serve]   faults injected: {fired or 'none'}")
    if shed:
        reasons: dict = {}
        for r in shed:
            reasons[r.shed_reason] = reasons.get(r.shed_reason, 0) + 1
        print(f"[serve]   shed {len(shed)} request(s): {reasons}")


def _write_json(m, json_out):
    if json_out:
        with open(json_out, "w") as f:
            json.dump(m.to_dict(), f, indent=2, default=float)
        print(f"[serve]   metrics -> {json_out}")


def _make_reqs(spec: ServeSpec, *, n_requests, mean_in, mean_out, rate,
               shared_frac: float = 0.0, prefix_len: int = 32):
    """The trace every trace-mode deployment replays: plain BurstGPT-style
    (:func:`make_trace`), or the shared-system-prompt variant
    (:func:`make_prefix_trace`) when ``--shared-frac`` > 0 — the same
    trace either way for a given seed, so prefix-cache on/off runs are
    comparable token-for-token."""
    r = spec.replica
    cfg = _cfg(spec)
    if shared_frac > 0.0:
        return make_prefix_trace(
            n_requests, prefix_len=prefix_len, shared_frac=shared_frac,
            mean_in=mean_in, mean_out=mean_out, rate=rate,
            vocab=cfg.vocab_size, seed=r.seed, clip_len=r.s_max - 1)
    return make_trace(n_requests, mean_in=mean_in, mean_out=mean_out,
                      rate=rate, vocab=cfg.vocab_size, seed=r.seed)


def run_batch(spec: ServeSpec, *, batch: int = 4, prompt_len: int = 16,
              max_new: int = 16):
    """Batched generation through :func:`build_engine` (DESIGN.md §13:
    the spec is the only construction path)."""
    r = spec.replica
    cfg = _cfg(spec)
    s_max = prompt_len + max_new + 8
    if r.block_size:
        s_max = -(-s_max // r.block_size) * r.block_size
    eng = build_engine(r.replace(s_max=s_max))
    rng = np.random.default_rng(r.seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    extra = {}
    if cfg.family == "encdec":
        extra["frame_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "vlm":
        extra["patch_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)),
            cfg.dtype)
    res = eng.generate(prompts, max_new, extra=extra)
    layout = f"paged(bs={r.block_size})" if r.block_size else "dense"
    sp = f" spec={r.spec_mode}(k={r.spec_k})" if r.spec_mode else ""
    print(f"[serve] {r.arch}: batch {batch} prompt {prompt_len} "
          f"new {max_new} ar={r.ar_strategy} tp={r.tp} {layout}{sp} "
          f"| prefill {res.prefill_s*1e3:.0f}ms "
          f"decode {res.decode_s*1e3:.0f}ms "
          f"({res.decode_tokens_per_s:.0f} tok/s, {res.steps} steps)")
    return res


def _print_trace_metrics(spec: ServeSpec, m, slots: int):
    r = spec.replica
    ar = r.ar_strategy
    if r.ar_quant != "none":
        ar = f"{ar}/q={r.ar_quant}"
    layout = f"paged(bs={r.block_size})" if r.block_size else "dense"
    if r.kv_quant:
        layout += "+kv8"
    if r.prefix_cache == "on":
        layout += "+prefix"
    print(f"[serve] trace {r.arch} [{layout} ar={ar} tp={r.tp}"
          f"{' overlap' if r.overlap else ''}]: "
          f"{m.completed}/{m.requests} reqs, {m.total_new_tokens} tokens "
          f"in {m.wall_s:.1f}s ({m.throughput_tok_s:.0f} tok/s, "
          f"slots={slots}, {m.steps} steps)")
    print(f"[serve]   TTFT p50/p99: {m.ttft_steps_p50:.1f}/"
          f"{m.ttft_steps_p99:.1f} steps = {m.ttft_s_p50*1e3:.0f}/"
          f"{m.ttft_s_p99*1e3:.0f} ms | TPOT p50/p99: "
          f"{m.tpot_steps_p50:.2f}/{m.tpot_steps_p99:.2f} steps = "
          f"{m.tpot_s_p50*1e3:.1f}/{m.tpot_s_p99*1e3:.1f} ms")
    print(f"[serve]   KV peak {m.peak_kv_tokens} tokens of "
          f"{m.kv_capacity_tokens} reserved "
          f"(util {m.cache_utilization:.2f}), "
          f"{m.preemptions} preemptions")
    if r.prefix_cache == "on":
        print(f"[serve]   prefix cache: {m.prefix_hits}/"
              f"{m.prefix_lookups} admissions hit "
              f"(rate {m.prefix_hit_rate:.2f}), "
              f"{m.prefix_tokens_saved} prompt tokens spliced "
              "instead of re-prefilled")
    if r.spec_mode:
        print(f"[serve]   spec[{r.spec_mode} k_mean={m.spec_k_mean:.1f}"
              f"{' adaptive' if r.spec_adaptive else ''}]: "
              f"{m.accepted_tokens}/{m.drafted_tokens} drafts accepted "
              f"(rate {m.acceptance_rate:.2f}), "
              f"{m.accepted_tokens_per_step:.2f} accepted/step over "
              f"{m.spec_steps} verify steps, drafter hit rate "
              f"{m.drafter_hit_rate:.2f}")


def run_trace(spec: ServeSpec, *, n_requests: int = 12, mean_in: int = 12,
              mean_out: int = 10, rate: float = 2.0, json_out=None,
              shared_frac: float = 0.0, prefix_len: int = 32):
    """Colocated trace replay: one :func:`build_replica` batcher."""
    r = spec.replica
    sched = build_replica(r)
    injector = sched.injector
    reqs = _make_reqs(spec, n_requests=n_requests, mean_in=mean_in,
                      mean_out=mean_out, rate=rate,
                      shared_frac=shared_frac, prefix_len=prefix_len)
    done = sched.run(reqs)
    _check_outcomes(done, injector, r.deadline_ms)
    m = sched.metrics(done)
    _print_trace_metrics(spec, m, r.slots)
    if injector is not None or m.shed_requests:
        print(f"[serve]   robustness: {m.quarantines} quarantines, "
              f"{m.injected_oom} injected OOM, {m.straggler_steps} "
              f"straggler steps, {m.spec_autodisables} spec autodisables")
        _print_faults(m, injector, sched._shed)
    _write_json(m, json_out)
    return done, m


def run_disagg(spec: ServeSpec, *, n_requests: int = 12, mean_in: int = 12,
               mean_out: int = 10, rate: float = 2.0, json_out=None,
               shared_frac: float = 0.0, prefix_len: int = 32):
    """Disaggregated trace serving: prefill pool + decode pool, each with
    its own mesh layout and AR dispatch table (DESIGN.md §9), built from
    one :func:`build_replica` call.  ``spec.replica.ar_table`` seeds BOTH
    pools when a per-pool table is not given; ``fault_plan`` /
    ``deadline_ms`` arm the robustness layer (DESIGN.md §11)."""
    r = spec.replica
    coord = build_replica(r)
    decode, injector = coord.decode, coord.injector
    reqs = _make_reqs(spec, n_requests=n_requests, mean_in=mean_in,
                      mean_out=mean_out, rate=rate,
                      shared_frac=shared_frac, prefix_len=prefix_len)
    done = coord.run(reqs)
    _check_outcomes(done, injector, r.deadline_ms)
    m = coord.metrics(done)
    layout = f"paged(bs={r.block_size})" if decode.paged else "dense"
    sp = f" spec={r.spec_mode}(k={r.spec_k})" if r.spec_mode else ""
    print(f"[serve] disagg {r.arch} [{layout} ar={r.ar_strategy} "
          f"prefill tp={r.prefill_tp}x{r.prefill_pods} decode "
          f"tp={r.decode_tp}x{r.decode_pods}{sp}]: "
          f"{m.completed}/{m.requests} reqs, "
          f"{m.total_new_tokens} tokens in {m.wall_s:.1f}s "
          f"({m.throughput_tok_s:.0f} tok/s, {m.steps} decode steps)")
    print(f"[serve]   TTFT p50/p99: {m.ttft_steps_p50:.1f}/"
          f"{m.ttft_steps_p99:.1f} steps "
          f"(prefill {m.prefill_steps_p50:.1f} + transfer "
          f"{m.transfer_steps_p50:.1f} at p50) | TPOT p50/p99: "
          f"{m.tpot_steps_p50:.2f}/{m.tpot_steps_p99:.2f} steps "
          f"[decode pool]")
    print(f"[serve]   handoff: {m.handoffs} bundles, "
          f"{m.transfer_bytes / 1024:.0f} KiB, ready/pending queue peaks "
          f"{m.peak_ready_depth}/{m.peak_pending_depth}, "
          f"{m.preemptions} decode-pool preemptions")
    print(f"[serve]   AR buckets: prefill pool 2^{m.prefill_ar_bucket} "
          f"vs decode pool 2^{m.decode_ar_bucket} "
          f"(prefill {m.prefill_pool['ar_buckets_analytic']} analytic, "
          f"{m.prefill_pool['ar_buckets_dispatched']} dispatched)")
    if injector is not None or m.shed_requests:
        print(f"[serve]   robustness: {m.handoff_drops} drops / "
              f"{m.handoff_retries} retries / {m.handoff_corrupt} corrupt "
              f"/ {m.handoff_reprefills} re-prefills, "
              f"{m.backpressure_steps} backpressure steps "
              f"(ready cap {m.ready_cap}), stalls prefill="
              f"{m.prefill_stall_steps} decode={m.decode_stall_steps}")
        _print_faults(m, injector, coord._shed + decode._shed)
    _write_json(m, json_out)
    return done, m


def run_router(spec: ServeSpec, *, n_requests: int = 12, mean_in: int = 12,
               mean_out: int = 10, rate: float = 2.0, json_out=None,
               shared_frac: float = 0.0, prefix_len: int = 32):
    """Multi-replica trace serving (DESIGN.md §13): ``spec.replicas``
    self-contained replicas on disjoint device groups, placed by
    ``spec.router_policy``, reported as per-replica metrics plus their
    lossless fleet merge."""
    r = spec.replica
    router = Router.from_spec(spec)
    reqs = _make_reqs(spec, n_requests=n_requests, mean_in=mean_in,
                      mean_out=mean_out, rate=rate,
                      shared_frac=shared_frac, prefix_len=prefix_len)
    done = router.run(reqs)
    # each replica has an independently-seeded injector; outcome checking
    # only needs to know whether ANY faults/deadlines were armed
    injector = router.replicas[0].injector
    _check_outcomes(done, injector, r.deadline_ms)
    rm = router.metrics(done)
    m = rm.fleet
    kind = "disagg" if r.disagg else \
        (f"tp={r.tp}" if r.tp > 1 else "local")
    print(f"[serve] router {r.arch} [{spec.replicas}x {kind} "
          f"policy={spec.router_policy}]: {m.completed}/{m.requests} reqs, "
          f"{m.total_new_tokens} tokens in {m.wall_s:.1f}s "
          f"({m.throughput_tok_s:.0f} tok/s, {m.steps} steps)")
    print(f"[serve]   fleet TTFT p50/p99: {m.ttft_steps_p50:.1f}/"
          f"{m.ttft_steps_p99:.1f} steps | TPOT p50/p99: "
          f"{m.tpot_steps_p50:.2f}/{m.tpot_steps_p99:.2f} steps")
    print(f"[serve]   placements {rm.placements} "
          f"(imbalance {rm.load_imbalance:.2f}), preemptions "
          f"{m.preemptions}, shed {m.shed_requests}")
    if r.prefix_cache == "on":
        # per-replica tries (no cross-replica sharing): the fleet line is
        # the lossless sum over replicas
        print(f"[serve]   fleet prefix cache: {m.prefix_hits}/"
              f"{m.prefix_lookups} admissions hit "
              f"(rate {m.prefix_hit_rate:.2f}), "
              f"{m.prefix_tokens_saved} prompt tokens spliced")
    for i, pm in enumerate(rm.per_replica):
        print(f"[serve]   replica {i}: {pm.completed}/{pm.requests} reqs, "
              f"TTFT p99 {pm.ttft_steps_p99:.1f}, "
              f"{pm.total_new_tokens} tokens")
    if injector is not None:
        for i, rep in enumerate(router.replicas):
            fired = {k: v for k, v in rep.injector.stats().items() if v}
            print(f"[serve]   replica {i} faults: {fired or 'none'}")
    _write_json(rm, json_out)
    return done, rm


def build_parser() -> argparse.ArgumentParser:
    """The serve CLI (introspected by tools/check_docs.py: every flag
    added here must be documented in docs/serving.md)."""
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    p.add_argument("--mode", choices=["batch", "trace"], default="batch")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--s-max", type=int, default=128)
    p.add_argument("--block-size", type=int, default=0,
                   help="paged KV block size (0 = dense layout)")
    p.add_argument("--n-blocks", type=int, default=None,
                   help="physical block pool size (default: full capacity)")
    p.add_argument("--ar-strategy", choices=list(AR_STRATEGIES),
                   default="flat")
    p.add_argument("--ar-table", default=None,
                   help="persisted autotune table for --ar-strategy auto")
    p.add_argument("--overlap", action="store_true",
                   help="overlapped collective-matmul decode path")
    p.add_argument("--seq-parallel", choices=list(SEQ_PARALLEL_MODES),
                   default="off",
                   help="sequence-parallel prefill residual layout: "
                        "reduce-scatter + all-gather replace the fused "
                        "per-residual all-reduce (auto = per-call-site "
                        "message-size dispatch; decode is never "
                        "decomposed)")
    p.add_argument("--ar-quant", choices=["off", "int8", "int4", "auto"],
                   default="off",
                   help="quantized all-reduce wire format: int8/int4 "
                        "payloads with per-group scales and error "
                        "feedback on the decode residuals (auto = "
                        "per-call-site pick among off/int8/int4, "
                        "requires --ar-strategy auto)")
    p.add_argument("--kv-quant", action="store_true",
                   help="int8 KV cache with per-(pos, head) scales "
                        "(trace mode, dense layout, full admission, "
                        "no speculation)")
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--top-k", type=int, default=0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--tp", type=int, default=1,
                   help="tensor-parallel ways (mesh path when > 1)")
    p.add_argument("--pods", type=int, default=1,
                   help="split --tp across this many pods (slow axis)")
    p.add_argument("--admit-mode", choices=["full", "chunked"],
                   default="full")
    p.add_argument("--admit-chunk", type=int, default=32)
    p.add_argument("--rate", type=float, default=2.0)
    # -- prefix-sharing radix KV cache (trace mode only) -----------------
    p.add_argument("--prefix-cache", choices=list(PREFIX_MODES),
                   default="off",
                   help="radix prefix cache over paged KV blocks "
                        "(DESIGN.md §14): admission splices the longest "
                        "previously-prefilled prompt prefix via "
                        "copy-on-write block sharing and prefills only "
                        "the suffix (needs --block-size > 0; rejects "
                        "--kv-quant and --disagg)")
    p.add_argument("--prefix-capacity", type=int, default=None,
                   help="max trie-pinned blocks before LRU eviction of "
                        "unreferenced prefix nodes (default: bounded by "
                        "the physical pool)")
    p.add_argument("--shared-frac", type=float, default=0.0,
                   help="fraction of trace requests opening with one "
                        "common system prompt (make_prefix_trace; 0 = "
                        "plain make_trace)")
    p.add_argument("--prefix-len", type=int, default=32,
                   help="length of the shared system prompt for "
                        "--shared-frac > 0")
    p.add_argument("--spec-mode", choices=["none", "ngram", "draft"],
                   default="none",
                   help="speculative decoding drafter (none = off)")
    p.add_argument("--spec-k", type=int, default=4,
                   help="draft tokens verified per fused pass")
    p.add_argument("--spec-adaptive", action="store_true",
                   help="acceptance-rate-adaptive speculation length")
    p.add_argument("--draft-arch", default="llama3.2-1b",
                   help="registry arch for --spec-mode draft")
    p.add_argument("--json", "--metrics-json", dest="json_out",
                   default=None, help="write trace metrics JSON here")
    # -- multi-replica router (trace mode only) --------------------------
    p.add_argument("--replicas", type=int, default=1,
                   help="data-parallel replica count; > 1 serves the "
                        "trace through the router tier, each replica on "
                        "its own disjoint device group (DESIGN.md §13)")
    p.add_argument("--router-policy", choices=list(ROUTER_POLICIES),
                   default="round_robin",
                   help="placement policy for --replicas > 1: "
                        "round_robin (arrival index mod N), least_queue "
                        "(fewest in flight), ttft_aware (smallest "
                        "estimated wait from queue depth + analytic "
                        "prefill cost)")
    # -- disaggregated prefill/decode pools (trace mode only) ------------
    p.add_argument("--disagg", action="store_true",
                   help="disaggregated serving: prefill pool + decode "
                        "pool with per-pool mesh layouts and AR tables")
    p.add_argument("--prefill-tp", type=int, default=1,
                   help="prefill-pool tensor-parallel ways (--disagg)")
    p.add_argument("--prefill-pods", type=int, default=1,
                   help="prefill-pool pod split of --prefill-tp")
    p.add_argument("--decode-tp", type=int, default=1,
                   help="decode-pool tensor-parallel ways (--disagg)")
    p.add_argument("--decode-pods", type=int, default=1,
                   help="decode-pool pod split of --decode-tp")
    p.add_argument("--prefill-ar-table", default=None,
                   help="persisted autotune table for the prefill pool")
    p.add_argument("--decode-ar-table", default=None,
                   help="persisted autotune table for the decode pool")
    p.add_argument("--prefill-per-step", type=int, default=1,
                   help="prompts the prefill pool admits per logical step")
    # -- robustness / fault injection (trace modes) ----------------------
    p.add_argument("--fault-plan", default=None,
                   help="deterministic fault plan: 'key=rate,...' string "
                        "or JSON file (see docs/robustness.md); e.g. "
                        "'seed=7,handoff_drop=0.1,nan_logits=0.02'")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="TTFT deadline; 1 logical step = 1 ms, so this "
                        "is a deterministic step budget — expired "
                        "never-admitted requests are shed (reported, "
                        "never silent)")
    return p


def main(argv=None):
    args = build_parser().parse_args(argv)
    try:
        spec = ServeSpec.from_args(args)
    except SpecError as e:
        # one validation home (ServeSpec.validate); the CLI only converts
        # the rejection into an exit status
        raise SystemExit(str(e))
    # every CLI combination must survive the JSON round trip (the bench /
    # router serialization contract; cheap, so asserted on every run)
    assert ServeSpec.from_json(spec.to_json()) == spec, "spec round trip"
    if spec.mode == "batch":
        run_batch(spec, batch=args.batch, prompt_len=args.prompt_len,
                  max_new=args.max_new)
        return 0
    if _cfg(spec).family in ("encdec", "vlm"):
        raise SystemExit("trace mode supports text-only archs")
    kw = dict(n_requests=args.requests, rate=args.rate,
              json_out=args.json_out, shared_frac=args.shared_frac,
              prefix_len=args.prefix_len)
    if spec.replicas > 1:
        run_router(spec, **kw)
    elif spec.replica.disagg:
        run_disagg(spec, **kw)
    else:
        run_trace(spec, **kw)
    return 0


if __name__ == "__main__":
    sys.exit(main())
