"""Serving driver: batched generation or trace-replay continuous batching.

    python -m repro.launch.serve --arch llama3.2-1b --smoke --mode batch
    python -m repro.launch.serve --arch rwkv6-7b --smoke --mode trace
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke, ARCH_IDS
from ..models.transformer import make_plan, init_params
from ..inference.engine import InferenceEngine
from ..inference.scheduler import ContinuousBatcher, make_trace


def run_batch(arch: str, *, smoke: bool = True, batch: int = 4,
              prompt_len: int = 16, max_new: int = 16,
              ar_strategy: str = "flat", seed: int = 0):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(seed), ap)
    eng = InferenceEngine(ap, params, s_max=prompt_len + max_new + 8)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    extra = {}
    if cfg.family == "encdec":
        extra["frame_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.enc_seq, cfg.d_model)),
            cfg.dtype)
    if cfg.family == "vlm":
        extra["patch_embeds"] = jax.numpy.asarray(
            rng.standard_normal((batch, cfg.n_patches, cfg.d_model)),
            cfg.dtype)
    res = eng.generate(prompts, max_new, extra=extra)
    print(f"[serve] {arch}: batch {batch} prompt {prompt_len} "
          f"new {max_new} | prefill {res.prefill_s*1e3:.0f}ms "
          f"decode {res.decode_s*1e3:.0f}ms "
          f"({res.decode_tokens_per_s:.0f} tok/s)")
    return res


def run_trace(arch: str, *, smoke: bool = True, n_requests: int = 12,
              slots: int = 4, seed: int = 0):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("trace mode supports text-only archs")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(seed), ap)
    sched = ContinuousBatcher(ap, params, slots=slots, s_max=128)
    reqs = make_trace(n_requests, mean_in=12, mean_out=10, rate=2.0,
                      vocab=cfg.vocab_size, seed=seed)
    t0 = time.perf_counter()
    done = sched.run(reqs)
    dt = time.perf_counter() - t0
    total_out = sum(len(r.output) for r in done if r.output is not None)
    assert all(r.output is not None for r in done), "requests dropped!"
    print(f"[serve] trace: {len(done)} reqs, {total_out} tokens "
          f"in {dt:.1f}s wall ({total_out/dt:.0f} tok/s, slots={slots})")
    return done


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    p.add_argument("--mode", choices=["batch", "trace"], default="batch")
    p.add_argument("--smoke", action="store_true", default=True)
    p.add_argument("--full", dest="smoke", action="store_false")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=16)
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    args = p.parse_args(argv)
    if args.mode == "batch":
        run_batch(args.arch, smoke=args.smoke, batch=args.batch,
                  prompt_len=args.prompt_len, max_new=args.max_new)
    else:
        run_trace(args.arch, smoke=args.smoke, n_requests=args.requests,
                  slots=args.slots)
    return 0


if __name__ == "__main__":
    sys.exit(main())
