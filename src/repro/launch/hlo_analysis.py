"""Compiled-HLO analysis: collective-byte accounting and memory/cost capture.

``collective_bytes`` parses ``compiled.as_text()``, resolves every
collective's *operand* sizes (the payload each device injects), and splits
them into ICI (intra-pod) vs DCN (cross-pod) traffic by inspecting
replica_groups / source_target_pairs against the pod boundary.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DEF_RE = re.compile(r"(%?[\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
                     r"([\w\-]+)\(")
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}|replica_groups=\{(.*?)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: int = 0          # operand-sum convention (task spec)
    dcn_bytes: int = 0
    wire_ici_bytes: float = 0.0  # per-device wire traffic (ring model)
    wire_dcn_bytes: float = 0.0
    by_kind: Dict[str, int] = dataclasses.field(default_factory=dict)
    count: int = 0

    @property
    def total_bytes(self) -> int:
        return self.ici_bytes + self.dcn_bytes


def _group_size(line: str, n_devices: int) -> int:
    gm = re.search(r"replica_groups=\{\{(.*?)(?:\}|$)", line)
    if gm:
        return max(1, gm.group(1).count(",") + 1)
    gm = re.search(r"replica_groups=\[([\d,]+)\]<=\[(\d+)\]", line)
    if gm:
        dims = [int(x) for x in gm.group(1).split(",")]
        return max(1, dims[-1])
    if "source_target_pairs" in line:
        return 2
    return n_devices


def _wire_bytes(kind: str, operand_bytes: int, n: int) -> float:
    """Per-device wire traffic under the ring model.

    all-reduce: 2(n-1)/n * M;  reduce-scatter / all-to-all: (n-1)/n * M;
    all-gather: (n-1) * shard (operand IS the shard);
    collective-permute: M.
    """
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * f * operand_bytes
    if kind == "all-gather":
        return (n - 1) * operand_bytes
    if kind == "collective-permute":
        return float(operand_bytes)
    return f * operand_bytes  # reduce-scatter, all-to-all


def _crosses_pod(line: str, pod_size: int) -> bool:
    """True if any communication edge crosses a pod boundary."""
    if pod_size <= 0:
        return False
    m = _PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return any(int(a) // pod_size != int(b) // pod_size
                   for a, b in pairs)
    gm = re.search(r"replica_groups=\{\{(.*?)\}\}", line)
    if gm:
        for grp in gm.group(1).split("},{"):
            ids = [int(x) for x in grp.split(",") if x.strip()]
            if ids and any(i // pod_size != ids[0] // pod_size
                           for i in ids):
                return True
        return False
    # iota/strided replica group formats: v2 "replica_groups=[2,256]<=[512]"
    gm = re.search(r"replica_groups=\[([\d,]+)\]<=\[(\d+)\]"
                   r"(?:T\(([\d,]+)\))?", line)
    if gm:
        dims = [int(x) for x in gm.group(1).split(",")]
        total = int(gm.group(2))
        # groups iterate the device range; a group spans pods when the
        # fastest-varying (within-group) extent crosses a pod boundary.
        group_size = dims[-1]
        # devices assigned contiguously (possibly transposed); conservative:
        if gm.group(3):  # transposed — groups stride across the range
            return group_size > 1 and total > pod_size
        return group_size > pod_size or (total > pod_size and
                                         group_size > pod_size)
    return False


def collective_bytes(hlo_text: str, n_devices: int,
                     n_pods: int = 1) -> CollectiveStats:
    """Sum collective operand bytes (per device) from compiled HLO text."""
    pod_size = n_devices // max(n_pods, 1)
    sizes: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.search(line)
        if m:
            sizes[m.group(1).lstrip("%")] = _type_bytes(m.group(2))
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _DEF_RE.search(stripped)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-") or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # -start carries the operands
        # operand refs: everything inside the parens before the attributes
        paren = stripped[stripped.index(op) + len(op):]
        cut = paren.find("channel_id")
        if cut > 0:
            paren = paren[:cut]
        else:
            paren = paren.split(")", 1)[0]
        refs = [r.lstrip("%") for r in
                re.findall(r"%?[\w.\-]+", paren)]
        nbytes = sum(sizes.get(r, 0) for r in refs)
        if nbytes == 0:
            # fall back to the result type
            nbytes = _type_bytes(m.group(2))
        cross = n_pods > 1 and _crosses_pod(stripped, pod_size)
        wire = _wire_bytes(kind, nbytes, _group_size(stripped, n_devices))
        if cross:
            stats.dcn_bytes += nbytes
            stats.wire_dcn_bytes += wire
        else:
            stats.ici_bytes += nbytes
            stats.wire_ici_bytes += wire
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + nbytes
        stats.count += 1
    return stats


def summarize_compiled(compiled, mesh, lowered=None) -> Dict[str, object]:
    """memory_analysis + cost_analysis + collective stats for one
    executable.

    Collective bytes are parsed from the *lowered* (pre-optimization) HLO
    when available, because the CPU backend upcasts bf16 compute to f32
    during compilation, which would inflate payload sizes 2x; the lowered
    module carries the logical dtypes that real TPU lowering preserves.
    """
    n_dev = int(mesh.devices.size)
    n_pods = (mesh.devices.shape[0]
              if "pod" in mesh.axis_names else 1)
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict] per program
        ca = ca[0] if ca else {}
    if lowered is not None:
        txt = lowered.as_text(dialect="hlo")
    else:
        txt = compiled.as_text()
    coll = collective_bytes(txt, n_dev, n_pods)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "ici_bytes": coll.ici_bytes,
        "dcn_bytes": coll.dcn_bytes,
        "wire_ici_bytes": coll.wire_ici_bytes,
        "wire_dcn_bytes": coll.wire_dcn_bytes,
        "collective_count": coll.count,
        "collectives_by_kind": coll.by_kind,
        "argument_bytes_per_device": ma.argument_size_in_bytes,
        "output_bytes_per_device": ma.output_size_in_bytes,
        "temp_bytes_per_device": ma.temp_size_in_bytes,
        "alias_bytes_per_device": ma.alias_size_in_bytes,
        "peak_bytes_per_device": (ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
    }


__all__ = ["collective_bytes", "summarize_compiled", "CollectiveStats"]
