"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation ever happens here: params, optimizer state, caches and
batches are all ShapeDtypeStructs carrying NamedShardings, so
``jit(step).lower(...)`` sees the exact production layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, SHAPES, cell_plan, CellPlan
from ..core.pcontext import ParallelCtx
from ..models.transformer import (ArchPlan, make_plan, init_params,
                                  init_cache, ef_sites_for)
from ..parallel import steps as st
from ..training.optimizer import adamw_init
from .mesh import make_ctx, tp_size


def _sds(tree, specs, mesh):
    def f(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(f, tree, specs)


@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    plan: CellPlan
    ap: ArchPlan
    ctx: ParallelCtx
    built: st.BuiltStep
    args: Tuple[Any, ...]          # ShapeDtypeStructs with shardings

    def lower(self):
        return self.built.jit().lower(*self.args)


def build_cell(arch: str, shape_name: str, mesh, *,
               ar_strategy: str = "flat", scan_layers: bool = True,
               cross_pod_tp: bool = False,
               cfg_override=None, extra_ctx: Optional[dict] = None,
               probe: bool = False, shape_override=None,
               kv_quant: bool = False, window_kv: bool = False,
               weight_quant: bool = False,
               fsdp_serve_override=None, sp_prefill: bool = False) -> Cell:
    """Construct the step + input specs for one dry-run cell.

    ``probe=True`` builds the roofline costing variant: layers unrolled
    (accurate cost_analysis), attention chunking disabled (chunk loops are
    also counted once), one grad-accum microbatch.
    """
    cfg = cfg_override or get_config(arch)
    plan = cell_plan(arch, shape_name)
    shape = shape_override or plan.shape
    attn_chunk = 0 if probe else None
    if probe:
        scan_layers = False
    ctx = make_ctx(mesh, ar_strategy=ar_strategy,
                   cross_pod_tp=cross_pod_tp,
                   batch_replicated=plan.batch_replicated,
                   **(extra_ctx or {}))
    tp = tp_size(mesh, ctx)
    ap = make_plan(cfg, tp)

    params_t = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))

    if shape.kind == "train":
        built = st.build_train_step(
            ap, ctx, mesh,
            microbatches=1 if probe else plan.microbatches,
            scan_layers=scan_layers,
            frame_embeds=cfg.family == "encdec",
            patch_embeds=cfg.family == "vlm")
        opt_t = jax.eval_shape(lambda: adamw_init(params_t))
        batch_t = {
            "tokens": jax.ShapeDtypeStruct((shape.global_batch,
                                            shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((shape.global_batch,
                                            shape.seq_len), jnp.int32),
        }
        if cfg.family == "encdec":
            batch_t["frames"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_seq, cfg.d_model), cfg.dtype)
        if cfg.family == "vlm":
            batch_t["patches"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_patches, cfg.d_model), cfg.dtype)
        ps, os_, bs = built.in_specs
        args = (_sds(params_t, ps, mesh), _sds(opt_t, os_, mesh),
                _sds(batch_t, bs, mesh))
        return Cell(arch, shape_name, plan, ap, ctx, built, args)

    if shape.kind == "prefill":
        built = st.build_prefill(
            ap, ctx, mesh, s_max=shape.seq_len + 64,
            scan_layers=scan_layers,
            fsdp_serve=plan.fsdp_serve if fsdp_serve_override is None
            else fsdp_serve_override,
            attn_chunk=attn_chunk, sp=sp_prefill,
            frame_embeds=cfg.family == "encdec",
            patch_embeds=cfg.family == "vlm")
        tok_t = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                     jnp.int32)
        arg_ts = [params_t, tok_t]
        if cfg.family == "encdec":
            arg_ts.append(jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_seq, cfg.d_model), cfg.dtype))
        if cfg.family == "vlm":
            arg_ts.append(jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.n_patches, cfg.d_model), cfg.dtype))
        args = tuple(_sds(t, s, mesh)
                     for t, s in zip(arg_ts, built.in_specs))
        return Cell(arch, shape_name, plan, ap, ctx, built, args)

    # decode (decode_32k / long_500k): one new token against a seq_len cache
    window_cache = window_kv and cfg.sliding_window > 0
    built = st.build_decode_step(ap, ctx, mesh,
                                 scan_layers=scan_layers,
                                 fsdp_serve=plan.fsdp_serve
                                 if fsdp_serve_override is None
                                 else fsdp_serve_override,
                                 attn_chunk=attn_chunk,
                                 kv_quant=kv_quant,
                                 weight_quant=weight_quant,
                                 window_cache=window_cache)
    if weight_quant:
        from ..parallel.quant import quantize_params
        params_t = jax.eval_shape(quantize_params, params_t)
    cache_t = jax.eval_shape(
        lambda: init_cache(ap, shape.global_batch, shape.seq_len,
                           local=False, kv_quant=kv_quant,
                           window_cache=window_cache,
                           ef_sites=ef_sites_for(built.ctx, cfg)))
    tok_t = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    pos_t = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    ps, cs, ts, pss = built.in_specs
    args = (_sds(params_t, ps, mesh), _sds(cache_t, cs, mesh),
            _sds(tok_t, ts, mesh), _sds(pos_t, pss, mesh))
    return Cell(arch, shape_name, plan, ap, ctx, built, args)


def input_specs(arch: str, shape_name: str, mesh, **kw):
    """The task-mandated entry point: ShapeDtypeStruct stand-ins for every
    model input of this cell (weak-type-correct, shardable, no allocation)."""
    return build_cell(arch, shape_name, mesh, **kw).args


__all__ = ["build_cell", "input_specs", "Cell"]
