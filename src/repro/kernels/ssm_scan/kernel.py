"""Mamba selective-scan as a Pallas TPU kernel.

Recurrence per channel c and state s:

    h_t = exp(A[c,s] * dt[t,c]) h_{t-1} + dt[t,c] * x[t,c] * B[t,s]
    y_t[c] = sum_s h_t[c,s] * C[t,s]

Tiling: grid (B, Ci/BC, T/CT), time innermost/sequential; the recurrent
state h (BC, S) lives in f32 VMEM scratch.  Within a time chunk the kernel
walks CT steps with a fori_loop of (BC, S) VPU ops — channels are the 128-
lane dimension, the small state dim (16) rides in sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params


def _ssm_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
                y_ref, hout_ref, h_ref, *, chunk_t: int):
    it = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)            # (BC, S)

    def step(t, carry):
        h = carry
        dt_t = dt_ref[0, t, :].astype(jnp.float32)       # (BC,)
        x_t = x_ref[0, t, :].astype(jnp.float32)         # (BC,)
        b_t = b_ref[0, t, :].astype(jnp.float32)         # (S,)
        c_t = c_ref[0, t, :].astype(jnp.float32)         # (S,)
        decay = jnp.exp(a * dt_t[:, None])               # (BC, S)
        h = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)          # (BC,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, chunk_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(it == nt - 1)
    def _final():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk_t", "block_c",
                                             "interpret"))
def ssm_scan_call(x, dt, b, c, a, h0, *, chunk_t: int = 64,
                  block_c: int = 128, interpret=False):
    """x/dt: (B, T, Ci); b/c: (B, T, S); a: (Ci, S); h0: (B, Ci, S) f32.
    T % chunk_t == 0, Ci % block_c == 0.
    Returns (y (B,T,Ci) f32, h_fin (B,Ci,S) f32)."""
    B, T, Ci = x.shape
    S = b.shape[-1]
    grid = (B, Ci // block_c, T // chunk_t)
    xspec = pl.BlockSpec((1, chunk_t, block_c),
                         lambda ib, ic, it: (ib, it, ic))
    bspec = pl.BlockSpec((1, chunk_t, S), lambda ib, ic, it: (ib, it, 0))
    return pl.pallas_call(
        functools.partial(_ssm_kernel, chunk_t=chunk_t),
        grid=grid,
        in_specs=[xspec, xspec, bspec, bspec,
                  pl.BlockSpec((block_c, S), lambda ib, ic, it: (ic, 0)),
                  pl.BlockSpec((1, block_c, S),
                               lambda ib, ic, it: (ib, ic, 0))],
        out_specs=[xspec,
                   pl.BlockSpec((1, block_c, S),
                                lambda ib, ic, it: (ib, ic, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, T, Ci), jnp.float32),
                   jax.ShapeDtypeStruct((B, Ci, S), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_c, S), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, b, c, a, h0)
