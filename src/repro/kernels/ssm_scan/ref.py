"""Pure-jnp oracle for the selective scan (associative-scan form, matching
models.ssm.ssm_mixer's inner recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def ssm_scan_ref(x, dt, b, c, a, h0=None):
    """x/dt: (B,T,Ci); b/c: (B,T,S); a: (Ci,S); h0: (B,Ci,S).
    Returns (y (B,T,Ci), h_fin)."""
    B, T, Ci = x.shape
    S = b.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * a[None, None])          # (B,T,Ci,S)
    drive = (dtf * xf)[..., None] * b[:, :, None, :].astype(jnp.float32)
    if h0 is not None:
        drive = drive.at[:, 0].add(decay[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, hs = lax.associative_scan(combine, (decay, drive), axis=1)
    y = jnp.einsum("btcs,bts->btc", hs, c.astype(jnp.float32))
    return y, hs[:, -1]


__all__ = ["ssm_scan_ref"]
