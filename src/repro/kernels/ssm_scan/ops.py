"""Public wrapper for the selective-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssm_scan_call


def ssm_scan(x, dt, b, c, a, h0=None, *, chunk_t: int = 64,
             block_c: int = 128, interpret=False):
    B, T, Ci = x.shape
    S = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, Ci, S), jnp.float32)
    pad_t = (-T) % chunk_t
    pad_c = (-Ci) % block_c
    if pad_t:
        # dt=0 on padded steps => decay 1, drive 0: state preserved
        x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_t), (0, 0)))
    if pad_c:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_c)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_c)))
        a = jnp.pad(a, ((0, pad_c), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c), (0, 0)))
    y, h_fin = ssm_scan_call(
        x.astype(jnp.float32), dt.astype(jnp.float32),
        b.astype(jnp.float32), c.astype(jnp.float32),
        a.astype(jnp.float32), h0.astype(jnp.float32),
        chunk_t=chunk_t, block_c=block_c, interpret=interpret)
    return y[:, :T, :Ci], h_fin[:, :Ci]


__all__ = ["ssm_scan"]
