from .ops import rwkv6_scan
from .ref import rwkv6_scan_ref

__all__ = ["rwkv6_scan", "rwkv6_scan_ref"]
