"""Oracle: the step-exact RWKV6 recurrence from the model layer."""
from ...models.rwkv import rwkv_scan_ref as rwkv6_scan_ref

__all__ = ["rwkv6_scan_ref"]
