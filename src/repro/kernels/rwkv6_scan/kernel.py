"""RWKV6 time-mix recurrence as a chunked Pallas TPU kernel.

Per (batch, head), the data-dependent-decay linear-attention recurrence

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T

is evaluated chunk-parallel: within a chunk of C=64 steps everything is
(C x hd) / (hd x hd) matmuls (MXU-shaped for hd=64), and the cross-chunk
state S lives in f32 VMEM scratch across the sequential chunk grid
dimension.  This is the TPU analogue of flash-linear-attention's chunked
form; the step-exact oracle lives in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 y_ref, sout_ref, s_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0].astype(jnp.float32)     # (C, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    logw = w_ref[0, :, 0].astype(jnp.float32)  # log decay, < 0
    u = u_ref[0].astype(jnp.float32)           # (hd,)

    L = jnp.cumsum(logw, axis=0)               # inclusive
    Lm1 = L - logw                             # exclusive
    s = s_ref[...]                             # (hd_k, hd_v)

    rdec = r * jnp.exp(Lm1)
    y = jax.lax.dot(rdec, s, preferred_element_type=jnp.float32)
    kdec = k * jnp.exp(jnp.minimum(-L, 60.0))
    scores = jax.lax.dot_general(rdec, kdec, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    c = logw.shape[0]
    ti = lax.broadcasted_iota(jnp.int32, (c, c), 0)
    si = lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(si < ti, scores, 0.0)
    y = y + jax.lax.dot(scores, v, preferred_element_type=jnp.float32)
    y = y + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v

    Lc = L[-1:, :]                             # (1, hd)
    kfac = k * jnp.exp(Lc - L)
    s_new = jnp.exp(Lc[0])[:, None] * s + jax.lax.dot_general(
        kfac, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = s_new
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        sout_ref[0, 0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_call(r, k, v, logw, u, s0, *, chunk: int = 64,
                    interpret=False):
    """r/k/v/logw: (B, T, H, hd) with T % chunk == 0; u: (H, hd);
    s0: (B, H, hd, hd) f32.  Returns (y (B,T,H,hd) f32, s_fin)."""
    B, T, H, hd = r.shape
    grid = (B, H, T // chunk)
    io_spec = pl.BlockSpec((1, chunk, 1, hd),
                           lambda b, h, ic: (b, ic, h, 0))
    return pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec,
                  pl.BlockSpec((1, hd), lambda b, h, ic: (h, 0)),
                  pl.BlockSpec((1, 1, hd, hd),
                               lambda b, h, ic: (b, h, 0, 0))],
        out_specs=[io_spec,
                   pl.BlockSpec((1, 1, hd, hd),
                                lambda b, h, ic: (b, h, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, T, H, hd), jnp.float32),
                   jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, s0)
