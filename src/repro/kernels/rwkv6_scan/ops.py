"""Public wrapper for the RWKV6 chunked-scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import rwkv6_scan_call


def rwkv6_scan(r, k, v, logw, u, s0=None, *, chunk: int = 64,
               interpret=False):
    """r/k/v/logw: (B, T, H, hd) f32; u: (H, hd); s0: (B, H, hd, hd).
    Returns (y, s_final) matching models.rwkv.rwkv_scan_ref."""
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    pad = (-T) % chunk
    if pad:
        padder = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padder(r), padder(k), padder(v)
        logw = padder(logw)  # log-decay 0 => decay 1 (state preserved)
    y, s_fin = rwkv6_scan_call(
        r.astype(jnp.float32), k.astype(jnp.float32),
        v.astype(jnp.float32), logw.astype(jnp.float32),
        u.astype(jnp.float32), s0.astype(jnp.float32),
        chunk=chunk, interpret=interpret)
    return y[:, :T], s_fin


__all__ = ["rwkv6_scan"]
