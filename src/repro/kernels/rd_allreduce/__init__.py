from .ops import rd_all_reduce_pallas
from .ref import rd_all_reduce_ref
from .fused_matmul import collective_matmul_pallas

__all__ = ["rd_all_reduce_pallas", "rd_all_reduce_ref",
           "collective_matmul_pallas"]
