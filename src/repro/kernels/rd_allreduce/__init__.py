from .ops import rd_all_reduce_pallas
from .ref import rd_all_reduce_ref
from .fused_matmul import collective_matmul_pallas
from .quant import (group_for, packed_width, quantize_pack, unpack_dequant,
                    wire_factor)
from .quant_kernel import quantize_pack_pallas, unpack_dequant_pallas

__all__ = ["rd_all_reduce_pallas", "rd_all_reduce_ref",
           "collective_matmul_pallas", "group_for", "packed_width",
           "quantize_pack", "unpack_dequant", "wire_factor",
           "quantize_pack_pallas", "unpack_dequant_pallas"]
