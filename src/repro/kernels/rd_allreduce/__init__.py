from .ops import rd_all_reduce_pallas
from .ref import rd_all_reduce_ref

__all__ = ["rd_all_reduce_pallas", "rd_all_reduce_ref"]
