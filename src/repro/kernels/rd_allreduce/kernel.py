"""Recursive-doubling all-reduce as a single Pallas TPU kernel.

This is the TPU-native re-expression of the paper's NVRAR inter-node phase
(Algorithm 1, ``RD_inter``): log2(N) XOR-peer exchange steps, each sending
the full partial sum, chunked into ``n_chunks`` independently-DMA'd pieces so
the reduction of chunk q overlaps the transfer of chunk q+1 (paper
Sec. 4.2.1's chunked non-blocking communication).

GPU->TPU mechanism mapping (DESIGN.md §2):
  NVSHMEM put_nbi            -> pltpu.make_async_remote_copy(...).start()
  LL fused data+flag payload -> hardware DMA completion semaphores
                                (recv_sem) — no flag words needed
  sequence-number sync       -> per-step barrier semaphore handshake with
                                the peer (prevents recv-buffer reuse races)

The kernel is written for a 1-D logical axis (the slow/DCN axis) inside
shard_map; x must be the caller's partial sum, padded to
(n_chunks, chunk_elems).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params


def _rd_kernel(x_ref, out_ref, recv_ref, step_sem, send_sem, recv_sem, *,
               axis_name: str, n_devices: int, n_chunks: int):
    my = lax.axis_index(axis_name)
    out_ref[...] = x_ref[...]
    n_steps = int(math.log2(n_devices))

    for step in range(n_steps):
        peer = my ^ (1 << step)
        # --- per-step peer handshake (replaces the paper's sequence
        # numbers): both sides signal + wait so the peer's recv buffer for
        # this step parity is known-free before any chunk lands.  The
        # semaphore is indexed BY STEP: a single shared barrier would let a
        # fast device's step-(i+1) signal satisfy a slow device's step-i
        # wait (the race the paper's sequence numbers also prevent).
        pltpu.semaphore_signal(step_sem.at[step], 1, device_id=peer,
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(step_sem.at[step], 1)

        parity = step % 2
        copies = []
        for c in range(n_chunks):
            copy = pltpu.make_async_remote_copy(
                src_ref=out_ref.at[c],
                dst_ref=recv_ref.at[parity, c],
                send_sem=send_sem.at[c],
                recv_sem=recv_sem.at[c],
                device_id=peer,
                device_id_type=pltpu.DeviceIdType.LOGICAL,
            )
            copy.start()           # non-blocking put (put_nbi analogue)
            copies.append(copy)
        for c in range(n_chunks):
            copies[c].wait()        # send done (our buffer reusable) +
            #                         recv done (peer's chunk arrived)
            out_ref[c] = out_ref[c] + recv_ref[parity, c]


@functools.partial(jax.jit,
                   static_argnames=("axis_name", "n_devices", "n_chunks",
                                    "interpret", "collective_id"))
def rd_all_reduce_kernel_call(x, *, axis_name: str, n_devices: int,
                              n_chunks: int = 1, interpret=False,
                              collective_id: int = 7):
    """x: (n_chunks, chunk_elems) f32/bf16 partial sum (inside shard_map)."""
    kern = functools.partial(_rd_kernel, axis_name=axis_name,
                             n_devices=n_devices, n_chunks=n_chunks)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2,) + tuple(x.shape), x.dtype),   # recv (dbl-buffer)
            pltpu.SemaphoreType.REGULAR(                   # per-step barrier
                (max(1, int(math.log2(n_devices))),)),
            pltpu.SemaphoreType.DMA((n_chunks,)),          # send sems
            pltpu.SemaphoreType.DMA((n_chunks,)),          # recv sems
        ],
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(x)
