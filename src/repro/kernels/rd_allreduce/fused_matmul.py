"""Collective matmul as a single Pallas TPU kernel: row-parallel GEMM with
the slow-axis recursive-doubling exchange fused into its epilogue.

This is the kernel form of :func:`repro.core.overlap.collective_matmul` for
the cross-pod (DCN-dominant) decode deployments the paper targets.  The
output features are split into ``n_chunks`` column blocks; the kernel

  1. computes the GEMM for block c,
  2. immediately starts the step-0 XOR-peer remote DMA for block c,
  3. computes the GEMM for block c+1 *while block c is on the wire*,

so the first (most expensive, full-payload) RD exchange step hides entirely
behind MXU work — the paper's Sec. 4.2.1 chunked non-blocking communication
applied at the producer rather than after it.  Remaining RD steps reuse the
double-buffered ``make_async_remote_copy`` machinery of ``_rd_kernel`` (see
``kernel.py``; same per-step barrier-semaphore handshake replacing the
paper's sequence numbers).

Layout contract (the ``ops``-style wrapper below handles it):
  x: (M, K)                       — local activation rows x contracted dim
  w: (n_chunks, K, chunk_d)       — column blocks of this device's weight
  out: (n_chunks, M, chunk_d)     — RD-reduced over the slow axis

Fast-axis (ICI) reduction is intentionally left to the caller: fusing it
would re-serialize the GEMM against the intra-pod phase, and on the slow-axis
crossings this kernel targets the ICI psum is noise (DESIGN.md
§Overlap-and-autotune).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params


def _fused_kernel(x_ref, w_ref, out_ref, recv_ref, step_sem, send_sem,
                  recv_sem, *, axis_name: str, n_devices: int,
                  n_chunks: int):
    my = lax.axis_index(axis_name)
    n_steps = int(math.log2(n_devices))

    # --- step 0, fused into the GEMM epilogue ------------------------------
    # Handshake once with the step-0 peer so its recv buffer is known-free
    # before any chunk lands (same race the per-step semaphores in
    # _rd_kernel prevent).
    peer0 = my ^ 1
    pltpu.semaphore_signal(step_sem.at[0], 1, device_id=peer0,
                           device_id_type=pltpu.DeviceIdType.LOGICAL)
    pltpu.semaphore_wait(step_sem.at[0], 1)
    copies = []
    for c in range(n_chunks):
        acc = jnp.dot(x_ref[...], w_ref[c],
                      preferred_element_type=jnp.float32)
        out_ref[c] = acc.astype(out_ref.dtype)
        copy = pltpu.make_async_remote_copy(
            src_ref=out_ref.at[c],
            dst_ref=recv_ref.at[0, c],
            send_sem=send_sem.at[c],
            recv_sem=recv_sem.at[c],
            device_id=peer0,
            device_id_type=pltpu.DeviceIdType.LOGICAL,
        )
        copy.start()  # chunk c rides DCN while chunk c+1 runs on the MXU
        copies.append(copy)
    for c in range(n_chunks):
        copies[c].wait()
        out_ref[c] = out_ref[c] + recv_ref[0, c]

    # --- remaining RD steps (identical to _rd_kernel) ----------------------
    for step in range(1, n_steps):
        peer = my ^ (1 << step)
        pltpu.semaphore_signal(step_sem.at[step], 1, device_id=peer,
                               device_id_type=pltpu.DeviceIdType.LOGICAL)
        pltpu.semaphore_wait(step_sem.at[step], 1)
        parity = step % 2
        copies = []
        for c in range(n_chunks):
            copy = pltpu.make_async_remote_copy(
                src_ref=out_ref.at[c],
                dst_ref=recv_ref.at[parity, c],
                send_sem=send_sem.at[c],
                recv_sem=recv_sem.at[c],
                device_id=peer,
                device_id_type=pltpu.DeviceIdType.LOGICAL,
            )
            copy.start()
            copies.append(copy)
        for c in range(n_chunks):
            copies[c].wait()
            out_ref[c] = out_ref[c] + recv_ref[parity, c]


@functools.partial(jax.jit,
                   static_argnames=("axis_name", "n_devices", "n_chunks",
                                    "interpret", "collective_id"))
def fused_matmul_rd_call(x, w, *, axis_name: str, n_devices: int,
                         n_chunks: int, interpret=False,
                         collective_id: int = 8):
    """x: (M, K); w: (n_chunks, K, chunk_d) -> (n_chunks, M, chunk_d)
    RD-all-reduced over ``axis_name`` (inside shard_map)."""
    m = x.shape[0]
    chunk_d = w.shape[-1]
    out_shape = (n_chunks, m, chunk_d)
    kern = functools.partial(_fused_kernel, axis_name=axis_name,
                             n_devices=n_devices, n_chunks=n_chunks)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(out_shape, x.dtype),
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec(memory_space=pltpu.VMEM),
        scratch_shapes=[
            pltpu.VMEM((2,) + out_shape, x.dtype),        # recv (dbl-buffer)
            pltpu.SemaphoreType.REGULAR(                   # per-step barrier
                (max(1, int(math.log2(n_devices))),)),
            pltpu.SemaphoreType.DMA((n_chunks,)),          # send sems
            pltpu.SemaphoreType.DMA((n_chunks,)),          # recv sems
        ],
        compiler_params=tpu_compiler_params(collective_id=collective_id),
        interpret=interpret,
    )(x, w)


def collective_matmul_pallas(x: jax.Array, w: jax.Array, ctx, *,
                             spec: str = "bsf,fd->bsd", chunks: int = 4,
                             interpret=False) -> jax.Array:
    """Wrapper: flatten to the kernel layout, run GEMM+RD(slow) fused, then
    finish the fast-axis reduction with a plain psum.

    Falls back to the portable lax path when the slow axis is absent,
    non-power-of-two, or more than one axis (the same fallbacks
    ``rd_all_reduce`` uses).
    """
    from ...core import hierarchical as hier
    from ...core import overlap as ov

    if len(ctx.tp_slow) != 1:
        return ov.collective_matmul(x, w, ctx, spec=spec, chunks=chunks,
                                    backend="lax")
    axis = ctx.tp_slow[0]
    n = lax.axis_size(axis)
    if n == 1 or (n & (n - 1)):
        return ov.collective_matmul(x, w, ctx, spec=spec, chunks=chunks,
                                    backend="lax")
    d_out = w.shape[-1]
    k_dim = 1
    for s in w.shape[:-1]:
        k_dim *= s
    lead = x.shape[: x.ndim - (w.ndim - 1)]
    xm = x.reshape(-1, k_dim)
    wm = w.reshape(k_dim, d_out)
    # column blocks, chunk width aligned to the 128-lane MXU width
    ce = -(-d_out // chunks)
    ce = ((ce + 127) // 128) * 128
    pad = chunks * ce - d_out
    if pad:
        wm = jnp.pad(wm, ((0, 0), (0, pad)))
    wc = wm.reshape(k_dim, chunks, ce).transpose(1, 0, 2)
    out = fused_matmul_rd_call(xm, wc, axis_name=axis, n_devices=n,
                               n_chunks=chunks, interpret=interpret)
    out = out.transpose(1, 0, 2).reshape(xm.shape[0], chunks * ce)
    if pad:
        out = out[:, :d_out]
    out = out.reshape(lead + (d_out,))
    if ctx.tp_fast:
        out = lax.psum(out, ctx.tp_fast)
    return out


__all__ = ["collective_matmul_pallas", "fused_matmul_rd_call"]
