"""Group-quantized pack/unpack for low-bit collectives (reference math).

The quantized all-reduce strategies (``core.hierarchical``, ``ar_quant=
int8|int4``) ship payloads as int8 arrays + per-group bf16 scales; int4
packs two values per byte (nibble layout) so the wire really carries half
the bytes — the HLO byte accountant (``launch.hlo_analysis``) has no s4
dtype, so anything narrower than a byte must be physically packed to count.

Layout contract (shared with the Pallas kernel in ``quant_kernel``):

- Groups run along the **last** dim only: ``x[..., k*group:(k+1)*group]``
  shares one bf16 scale.  Never across batch/sequence dims — that is what
  keeps serving slots independent (one request's magnitudes cannot poison
  another's scales) and makes the overlapped chunked matmul bitwise
  chunk-invariant whenever the chunk step is a multiple of ``group``.
- ``scale = max|group| / qmax`` (symmetric), clamped to 1e-30 so all-zero
  groups stay exact; a NaN/Inf in the group makes the *scale* non-finite,
  so dequantization poisons exactly that group and the serving stack's
  finite-logits quarantine (DESIGN.md §11) still fires.  No masking.
- int4 values live in [-7, 7] (we give up -8 for symmetry); packing pairs
  adjacent elements ``(2i, 2i+1)`` into one byte (low nibble first).
  Pairs may cross group boundaries — packing is independent of scaling.

All reference functions are plain jnp (traceable inside shard_map); the
collectives call these, while ``quant_kernel`` provides the fused Pallas
variant benched in ``tests/test_kernels.py`` / ``bench_allreduce``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

QMAX = {8: 127, 4: 7}
# Default/maximum group sizes: chosen so the wire factor clears the
# acceptance bars with bf16 payloads (see wire_factor):
#   int8 g=128: (1 + 2/128)/2   = 0.5078 -> 1.97x reduction
#   int4 g=64:  (0.5 + 2/64)/2  = 0.2656 -> 3.76x reduction
GROUP_CAP = {8: 128, 4: 64}
_EPS = 1e-30


def group_for(n_last: int, bits: int) -> int:
    """Largest power-of-2 divisor of ``n_last``, capped per ``bits``.

    Power-of-2 keeps groups aligned with the 2^k shard splits the
    hierarchical strategies perform along the same dim.
    """
    if n_last <= 0:
        return 1
    low = n_last & (-n_last)            # largest pow2 dividing n_last
    return min(low, GROUP_CAP[bits])


def wire_factor(bits: int, group: int, dtype_bytes: int = 2) -> float:
    """Quantized wire bytes per full-precision wire byte (payload+scales)."""
    return (bits / 8.0 + 2.0 / group) / dtype_bytes


def packed_width(n_last: int, bits: int) -> int:
    """Byte width of the packed payload for a trailing dim of ``n_last``."""
    if bits == 8:
        return n_last
    assert n_last % 2 == 0, n_last
    return n_last // 2


def quantize_pack(x: jax.Array, bits: int,
                  group: int) -> Tuple[jax.Array, jax.Array]:
    """(..., D) -> (packed int8 (..., Dp), scales bf16 (..., D/group)).

    Requires D % group == 0 and, for int4, D even (callers pad).
    Saturation-safe: values are clipped to [-qmax, qmax] after rounding.
    """
    qmax = QMAX[bits]
    D = x.shape[-1]
    assert D % group == 0, (D, group)
    g = x.astype(jnp.float32).reshape(x.shape[:-1] + (D // group, group))
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.maximum(absmax / qmax, _EPS)     # NaN/Inf propagate
    q = jnp.clip(jnp.round(g / scale[..., None]), -qmax, qmax)
    q = q.astype(jnp.int32).reshape(x.shape[:-1] + (D,))
    if bits == 4:
        assert D % 2 == 0, D
        pairs = q.reshape(x.shape[:-1] + (D // 2, 2))
        v = (pairs[..., 0] & 0xF) | ((pairs[..., 1] & 0xF) << 4)
        q = jnp.where(v > 127, v - 256, v)       # reinterpret as int8 bits
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def unpack_dequant(packed: jax.Array, scales: jax.Array, bits: int,
                   group: int) -> jax.Array:
    """Inverse of :func:`quantize_pack`; returns f32 (..., D)."""
    if bits == 4:
        v = packed.astype(jnp.int32) & 0xFF
        lo = v & 0xF
        hi = (v >> 4) & 0xF
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(
            packed.shape[:-1] + (packed.shape[-1] * 2,))
    else:
        q = packed.astype(jnp.int32)
    D = q.shape[-1]
    assert D % group == 0, (D, group)
    g = q.reshape(q.shape[:-1] + (D // group, group)).astype(jnp.float32)
    out = g * scales.astype(jnp.float32)[..., None]
    return out.reshape(q.shape[:-1] + (D,))


__all__ = ["QMAX", "GROUP_CAP", "group_for", "wire_factor", "packed_width",
           "quantize_pack", "unpack_dequant"]
