"""Public wrapper: pad/reshape to the chunked layout and invoke the kernel.

Call *inside* shard_map over the slow axis, exactly like
``repro.core.rd_all_reduce``:

    y = rd_all_reduce_pallas(x_partial, "pod", n_chunks=4)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .kernel import rd_all_reduce_kernel_call


def rd_all_reduce_pallas(x: jax.Array, axis_name: str, *,
                         n_chunks: int = 4, interpret=False) -> jax.Array:
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if n & (n - 1):
        # non-power-of-two: same fallback the ppermute path uses
        return lax.psum(x, axis_name)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    # chunk_elems aligned to the 128-lane VPU/MXU width
    ce = -(-flat.shape[0] // n_chunks)
    ce = ((ce + 127) // 128) * 128
    pad = n_chunks * ce - flat.shape[0]
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = rd_all_reduce_kernel_call(
        flat.reshape(n_chunks, ce), axis_name=axis_name, n_devices=n,
        n_chunks=n_chunks, interpret=interpret)
    out = out.reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(shape).astype(dtype)


__all__ = ["rd_all_reduce_pallas"]
