"""Oracle: the lax.ppermute-based recursive doubling from repro.core.

Forwarded lazily — ``core.hierarchical`` imports this package for the
quantized pack/unpack math, so a module-level import here would be
circular.
"""


def rd_all_reduce_ref(*args, **kwargs):
    from ...core.hierarchical import rd_all_reduce
    return rd_all_reduce(*args, **kwargs)


__all__ = ["rd_all_reduce_ref"]
