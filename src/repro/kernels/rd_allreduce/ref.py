"""Oracle: the lax.ppermute-based recursive doubling from repro.core."""
from ...core.hierarchical import rd_all_reduce as rd_all_reduce_ref

__all__ = ["rd_all_reduce_ref"]
