"""Pallas pack/unpack kernels for the quantized collective payloads.

Fused absmax -> scale -> round/clip -> nibble-pack in one VMEM pass (and
the inverse), implementing the layout contract documented in ``quant``:
trailing-dim groups, bf16 scales, int4 nibble pairs, saturation-safe.

The collectives themselves call the jnp reference (they run inside
shard_map where XLA already fuses the elementwise chain); these kernels
are the standalone fast path for host-side pack/unpack (e.g. KV-handoff
compression) and the equivalence exhibit: ``tests/test_kernels.py``
pins kernel == reference bit-for-bit in interpret mode.

Tiling: one grid row-block per program, whole trailing dim in VMEM —
decode/prefill residual messages (<= a few MB) fit comfortably.  The
trailing dim should be a multiple of 128 (TPU lane width); callers pad.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import QMAX, _EPS


def _quantize_kernel(x_ref, q_ref, s_ref, *, bits: int, group: int):
    x = x_ref[...].astype(jnp.float32)            # (R, D)
    R, D = x.shape
    g = x.reshape(R, D // group, group)
    absmax = jnp.max(jnp.abs(g), axis=-1)
    scale = jnp.maximum(absmax / QMAX[bits], _EPS)
    q = jnp.clip(jnp.round(g / scale[..., None]), -QMAX[bits], QMAX[bits])
    q = q.astype(jnp.int32).reshape(R, D)
    if bits == 4:
        pairs = q.reshape(R, D // 2, 2)
        v = (pairs[..., 0] & 0xF) | ((pairs[..., 1] & 0xF) << 4)
        q = jnp.where(v > 127, v - 256, v)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.bfloat16)


def _dequant_kernel(q_ref, s_ref, out_ref, *, bits: int, group: int):
    q = q_ref[...]
    if bits == 4:
        v = q.astype(jnp.int32) & 0xFF
        lo = v & 0xF
        hi = (v >> 4) & 0xF
        lo = jnp.where(lo > 7, lo - 16, lo)
        hi = jnp.where(hi > 7, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(v.shape[0],
                                                 v.shape[1] * 2)
    R, D = q.shape[0], q.shape[-1]
    g = q.reshape(R, D // group, group).astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    out_ref[...] = (g * s[..., None]).reshape(R, D)


@functools.partial(jax.jit, static_argnames=("bits", "group", "interpret"))
def quantize_pack_pallas(x: jax.Array, *, bits: int, group: int,
                         interpret: bool = False
                         ) -> Tuple[jax.Array, jax.Array]:
    """(R, D) f32/bf16 -> (packed int8 (R, Dp), scales bf16 (R, D/group))."""
    assert x.ndim == 2, x.shape
    R, D = x.shape
    assert D % group == 0, (D, group)
    dp = D if bits == 8 else D // 2
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, group=group),
        out_shape=(jax.ShapeDtypeStruct((R, dp), jnp.int8),
                   jax.ShapeDtypeStruct((R, D // group), jnp.bfloat16)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("bits", "group", "interpret"))
def unpack_dequant_pallas(packed: jax.Array, scales: jax.Array, *,
                          bits: int, group: int,
                          interpret: bool = False) -> jax.Array:
    """Inverse of :func:`quantize_pack_pallas`; returns f32 (R, D)."""
    assert packed.ndim == 2, packed.shape
    R = packed.shape[0]
    D = packed.shape[1] * (2 if bits == 4 else 1)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits, group=group),
        out_shape=jax.ShapeDtypeStruct((R, D), jnp.float32),
        interpret=interpret,
    )(packed, scales)


__all__ = ["quantize_pack_pallas", "unpack_dequant_pallas"]
