from .ops import decode_attention, paged_decode_attention
from .ref import decode_attention_ref, paged_decode_attention_ref

__all__ = ["decode_attention", "decode_attention_ref",
           "paged_decode_attention", "paged_decode_attention_ref"]
