"""Pure-jnp oracle for decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def decode_attention_ref(q, k, v, positions, *, window: int = 0):
    """q: (B, Hq, hd); k/v: (B, S, Hkv, hd); positions: (B,)."""
    B, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bugh,bsuh->bugs", qg, k.astype(jnp.float32)) \
        * (hd ** -0.5)
    kp = jnp.arange(S)[None, :]
    mask = kp <= positions[:, None]
    if window > 0:
        mask &= kp > positions[:, None] - window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bugs,bsuh->bugh", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd).astype(q.dtype)


def paged_decode_attention_ref(q, k_phys, v_phys, block_tbl, positions, *,
                               window: int = 0):
    """Oracle for the paged kernel: gather the logical K/V view through the
    block table, then run the dense oracle.

    q: (B, Hq, hd); k_phys/v_phys: (n_blocks, bs, Hkv, hd);
    block_tbl: (B, max_blocks) int32; positions: (B,).
    """
    B = q.shape[0]
    mb, bs = block_tbl.shape[1], k_phys.shape[1]
    Hkv, hd = k_phys.shape[2], k_phys.shape[3]
    k = k_phys[block_tbl].reshape(B, mb * bs, Hkv, hd)
    v = v_phys[block_tbl].reshape(B, mb * bs, Hkv, hd)
    return decode_attention_ref(q, k, v, positions, window=window)


__all__ = ["decode_attention_ref", "paged_decode_attention_ref"]
