"""GQA decode attention (flash-decode) for TPU.

The decode phase is memory-bandwidth-bound: one query token attends over the
whole KV cache.  Tiling streams the cache HBM->VMEM in (BK, hd) tiles with
the batch dimension blocked to 8 sublanes; the online-softmax state lives in
f32 VMEM scratch across KV blocks (innermost, sequential).

Per-sequence write positions arrive as a (B,) int32 array; keys at index
> pos[b] (or outside the sliding window) are masked, so one kernel serves
ragged continuous-batching batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params

NEG_INF = -1.0e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, window: int,
                   block_b: int, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[...]                                   # (BB,)
    k_pos = ik * block_k + lax.broadcasted_iota(
        jnp.int32, (block_b, block_k), 1)
    mask = k_pos <= pos[:, None]
    if window > 0:
        mask &= k_pos > pos[:, None] - window

    # Skip blocks beyond every sequence's position.
    @pl.when(ik * block_k <= jnp.max(pos))
    def _compute():
        q = q_ref[:, 0].astype(jnp.float32)              # (BB, hd)
        k = k_ref[:, :, 0].astype(jnp.float32)           # (BB, BK, hd)
        v = v_ref[:, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * scale  # (BB, BK)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[:, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def _paged_decode_kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, scale: float,
                         window: int, block_size: int):
    """Grid (B, Hq, max_blocks).  The KV refs are *physical-block* views:
    the index_map below resolves logical block j of sequence b to physical
    block tbl[b, j] via scalar prefetch, so the gather happens in the DMA
    schedule — the logical (B, S, U, hd) view is never materialized in HBM.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    pos = pos_ref[b]
    k_pos = j * block_size + lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)[0]
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > pos - window

    # Skip logical blocks entirely beyond this sequence's position (their
    # table entries may point at the trash block).
    @pl.when(j * block_size <= pos)
    def _compute():
        q_vec = q_ref[0, 0].astype(jnp.float32)           # (hd,)
        kb = k_ref[0, :, 0].astype(jnp.float32)           # (bs, hd)
        vb = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            kb, q_vec, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bs,)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
        acc_ref[0] = acc_ref[0] * alpha + jax.lax.dot_general(
            p, vb, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[0]
                       / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "q_per_kv", "interpret"))
def paged_decode_attention_call(q, k_phys, v_phys, block_tbl, positions, *,
                                window: int, q_per_kv: int,
                                interpret=False):
    """Paged flash-decode: one query token per sequence attends over K/V
    scattered across fixed-size physical blocks.

    q: (B, Hq, hd); k_phys/v_phys: (n_blocks, block_size, Hkv, hd);
    block_tbl: (B, max_blocks) int32 logical->physical; positions: (B,).
    Returns (B, Hq, hd).

    The block table and positions ride in SMEM as scalar-prefetch operands
    (``PrefetchScalarGridSpec``): each grid step (b, h, j) DMAs physical
    block ``tbl[b, j]`` HBM->VMEM directly, so non-resident blocks cost
    nothing and the KV working set per step is one (block_size, hd) tile.
    Batch is *not* sublane-blocked (unlike the dense kernel): each
    sequence's block list is independent, which trades sublane utilization
    for zero logical-view materialization — the PagedAttention layout.
    """
    B, Hq, hd = q.shape
    max_blocks = block_tbl.shape[1]
    block_size = k_phys.shape[1]
    grid = (B, Hq, max_blocks)
    kern = functools.partial(_paged_decode_kernel, scale=hd ** -0.5,
                             window=window, block_size=block_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, j, tbl, pos: (b, h, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, tbl, pos, qpk=q_per_kv:
                         (tbl[b, j], 0, h // qpk, 0)),
            pl.BlockSpec((1, block_size, 1, hd),
                         lambda b, h, j, tbl, pos, qpk=q_per_kv:
                         (tbl[b, j], 0, h // qpk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, h, j, tbl, pos: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(block_tbl, positions, q, k_phys, v_phys)


@functools.partial(jax.jit, static_argnames=(
    "window", "q_per_kv", "block_b", "block_k", "interpret"))
def decode_attention_call(q, k, v, positions, *, window: int,
                          q_per_kv: int, block_b: int = 8,
                          block_k: int = 256, interpret=False):
    """q: (B, Hq, hd); k/v: (B, S, Hkv, hd); positions: (B,) int32.
    B pre-padded to block_b, S to block_k.  Returns (B, Hq, hd)."""
    B, Hq, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    grid = (B // block_b, Hq, S // block_k)
    kern = functools.partial(_decode_kernel, scale=hd ** -0.5,
                             window=window, block_b=block_b,
                             block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda ib, h, ik: (ib,)),
            pl.BlockSpec((block_b, 1, hd), lambda ib, h, ik: (ib, h, 0)),
            pl.BlockSpec((block_b, block_k, 1, hd),
                         lambda ib, h, ik, qpk=q_per_kv:
                         (ib, ik, h // qpk, 0)),
            pl.BlockSpec((block_b, block_k, 1, hd),
                         lambda ib, h, ik, qpk=q_per_kv:
                         (ib, ik, h // qpk, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1, hd),
                               lambda ib, h, ik: (ib, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_b, hd), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
            pltpu.VMEM((block_b,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(positions, q, k, v)
