"""Public wrappers: batch/sequence padding for the decode-attention kernel
and the paged (block-table) variant."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import decode_attention_call, paged_decode_attention_call


def decode_attention(q, k, v, positions, *, window: int = 0,
                     block_b: int = 8, block_k: int = 256,
                     interpret=False):
    """q: (B, Hq, hd); k/v: (B, S, Hkv, hd); positions: (B,) -> (B, Hq, hd).
    """
    B, Hq, hd = q.shape
    S = k.shape[1]
    pad_b = (-B) % block_b
    pad_s = (-S) % block_k
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        positions = jnp.pad(positions, (0, pad_b))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    out = decode_attention_call(q, k, v, positions, window=window,
                                q_per_kv=Hq // k.shape[2],
                                block_b=block_b, block_k=block_k,
                                interpret=interpret)
    return out[:B]


def paged_decode_attention(q, k_phys, v_phys, block_tbl, positions, *,
                           window: int = 0, interpret=False):
    """Paged decode attention: K/V gathered through a block table.

    q: (B, Hq, hd); k_phys/v_phys: (n_blocks, block_size, Hkv, hd);
    block_tbl: (B, max_blocks) int32 (trash entries must only cover
    positions > pos); positions: (B,) -> (B, Hq, hd).

    No padding is applied: the grid iterates (B, Hq, max_blocks) directly —
    sequence length is already block-quantized by construction and batch is
    unblocked (see kernel docstring).
    """
    return paged_decode_attention_call(
        q, k_phys, v_phys, block_tbl, positions, window=window,
        q_per_kv=q.shape[1] // k_phys.shape[2], interpret=interpret)


__all__ = ["decode_attention", "paged_decode_attention"]
