"""Public wrapper: batch/sequence padding for the decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import decode_attention_call


def decode_attention(q, k, v, positions, *, window: int = 0,
                     block_b: int = 8, block_k: int = 256,
                     interpret=False):
    """q: (B, Hq, hd); k/v: (B, S, Hkv, hd); positions: (B,) -> (B, Hq, hd).
    """
    B, Hq, hd = q.shape
    S = k.shape[1]
    pad_b = (-B) % block_b
    pad_s = (-S) % block_k
    if pad_b:
        q = jnp.pad(q, ((0, pad_b), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad_b), (0, 0), (0, 0), (0, 0)))
        positions = jnp.pad(positions, (0, pad_b))
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    out = decode_attention_call(q, k, v, positions, window=window,
                                q_per_kv=Hq // k.shape[2],
                                block_b=block_b, block_k=block_k,
                                interpret=interpret)
    return out[:B]


__all__ = ["decode_attention"]
