"""Pallas TPU kernels for the perf-critical layers.

Each kernel subpackage ships:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (shape plumbing, padding, GQA mapping)
  ref.py    — pure-jnp oracle used by the tests' assert_allclose sweeps

Kernels are TARGETED at TPU (MXU-aligned tiles, HBM->VMEM pipelines,
remote-DMA collectives) and VALIDATED here in interpret mode on CPU.
The jnp model layers remain the default execution path on CPU; on real TPU
the ops in this package slot in via the same call signatures.

rd_allreduce is the paper's core kernel: the NVSHMEM GPU-initiated
recursive-doubling all-reduce, re-expressed with TPU async remote DMA.
"""
