"""Fused grouped expert FFN (gated-SiLU) for the MoE dispatch path.

One kernel computes, per local expert e and token tile c:

    out[e, c] = (silu(x[e,c] @ wg[e]) * (x[e,c] @ wu[e])) @ wd[e]

Tiling: grid (E_loc, C/BC, F/BF) with the expert-hidden dim innermost
("arbitrary") so the (BC, D) f32 accumulator persists in VMEM across F
tiles — the gate/up/down chain never round-trips through HBM, which is the
fusion XLA cannot do across the dispatch buffers.  BC=BF=128 keeps every
matmul MXU-shaped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params


def _moe_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref):
    i_f = pl.program_id(2)
    n_f = pl.num_programs(2)

    @pl.when(i_f == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0].astype(jnp.float32)       # (BC, D)
    wg = wg_ref[0].astype(jnp.float32)     # (D, BF)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)     # (BF, D)
    g = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
    u = jax.lax.dot(x, wu, preferred_element_type=jnp.float32)
    h = jax.nn.silu(g) * u                 # (BC, BF)
    acc_ref[...] += jax.lax.dot(h, wd, preferred_element_type=jnp.float32)

    @pl.when(i_f == n_f - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f",
                                             "interpret"))
def moe_expert_ffn_call(x, wg, wu, wd, *, block_c: int = 128,
                        block_f: int = 128, interpret=False):
    """x: (E, C, D); wg/wu: (E, D, F); wd: (E, F, D) -> (E, C, D).
    C % block_c == 0, F % block_f == 0 (ops.py pads)."""
    E, C, D = x.shape
    F = wg.shape[-1]
    grid = (E, C // block_c, F // block_f)
    return pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, ic, i_f: (e, ic, 0)),
            pl.BlockSpec((1, D, block_f), lambda e, ic, i_f: (e, 0, i_f)),
            pl.BlockSpec((1, D, block_f), lambda e, ic, i_f: (e, 0, i_f)),
            pl.BlockSpec((1, block_f, D), lambda e, ic, i_f: (e, i_f, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, D),
                               lambda e, ic, i_f: (e, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, D), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wg, wu, wd)
