from .ops import moe_expert_ffn
from .ref import moe_expert_ffn_ref

__all__ = ["moe_expert_ffn", "moe_expert_ffn_ref"]
