"""Oracle: the model layer's batched gated expert FFN."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_expert_ffn_ref(x, wg, wu, wd):
    """x: (E, C, D); wg/wu: (E, D, F); wd: (E, F, D)."""
    a = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   wg.astype(jnp.float32))
    b = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                   wu.astype(jnp.float32))
    h = jax.nn.silu(a) * b
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(jnp.float32)
                      ).astype(x.dtype)


__all__ = ["moe_expert_ffn_ref"]
