"""Public wrapper: pad the token-capacity and hidden dims to tile multiples."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import moe_expert_ffn_call


def moe_expert_ffn(x, wg, wu, wd, *, block_c: int = 128,
                   block_f: int = 128, interpret=False):
    """x: (E, C, D); wg/wu: (E, D, F); wd: (E, F, D) -> (E, C, D)."""
    E, C, D = x.shape
    F = wg.shape[-1]
    pc = (-C) % block_c
    pf = (-F) % block_f
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0)))
    if pf:
        wg = jnp.pad(wg, ((0, 0), (0, 0), (0, pf)))
        wu = jnp.pad(wu, ((0, 0), (0, 0), (0, pf)))
        wd = jnp.pad(wd, ((0, 0), (0, pf), (0, 0)))
    out = moe_expert_ffn_call(x, wg, wu, wd, block_c=block_c,
                              block_f=block_f, interpret=interpret)
    return out[:, :C]


__all__ = ["moe_expert_ffn"]
