"""Pure-jnp oracle for flash attention (masked softmax, f32 math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        kv_len=None):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd).  GQA by head grouping."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Sq, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bugsh,buth->bugst", qg, kf) * (hd ** -0.5)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if kv_len is not None:
        mask &= kp < kv_len
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bugst,buth->bugsh", p, vf)
    return o.reshape(B, Hq, Sq, hd).astype(q.dtype)


__all__ = ["flash_attention_ref"]
