"""Public wrapper: padding + GQA plumbing for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention_call


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret=False):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd) -> (B, Hq, Sq, hd).

    Pads sequence dims to block multiples; padded KV is masked inside the
    kernel via kv_len, padded Q rows are sliced off.
    """
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_call(q, k, v, causal=causal, window=window,
                               q_per_kv=Hq // Hkv, kv_len=Skv,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out[:, :, :Sq]


__all__ = ["flash_attention"]
