"""Blocked causal flash attention (prefill) for TPU.

Tiling: grid (B, Hq, Sq/BQ, Skv/BK) with the KV dimension innermost
("arbitrary" semantics) so the f32 accumulator scratch persists across KV
blocks — the online-softmax state never leaves VMEM.  Q/K tiles are
(BQ|BK, head_dim) with BQ=BK=128 by default: MXU-shaped (128x128) matmuls.

GQA is handled in the index maps: query head h reads kv head h // q_per_kv
— no materialized KV expansion.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...core.compat import tpu_compiler_params

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
    k_pos = ik * block_k + lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window

    # Skip fully-masked KV blocks (causal upper triangle / outside window).
    run = ik >= 0
    if causal:
        run &= ik * block_k <= iq * block_q + block_q - 1
    if window > 0:
        run &= ik * block_k + block_k - 1 > iq * block_q - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "q_per_kv", "kv_len",
    "interpret"))
def flash_attention_call(q, k, v, *, causal: bool, window: int,
                         q_per_kv: int, kv_len: int,
                         block_q: int = 128, block_k: int = 128,
                         interpret=False):
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd), Sq/Skv pre-padded to
    block multiples.  Returns (B, Hq, Sq, hd)."""
    B, Hq, Sq, hd = q.shape
    Skv = k.shape[2]
    scale = hd ** -0.5
    grid = (B, Hq, Sq // block_q, Skv // block_k)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, block_q=block_q,
                             block_k=block_k, kv_len=kv_len)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, qpk=q_per_kv:
                         (b, h // qpk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, qpk=q_per_kv:
                         (b, h // qpk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
