"""Mesh-topology helpers: one place that turns a requested layout
(tp ways, pod split, AR knobs) into a ``(mesh, ctx, tp)`` triple, plus
the device carving that gives every serving replica its own disjoint
mesh.

Historically each driver (``launch.serve``, dist cases, benchmarks)
built its mesh inline; the multi-replica router needs the same
construction *parameterized by an explicit device subset* so N replicas
can coexist in one process without sharing collectives.  ``jax.devices()``
is carved into contiguous groups (replica i gets devices
``[i*tp, (i+1)*tp)``) — contiguous so a replica's fast axis stays on
neighbouring devices, matching how dp replicas are placed on real
fabrics (paper Sec. 3.1's topology hierarchy).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from ..core.pcontext import ParallelCtx, LOCAL


def mesh_and_ctx(tp: int, pods: int = 1, *, ar_strategy: str = "flat",
                 overlap: bool = False, seq_parallel: str = "off",
                 ar_quant: str = "none",
                 devices: Optional[Sequence] = None
                 ) -> Tuple[object, ParallelCtx, int]:
    """(mesh, ctx, tp_total) for the requested layout; local when tp == 1.

    ``devices`` restricts the mesh to an explicit device subset (must hold
    exactly ``tp`` devices) — the per-replica construction path.  With
    ``tp == 1`` the mesh is None and every collective is the identity, so
    a 1-way "replica" is just the local engine path.
    """
    ctx = LOCAL.replace(ar_strategy=ar_strategy, overlap_matmul=overlap,
                        seq_parallel=seq_parallel, ar_quant=ar_quant)
    if tp <= 1:
        return None, ctx, 1
    if tp % pods:
        raise ValueError(f"tp={tp} not divisible by pods={pods}")
    if devices is not None and len(devices) != tp:
        raise ValueError(f"device subset holds {len(devices)} devices, "
                         f"need exactly tp={tp}")
    from ..core.compat import AxisType, make_mesh
    if pods > 1:
        mesh = make_mesh((pods, tp // pods), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2, devices=devices)
        ctx = ctx.replace(tp_fast=("model",), tp_slow=("pod",),
                          ep=("model",))
    else:
        mesh = make_mesh((tp,), ("model",), axis_types=(AxisType.Auto,),
                         devices=devices)
        ctx = ctx.replace(tp_fast=("model",), ep=("model",))
    return mesh, ctx, tp


def replica_device_groups(n_replicas: int, tp: int,
                          devices: Optional[Sequence] = None) -> list:
    """Carve the device pool into ``n_replicas`` disjoint contiguous
    groups of ``tp`` devices each (replica i owns ``[i*tp, (i+1)*tp)``).

    With ``tp == 1`` replicas run the local (mesh-less) engine path and
    need no devices of their own — returns ``[None] * n_replicas``.
    """
    if n_replicas < 1:
        raise ValueError(f"need n_replicas >= 1, got {n_replicas}")
    if tp <= 1:
        return [None] * n_replicas
    pool = list(jax.devices()) if devices is None else list(devices)
    need = n_replicas * tp
    if len(pool) < need:
        raise ValueError(
            f"{n_replicas} replicas x tp={tp} needs {need} devices, "
            f"but only {len(pool)} are visible")
    return [pool[i * tp:(i + 1) * tp] for i in range(n_replicas)]


__all__ = ["mesh_and_ctx", "replica_device_groups"]
