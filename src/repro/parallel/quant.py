"""Weight quantization for serving (beyond-paper optimization).

Block weights are stored as int8 with per-output-channel bf16 scales and
dequantized per layer inside the decode/prefill scan via the same
``layer_map`` hook used for FSDP gathering.  Each original leaf ``w``
becomes ``{"q": int8 w, "s": bf16 scale}``; the sharding rules resolve the
rule name one path level up, and the size-1 scale dims fall out of TP/FSDP
sharding automatically (divisibility check).

Halves the dominant weight-streaming term of big-model decode (§Perf cell
A8) at ~0.4 % per-channel quantization error; embeddings and norms stay
bf16 (small, accuracy-sensitive).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

# leaves that stay un-quantized (tiny and/or accuracy-critical)
_SKIP = {"w", "b", "mu", "beta", "u", "w0", "ln_w", "ln_b", "dt_bias",
         "A_log", "D_skip", "conv_b", "router", "bq", "bk", "bv", "b1"}


def _is_qs(node) -> bool:
    return isinstance(node, dict) and set(node) == {"q", "s"}


def quantize_blocks(blocks: Dict[str, Any]) -> Dict[str, Any]:
    """Quantize a (stacked) block-param tree: w -> {'q','s'}."""
    from jax.tree_util import tree_map_with_path, DictKey

    def f(path, leaf):
        name = path[-1].key if isinstance(path[-1], DictKey) else str(path[-1])
        if name in _SKIP or leaf.dtype not in (jnp.bfloat16, jnp.float32) \
                or leaf.ndim < 3:
            return leaf
        # per-output-channel scale: reduce all dims except (layer, last)
        red = tuple(range(1, leaf.ndim - 1))
        lf = leaf.astype(jnp.float32)
        s = jnp.maximum(jnp.max(jnp.abs(lf), axis=red, keepdims=True)
                        / 127.0, 1e-30)
        q = jnp.clip(jnp.round(lf / s), -127, 127).astype(jnp.int8)
        return {"q": q, "s": s.astype(jnp.bfloat16)}

    return tree_map_with_path(f, blocks)


def dequant_layer(bp):
    """Per-layer dequant (inside the scan): {'q','s'} -> bf16 leaf."""
    def f(node):
        if _is_qs(node):
            return (node["q"].astype(jnp.float32)
                    * node["s"].astype(jnp.float32)).astype(jnp.bfloat16)
        return node

    return jax.tree.map(f, bp, is_leaf=lambda n: _is_qs(n) or not
                        isinstance(n, dict))


def quantize_params(params: Dict[str, Any]) -> Dict[str, Any]:
    out = dict(params)
    for k in ("blocks", "enc_blocks"):
        if k in params:
            out[k] = quantize_blocks(params[k])
    return out


__all__ = ["quantize_params", "quantize_blocks", "dequant_layer"]
