"""Distribution layer: sharding rules, step builders, pipeline parallelism,
and mesh-topology construction (per-replica device carving)."""
from .sharding import (param_specs, param_fsdp_dims, cache_spec, data_specs,
                       gather_params, TP_RULES)
from .topology import mesh_and_ctx, replica_device_groups

__all__ = ["param_specs", "param_fsdp_dims", "cache_spec", "data_specs",
           "gather_params", "TP_RULES", "mesh_and_ctx",
           "replica_device_groups"]
