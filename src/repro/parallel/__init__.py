"""Distribution layer: sharding rules, step builders, pipeline parallelism."""
from .sharding import (param_specs, param_fsdp_dims, cache_spec, data_specs,
                       gather_params, TP_RULES)

__all__ = ["param_specs", "param_fsdp_dims", "cache_spec", "data_specs",
           "gather_params", "TP_RULES"]
