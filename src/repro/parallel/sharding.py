"""Sharding rules: parameter/cache/data PartitionSpecs for the production
meshes, plus the per-layer FSDP gather used inside scanned step functions.

Rules are name-based on the leaf path.  Every rule gives the *TP* dimension
assignment; the FSDP dimension is then chosen automatically as the largest
remaining dimension divisible by the FSDP-axes size (small or indivisible
leaves stay replicated across FSDP — they are negligible).

Invariants / known gaps: a *paged* decode cache shards its physical K/V
block pool on the head dim only and can never ride a dp batch axis (the
pool is one shared resource indexed by a host-managed table — serving
replicas are separate processes, not dp shards); cross-attention weights
stay FSDP-replicated (their K/V are precomputed over vmapped stacked
layers, which cannot nest a per-layer all-gather).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_map_with_path, DictKey

from ..core.pcontext import ParallelCtx

# ---------------------------------------------------------------------------
# TP rules: leaf name -> which dim (counted from the *end*, ignoring the
# leading stacked-layer dim) is TP-sharded.  None = replicated over TP.
# ---------------------------------------------------------------------------
# Attention slot layouts: wq/wk/wv (D, slots, hd) -> slot dim = -2;
# wo (slots, hd, D) -> -3.  Biases (slots, hd) -> -2.
TP_RULES: Dict[str, Optional[int]] = {
    # embedding: tok (V, D) shard vocab; head (D, V) shard vocab
    "tok": -2, "head": -1,
    # attention
    "wq": -2, "wk": -2, "wv": -2, "wo": -3,
    "bq": -2, "bk": -2, "bv": -2,
    # dense mlp: wg/wu/w1 (D,F) col; wd/w2 (F,D) row; b1 (F,)
    "wg": -1, "wu": -1, "w1": -1, "b1": -1, "wd": -2, "w2": -2,
    # norms replicated
    "w": None, "b": None,
    # moe: router replicated; experts (E, ...) shard expert dim
    "router": None,
    # NOTE: moe expert wg/wu/wd are (E,D,F)/(E,F,D): expert dim = -3
    # handled by path context below (under a "moe" parent).
    # ssm (mamba): d_inner-sharded leaves
    "w_x": -1, "w_z": -1, "w_dt": -1, "dt_bias": -1,
    "conv_w": -1, "conv_b": -1, "A_log": -2, "D_skip": -1,
    "w_out": -2, "w_bc": None,
    # rwkv time-mix: A(-heads)-sharded
    "w_r": -1, "w_k": -1, "w_v": -1, "w_g": -1, "w0": -1, "u": -1,
    "ln_w": -1, "ln_b": -1, "w_a": None, "w_b": -1, "w_o": -2,
    "mu": None, "beta": None,
    # rwkv channel-mix (under "cm"): wk (D,F) col, wv (F,D) row, wr (D,D) row
    "wr": -2,
}

_MOE_EXPERT_LEAVES = {"wg", "wu", "wd"}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, DictKey):
            out.append(str(k.key))
        else:
            out.append(str(k))
    return tuple(out)


def _tp_dim(path_names: Tuple[str, ...], ndim: int) -> Optional[int]:
    name = path_names[-1]
    parents = path_names[:-1]
    if name in ("q", "s") and len(path_names) >= 2:
        # quantized leaf {'q','s'}: rule name is one level up; scale dims of
        # size 1 drop out of sharding via the divisibility check
        name = path_names[-2]
        parents = path_names[:-2]
    if "moe" in parents and name in _MOE_EXPERT_LEAVES:
        return ndim - 3  # expert dim of (E, D, F)/(E, F, D) [+L if stacked]
    if "cm" in parents:  # rwkv channel-mix: wk (D,F) col / wv (F,D) row
        d = {"wk": -1, "wv": -2, "wr": -2, "mu": None}[name]
        return None if d is None else ndim + d
    if name not in TP_RULES:
        raise KeyError(f"no TP rule for param {'/'.join(path_names)}")
    d = TP_RULES[name]
    return None if d is None else ndim + d


def _axes_prod(mesh_axis_sizes: Dict[str, int], axes) -> int:
    n = 1
    for a in axes:
        n *= mesh_axis_sizes[a]
    return n


def _leaf_plan(path_names, shape, ctx: ParallelCtx,
               mesh_axis_sizes: Dict[str, int], fsdp: bool,
               stacked: bool):
    """Returns (PartitionSpec, fsdp_dim or None)."""
    ndim = len(shape)
    spec = [None] * ndim
    tp_axes = ctx.tp_slow + ctx.tp_fast
    tpd = _tp_dim(path_names, ndim)
    if tpd is not None and tp_axes:
        tp_size = _axes_prod(mesh_axis_sizes, tp_axes)
        if shape[tpd] % tp_size == 0:
            spec[tpd] = tp_axes if len(tp_axes) > 1 else tp_axes[0]
        else:
            tpd = None
    fsdp_dim = None
    # Cross-attention weights stay FSDP-replicated: their K/V are precomputed
    # once per generation over *stacked* layers (vmapped), which cannot nest
    # a per-layer all-gather.  They are a small fraction of enc-dec models.
    if "xattn" in path_names:
        fsdp = False
    if fsdp and ctx.fsdp:
        fs = _axes_prod(mesh_axis_sizes, ctx.fsdp)
        first = 1 if stacked else 0  # never shard the stacked-layer dim
        cands = [d for d in range(first, ndim)
                 if d != tpd and spec[d] is None and shape[d] % fs == 0
                 and shape[d] // fs >= 8]
        if cands:
            fsdp_dim = max(cands, key=lambda d: shape[d])
            spec[fsdp_dim] = ctx.fsdp if len(ctx.fsdp) > 1 else ctx.fsdp[0]
    return P(*spec), fsdp_dim


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def param_specs(params, ctx: ParallelCtx, mesh, *, fsdp: bool = False):
    """PartitionSpec pytree for a parameter pytree.

    Leaves under 'blocks'/'enc_blocks' have a leading stacked-layer dim.
    """
    sizes = _mesh_axis_sizes(mesh)

    def f(path, leaf):
        names = _path_names(path)
        stacked = "blocks" in names or "enc_blocks" in names
        spec, _ = _leaf_plan(names, leaf.shape, ctx, sizes, fsdp, stacked)
        return spec

    return tree_map_with_path(f, params)


def param_fsdp_dims(params, ctx: ParallelCtx, mesh):
    """Pytree of ints: the dim each leaf is FSDP-sharded along, *relative to
    the per-layer slice* (stacked-layer dim stripped); -1 = not sharded.
    (-1 rather than None so the tree structure matches the param tree.)"""
    sizes = _mesh_axis_sizes(mesh)

    def f(path, leaf):
        names = _path_names(path)
        stacked = "blocks" in names or "enc_blocks" in names
        _, fd = _leaf_plan(names, leaf.shape, ctx, sizes, True, stacked)
        if fd is None:
            return -1
        return fd - 1 if stacked else fd

    return tree_map_with_path(f, params)


def gather_params(layer_params, fsdp_dims, ctx: ParallelCtx):
    """All-gather FSDP-sharded leaves of one layer's params (inside
    shard_map).  AD transposes this into the gradient reduce-scatter."""
    if not ctx.fsdp:
        return layer_params

    def g(leaf, dim):
        if dim < 0:
            return leaf
        return lax.all_gather(leaf, ctx.fsdp, axis=dim, tiled=True)

    return jax.tree.map(g, layer_params, fsdp_dims)


# ---------------------------------------------------------------------------
# Cache and data specs
# ---------------------------------------------------------------------------


def cache_spec(cache, ctx: ParallelCtx):
    """Decode-cache specs: batch over dp axes, head/channel dims over TP.

    A *paged* cache (``block_tbl`` present) shards its physical K/V blocks
    on the head dim only: the block pool is a shared resource indexed by a
    host-managed table, so the block dim cannot ride a batch axis (serving
    replicas are separate processes, not dp shards).
    """
    tp = ctx.tp_slow + ctx.tp_fast
    tp_s = tp if len(tp) > 1 else (tp[0] if tp else None)
    dp = ctx.dp if len(ctx.dp) > 1 else (ctx.dp[0] if ctx.dp else None)
    paged = isinstance(cache, dict) and "block_tbl" in cache
    if paged and dp is not None:
        raise ValueError("paged cache cannot shard slots over dp axes")

    def f(path, leaf):
        name = _path_names(path)[-1]
        nd = leaf.ndim
        if name == "block_tbl":                     # (slots, max_blocks)
            return P(None, None)
        if paged and name in ("k", "v"):            # (L,nb,bs,U,hd)
            return P(None, None, None, tp_s, None)
        if name in ("k", "v", "enc_k", "enc_v"):   # (L,B,S,U,hd)
            return P(None, dp, None, tp_s, None)
        if name in ("k_scale", "v_scale"):          # (L,B,S,U)
            return P(None, dp, None, tp_s)
        if name == "conv":                          # (L,B,K-1,Ci)
            return P(None, dp, None, tp_s)
        if name == "ssm":                           # (L,B,Ci,s)
            return P(None, dp, tp_s, None)
        if name in ("shift_tm", "shift_cm"):        # (L,B,D) replicated D
            return P(None, dp, None)
        if name == "wkv":                           # (L,B,H,hd,hd)
            return P(None, dp, tp_s, None, None)
        if name == "ef":                            # (L,sites,tp,B,D)
            # Error-feedback residual for quantized all-reduce: one
            # per-device rounding state, so the device dim shards over
            # the TP axes (each rank keeps only its own residual).
            return P(None, None, tp_s, dp, None)
        raise KeyError(f"no cache rule for {name} ndim={nd}")

    return tree_map_with_path(f, cache)


def data_specs(ctx: ParallelCtx, *, ndim: int = 2):
    """Spec for (B, S[, D]) batch inputs: batch over dp axes."""
    dp = ctx.dp if len(ctx.dp) > 1 else (ctx.dp[0] if ctx.dp else None)
    return P(*((dp,) + (None,) * (ndim - 1)))


def kv_states_spec(ctx: ParallelCtx):
    """Spec for per-layer attention K/V states ``(L, B, S, U, hd)`` moving
    in/out of a step as a standalone value (prefill-only outputs, handoff
    splice inputs): kv-slot dim over TP, everything else replicated —
    matching the ``k``/``v`` rule in :func:`cache_spec` without a dp batch
    axis (handoff payloads are per-request, not batch-sharded)."""
    tp = ctx.tp_slow + ctx.tp_fast
    tp_s = tp if len(tp) > 1 else (tp[0] if tp else None)
    return P(None, None, None, tp_s, None)


__all__ = ["param_specs", "param_fsdp_dims", "gather_params", "cache_spec",
           "data_specs", "kv_states_spec", "TP_RULES"]
