"""Pipeline parallelism (the paper's HP baseline: TP intra-node x PP
inter-node), as a real shard_map GPipe schedule.

Layers are sharded over the stage axis (the leading stacked-layer dim of the
block params), activations travel between stages via ``lax.ppermute``, and a
microbatch pipeline fills/drains over ``M + P - 1`` ticks.  TP composes
inside each stage through the same ParallelCtx collectives as everywhere
else.

Used by the TP-vs-HP comparison tests and (in alpha-beta form) by the
strong-scaling benchmarks; decode-side HP is intentionally modelled rather
than run (the paper's Obs. 2: it cannot shrink decode GEMMs — our Table 4
benchmark shows why).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core.compat import shard_map
from ..core.pcontext import ParallelCtx
from ..core import hierarchical as hier
from ..models.transformer import ArchPlan, block_forward
from ..models import layers as L
from . import sharding as shd


def build_pp_forward(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                     stage_axis: str, microbatches: int):
    """Forward pass -> vocab-sharded logits, pipelined over ``stage_axis``.

    Requirements: cfg.n_layers % n_stages == 0; global batch % microbatches
    == 0.  Embedding/head run on every stage (cheap, replicated math) but
    only stage 0's embed output and the last stage's logits are live.
    """
    cfg = ap.cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_stages = sizes[stage_axis]
    assert cfg.n_layers % n_stages == 0
    layers_per_stage = cfg.n_layers // n_stages

    from ..models.transformer import init_params
    template = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))
    pspecs = shd.param_specs(template, ctx, mesh, fsdp=False)

    # blocks additionally shard their leading layer dim over the stage axis
    def stage_spec(spec):
        return P(*((stage_axis,) + tuple(spec)[1:]))

    pspecs = dict(pspecs)
    pspecs["blocks"] = jax.tree.map(stage_spec, pspecs["blocks"])

    def fwd(params, tokens):
        stage = lax.axis_index(stage_axis)
        B, S = tokens.shape
        mb = microbatches
        mb_sz = B // mb
        x_all = L.embed_lookup(params["embed"], tokens, ctx, ap.vocab_pad)
        x_mbs = x_all.reshape(mb, mb_sz, S, -1)
        positions = jnp.arange(S, dtype=jnp.int32)

        def run_stage(x):
            def body(x, bp):
                x, _, _ = block_forward(bp, x, ap, ctx,
                                        positions=positions, sp=False,
                                        causal=True)
                return x, None
            x, _ = lax.scan(body, x, params["blocks"])
            return x

        n_ticks = mb + n_stages - 1
        buf = jnp.zeros((mb_sz, S, x_all.shape[-1]), x_all.dtype)
        out = jnp.zeros_like(x_mbs)

        def tick(carry, t):
            buf, out = carry
            # stage 0 ingests microbatch t (when valid)
            take = jnp.clip(t, 0, mb - 1)
            injected = lax.dynamic_index_in_dim(x_mbs, take, axis=0,
                                                keepdims=False)
            buf = jnp.where((stage == 0) & (t < mb), injected, buf)
            buf = run_stage(buf)
            # collect the last stage's finished microbatch t-(P-1)
            done_idx = jnp.clip(t - (n_stages - 1), 0, mb - 1)
            is_done = (stage == n_stages - 1) & (t >= n_stages - 1)
            out = lax.cond(
                is_done,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, buf, done_idx, axis=0),
                lambda o: o, out)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = lax.ppermute(buf, stage_axis, perm)
            return (buf, out), None

        (buf, out), _ = lax.scan(tick, (buf, out),
                                 jnp.arange(n_ticks, dtype=jnp.int32))
        # broadcast the final stage's collected outputs to all stages
        out = lax.psum(jnp.where(stage == n_stages - 1, out,
                                 jnp.zeros_like(out)), stage_axis)
        x_full = out.reshape(B, S, -1)
        x_full = L.apply_norm(x_full, params["final_norm"], cfg)
        return L.lm_logits(params["embed"], x_full)

    tp = ctx.tp_slow + ctx.tp_fast
    vspec = tp if len(tp) > 1 else (tp[0] if tp else None)
    in_specs = (pspecs, P(None, None))
    out_specs = P(None, None, vspec)   # logits stay vocab-sharded over TP
    fn = shard_map(fwd, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    return fn, pspecs


__all__ = ["build_pp_forward"]
