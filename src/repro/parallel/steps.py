"""Step builders: shard_map'd train / prefill / decode step functions.

These close over an :class:`ArchPlan`, a :class:`ParallelCtx` and a mesh, and
return jit-ready functions together with their in/out shardings — consumed by
the launcher, the dry-run, the inference engine and the tests alike.

Training step = FSDP(all-gather weights per layer) x TP x SP x grad-accum
microbatches x remat, with the cross-pod gradient reduction performed by the
paper's recursive-doubling strategy (optionally int8-compressed).

Decode step = Megatron-style TP with the per-layer all-reduce strategy under
study (flat | hier_ring | hier_rd | hier_rd_halving).

Serving-stack builders (cache init / admission / fused serve / spec verify
/ prefill-only / KV splice) share two conventions: ``mesh=None`` returns a
plain jit-able callable over the LOCAL ctx while a mesh returns the
shard_map'd production step (one engine, two deployments), and every
builder captures its ``ar_table`` at build time (``autotune.using``) so
``ar_strategy="auto"`` call sites resolve against the right table even
when jit defers tracing — in disaggregated serving the prefill and decode
pools' builders therefore dispatch against *different* tables.  The same
scope also resolves ``ctx.seq_parallel="auto"``: prefill-shaped builders
(full prefill / admission / chunked admission / prefill-only) ask the
captured tuner whether their residual message size warrants the
sequence-parallel RS+AG layout, while decode builders never decompose
(DESIGN.md §10).

Invariants the serve-side steps rely on (details in ``inference.kv_cache``
and DESIGN.md §7-§9): stale-slot / pad / rejected-draft K/V writes are
harmless (trash-routed on the paged path, write-order-covered on the dense
path), and a paged cache's block table rides outside the layer scan.
Known gaps: chunked admission, spec verify, and the disaggregation steps
are dense-family-only; serve steps cannot shard slots over dp axes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P, NamedSharding

from ..core.compat import shard_map
from ..core.pcontext import ParallelCtx, LOCAL
from ..core import autotune
from ..core import hierarchical as hier
from ..models.transformer import (ArchPlan, forward_lm, decode_step,
                                  ef_sites_for, init_cache, prefill_chunk,
                                  seed_cache)
from ..models import layers as L
from ..training.optimizer import (adamw_init, adamw_update, cosine_lr,
                                  global_grad_norm)
from . import sharding as shd


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _all_axes(ctx: ParallelCtx):
    seen, out = set(), []
    for a in ctx.tp_slow + ctx.tp_fast + ctx.dp + ctx.fsdp:
        if a not in seen:
            seen.add(a)
            out.append(a)
    return tuple(out)


def _repl_factors(params, specs, mesh):
    """How many devices hold each leaf's shard (for norm accounting)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = int(np.prod(mesh.devices.shape))

    def f(_, spec):
        shard_ways = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shard_ways *= sizes[a]
        return total // shard_ways

    return jax.tree.map(f, params, specs)


@dataclasses.dataclass
class BuiltStep:
    fn: Callable            # the jit-able python callable (shard_map'd)
    in_specs: Any
    out_specs: Any
    mesh: Any
    ctx: ParallelCtx
    donate_argnums: Tuple[int, ...] = ()

    def jit(self, **kw):
        kw.setdefault("donate_argnums", self.donate_argnums)
        return jax.jit(self.fn, **kw)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def build_train_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                     microbatches: int = 1, scan_layers: bool = True,
                     remat: bool = True, sp: bool = True,
                     base_lr: float = 3e-4, warmup: int = 100,
                     total_steps: int = 10000, clip_norm: float = 1.0,
                     frame_embeds: bool = False, patch_embeds: bool = False
                     ) -> BuiltStep:
    cfg = ap.cfg
    sp = sp and cfg.family not in ("ssm", "hybrid")  # recurrences need full seq
    pod_axes = tuple(a for a in ctx.dp if a not in ctx.fsdp)

    # All specs are computed from a ShapeDtypeStruct template — no arrays
    # are materialized here.
    from ..models.transformer import init_params  # local import

    template = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))
    pspecs = shd.param_specs(template, ctx, mesh, fsdp=True)
    fdims = shd.param_fsdp_dims(template, ctx, mesh)
    repl = _repl_factors(template, pspecs, mesh)
    all_axes = _all_axes(ctx)

    fdims_blocks = fdims["blocks"]
    layer_map = (lambda bp: shd.gather_params(bp, fdims_blocks, ctx)) \
        if ctx.fsdp else None
    enc_layer_map = None
    if ctx.fsdp and "enc_blocks" in fdims:
        fdims_enc = fdims["enc_blocks"]
        enc_layer_map = lambda bp: shd.gather_params(bp, fdims_enc, ctx)

    def loss_fn(params, tokens, labels, extra):
        logits, aux, _, _ = forward_lm(
            params, tokens, ap, ctx, sp=sp, scan_layers=scan_layers,
            layer_map=layer_map, enc_layer_map=enc_layer_map, remat=remat,
            frame_embeds=extra.get("frames"),
            patch_embeds=extra.get("patches"))
        # data pipeline provides labels already shifted/aligned per position
        loss = L.sharded_xent(logits, labels, ctx, ap.vocab_pad,
                              cfg.vocab_size)
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * hier.dp_psum_mean(aux, ctx)
        return loss

    def train_step(params, opt_state, batch):
        # Gather non-block params once (embed / final norms).
        def full_params(p):
            if not ctx.fsdp:
                return p
            out = dict(p)
            for k in p:
                if k in ("blocks", "enc_blocks"):
                    continue
                out[k] = shd.gather_params(p[k], fdims[k], ctx)
            return out

        tokens, labels = batch["tokens"], batch["labels"]
        B_loc = tokens.shape[0]
        mb = microbatches
        assert B_loc % mb == 0, (B_loc, mb)
        tok_mb = tokens.reshape(mb, B_loc // mb, -1)
        lab_mb = labels.reshape(mb, B_loc // mb, -1)
        extras = {}
        for k2, name in (("frames", "frames"), ("patches", "patches")):
            if name in batch:
                e = batch[name]
                extras[k2] = e.reshape((mb, B_loc // mb) + e.shape[1:])

        def micro(grads_acc, xs):
            tok, lab = xs[0], xs[1]
            extra = {}
            i = 2
            if "frames" in extras:
                extra["frames"] = xs[i]; i += 1
            if "patches" in extras:
                extra["patches"] = xs[i]; i += 1
            l, g = jax.value_and_grad(
                lambda p: loss_fn(full_params(p), tok, lab, extra))(params)
            grads_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), grads_acc, g)
            return grads_acc, l

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        xs = (tok_mb, lab_mb)
        if "frames" in extras:
            xs = xs + (extras["frames"],)
        if "patches" in extras:
            xs = xs + (extras["patches"],)
        grads, losses = lax.scan(micro, g0, xs)
        grads = jax.tree.map(lambda g: g / mb, grads)
        loss = jnp.mean(losses)

        # Cross-pod / replicated-leaf gradient reduction (paper technique).
        # FSDP-gathered leaves (fd >= 0) are already reduce-scattered over
        # ctx.fsdp by AD; only the pod (slow-DCN) sum remains — the paper's
        # inter-node recursive-doubling phase.  Leaves replicated across
        # FSDP still need the sum over every dp axis.
        def finish(g, fd):
            if fd >= 0:
                return hier.grad_cross_pod_reduce(g, ctx, pod_axes) \
                    if pod_axes else g
            fast_dp = tuple(a for a in ctx.dp if a not in pod_axes)
            if fast_dp:
                g = lax.psum(g, fast_dp)
            return hier.grad_cross_pod_reduce(g, ctx, pod_axes) \
                if pod_axes else g

        grads = jax.tree.map(finish, grads, fdims)
        gnorm = global_grad_norm(grads, repl, all_axes)
        skip = ~jnp.isfinite(gnorm)
        scale = jnp.where(gnorm > clip_norm, clip_norm / (gnorm + 1e-9), 1.0)
        lr = cosine_lr(opt_state["step"], base_lr=base_lr, warmup=warmup,
                       total=total_steps)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr,
                                           grad_scale=scale, skip=skip)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "skipped": skip.astype(jnp.float32), "lr": lr}
        return new_params, new_opt, metrics

    data_spec = {"tokens": shd.data_specs(ctx, ndim=2),
                 "labels": shd.data_specs(ctx, ndim=2)}
    if frame_embeds:
        data_spec["frames"] = shd.data_specs(ctx, ndim=3)
    if patch_embeds:
        data_spec["patches"] = shd.data_specs(ctx, ndim=3)
    opt_spec = {"m": pspecs, "v": pspecs, "step": P()}
    in_specs = (pspecs, opt_spec, data_spec)
    out_specs = (pspecs, opt_spec,
                 {"loss": P(), "grad_norm": P(), "skipped": P(), "lr": P()})
    fn = shard_map(train_step, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=ctx, donate_argnums=(0, 1))


# ---------------------------------------------------------------------------
# Serve steps (prefill + decode)
# ---------------------------------------------------------------------------


def build_decode_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                      scan_layers: bool = True, fsdp_serve: bool = False,
                      sample: bool = True, attn_chunk=None,
                      kv_quant: bool = False, weight_quant: bool = False,
                      window_cache: bool = False,
                      ar_table: Optional[str] = None) -> BuiltStep:
    """One-token decode across the batch: (params, cache, tokens, positions)
    -> (next_tokens | logits, new_cache).

    ``ar_table``: path to a persisted autotune table (JSON).  The tuner is
    captured at build time and activated around the step body during
    tracing, so every ``ar_strategy="auto"`` call site in THIS step
    resolves against THIS table even if another build installs a different
    one before jit traces (falls back to the analytic seed, or the
    ``REPRO_AR_TABLE`` env var, when None/missing)."""
    cfg = ap.cfg
    ar_tuner = autotune.tuner_for(ar_table)
    from ..models.transformer import init_params

    serve_ctx = ctx if fsdp_serve else ctx.replace(fsdp=())
    template = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))
    if weight_quant:
        from .quant import quantize_params, dequant_layer
        template = jax.eval_shape(quantize_params, template)
    pspecs = shd.param_specs(template, serve_ctx, mesh, fsdp=fsdp_serve)
    fdims = shd.param_fsdp_dims(template, serve_ctx, mesh) if fsdp_serve \
        else None
    layer_map = None
    if fsdp_serve:
        layer_map = lambda bp: shd.gather_params(bp, fdims["blocks"],
                                                 serve_ctx)
    if weight_quant:
        from .quant import dequant_layer
        _g = layer_map
        layer_map = (lambda bp: dequant_layer(_g(bp))) if _g \
            else dequant_layer

    def step(params, cache, tokens, positions):
        if fsdp_serve:
            full = dict(params)
            for k in params:
                if k not in ("blocks", "enc_blocks"):
                    full[k] = shd.gather_params(params[k], fdims[k],
                                                serve_ctx)
            params = full
        with autotune.using(ar_tuner):  # trace-time 'auto' dispatch
            logits, new_cache = decode_step(params, cache, tokens,
                                            positions, ap, serve_ctx,
                                            scan_layers=scan_layers,
                                            layer_map=layer_map,
                                            attn_chunk=attn_chunk,
                                            kv_ring=window_cache)
        if sample:
            out = L.greedy_sample(logits, serve_ctx, cfg.vocab_size)
        else:
            out = lax.all_gather(logits, serve_ctx.tp_axes, axis=1,
                                 tiled=True) if serve_ctx.has_tp else logits
        return out, new_cache

    cache_t = jax.eval_shape(lambda: init_cache(
        ap, 1, 8, local=False, kv_quant=kv_quant,
        window_cache=window_cache,
        ef_sites=ef_sites_for(serve_ctx, cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    dp = serve_ctx.dp
    dspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    in_specs = (pspecs, cspecs, P(dspec), P(dspec))
    out_specs = (P(dspec) if sample else P(dspec, None), cspecs)
    fn = shard_map(step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx, donate_argnums=(1,))


def build_prefill(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                  scan_layers: bool = True, s_max: int,
                  fsdp_serve: bool = False, attn_chunk=None,
                  sp: Optional[bool] = None,
                  frame_embeds: bool = False, patch_embeds: bool = False,
                  ar_table: Optional[str] = None) -> BuiltStep:
    """Prefill: run the full prompt, return (first_token, cache).
    ``ar_table`` as in :func:`build_decode_step`.  ``sp=None`` resolves
    the sequence-parallel residual layout from ``ctx.seq_parallel`` per
    prompt length (an explicit bool forces it)."""
    cfg = ap.cfg
    ar_tuner = autotune.tuner_for(ar_table)
    from ..models.transformer import init_params

    serve_ctx = ctx if fsdp_serve else ctx.replace(fsdp=())
    template = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))
    pspecs = shd.param_specs(template, serve_ctx, mesh, fsdp=fsdp_serve)
    fdims = shd.param_fsdp_dims(template, serve_ctx, mesh) if fsdp_serve \
        else None
    layer_map = (lambda bp: shd.gather_params(bp, fdims["blocks"], serve_ctx)) \
        if fsdp_serve else None

    def prefill(params, tokens, *extra):
        if fsdp_serve:
            full = dict(params)
            for k in params:
                if k not in ("blocks", "enc_blocks"):
                    full[k] = shd.gather_params(params[k], fdims[k],
                                                serve_ctx)
            params = full
        kw = {}
        i = 0
        if frame_embeds:
            kw["frame_embeds"] = extra[i]; i += 1
        if patch_embeds:
            kw["patch_embeds"] = extra[i]; i += 1
        B, S = tokens.shape
        chunk = attn_chunk if attn_chunk is not None \
            else (1024 if S > 8192 else 0)
        with autotune.using(ar_tuner):  # trace-time 'auto' dispatch
            logits, _, states, enc_out = forward_lm(
                params, tokens, ap, serve_ctx, sp=sp,
                scan_layers=scan_layers, collect_state=True,
                layer_map=layer_map, chunk=chunk, **kw)
        cache = init_cache(ap, B, s_max, local=True,
                           ef_sites=ef_sites_for(serve_ctx, cfg))
        enc_kv = None
        if cfg.enc_layers:
            def xkv(bp):
                # xattn is never FSDP-sharded (see sharding._leaf_plan)
                return L.cross_kv(bp["xattn"], enc_out)
            enc_kv = jax.vmap(xkv)(params["blocks"])
        cache = seed_cache(cache, states, enc_kv=enc_kv)
        nxt = L.greedy_sample(logits[:, -1], serve_ctx, cfg.vocab_size)
        return nxt, cache

    cache_t = jax.eval_shape(lambda: init_cache(
        ap, 1, 8, local=False, ef_sites=ef_sites_for(serve_ctx, cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    dp = serve_ctx.dp
    dspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    in_sp = [pspecs, P(dspec, None)]
    if frame_embeds:
        in_sp.append(P(dspec, None, None))
    if patch_embeds:
        in_sp.append(P(dspec, None, None))
    in_specs = tuple(in_sp)
    out_specs = (P(dspec), cspecs)
    fn = shard_map(prefill, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx)


# ---------------------------------------------------------------------------
# Serving-stack steps (continuous batching: cache init / admission / serve)
# ---------------------------------------------------------------------------
#
# These power ``inference.scheduler.ContinuousBatcher`` on *either* path:
# with ``mesh=None`` they return plain jit-able callables over the LOCAL ctx
# (single device), with a mesh they return shard_map'd steps that inherit
# the ar_table / overlap_matmul wiring of the decode builder above — one
# serving engine, two deployments.


def _serve_ctx(ctx: ParallelCtx, mesh, fsdp_serve: bool) -> ParallelCtx:
    if mesh is None:
        return LOCAL
    return ctx if fsdp_serve else ctx.replace(fsdp=())


def _serve_params(ap: ArchPlan, serve_ctx, mesh, fsdp_serve):
    """(pspecs, fdims, layer_map, full_params) for the serve-side builders."""
    from ..models.transformer import init_params
    if mesh is None:
        return None, None, None, lambda p: p
    template = jax.eval_shape(lambda k: init_params(k, ap),
                              jax.random.PRNGKey(0))
    pspecs = shd.param_specs(template, serve_ctx, mesh, fsdp=fsdp_serve)
    if not fsdp_serve:
        return pspecs, None, None, lambda p: p
    fdims = shd.param_fsdp_dims(template, serve_ctx, mesh)
    layer_map = lambda bp: shd.gather_params(bp, fdims["blocks"], serve_ctx)

    def full_params(params):
        full = dict(params)
        for k in params:
            if k not in ("blocks", "enc_blocks"):
                full[k] = shd.gather_params(params[k], fdims[k], serve_ctx)
        return full

    return pspecs, fdims, layer_map, full_params


def _full_vocab(logits, serve_ctx: ParallelCtx):
    """Gather vocab-sharded logits (vocab last) back to the full vocab —
    the one shared gather every sampled path routes through."""
    if not serve_ctx.has_tp:
        return logits
    return lax.all_gather(logits, serve_ctx.tp_axes,
                          axis=logits.ndim - 1, tiled=True)


def _sample_next(logits, serve_ctx: ParallelCtx, cfg, rng,
                 temperature: float, top_k: int):
    """Next-token sampling over (possibly vocab-sharded) logits, on device.
    temperature=0 -> sharded greedy argmax; otherwise gather the vocab and
    run layers.sample_token (temperature / top-k)."""
    if temperature > 0.0:
        return L.sample_token(_full_vocab(logits, serve_ctx), rng,
                              temperature=temperature,
                              top_k=top_k, vocab_real=cfg.vocab_size)
    return L.greedy_sample(logits, serve_ctx, cfg.vocab_size)


def _finite_slots(logits, serve_ctx: ParallelCtx):
    """Per-slot all-finite flag over (possibly vocab-sharded) logits.

    ``logits``: (..., slots leading, vocab last).  Non-finite counts are
    psum'd over the TP axes so the flag is *replicated* across shards —
    a NaN on any vocab shard marks the slot on every device (relying on
    NaN propagation to make shards agree independently would be
    replication-unsound).  This is the device half of the batcher's
    quarantine guard (DESIGN.md §11): detection happens where the
    corruption lives, the host only reads one bool per slot.
    """
    bad = (~jnp.isfinite(logits.astype(jnp.float32)))
    bad = bad.sum(axis=tuple(range(1, bad.ndim))).astype(jnp.int32)
    if serve_ctx.has_tp:
        bad = lax.psum(bad, serve_ctx.tp_axes)
    return bad == 0


def _sample_next_slots(logits, serve_ctx: ParallelCtx, cfg, keys, idx,
                       temperature: float, top_k: int):
    """Per-slot next-token sampling for the fused serve step.

    Slot ``s`` draws with the *stateless* key ``fold_in(keys[s], idx[s])``
    — the request's own sampling chain (``scheduler.request_sampling_key``),
    independent of the global step schedule and of which other slots are
    active.  That schedule-independence is what makes sampled
    (temperature > 0) disaggregated streams token-identical to colocated
    serving and preemption recomputes resample their original tokens.
    temperature=0 -> sharded greedy argmax (keys untouched).
    """
    if temperature <= 0.0:
        return L.greedy_sample(logits, serve_ctx, cfg.vocab_size)
    full = _full_vocab(logits, serve_ctx)
    subs = jax.vmap(jax.random.fold_in)(keys, idx)
    return jax.vmap(
        lambda row, k2: L.sample_token(row[None], k2,
                                       temperature=temperature,
                                       top_k=top_k,
                                       vocab_real=cfg.vocab_size)[0]
    )(full, subs)


def build_cache_init(ap: ArchPlan, ctx: ParallelCtx, mesh, *, slots: int,
                     s_max: int, block_size: int = 0,
                     n_blocks: Optional[int] = None,
                     kv_quant: bool = False,
                     fsdp_serve: bool = False) -> BuiltStep:
    """() -> zeroed decode cache for ``slots`` batch rows (paged when
    block_size > 0, int8 K/V + scales when ``kv_quant``), created
    shard-local under the mesh."""
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    ef_sites = ef_sites_for(serve_ctx, ap.cfg)

    def init():
        return init_cache(ap, slots, s_max, local=True,
                          block_size=block_size, n_blocks=n_blocks,
                          kv_quant=kv_quant, ef_sites=ef_sites)

    if mesh is None:
        return BuiltStep(fn=init, in_specs=(), out_specs=None, mesh=None,
                         ctx=serve_ctx)
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, kv_quant=kv_quant, ef_sites=ef_sites))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    fn = shard_map(init, mesh=mesh, in_specs=(), out_specs=cspecs,
                   check_vma=False)
    return BuiltStep(fn=fn, in_specs=(), out_specs=cspecs, mesh=mesh,
                     ctx=serve_ctx)


def build_serve_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *, s_max: int,
                     scan_layers: bool = True, fsdp_serve: bool = False,
                     temperature: float = 0.0, top_k: int = 0,
                     block_size: int = 0, n_blocks: Optional[int] = None,
                     slots: int = 1, attn_chunk=None,
                     kv_quant: bool = False,
                     ar_table: Optional[str] = None) -> BuiltStep:
    """Fused continuous-batching step: decode all slots + sample + advance
    the device-side slot state.

    (params, cache, state) -> (emitted, done, finite, state', cache')
    with state = {tokens, positions, remaining: (slots,) i32, active:
    (slots,) bool, rng: (slots, 2) u32 per-request sampling-chain base
    keys, sample_idx: (slots,) i32 tokens sampled so far}.  ``finite``
    is the per-slot all-finite-logits flag (``_finite_slots``): a False
    entry means the slot's token this step is garbage — the batcher
    quarantines the slot and recomputes it (DESIGN.md §11) instead of
    emitting.  Slot ``s`` samples
    with ``fold_in(rng[s], sample_idx[s])`` — the request's own chain, so
    sampled streams are schedule-independent (see ``_sample_next_slots``).
    Inactive slots keep decoding into their own (dense) row or the
    trash block (paged) — no masking in the hot path; ``emitted`` holds the
    sampled token where active, the stale token elsewhere, and ``done``
    flags slots that finished this step (caller frees/refills them).
    ``ar_table`` / ``ctx.overlap_matmul`` behave as in build_decode_step.
    """
    cfg = ap.cfg
    ar_tuner = autotune.tuner_for(ar_table)
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    if mesh is not None and serve_ctx.dp:
        raise ValueError("serve step cannot shard slots over dp axes; "
                         "run one batcher per data-parallel replica")
    pspecs, _, layer_map, full_params = _serve_params(ap, serve_ctx, mesh,
                                                      fsdp_serve)

    def step(params, cache, state):
        params = full_params(params)
        active = state["active"]
        with autotune.using(ar_tuner):
            logits, new_cache = decode_step(
                params, cache, state["tokens"], state["positions"], ap,
                serve_ctx, scan_layers=scan_layers, layer_map=layer_map,
                attn_chunk=attn_chunk)
        nxt = _sample_next_slots(logits, serve_ctx, cfg, state["rng"],
                                 state["sample_idx"], temperature, top_k)
        finite = _finite_slots(logits, serve_ctx)
        emitted = jnp.where(active, nxt, state["tokens"])
        act_i = active.astype(jnp.int32)
        positions = state["positions"] + act_i
        remaining = state["remaining"] - act_i
        done = active & ((remaining <= 0) | (positions >= s_max - 1))
        state2 = {"tokens": emitted, "positions": positions,
                  "remaining": remaining, "active": active & ~done,
                  "rng": state["rng"],
                  "sample_idx": state["sample_idx"] + act_i}
        return emitted, done, finite, state2, new_cache

    if mesh is None:
        return BuiltStep(fn=step, in_specs=None, out_specs=None, mesh=None,
                         ctx=serve_ctx, donate_argnums=(1, 2))
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, kv_quant=kv_quant,
        ef_sites=ef_sites_for(serve_ctx, ap.cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    sspec = {"tokens": P(None), "positions": P(None),
             "remaining": P(None), "active": P(None),
             "rng": P(None, None), "sample_idx": P(None)}
    in_specs = (pspecs, cspecs, sspec)
    out_specs = (P(None), P(None), P(None), sspec, cspecs)
    fn = shard_map(step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx, donate_argnums=(1, 2))


def _spec_targets(logits, drafts, serve_ctx: ParallelCtx, cfg, rng,
                  temperature: float, top_k: int):
    """Per-position target tokens + draft accept mask for the verify pass.

    logits: (B, C, V_loc) vocab-sharded scores for the C = k+1 chunk
    inputs; drafts: (B, k) proposed tokens.  Returns (tgt (B, C) int32,
    match (B, k) bool) where ``tgt[:, j]`` is the target-model token
    emitted at draft position j when j is the first rejection (or the
    bonus position j == k), and ``match[:, j]`` accepts draft j.

    Greedy (temperature == 0): tgt is the sharded argmax and a draft
    matches iff it equals it — the emitted stream is bitwise the plain
    greedy stream.  Sampled: drafts are deterministic proposals (every
    shipped drafter is), so exact speculative rejection sampling reduces
    to accept draft d with probability p(d), else resample from the
    renormalized leftover p with d zeroed — per-token output distribution
    is exactly the target p (see DESIGN.md §8).
    """
    B, C = logits.shape[0], logits.shape[1]
    k = C - 1
    if temperature <= 0.0:
        flat = logits.reshape(B * C, logits.shape[-1])
        tgt = L.greedy_sample(flat, serve_ctx, cfg.vocab_size)
        tgt = tgt.reshape(B, C)
        return tgt, drafts == tgt[:, :k]
    lf = _full_vocab(logits, serve_ctx).astype(jnp.float32)
    V = lf.shape[-1]
    lf = jnp.where((jnp.arange(V) < cfg.vocab_size)[None, None, :], lf,
                   L.NEG_INF)
    lf = lf / temperature
    if top_k > 0 and top_k < V:
        kth = jnp.sort(lf, axis=-1)[..., -top_k][..., None]
        lf = jnp.where(lf >= kth, lf, L.NEG_INF)
    p = jax.nn.softmax(lf, axis=-1)                       # (B, C, V)
    r_acc, r_res, r_bonus = jax.random.split(rng, 3)
    p_draft = jnp.take_along_axis(p[:, :k], drafts[..., None],
                                  axis=-1)[..., 0]        # (B, k)
    match = jax.random.uniform(r_acc, (B, k)) < p_draft
    # residual resample: zero the rejected draft, renormalize -> exactly p
    onehot = jax.nn.one_hot(drafts, V, dtype=bool)
    resid = jnp.where(onehot, L.NEG_INF, lf[:, :k])
    corr = jax.random.categorical(r_res, resid, axis=-1)  # (B, k)
    bonus = jax.random.categorical(r_bonus, lf[:, k], axis=-1)
    tgt = jnp.concatenate([corr, bonus[:, None]],
                          axis=1).astype(jnp.int32)
    return tgt, match


def build_spec_verify_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *, k: int,
                           s_max: int, slots: int = 1,
                           scan_layers: bool = True,
                           fsdp_serve: bool = False,
                           temperature: float = 0.0, top_k: int = 0,
                           block_size: int = 0,
                           n_blocks: Optional[int] = None,
                           attn_chunk: int = 0,
                           kv_quant: bool = False,
                           ar_table: Optional[str] = None) -> BuiltStep:
    """Speculative-decoding verify step: score ``k`` drafted tokens for
    every slot in ONE fused pass over the chunked-prefill machinery.

    (params, cache, state, drafts (slots, k), rng) ->
    (emitted (slots, k+1), accepted (slots,) i32, finite (slots,) bool,
    cache').  ``finite`` flags slots whose verify logits were all finite
    (``_finite_slots``); a False slot's emitted/accepted are garbage and
    the batcher quarantines it (DESIGN.md §11).

    The chunk input for each slot is ``[state.tokens, drafts]`` (C = k+1
    tokens) written/attended at positions ``state.positions + [0..k]`` —
    exactly the K/V writes sequential decode would perform if every draft
    were accepted.  ``accepted`` is the longest verified draft prefix;
    the caller takes ``emitted[:, :accepted+1]`` (accepted drafts + one
    correction/bonus token) and rolls the rejected tail's K/V back
    (``BlockAllocator.truncate`` on the paged path; on the dense path the
    stale tail is overwritten before any read by the same write-ordering
    invariant that covers chunk padding).

    The per-layer all-reduces of this step carry C-times-wider messages
    than one-token decode, so with ``ar_strategy="auto"`` the captured
    ``ar_table`` re-dispatches every call site on the new sizes — the
    workload-side shift into the paper's strategy-sensitive regime.

    Dense (attention-only) families only, like ``prefill_chunk``.
    """
    cfg = ap.cfg
    if cfg.family != "dense":
        raise ValueError("speculative verify rides the chunked-prefill "
                         f"path: dense families only, not {cfg.family!r}")
    if kv_quant:
        raise ValueError("spec verify rides prefill_chunk, which cannot "
                         "re-read an int8 KV cache mid-chunk: kv_quant "
                         "is incompatible with speculative decoding")
    if k < 1:
        raise ValueError(f"spec k must be >= 1, got {k}")
    C = k + 1
    ar_tuner = autotune.tuner_for(ar_table)
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    if mesh is not None and serve_ctx.dp:
        raise ValueError("spec verify step cannot shard slots over dp "
                         "axes; run one batcher per replica")
    pspecs, _, layer_map, full_params = _serve_params(ap, serve_ctx, mesh,
                                                      fsdp_serve)

    def verify(params, cache, state, drafts, rng):
        params = full_params(params)
        tokens, positions = state["tokens"], state["positions"]
        x = jnp.concatenate([tokens[:, None], drafts], axis=1)   # (B, C)
        pos = positions[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        with autotune.using(ar_tuner):
            logits, cache2 = prefill_chunk(
                params, cache, x, pos, ap, serve_ctx,
                scan_layers=scan_layers, layer_map=layer_map,
                attn_chunk=attn_chunk, return_logits=True)
        finite = _finite_slots(logits, serve_ctx)
        tgt, match = _spec_targets(logits, drafts, serve_ctx, cfg, rng,
                                   temperature, top_k)
        prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)    # (B, k)
        accepted = prefix.sum(axis=1)                            # in [0, k]
        idx = jnp.arange(C, dtype=jnp.int32)[None, :]
        drafts_pad = jnp.pad(drafts, ((0, 0), (0, 1)))
        # j < accepted: the (verified) draft; j == accepted: correction or
        # bonus.  Greedy drafts equal tgt where accepted, so either branch
        # is the plain greedy token there.
        emitted = jnp.where(idx < accepted[:, None], drafts_pad, tgt)
        return emitted, accepted.astype(jnp.int32), finite, cache2

    if mesh is None:
        return BuiltStep(fn=verify, in_specs=None, out_specs=None,
                         mesh=None, ctx=serve_ctx, donate_argnums=(1,))
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, ef_sites=ef_sites_for(serve_ctx, ap.cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    sspec = {"tokens": P(None), "positions": P(None),
             "remaining": P(None), "active": P(None)}
    in_specs = (pspecs, cspecs, sspec, P(None, None), P(None))
    out_specs = (P(None, None), P(None), P(None), cspecs)
    fn = shard_map(verify, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx, donate_argnums=(1,))


def build_prefill_only_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                            prompt_len: int, scan_layers: bool = True,
                            fsdp_serve: bool = False,
                            temperature: float = 0.0, top_k: int = 0,
                            ar_table=None) -> BuiltStep:
    """Prefill-pool step for disaggregated serving: run one request's
    prompt, sample the first token, and hand the per-layer K/V states
    straight back — no decode loop, no persistent serving cache.

    (params, prompt (1, prompt_len), rng) -> (first_token (1,),
    k (L, 1, prompt_len, U, hd), v (same)).

    The returned states are the raw material of the KV handoff
    (``inference.kv_cache.export-from-states`` -> :class:`KVBundle` ->
    decode-pool splice); on a mesh the kv-slot dim comes back TP-gathered
    per ``sharding.kv_states_spec`` so the host sees the full global slot
    layout.  ``prompt_len`` is static — one executable per distinct
    length, cached by the pool (the chunked-admission path avoids the
    recompiles; dense families only either way).  Because this step runs
    on the *prefill pool's* mesh with its own ``ar_table``, its per-layer
    all-reduces dispatch on prompt-sized messages — the bandwidth-bound
    end of the paper's strategy crossover — independent of the decode
    pool's operating point.
    """
    cfg = ap.cfg
    if cfg.family != "dense":
        raise ValueError("disaggregated prefill is attention-only: dense "
                         f"families only, not {cfg.family!r}")
    ar_tuner = autotune.tuner_for(ar_table)
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    pspecs, _, layer_map, full_params = _serve_params(ap, serve_ctx, mesh,
                                                      fsdp_serve)

    def prefill_only(params, prompt, rng):
        params = full_params(params)
        with autotune.using(ar_tuner):
            logits, _, states, _ = forward_lm(
                params, prompt, ap, serve_ctx, scan_layers=scan_layers,
                collect_state=True, layer_map=layer_map,
                chunk=1024 if prompt_len > 8192 else 0)
        nxt = _sample_next(logits[:, -1], serve_ctx, cfg, rng,
                           temperature, top_k)
        return nxt, states["k"], states["v"]

    if mesh is None:
        return BuiltStep(fn=prefill_only, in_specs=None, out_specs=None,
                         mesh=None, ctx=serve_ctx)
    kv_spec = shd.kv_states_spec(serve_ctx)
    in_specs = (pspecs, P(None, None), P(None))
    out_specs = (P(None), kv_spec, kv_spec)
    fn = shard_map(prefill_only, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx)


def build_kv_splice_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                         n_tokens: int, s_max: int, slots: int = 1,
                         block_size: int = 0,
                         n_blocks: Optional[int] = None,
                         fsdp_serve: bool = False) -> BuiltStep:
    """Decode-pool import of a KV handoff: splice one request's K/V states
    into cache row ``slot`` on device.

    (cache, k (L, 1, n_tokens, U, hd), v, slot) -> cache'.

    The inbound states must already be in THIS pool's global slot layout
    (``kv_cache.heads_to_slots`` re-expands the canonical bundle); the
    splice itself is the shared ``seed_cache`` path, so dense targets take
    a ``dynamic_update_slice`` and paged targets scatter through the
    block table — the caller must have grown the slot's block list to
    cover ``[0, n_tokens + 1)`` first (the +1 covers the first decode
    write, same as admission).  ``n_tokens`` is static: one executable
    per distinct handoff length, cached by the batcher.
    """
    del n_tokens  # static via the bundle's shape; named for the cache key
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)

    def splice(cache, k, v, slot):
        return seed_cache(cache, {"k": k, "v": v}, slot=slot)

    if mesh is None:
        return BuiltStep(fn=splice, in_specs=None, out_specs=None,
                         mesh=None, ctx=serve_ctx, donate_argnums=(0,))
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, ef_sites=ef_sites_for(serve_ctx, ap.cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    kv_spec = shd.kv_states_spec(serve_ctx)
    in_specs = (cspecs, kv_spec, kv_spec, P())
    fn = shard_map(splice, mesh=mesh, in_specs=in_specs, out_specs=cspecs,
                   check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=cspecs, mesh=mesh,
                     ctx=serve_ctx, donate_argnums=(0,))


def build_admit_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *, s_max: int,
                     prompt_len: int, slots: int = 1,
                     scan_layers: bool = True, fsdp_serve: bool = False,
                     temperature: float = 0.0, top_k: int = 0,
                     block_size: int = 0, n_blocks: Optional[int] = None,
                     kv_quant: bool = False,
                     ar_table: Optional[str] = None) -> BuiltStep:
    """Full-prefill admission: run one request's prompt, splice its KV /
    recurrent states into cache row ``slot`` on device, sample the first
    token.  (params, cache, prompt (1, prompt_len), slot, rng) ->
    (first_token (1,), cache').

    ``prompt_len`` is static — one executable per distinct length, cached
    by the batcher.  Length-bucketing via padding is NOT safe here in
    general (recurrent states advance over pads, MoE routing capacity is
    load-dependent), which is exactly why this path exists for every
    family; attention-only (dense) families should use
    :func:`build_admit_chunk_step` instead to avoid per-length recompiles.
    """
    cfg = ap.cfg
    ar_tuner = autotune.tuner_for(ar_table)
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    pspecs, _, layer_map, full_params = _serve_params(ap, serve_ctx, mesh,
                                                      fsdp_serve)

    def admit(params, cache, prompt, slot, rng):
        params = full_params(params)
        with autotune.using(ar_tuner):
            logits, _, states, _ = forward_lm(
                params, prompt, ap, serve_ctx, scan_layers=scan_layers,
                collect_state=True, layer_map=layer_map,
                chunk=1024 if prompt_len > 8192 else 0)
        cache2 = seed_cache(cache, states, slot=slot)
        nxt = _sample_next(logits[:, -1], serve_ctx, cfg, rng,
                           temperature, top_k)
        return nxt, cache2

    if mesh is None:
        return BuiltStep(fn=admit, in_specs=None, out_specs=None,
                         mesh=None, ctx=serve_ctx, donate_argnums=(1,))
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, kv_quant=kv_quant,
        ef_sites=ef_sites_for(serve_ctx, ap.cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    in_specs = (pspecs, cspecs, P(None, None), P(), P(None))
    out_specs = (P(None), cspecs)
    fn = shard_map(admit, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx, donate_argnums=(1,))


def build_admit_chunk_step(ap: ArchPlan, ctx: ParallelCtx, mesh, *,
                           chunk: int, s_max: int, slots: int = 1,
                           scan_layers: bool = True,
                           fsdp_serve: bool = False,
                           temperature: float = 0.0, top_k: int = 0,
                           block_size: int = 0,
                           n_blocks: Optional[int] = None,
                           sample: bool = True,
                           kv_quant: bool = False,
                           ar_table: Optional[str] = None) -> BuiltStep:
    """Chunked-prefill admission: feed the prompt through in fixed-size
    chunks of ``chunk`` tokens, writing K/V into cache row ``slot`` as it
    goes — one executable for every prompt length (trailing chunk is
    padded; see layers.attention_chunk_step for why pads are safe).

    With ``sample=True`` (the *final*-chunk executable):
    (params, cache, tokens (1, chunk), positions (1, chunk), slot,
    last_idx, rng) -> (token (1,), cache') — the sampled continuation of
    the token at in-chunk index ``last_idx``.  With ``sample=False`` (the
    intermediate-chunk executable) the vocab head, sampling, and their TP
    collectives are skipped entirely and the step returns just ``cache'``.
    Dense families only (see transformer.prefill_chunk).
    """
    cfg = ap.cfg
    if kv_quant:
        raise ValueError("chunked admission rides prefill_chunk, which "
                         "cannot re-read an int8 KV cache mid-prompt: "
                         "kv_quant needs full-prefill admission")
    ar_tuner = autotune.tuner_for(ar_table)
    serve_ctx = _serve_ctx(ctx, mesh, fsdp_serve)
    pspecs, _, layer_map, full_params = _serve_params(ap, serve_ctx, mesh,
                                                      fsdp_serve)

    def admit_chunk(params, cache, tokens, positions, slot, last_idx, rng):
        params = full_params(params)
        with autotune.using(ar_tuner):
            logits, cache2 = prefill_chunk(
                params, cache, tokens, positions, ap, serve_ctx,
                scan_layers=scan_layers, layer_map=layer_map, slot=slot,
                return_logits=sample)
        if not sample:
            return cache2
        last = lax.dynamic_index_in_dim(logits, last_idx, 1,
                                        keepdims=False)   # (1, V_loc)
        nxt = _sample_next(last, serve_ctx, cfg, rng, temperature, top_k)
        return nxt, cache2

    if mesh is None:
        return BuiltStep(fn=admit_chunk, in_specs=None, out_specs=None,
                         mesh=None, ctx=serve_ctx, donate_argnums=(1,))
    cache_t = jax.eval_shape(lambda: init_cache(
        ap, slots, s_max, local=False, block_size=block_size,
        n_blocks=n_blocks, ef_sites=ef_sites_for(serve_ctx, ap.cfg)))
    cspecs = shd.cache_spec(cache_t, serve_ctx)
    in_specs = (pspecs, cspecs, P(None, None), P(None, None), P(), P(),
                P(None))
    out_specs = (P(None), cspecs) if sample else cspecs
    fn = shard_map(admit_chunk, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return BuiltStep(fn=fn, in_specs=in_specs, out_specs=out_specs,
                     mesh=mesh, ctx=serve_ctx, donate_argnums=(1,))


__all__ = ["build_train_step", "build_decode_step", "build_prefill",
           "build_cache_init", "build_serve_step", "build_admit_step",
           "build_admit_chunk_step", "build_spec_verify_step",
           "build_prefill_only_step", "build_kv_splice_step", "BuiltStep"]
