"""Mixture-of-Experts FFN with expert parallelism.

Two execution paths, selected by token count (mirroring real inference
engines and the paper's Sec. 5.2.4 TP x EP deployments):

* ``dispatch`` (train / prefill): sort-based capacity dispatch +
  ``lax.all_to_all`` over the EP axes.  Tokens are routed to the devices
  owning their experts; capacity overflow drops tokens (standard
  capacity-factor semantics, reported via aux stats).
* ``dense`` (decode): token counts are tiny (B x 1), so every device runs its
  local experts on *all* tokens, masks by the router's top-k gates, and the
  combine is a TP all-reduce — which routes decode MoE traffic through the
  paper's optimized collective.

Experts are sharded over the EP axes (== the TP "model" axis); attention and
router stay TP/replicated, matching the paper's Qwen3-235B deployment.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.pcontext import ParallelCtx
from ..core import hierarchical as hier
from .common import ModelConfig, dense_init, split_keys
from .layers import tp_rank

Params = Dict[str, jax.Array]


def init_moe(key, cfg: ModelConfig) -> Params:
    """Experts in global layout (E, ...); sharded on the expert axis."""
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    kr, kg, ku, kd = split_keys(key, 4)
    return {
        "router": dense_init(kr, (d, e), d, jnp.float32),
        "wg": dense_init(kg, (e, d, fe), d, cfg.dtype),
        "wu": dense_init(ku, (e, d, fe), d, cfg.dtype),
        "wd": dense_init(kd, (e, fe, d), fe, cfg.dtype),
    }


def _router(p: Params, x2d: jax.Array, cfg: ModelConfig):
    """x2d: (T, D) -> gates (T, K) normalized, idx (T, K), probs (T, E)."""
    logits = (x2d.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def aux_load_balance(probs: jax.Array, idx: jax.Array,
                     cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing loss: E * sum_e f_e * p_e."""
    e = cfg.n_experts
    f = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=(0, 1))
    pbar = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pbar) * cfg.top_k


def _expert_ffn(p: Params, x: jax.Array) -> jax.Array:
    """x: (E_loc, C, D) -> (E_loc, C, D); batched gated-SiLU experts."""
    a = jnp.einsum("ecd,edf->ecf", x, p["wg"])
    b = jnp.einsum("ecd,edf->ecf", x, p["wu"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(a) * b, p["wd"])


def moe_ffn_dispatch(p: Params, x: jax.Array, cfg: ModelConfig,
                     ctx: ParallelCtx) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch with EP all-to-all.

    x: (B, S, D) local tokens (sequence-sharded under SP).  Returns
    (out, aux_loss).  Per-device expert shard size E_loc = E / ep_size.
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.n_experts, cfg.top_k
    ep = hier.axes_size(ctx.ep) if ctx.ep else 1
    E_loc = E // ep
    x2 = x.reshape(T, D)
    gates, idx, probs = _router(p, x2, cfg)
    aux = aux_load_balance(probs, idx, cfg)

    cap = int(np.ceil(T * K / E * cfg.capacity_factor))
    cap = max(cap, 4)

    # Flatten (token, k) pairs and sort by expert.
    e_flat = idx.reshape(-1)                       # (T*K,)
    t_flat = jnp.repeat(jnp.arange(T), K)          # (T*K,)
    g_flat = gates.reshape(-1)
    order = jnp.argsort(e_flat)
    e_s, t_s, g_s = e_flat[order], t_flat[order], g_flat[order]
    # position of each entry within its expert group
    starts = jnp.searchsorted(e_s, jnp.arange(E), side="left")
    pos = jnp.arange(T * K) - starts[e_s]
    keep = pos < cap

    # Scatter into the (E, cap, D) send buffer.
    buf = jnp.zeros((E, cap, D), x.dtype)
    gbuf = jnp.zeros((E, cap), jnp.float32)
    src = jnp.where(keep, t_s, 0)
    be = jnp.where(keep, e_s, 0)
    bp = jnp.where(keep, pos, cap - 1)
    vals = jnp.where(keep[:, None], x2[src], 0)
    buf = buf.at[be, bp].add(vals)
    gbuf = gbuf.at[be, bp].add(jnp.where(keep, g_s, 0.0))

    if ep > 1:
        # (E, cap, D) -> send expert block i to device i.
        buf = buf.reshape(ep, E_loc * cap, D)
        buf = lax.all_to_all(buf, ctx.ep, split_axis=0, concat_axis=0,
                             tiled=True)
        # now (ep * E_loc * cap, D) grouped by source device; regroup by
        # local expert: (ep, E_loc, cap, D) -> (E_loc, ep*cap, D)
        buf = buf.reshape(ep, E_loc, cap, D).transpose(1, 0, 2, 3) \
            .reshape(E_loc, ep * cap, D)
    else:
        buf = buf.reshape(E_loc, cap, D)

    out_buf = _expert_ffn({k: v for k, v in p.items()}, buf)

    if ep > 1:
        out_buf = out_buf.reshape(E_loc, ep, cap, D).transpose(1, 0, 2, 3) \
            .reshape(ep, E_loc * cap, D)
        out_buf = lax.all_to_all(out_buf, ctx.ep, split_axis=0,
                                 concat_axis=0, tiled=True)
    out_buf = out_buf.reshape(E, cap, D)

    # Combine: gather each kept (token,k) contribution back, weighted.
    contrib = out_buf[be, bp] * (gbuf[be, bp][:, None]).astype(out_buf.dtype)
    contrib = jnp.where(keep[:, None], contrib, 0)
    out = jnp.zeros((T, D), jnp.float32).at[t_s].add(
        contrib.astype(jnp.float32))
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_ffn_dense(p: Params, x: jax.Array, cfg: ModelConfig,
                  ctx: ParallelCtx) -> jax.Array:
    """Decode path: all local experts on all tokens, gate-masked.

    x: (B, S, D) *replicated* over TP.  Returns the TP-partial combine (the
    caller's tp_all_reduce completes it — the paper's collective).
    """
    B, S, D = x.shape
    T = B * S
    E = cfg.n_experts
    ep = hier.axes_size(ctx.ep) if ctx.ep else 1
    E_loc = E // ep
    x2 = x.reshape(T, D)
    gates, idx, _ = _router(p, x2, cfg)
    # dense per-token per-local-expert weights (T, E_loc)
    w_full = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], idx].add(gates)
    e0 = tp_rank(ctx.replace(tp_slow=(), tp_fast=ctx.ep)) * E_loc if ctx.ep \
        else 0
    w_loc = lax.dynamic_slice_in_dim(w_full, e0, E_loc, axis=1) if ctx.ep \
        else w_full
    xe = jnp.broadcast_to(x2[None], (E_loc, T, D))
    ye = _expert_ffn(p, xe)                       # (E_loc, T, D)
    out = jnp.einsum("etd,te->td", ye.astype(jnp.float32), w_loc)
    return out.reshape(B, S, D).astype(x.dtype)


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig, ctx: ParallelCtx,
            *, decode: bool) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Returns (output, aux_loss_or_None).

    decode=True  -> dense path, output is TP-PARTIAL (reduce at call site).
    decode=False -> dispatch path, output is complete (all-to-all combined).
    """
    if decode:
        return moe_ffn_dense(p, x, cfg, ctx), None
    out, aux = moe_ffn_dispatch(p, x, cfg, ctx)
    return out, aux


__all__ = ["init_moe", "moe_ffn", "moe_ffn_dispatch", "moe_ffn_dense",
           "aux_load_balance"]
