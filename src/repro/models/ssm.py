"""Selective-state-space (Mamba-style) mixer, used by the hymba hybrid blocks.

Mamba2-flavoured projections (x, z, B, C, dt all projected from the block
input) so that TP sharding is clean: x/z/dt are d_inner-sharded, B/C are tiny
and computed replicated.  The sequence recurrence

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t * x_t
    y_t = C_t . h_t + D_skip * x_t

is evaluated with ``lax.associative_scan`` for full sequences (train /
prefill) and as a single-step update for decode.  The output projection is
row-sharded, so the mixer returns a TP-partial sum like every other mixer.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.pcontext import ParallelCtx
from .common import ModelConfig, dense_init, split_keys

Params = Dict[str, jax.Array]


def init_ssm(key, cfg: ModelConfig) -> Params:
    d, di, s = cfg.d_model, cfg.d_inner, cfg.ssm_state
    kx, kz, kbc, kdt, kcv, ko = split_keys(key, 6)
    # A initialized to -[1..state] per channel (S4D-real), stored as log.
    a = jnp.tile(jnp.arange(1, s + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        # x and z projections kept as separate leaves so the d_inner axis
        # TP-shards cleanly (a fused (d, 2*di) matrix would interleave
        # shards of x and z).
        "w_x": dense_init(kx, (d, di), d, cfg.dtype),
        "w_z": dense_init(kz, (d, di), d, cfg.dtype),
        "w_bc": dense_init(kbc, (d, 2 * s), d, cfg.dtype),
        "w_dt": dense_init(kdt, (d, di), d, cfg.dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "conv_w": dense_init(kcv, (cfg.d_conv, di), cfg.d_conv, cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "A_log": jnp.log(a),                       # (di, s) f32
        "D_skip": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ko, (di, d), di, cfg.dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, T, C); w: (K, C).  init_state: (B, K-1, C)
    prepended history (zeros if None)."""
    K = w.shape[0]
    B, T, C = x.shape
    if init_state is None:
        init_state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + xp[:, i:i + T, :] * w[i][None, None, :]
    return out + b[None, None, :]


def _ssd_inputs(p: Params, h: jax.Array, cfg: ModelConfig):
    """Common projections.  h: (B,T,D) -> x,z:(B,T,Ci), bc:(B,T,2s), dt:(B,T,Ci)."""
    x = jnp.einsum("btd,de->bte", h, p["w_x"])
    z = jnp.einsum("btd,de->bte", h, p["w_z"])
    bc = jnp.einsum("btd,de->bte", h, p["w_bc"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btd,de->bte", h, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"][None, None, :])
    return x, z, bc, dt


def ssm_mixer(p: Params, h: jax.Array, cfg: ModelConfig, ctx: ParallelCtx,
              state: Optional[Dict[str, jax.Array]] = None,
              return_state: bool = False):
    """Full-sequence selective scan.  Returns TP-partial (B,T,D) output
    (and the final recurrent state when ``return_state``)."""
    B, T, D = h.shape
    s = cfg.ssm_state
    x_in, z, bc, dt = _ssd_inputs(p, h, cfg)
    conv_init = state["conv"] if state is not None else None
    x = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"], conv_init))
    xf = x.astype(jnp.float32)
    Bm, Cm = bc[..., :s], bc[..., s:]                  # (B,T,s)
    A = -jnp.exp(p["A_log"])                           # (Ci,s)
    decay = jnp.exp(dt[..., None] * A[None, None])     # (B,T,Ci,s)
    drive = (dt * xf)[..., None] * Bm[:, :, None, :]   # (B,T,Ci,s)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    h0 = state["ssm"] if state is not None else None
    if h0 is not None:
        # fold the carried-in state into the first step's drive
        drive = drive.at[:, 0].add(decay[:, 0] * h0)
    a_c, b_c = lax.associative_scan(combine, (decay, drive), axis=1)
    hs = b_c                                           # (B,T,Ci,s)
    y = jnp.einsum("btcs,bts->btc", hs, Cm)
    y = y + p["D_skip"][None, None, :] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
    out = jnp.einsum("btc,cd->btd", y, p["w_out"])
    if return_state:
        new_state = {
            "conv": jnp.concatenate(
                [state["conv"] if state is not None else
                 jnp.zeros((B, cfg.d_conv - 1, x_in.shape[-1]), x_in.dtype),
                 x_in], axis=1)[:, -(cfg.d_conv - 1):, :],
            "ssm": hs[:, -1],
        }
        return out, new_state
    return out


def ssm_step(p: Params, h: jax.Array, state: Dict[str, jax.Array],
             cfg: ModelConfig, ctx: ParallelCtx
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode.  h: (B,1,D); state: conv (B,K-1,Ci), ssm (B,Ci,s)."""
    s = cfg.ssm_state
    x, z, bc, dt = _ssd_inputs(p, h, cfg)
    # conv update
    hist = jnp.concatenate([state["conv"], x], axis=1)     # (B,K,Ci)
    xc = jnp.einsum("bkc,kc->bc", hist, p["conv_w"]) + p["conv_b"][None]
    xc = jax.nn.silu(xc)[:, None, :]                       # (B,1,Ci)
    new_conv = hist[:, 1:]
    xf = xc.astype(jnp.float32)
    Bm, Cm = bc[..., :s], bc[..., s:]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt[..., None] * A[None, None])[:, 0]   # (B,Ci,s)
    drive = (dt * xf)[..., None][:, 0] * Bm[:, 0, None, :]
    new_ssm = decay * state["ssm"] + drive
    y = jnp.einsum("bcs,bs->bc", new_ssm, Cm[:, 0])
    y = y + p["D_skip"][None] * xf[:, 0]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32)))[:, None, :]
    out = jnp.einsum("btc,cd->btd", y.astype(h.dtype), p["w_out"])
    return out, {"conv": new_conv, "ssm": new_ssm}


def init_ssm_state(cfg: ModelConfig, batch: int, d_inner_local: int,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_inner_local), dtype),
        "ssm": jnp.zeros((batch, d_inner_local, cfg.ssm_state), jnp.float32),
    }


__all__ = ["init_ssm", "ssm_mixer", "ssm_step", "init_ssm_state"]
