"""Composable pure-JAX model zoo for the assigned architectures."""
from .common import ModelConfig, GQAPlan, plan_gqa, pad_to
from .transformer import (ArchPlan, make_plan, init_params, init_cache,
                          ef_sites_for, forward_lm, decode_step,
                          prefill_chunk, seed_cache, encoder_forward)

__all__ = ["ModelConfig", "GQAPlan", "plan_gqa", "pad_to", "ArchPlan",
           "make_plan", "init_params", "init_cache", "ef_sites_for",
           "forward_lm", "decode_step", "prefill_chunk", "seed_cache",
           "encoder_forward"]
