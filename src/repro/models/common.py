"""Shared model-definition machinery: configs, init helpers, and the GQA
head-padding planner that makes any (n_heads, n_kv_heads) pair TP-shardable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # SSM (mamba-style; used by hybrid hymba)
    ssm_state: int = 0
    d_inner: int = 0
    d_conv: int = 4
    dt_rank: int = 0
    # RWKV6
    rwkv_head_dim: int = 64
    decay_lora: int = 64
    # attention details
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention
    rope_theta: float = 1.0e4
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0                 # precomputed frame embeddings length
    # VLM (pixtral): patches arrive pre-embedded (frontend stub per task spec)
    n_patches: int = 0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1.0e-5
    dtype: Any = jnp.bfloat16
    max_seq: int = 32768

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        if self.family not in ("ssm",):
            assert self.n_heads % max(self.n_kv_heads, 1) == 0, \
                f"{self.name}: q heads must be a multiple of kv heads"

    # -- derived -----------------------------------------------------------
    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def vocab_padded(self, tp: int) -> int:
        return pad_to(self.vocab_size, tp)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline cross-checks)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if not self.attn_free:
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            per_layer += d * (hq + 2 * hkv) + hq * d  # qkvo
        if self.family == "ssm":   # rwkv6 time-mix + channel-mix
            per_layer += 4 * d * d + d * self.decay_lora * 2
            per_layer += d * f + f * d + d * d
        elif self.is_moe:
            per_layer += d * self.n_experts  # router
            per_layer += self.n_experts * 3 * d * self.d_ff_expert
        else:
            mult = 3 if self.act == "swiglu" else 2
            per_layer += mult * d * f
        if self.family == "hybrid":  # mamba branch (hymba)
            di, s = self.d_inner, self.ssm_state
            per_layer += d * 2 * di + di * (self.dt_rank + 2 * s) \
                + self.dt_rank * di + di * s + di + di * d
        if self.enc_layers:  # whisper: decoder cross-attention ...
            hq = self.n_heads * self.head_dim
            hkv = self.n_kv_heads * self.head_dim
            per_layer += d * (hq + 2 * hkv) + hq * d
        n += L * per_layer
        if self.enc_layers:  # ... plus encoder (attention + gelu mlp)
            hq = self.n_heads * self.head_dim
            n += self.enc_layers * (4 * d * hq + 2 * d * f)
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) \
            * 3 * self.d_model * self.d_ff_expert
        return full - inactive


def pad_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# GQA head-padding planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GQAPlan:
    """Slot layout making an arbitrary (n_q, n_kv) GQA TP-shardable.

    Each of ``tp`` devices gets ``u`` kv slots and ``u*g`` q slots; q slot
    ``(s, j)`` (kv slot s, j < g) attends to kv slot ``s``.  Original heads
    are packed into slots unit-by-unit (a unit = one kv head + up to ``g`` of
    its q heads); kv heads whose q heads span several units are *replicated*.
    Dead slots carry zero weights and are masked in the layer.  ``g`` is
    chosen to minimize padded-FLOPs overhead.
    """

    tp: int
    n_q: int
    n_kv: int
    g: int                 # q slots per kv slot
    u: int                 # kv slots per device
    q_map: Tuple[int, ...]   # len tp*u*g, original q-head idx or -1
    kv_map: Tuple[int, ...]  # len tp*u, original kv-head idx or -1

    @property
    def q_slots(self) -> int:
        return self.tp * self.u * self.g

    @property
    def kv_slots(self) -> int:
        return self.tp * self.u

    @property
    def q_slots_local(self) -> int:
        return self.u * self.g

    @property
    def kv_slots_local(self) -> int:
        return self.u

    @property
    def flops_overhead(self) -> float:
        """padded q slots / live q heads (>= 1)."""
        return self.q_slots / self.n_q

    def q_mask(self) -> np.ndarray:
        return (np.asarray(self.q_map) >= 0).astype(np.float32)


def plan_gqa(n_q: int, n_kv: int, tp: int) -> GQAPlan:
    q_per_kv = n_q // n_kv
    assert n_q == n_kv * q_per_kv
    best = None
    for g in range(1, q_per_kv + 1):
        units = n_kv * math.ceil(q_per_kv / g)
        u = math.ceil(units / tp)
        q_slots, kv_slots = tp * u * g, tp * u
        key = (q_slots, kv_slots)
        if best is None or key < best[0]:
            best = (key, g, u)
    _, g, u = best
    q_map = [-1] * (tp * u * g)
    kv_map = [-1] * (tp * u)
    # Build the unit list: (kv_head, [q heads]) chunks of size <= g.
    units = []
    for kv in range(n_kv):
        qs = list(range(kv * q_per_kv, (kv + 1) * q_per_kv))
        for c in range(0, len(qs), g):
            units.append((kv, qs[c:c + g]))
    assert len(units) <= tp * u
    for j, (kv, qs) in enumerate(units):
        dev, slot = divmod(j, u)
        kv_map[dev * u + slot] = kv
        for jj, q in enumerate(qs):
            q_map[(dev * u + slot) * g + jj] = q
    return GQAPlan(tp=tp, n_q=n_q, n_kv=n_kv, g=g, u=u,
                   q_map=tuple(q_map), kv_map=tuple(kv_map))


def place_heads(w: jax.Array, head_map, axis: int = 0) -> jax.Array:
    """Scatter per-head weights into a padded slot layout.

    ``w``: array with original head count along ``axis``; returns an array
    with ``len(head_map)`` slots along ``axis``; dead slots (map −1) zero.
    """
    head_map = np.asarray(head_map)
    w = jnp.moveaxis(w, axis, 0)
    gathered = jnp.where(
        (head_map >= 0).reshape((-1,) + (1,) * (w.ndim - 1)),
        w[np.maximum(head_map, 0)], 0.0)
    return jnp.moveaxis(gathered, 0, axis)


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...], fan_in: int,
               dtype=jnp.bfloat16) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))


__all__ = ["ModelConfig", "GQAPlan", "plan_gqa", "place_heads", "pad_to",
           "dense_init", "split_keys", "FAMILIES"]
