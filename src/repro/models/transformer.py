"""Model assembly: blocks per family, LM forward, decode step, caches.

The same code path serves every assigned architecture:

==========  ================================================================
dense       llama3.2-1b, codeqwen1.5-7b, qwen1.5-32b, mistral-large-123b
moe         dbrx-132b (16e top-4), qwen3-moe-30b-a3b (128e top-8)
hybrid      hymba-1.5b (parallel attention + mamba heads per block)
ssm         rwkv6-7b (attention-free time-mix/channel-mix)
encdec      whisper-medium (stub conv frontend -> encoder -> causal decoder
            with cross-attention)
vlm         pixtral-12b (stub ViT frontend -> dense decoder; patch embeddings
            overwrite the first n_patches positions)
==========  ================================================================

All functions run single-device (LOCAL ctx) or inside shard_map; layer
weights are stacked along a leading layer axis so the stack can be scanned
(`scan_layers=True`, small compiled HLO + realistic memory analysis) or
unrolled (`scan_layers=False`, exact `cost_analysis` FLOP counting for the
roofline).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.pcontext import ParallelCtx
from ..core import hierarchical as hier
from ..core import overlap as ov
from .common import ModelConfig, GQAPlan, plan_gqa, pad_to, split_keys
from . import layers as L
from . import moe as M
from . import ssm as S
from . import rwkv as R

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Architecture plan (static per (config, tp))
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchPlan:
    cfg: ModelConfig
    tp: int
    gqa: Optional[GQAPlan]
    vocab_pad: int

    @property
    def q_mask_tbl(self) -> Optional[np.ndarray]:
        if self.gqa is None:
            return None
        m = self.gqa.q_mask().reshape(self.tp, self.gqa.q_slots_local)
        if m.min() >= 1.0:
            return None  # no dead slots, skip the mask multiply
        return m

    @property
    def d_ff_local(self) -> int:
        return self.cfg.d_ff // self.tp

    @property
    def d_inner_local(self) -> int:
        return self.cfg.d_inner // self.tp

    @property
    def rwkv_heads_local(self) -> int:
        return self.cfg.d_model // self.cfg.rwkv_head_dim // self.tp

    def flops_overhead(self) -> float:
        return self.gqa.flops_overhead if self.gqa else 1.0


def make_plan(cfg: ModelConfig, tp: int) -> ArchPlan:
    gqa = None
    if not cfg.attn_free:
        gqa = plan_gqa(cfg.n_heads, cfg.n_kv_heads, tp)
    for dim, name in ((cfg.d_model, "d_model"), (cfg.d_ff, "d_ff")):
        if cfg.family != "moe" and dim % tp:
            raise ValueError(f"{cfg.name}: {name}={dim} not divisible by tp={tp}")
    return ArchPlan(cfg=cfg, tp=tp, gqa=gqa, vocab_pad=pad_to(cfg.vocab_size, tp))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _init_block(key, ap: ArchPlan) -> Params:
    cfg = ap.cfg
    ks = split_keys(key, 8)
    if cfg.family == "ssm":
        return {"ln1": L.init_norm(cfg), "tm": R.init_rwkv_time_mix(ks[0], cfg),
                "ln2": L.init_norm(cfg), "cm": R.init_rwkv_channel_mix(ks[1], cfg)}
    p: Params = {"ln1": L.init_norm(cfg),
                 "attn": L.init_attention(ks[0], cfg, ap.gqa),
                 "ln2": L.init_norm(cfg)}
    if cfg.family == "hybrid":
        p["ssm"] = S.init_ssm(ks[1], cfg)
        p["beta"] = jnp.ones((2,), jnp.float32)
        p["mlp"] = L.init_mlp(ks[2], cfg)
    elif cfg.is_moe:
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    if cfg.enc_layers:  # whisper decoder block: add cross-attention
        p["ln_x"] = L.init_norm(cfg)
        p["xattn"] = L.init_attention(ks[3], cfg, ap.gqa)
    return p


def _init_enc_block(key, ap: ArchPlan) -> Params:
    cfg = ap.cfg
    k1, k2 = split_keys(key, 2)
    return {"ln1": L.init_norm(cfg), "attn": L.init_attention(k1, cfg, ap.gqa),
            "ln2": L.init_norm(cfg), "mlp": L.init_mlp(k2, cfg)}


def _stack(blocks):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(key, ap: ArchPlan) -> Params:
    cfg = ap.cfg
    keys = split_keys(key, cfg.n_layers + cfg.enc_layers + 2)
    p: Params = {
        "embed": L.init_embed(keys[0], cfg, ap.vocab_pad),
        "blocks": _stack([_init_block(keys[1 + i], ap)
                          for i in range(cfg.n_layers)]),
        "final_norm": L.init_norm(cfg),
    }
    if cfg.enc_layers:
        off = 1 + cfg.n_layers
        p["enc_blocks"] = _stack([_init_enc_block(keys[off + i], ap)
                                  for i in range(cfg.enc_layers)])
        p["enc_norm"] = L.init_norm(cfg)
    return p


# ---------------------------------------------------------------------------
# Block forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _seq_parallel_active(ctx: ParallelCtx, cfg: ModelConfig,
                         n_tokens: int, seq_len: int,
                         explicit: Optional[bool]) -> bool:
    """Trace-time resolution of the sequence-parallel residual layout.

    ``explicit`` (a caller's ``sp=`` argument, e.g. the training step's)
    overrides the ``ctx.seq_parallel`` knob.  Either way SP only engages
    when fast TP axes exist and divide the sequence (psum_scatter tiling
    needs ``seq_len % fast == 0``; indivisible call sites fall back to the
    fused path, which is numerically identical).  Resolved from the knob,
    SP additionally requires a non-recurrent family (recurrences need the
    full sequence — the same gate ``build_train_step`` applies), and
    ``"auto"`` asks the active autotuner with this call site's residual
    message size — builders trace inside ``autotune.using(ar_table)``, so
    each executable dispatches against its own table (DESIGN.md §10).
    """
    if not ctx.tp_fast:
        return False
    fast = hier.axes_size(ctx.tp_fast)
    if fast <= 1 or seq_len % fast:
        return False
    if explicit is not None:
        return bool(explicit)
    mode = ctx.seq_parallel
    if mode == "off" or cfg.family in ("ssm", "hybrid"):
        return False
    if mode == "on":
        return True
    from ..core import autotune
    itemsize = jnp.dtype(cfg.dtype).itemsize
    slow = hier.axes_size(ctx.tp_slow) if ctx.tp_slow else 1
    return autotune.resolve_sp(n_tokens * cfg.d_model * itemsize, fast,
                               slow, jnp.dtype(cfg.dtype).name)


def _residual(x, partial, ctx: ParallelCtx, sp: bool):
    if sp:
        return x + hier.tp_reduce_scatter(partial, ctx, dim=1)
    return x + hier.tp_all_reduce(partial, ctx, scatter_dim=-1)


def _use_overlap(ctx: ParallelCtx) -> bool:
    """Route row-parallel output projections through the overlapped
    collective-matmul (the tentpole decode optimization)."""
    return ctx.overlap_matmul and ctx.has_tp


def _residual_proj(x, lhs, w, spec: str, ctx: ParallelCtx, sp: bool,
                   ef=None):
    """Residual add of projection + TP reduction, overlapped when enabled.

    ``lhs`` is the pre-projection activation, ``w`` the row-sharded weight
    with output features last; numerically identical to
    ``_residual(x, einsum(spec, lhs, w), ctx, sp)``.

    ``ef``: error-feedback residual for the quantized all-reduce, shaped
    like the projection output.  When given the return value is
    ``(x, new_ef)`` — same contract as ``tp_all_reduce`` (fp paths hand
    ``ef`` back untouched).  Decode-only: the SP branch never sees it."""
    if _use_overlap(ctx):
        if sp:
            return x + ov.collective_matmul_reduce_scatter(
                lhs, w, ctx, dim=1, spec=spec)
        if ef is not None:
            y, ef2 = ov.collective_matmul(lhs, w, ctx, spec=spec, ef=ef)
            return x + y, ef2
        return x + ov.collective_matmul(lhs, w, ctx, spec=spec)
    if ef is not None:
        y, ef2 = hier.tp_all_reduce(jnp.einsum(spec, lhs, w), ctx,
                                    scatter_dim=-1, ef=ef)
        return x + y, ef2
    return _residual(x, jnp.einsum(spec, lhs, w), ctx, sp)


def _gathered(x, ctx: ParallelCtx, sp: bool):
    return hier.tp_all_gather(x, ctx, dim=1) if sp else x


def _moe_tokens(h, ctx: ParallelCtx, sp: bool):
    """MoE consumes per-device-unique tokens: under SP the shard already is;
    otherwise slice this device's sequence chunk (no comm)."""
    if sp or not ctx.has_tp:
        return h
    tp = hier.axes_size(ctx.tp_axes)
    s_loc = h.shape[1] // tp
    start = L.tp_rank(ctx) * s_loc
    return lax.dynamic_slice_in_dim(h, start, s_loc, axis=1)


def _moe_restore(out, ctx: ParallelCtx, sp: bool):
    if sp or not ctx.has_tp:
        return out
    return lax.all_gather(out, ctx.tp_axes, axis=1, tiled=True)


def block_forward(bp: Params, x, ap: ArchPlan, ctx: ParallelCtx, *,
                  positions, sp: bool, causal: bool = True,
                  enc_kv=None, chunk: int = 0,
                  collect_state: bool = False):
    """One block, full sequence.  Returns (x, aux_loss, state_or_None)."""
    cfg = ap.cfg
    aux = jnp.zeros((), jnp.float32)
    state = {}
    if cfg.family == "ssm":
        h = _gathered(L.apply_norm(x, bp["ln1"], cfg), ctx, sp)
        if collect_state:
            tm, st = R.rwkv_time_mix(bp["tm"], h, cfg, ctx, return_state=True)
            state.update(st)
        else:
            tm = R.rwkv_time_mix(bp["tm"], h, cfg, ctx)
        x = _residual(x, tm, ctx, sp)
        h2 = _gathered(L.apply_norm(x, bp["ln2"], cfg), ctx, sp)
        if collect_state:
            stacked, st2 = R.rwkv_channel_mix(bp["cm"], h2, cfg, ctx,
                                              return_state=True)
            state.update(st2)
        else:
            stacked = R.rwkv_channel_mix(bp["cm"], h2, cfg, ctx)
        if sp:
            red = hier.tp_reduce_scatter(stacked, ctx, dim=2)
        else:
            red = hier.tp_all_reduce(stacked, ctx, scatter_dim=-1)
        x = x + jax.nn.sigmoid(red[1].astype(jnp.float32)).astype(x.dtype) \
            * red[0]
        return x, aux, (state or None)

    h = _gathered(L.apply_norm(x, bp["ln1"], cfg), ctx, sp)
    # hybrid mixes attn + ssm partials before reducing, so the projection
    # cannot be fused with the reduction there — overlap dense-ish only.
    attn_ov = _use_overlap(ctx) and cfg.family != "hybrid"
    attn_out, kv = _attention_with_kv(bp["attn"], h, ap, ctx,
                                      positions=positions, causal=causal,
                                      chunk=chunk, project=not attn_ov)
    if collect_state:
        state["k"], state["v"] = kv
    if cfg.family == "hybrid":
        if collect_state:
            ssm_out, st = S.ssm_mixer(bp["ssm"], h, cfg, ctx,
                                      return_state=True)
            state.update(st)
        else:
            ssm_out = S.ssm_mixer(bp["ssm"], h, cfg, ctx)
        beta = bp["beta"].astype(x.dtype)
        mix = beta[0] * attn_out + beta[1] * ssm_out
        x = _residual(x, mix, ctx, sp)
    elif attn_ov:
        x = _residual_proj(x, attn_out, bp["attn"]["wo"], "bsqh,qhd->bsd",
                           ctx, sp)
    else:
        x = _residual(x, attn_out, ctx, sp)

    if enc_kv is not None:
        hx = _gathered(L.apply_norm(x, bp["ln_x"], cfg), ctx, sp)
        xo = L.cross_attention(bp["xattn"], hx, enc_kv[0], enc_kv[1], cfg,
                               ap.gqa, ctx, ap.q_mask_tbl)
        x = _residual(x, xo, ctx, sp)

    h2 = L.apply_norm(x, bp["ln2"], cfg)
    if cfg.is_moe:
        toks = _moe_tokens(_gathered(h2, ctx, sp) if not sp else h2, ctx, sp)
        out, aux_l = M.moe_ffn(bp["moe"], toks, cfg, ctx, decode=False)
        if aux_l is not None:
            aux = aux + aux_l
        x = x + _moe_restore(out, ctx, sp)
    else:
        h2g = _gathered(h2, ctx, sp)
        if _use_overlap(ctx):
            x = _residual_proj(x, L.mlp_hidden(bp["mlp"], h2g, cfg),
                               L.mlp_down_w(bp["mlp"], cfg), "bsf,fd->bsd",
                               ctx, sp)
        else:
            x = _residual(x, L.mlp(bp["mlp"], h2g, cfg), ctx, sp)
    return x, aux, (state or None)


def _attention_with_kv(p, h, ap: ArchPlan, ctx, *, positions, causal, chunk,
                       project: bool = True):
    cfg = ap.cfg
    q, k, v = L._qkv(p, h, ap.gqa)
    if cfg.rope_theta > 0:
        cos, sin = L.rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = L.apply_rope(q, cos, sin)
        k = L.apply_rope(k, cos, sin)
    mask = L._mask(positions, positions, causal=causal,
                   window=cfg.sliding_window)
    o = L.attn_core(q, k, v, mask, ap.gqa.g, chunk=chunk)
    if ap.q_mask_tbl is not None:
        o = o * L.take_local(ap.q_mask_tbl, ctx)[None, None, :, None] \
            .astype(o.dtype)
    out = jnp.einsum("bsqh,qhd->bsd", o, p["wo"]) if project else o
    return out, (k, v)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------


def encoder_forward(params: Params, frames, ap: ArchPlan, ctx: ParallelCtx,
                    *, sp: bool, scan_layers: bool = True, layer_map=None):
    """frames: (B, T_enc, D) precomputed frame embeddings (frontend stub)."""
    cfg = ap.cfg
    sp = sp and bool(ctx.tp_fast) and frames.shape[1] % max(ap.tp, 1) == 0
    x = _moe_tokens(frames, ctx, sp=False) if sp else frames
    positions = jnp.arange(frames.shape[1], dtype=jnp.int32)

    def body(x, bp):
        if layer_map is not None:
            bp = layer_map(bp)
        h = _gathered(L.apply_norm(x, bp["ln1"], cfg), ctx, sp)
        ao, _ = _attention_with_kv(bp["attn"], h, ap, ctx,
                                   positions=positions, causal=False,
                                   chunk=0)
        x = _residual(x, ao, ctx, sp)
        h2 = _gathered(L.apply_norm(x, bp["ln2"], cfg), ctx, sp)
        x = _residual(x, L.mlp(bp["mlp"], h2, cfg), ctx, sp)
        return x

    if scan_layers:
        x, _ = lax.scan(lambda c, bp: (body(c, bp), None),
                        x, params["enc_blocks"])
    else:
        nl = cfg.enc_layers
        for i in range(nl):
            bp = jax.tree.map(lambda t: t[i], params["enc_blocks"])
            x = body(x, bp)
    x = L.apply_norm(x, params["enc_norm"], cfg)
    return _gathered(x, ctx, sp)


# ---------------------------------------------------------------------------
# Full-sequence LM forward (train / prefill)
# ---------------------------------------------------------------------------


def forward_lm(params: Params, tokens, ap: ArchPlan, ctx: ParallelCtx, *,
               sp: Optional[bool] = None, scan_layers: bool = True,
               patch_embeds=None, frame_embeds=None,
               collect_state: bool = False, chunk: int = 0,
               layer_map=None, enc_layer_map=None, remat: bool = False):
    """Returns (logits_local, aux_loss, states_or_None, enc_out_or_None).

    logits_local: (B, S, V_local) vocab-sharded (a sequence-parallel
    residual stream is gathered back to full S before the vocab head).
    states: per-layer pytree stacked on a leading layer axis (prefill cache
    seeds) when ``collect_state``.

    ``sp=None`` (serve-side prefill builders) resolves the sequence-
    parallel layout from ``ctx.seq_parallel`` per call site; an explicit
    bool (the training step) forces it, subject to the divisibility guard
    (see :func:`_seq_parallel_active`).
    """
    cfg = ap.cfg
    B, Sq = tokens.shape
    sp_active = _seq_parallel_active(ctx, cfg, B * Sq, Sq, sp)
    if patch_embeds is None:
        x = L.embed_lookup(params["embed"], tokens, ctx, ap.vocab_pad,
                           sp=sp_active)
    else:
        x = L.embed_lookup(params["embed"], tokens, ctx, ap.vocab_pad,
                           sp=False)
        x = lax.dynamic_update_slice(
            x, patch_embeds.astype(x.dtype), (0, 0, 0))
        if sp_active:
            x = _moe_tokens(x, ctx, sp=False)  # free slice to seq-shards
    enc_out = None
    enc_kv_all = None
    if cfg.enc_layers:
        enc_out = encoder_forward(params, frame_embeds, ap, ctx,
                                  sp=sp_active, scan_layers=scan_layers,
                                  layer_map=enc_layer_map)
        # Precompute per-layer cross K/V once (also the decode cache seed).
        def xkv(bp):
            return L.cross_kv(bp["xattn"], enc_out)
        enc_kv_all = jax.vmap(xkv)(params["blocks"]) if scan_layers else None
    positions = jnp.arange(Sq, dtype=jnp.int32)
    sp = sp_active
    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, layer_in):
        x, aux = carry
        bp, ekv = layer_in
        if layer_map is not None:
            bp = layer_map(bp)
        x, a, st = block_forward(bp, x, ap, ctx, positions=positions,
                                 sp=sp, causal=True, enc_kv=ekv,
                                 chunk=chunk, collect_state=collect_state)
        return (x, aux + a), st

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    if scan_layers:
        xs = (params["blocks"],
              enc_kv_all if cfg.enc_layers else None)
        (x, aux), states = lax.scan(body, (x, aux0), xs)
    else:
        states_list = []
        aux = aux0
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda t: t[i], params["blocks"])
            ekv = L.cross_kv(bp["xattn"], enc_out) if cfg.enc_layers \
                else None
            (x, aux), st = body((x, aux), (bp, ekv))
            if st is not None:
                states_list.append(st)
        states = _stack(states_list) if states_list else None

    x = L.apply_norm(x, params["final_norm"], cfg)
    if sp and ctx.tp_fast:
        x = hier.tp_all_gather(x, ctx, dim=1)
    logits = L.lm_logits(params["embed"], x)
    return logits, aux, states, enc_out


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def ef_sites_for(ctx: ParallelCtx, cfg) -> int:
    """Error-feedback site count for ``init_cache(..., ef_sites=...)``.

    Dense decode threads EF through its two row-parallel reductions
    (attn wo, mlp down) whenever the ctx may quantize the wire
    (``ar_quant`` forced or "auto"); recurrent/hybrid families take the
    one-shot rounding and carry no EF leaf.  Every builder of one serving
    deployment must derive the count from the same (ctx, cfg) so cache
    pytrees stay structurally identical across steps."""
    if getattr(ctx, "ar_quant", "none") == "none" or cfg.family != "dense":
        return 0
    return 2


def init_cache(ap: ArchPlan, batch: int, s_max: int,
               local: bool = True, *, kv_quant: bool = False,
               window_cache: bool = False, block_size: int = 0,
               n_blocks: Optional[int] = None,
               ef_sites: int = 0) -> Params:
    """Decode cache pytree, leading layer axis.  ``local`` shapes are
    per-device (tp already divided out); global shapes otherwise.

    kv_quant: int8 K/V payloads + per-(pos, head) bf16 scales.
    window_cache: ring buffer of size sliding_window (SWA archs only).
    block_size > 0: *paged* K/V layout — physical blocks
    (L, n_blocks, block_size, u, hd) plus an int32 logical->physical
    ``block_tbl`` (batch, s_max // block_size).  ``block_size=0`` is the
    dense (batch, s_max) layout, the bit-parity degenerate case.  When
    ``n_blocks`` is None the pool holds every slot at full length plus the
    reserved trash block 0, and the table starts as the identity mapping
    (dense-equivalent without an allocator); a smaller explicit pool starts
    all-trash and must be managed by a
    :class:`repro.inference.kv_cache.BlockAllocator`.  Paging applies to
    the self-attention K/V only; recurrent / encoder leaves are tiny,
    fixed-size per-slot states and stay batch-indexed.
    ef_sites > 0: error-feedback residual for quantized all-reduce
    (``ctx.ar_quant``) — one f32 (d_model,) state per (layer, reduction
    site, device, slot), carried as the cache leaf ``ef`` with shape
    (L, ef_sites, tp, batch, d_model) so it rides the decode scan and
    slot admission for free.  The device dim is this rank's OWN rounding
    residual (sharded over TP); dense decode has two sites per layer
    (attn wo, mlp down).
    """
    cfg = ap.cfg
    tp = 1 if local else ap.tp
    c: Params = {}
    Ldec = cfg.n_layers
    if window_cache:
        assert cfg.sliding_window > 0, "window cache needs SWA"
        s_max = min(s_max, cfg.sliding_window)
    if not cfg.attn_free:
        u = ap.gqa.u * tp if not local else ap.gqa.u
        hd = cfg.head_dim
        if cfg.family != "ssm":
            kv_dt = jnp.int8 if kv_quant else cfg.dtype
            if block_size > 0:
                assert not kv_quant and not window_cache, \
                    "paged cache is incompatible with kv_quant/window_cache"
                assert s_max % block_size == 0, (s_max, block_size)
                max_blocks = s_max // block_size
                if n_blocks is None:
                    n_blocks = batch * max_blocks + 1
                c["k"] = jnp.zeros((Ldec, n_blocks, block_size, u, hd),
                                   kv_dt)
                c["v"] = jnp.zeros((Ldec, n_blocks, block_size, u, hd),
                                   kv_dt)
                if n_blocks >= batch * max_blocks + 1:
                    c["block_tbl"] = 1 + jnp.arange(
                        batch * max_blocks,
                        dtype=jnp.int32).reshape(batch, max_blocks)
                else:
                    c["block_tbl"] = jnp.zeros((batch, max_blocks),
                                               jnp.int32)
            else:
                c["k"] = jnp.zeros((Ldec, batch, s_max, u, hd), kv_dt)
                c["v"] = jnp.zeros((Ldec, batch, s_max, u, hd), kv_dt)
            if kv_quant:
                c["k_scale"] = jnp.zeros((Ldec, batch, s_max, u),
                                         jnp.bfloat16)
                c["v_scale"] = jnp.zeros((Ldec, batch, s_max, u),
                                         jnp.bfloat16)
    if cfg.family == "hybrid":
        ci = ap.d_inner_local if local else cfg.d_inner
        c["conv"] = jnp.zeros((Ldec, batch, cfg.d_conv - 1, ci), cfg.dtype)
        c["ssm"] = jnp.zeros((Ldec, batch, ci, cfg.ssm_state), jnp.float32)
    if cfg.family == "ssm":
        hloc = ap.rwkv_heads_local if local \
            else cfg.d_model // cfg.rwkv_head_dim
        c["shift_tm"] = jnp.zeros((Ldec, batch, cfg.d_model), cfg.dtype)
        c["shift_cm"] = jnp.zeros((Ldec, batch, cfg.d_model), cfg.dtype)
        c["wkv"] = jnp.zeros((Ldec, batch, hloc, cfg.rwkv_head_dim,
                              cfg.rwkv_head_dim), jnp.float32)
    if cfg.enc_layers:
        u = ap.gqa.u if local else ap.gqa.kv_slots
        c["enc_k"] = jnp.zeros((Ldec, batch, cfg.enc_seq, u, cfg.head_dim),
                               cfg.dtype)
        c["enc_v"] = jnp.zeros((Ldec, batch, cfg.enc_seq, u, cfg.head_dim),
                               cfg.dtype)
    if ef_sites > 0:
        c["ef"] = jnp.zeros((Ldec, ef_sites, tp, batch, cfg.d_model),
                            jnp.float32)
    return c


def _paged_splice(phys, states, block_tbl, slot):
    """Scatter prefill K/V states (L, B, S, U, hd) into the physical block
    pool (L, n_blocks, bs, U, hd) through the block table.  The trailing
    partial block is zero-padded; those positions are overwritten by decode
    writes before any unmasked read (same invariant as chunk padding)."""
    Ldec, B, S, u, hd = states.shape
    bs = phys.shape[2]
    nb = -(-S // bs)
    pad = nb * bs - S
    upd = states.astype(phys.dtype)
    if pad:
        upd = jnp.pad(upd, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    upd = upd.reshape(Ldec, B, nb, bs, u, hd)
    if slot is None:
        tgt = block_tbl[:, :nb]                       # (B, nb)
        return phys.at[:, tgt].set(upd)
    row = lax.dynamic_index_in_dim(block_tbl, slot, 0, keepdims=False)
    return phys.at[:, row[:nb]].set(upd[:, 0])


def seed_cache(cache: Params, states: Params, *, slot=None,
               enc_kv: Optional[Tuple[Any, Any]] = None) -> Params:
    """Splice prefill-collected layer states into a decode cache.

    The one shared cache-splice: the engine's local prefill, the mesh
    prefill builder and the continuous batcher's admission step all route
    through here (they used to carry three copies of this logic).

    ``slot=None``: batch-wide splice (states batch == cache batch), written
    at position 0.  ``slot`` (traced scalar ok): single-request splice
    (states batch == 1) into that cache row.  Paged caches
    (``cache['block_tbl']`` present) route K/V through the block table.
    ``enc_kv``: (enc_k, enc_v) per-layer cross-attention K/V for enc-dec.
    """
    out = dict(cache)
    if "k" in cache:
        if "block_tbl" in cache:
            out["k"] = _paged_splice(cache["k"], states["k"],
                                     cache["block_tbl"], slot)
            out["v"] = _paged_splice(cache["v"], states["v"],
                                     cache["block_tbl"], slot)
        elif "k_scale" in cache:
            # int8 KV target: a raw astype would truncate the fp states and
            # leave the scale rows zero (dequant -> 0), so the splice
            # quantizes with the same per-(pos, head) scales the decode
            # write path uses (layers.attention_decode).
            idx0 = (0, 0, 0, 0, 0) if slot is None else (0, slot, 0, 0, 0)

            def _q8(t):  # (L,B,S,U,hd) -> int8 payload + bf16 (L,B,S,U)
                tf = t.astype(jnp.float32)
                sc = jnp.maximum(jnp.max(jnp.abs(tf), axis=-1) / 127.0,
                                 1e-30)
                qq = jnp.clip(jnp.round(tf / sc[..., None]), -127, 127)
                return qq.astype(jnp.int8), sc.astype(jnp.bfloat16)

            kq, ksc = _q8(states["k"])
            vq, vsc = _q8(states["v"])
            out["k"] = lax.dynamic_update_slice(cache["k"], kq, idx0)
            out["v"] = lax.dynamic_update_slice(cache["v"], vq, idx0)
            out["k_scale"] = lax.dynamic_update_slice(cache["k_scale"],
                                                      ksc, idx0[:-1])
            out["v_scale"] = lax.dynamic_update_slice(cache["v_scale"],
                                                      vsc, idx0[:-1])
        else:
            idx0 = (0, 0, 0, 0, 0) if slot is None else (0, slot, 0, 0, 0)
            out["k"] = lax.dynamic_update_slice(
                cache["k"], states["k"].astype(cache["k"].dtype), idx0)
            out["v"] = lax.dynamic_update_slice(
                cache["v"], states["v"].astype(cache["v"].dtype), idx0)
    for nm in ("conv", "ssm", "shift_tm", "shift_cm", "wkv"):
        if nm in cache:
            upd = states[nm].astype(cache[nm].dtype)
            if slot is None:
                out[nm] = upd
            else:
                idx = (0, slot) + (0,) * (cache[nm].ndim - 2)
                out[nm] = lax.dynamic_update_slice(cache[nm], upd, idx)
    if "ef" in cache:
        # A fresh request starts with no accumulated rounding residual —
        # stale EF from the slot's previous occupant must never leak into
        # the new request's reductions.
        if slot is None:
            out["ef"] = jnp.zeros_like(cache["ef"])
        else:
            zero = jnp.zeros(cache["ef"].shape[:3] + (1,)
                             + cache["ef"].shape[4:], cache["ef"].dtype)
            out["ef"] = lax.dynamic_update_slice(cache["ef"], zero,
                                                 (0, 0, 0, slot, 0))
    if enc_kv is not None and "enc_k" in cache:
        ek, ev = enc_kv
        if slot is None:
            out["enc_k"] = ek.astype(cache["enc_k"].dtype)
            out["enc_v"] = ev.astype(cache["enc_v"].dtype)
        else:
            idx = (0, slot, 0, 0, 0)
            out["enc_k"] = lax.dynamic_update_slice(
                cache["enc_k"], ek.astype(cache["enc_k"].dtype), idx)
            out["enc_v"] = lax.dynamic_update_slice(
                cache["enc_v"], ev.astype(cache["enc_v"].dtype), idx)
    return out


def block_decode(bp: Params, x, cache_l: Params, ap: ArchPlan,
                 ctx: ParallelCtx, *, positions,
                 attn_chunk=None, kv_ring: bool = False,
                 block_tbl=None) -> Tuple[Any, Params]:
    """One block, one token.  x: (B,1,D) replicated; cache_l: this layer's
    cache slice.  Returns (x, new_cache_l).  Every sublayer output is a
    TP-partial reduced by tp_all_reduce — the collective the paper targets.

    When the cache carries an ``ef`` leaf (quantized all-reduce with error
    feedback, shape (sites, 1, B, D) per layer locally), the dense attn-wo
    and mlp-down reductions consume and refresh their per-site residual;
    every other reduction site takes the one-shot rounding.
    """
    cfg = ap.cfg
    new_c: Params = {}
    ef = cache_l.get("ef") if isinstance(cache_l, dict) else None

    def _ef_in(site):
        # (sites, 1, B, D) -> (B, 1, D): the message layout of one token
        return jnp.swapaxes(ef[site], 0, 1)

    if cfg.family == "ssm":
        h = L.apply_norm(x, bp["ln1"], cfg)
        tm, st = R.rwkv_time_mix_step(
            bp["tm"], h, {"shift_tm": cache_l["shift_tm"],
                          "wkv": cache_l["wkv"]}, cfg, ctx)
        new_c["shift_tm"], new_c["wkv"] = st["shift_tm"], st["wkv"]
        x = x + hier.tp_all_reduce(tm, ctx, scatter_dim=-1)
        h2 = L.apply_norm(x, bp["ln2"], cfg)
        stacked, st2 = R.rwkv_channel_mix(
            bp["cm"], h2, cfg, ctx, state={"shift_cm": cache_l["shift_cm"]},
            return_state=True)
        new_c["shift_cm"] = st2["shift_cm"]
        red = hier.tp_all_reduce(stacked, ctx, scatter_dim=-1)
        x = x + jax.nn.sigmoid(red[1].astype(jnp.float32)).astype(x.dtype) \
            * red[0]
        if ef is not None:
            new_c["ef"] = ef
        return x, new_c

    h = L.apply_norm(x, bp["ln1"], cfg)
    kv_in = {k2: cache_l[k2] for k2 in
             ("k", "v", "k_scale", "v_scale") if k2 in cache_l}
    # Decode is the paper's regime: the wo projection + all-reduce pair
    # routes through _residual_proj (overlapped when ctx asks for it).
    # hybrid mixes attn+ssm partials pre-reduce, so it cannot fuse and
    # keeps the projected-partial form.
    hybrid = cfg.family == "hybrid"
    attn_out, kv_new = L.attention_decode(
        bp["attn"], h, kv_in, cfg, ap.gqa,
        ctx, positions=positions, q_mask_tbl=ap.q_mask_tbl,
        chunk=attn_chunk, ring=kv_ring, project=hybrid,
        block_tbl=block_tbl)
    new_c.update(kv_new)
    ef_attn = ef_mlp = None
    if hybrid:
        so, st = S.ssm_step(bp["ssm"], h, {"conv": cache_l["conv"],
                                           "ssm": cache_l["ssm"]}, cfg, ctx)
        new_c["conv"], new_c["ssm"] = st["conv"], st["ssm"]
        beta = bp["beta"].astype(x.dtype)
        x = x + hier.tp_all_reduce(beta[0] * attn_out + beta[1] * so, ctx,
                                   scatter_dim=-1)
        if ef is not None:
            ef_attn = _ef_in(0)
    elif ef is not None:
        x, ef_attn = _residual_proj(x, attn_out, bp["attn"]["wo"],
                                    "bsqh,qhd->bsd", ctx, sp=False,
                                    ef=_ef_in(0))
    else:
        x = _residual_proj(x, attn_out, bp["attn"]["wo"], "bsqh,qhd->bsd",
                           ctx, sp=False)

    if cfg.enc_layers:
        hx = L.apply_norm(x, bp["ln_x"], cfg)
        xo = L.cross_attention(bp["xattn"], hx, cache_l["enc_k"],
                               cache_l["enc_v"], cfg, ap.gqa, ctx,
                               ap.q_mask_tbl)
        x = x + hier.tp_all_reduce(xo, ctx, scatter_dim=-1)
        new_c["enc_k"], new_c["enc_v"] = cache_l["enc_k"], cache_l["enc_v"]

    h2 = L.apply_norm(x, bp["ln2"], cfg)
    if cfg.is_moe:
        out = M.moe_ffn_dense(bp["moe"], h2, cfg, ctx)
        x = x + hier.tp_all_reduce(out, ctx, scatter_dim=-1)
        if ef is not None:
            ef_mlp = _ef_in(1)
    elif ef is not None:
        x, ef_mlp = _residual_proj(x, L.mlp_hidden(bp["mlp"], h2, cfg),
                                   L.mlp_down_w(bp["mlp"], cfg),
                                   "bsf,fd->bsd", ctx, sp=False,
                                   ef=_ef_in(1))
    else:
        x = _residual_proj(x, L.mlp_hidden(bp["mlp"], h2, cfg),
                           L.mlp_down_w(bp["mlp"], cfg), "bsf,fd->bsd",
                           ctx, sp=False)
    if ef is not None:
        new_c["ef"] = jnp.stack([jnp.swapaxes(ef_attn, 0, 1),
                                 jnp.swapaxes(ef_mlp, 0, 1)])
    return x, new_c


def decode_step(params: Params, cache: Params, tokens, positions,
                ap: ArchPlan, ctx: ParallelCtx, *,
                scan_layers: bool = True, layer_map=None,
                attn_chunk=None, kv_ring: bool = False):
    """One decode step for the whole batch.

    tokens: (B,) int32; positions: (B,) write index.  Returns
    (logits_local (B, V_loc), new_cache).

    A paged cache (``cache['block_tbl']`` present) routes K/V writes/reads
    through the table; the table itself has no layer axis, so it rides
    outside the layer scan and is returned unchanged.
    """
    cfg = ap.cfg
    block_tbl = cache.get("block_tbl") if isinstance(cache, dict) else None
    if block_tbl is not None:
        cache = {k2: v for k2, v in cache.items() if k2 != "block_tbl"}
    x = L.embed_lookup(params["embed"], tokens[:, None], ctx, ap.vocab_pad)

    def body(x, inp):
        bp, cl = inp
        if layer_map is not None:
            bp = layer_map(bp)
        x, nc = block_decode(bp, x, cl, ap, ctx, positions=positions,
                             attn_chunk=attn_chunk, kv_ring=kv_ring,
                             block_tbl=block_tbl)
        return x, nc

    if scan_layers:
        x, new_cache = lax.scan(body, x, (params["blocks"], cache))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda t: t[i], params["blocks"])
            cl = jax.tree.map(lambda t: t[i], cache)
            x, nc = body(x, (bp, cl))
            ncs.append(nc)
        new_cache = _stack(ncs)

    if block_tbl is not None:
        new_cache["block_tbl"] = block_tbl
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.lm_logits(params["embed"], x)[:, 0]
    return logits, new_cache


def prefill_chunk(params: Params, cache: Params, tokens, positions,
                  ap: ArchPlan, ctx: ParallelCtx, *,
                  scan_layers: bool = True, layer_map=None,
                  attn_chunk: int = 0, slot=None,
                  return_logits: bool = True,
                  sp: Optional[bool] = None):
    """Chunked prefill: run C prompt tokens against the decode cache.

    tokens: (B, C) int32; positions: (B, C) write positions.  Returns
    (logits_local (B, C, V_loc), new_cache).  With ``slot`` (traced scalar),
    B must be 1 and the chunk is spliced into that row of a batch-wide
    cache — the continuous batcher's jitted admission step, replacing the
    host-side ``dynamic_update_slice`` round trips.
    ``return_logits=False`` skips the final norm + vocab head entirely
    (logits come back None) — intermediate chunks only feed the cache.

    ``sp`` selects the sequence-parallel residual layout (default: resolve
    from ``ctx.seq_parallel`` on this chunk's message size, like
    ``forward_lm``): the residual stream stays sharded on the chunk dim
    over the fast TP axes, the post-``wo``/post-``wd`` projections end in
    ``tp_reduce_scatter``, norms run on sequence shards, and
    ``tp_all_gather`` restores the full chunk only for the QKV / up-proj
    inputs — bitwise-equal to the fused path, with per-collective wire
    bytes halved and activations between collectives shrunk by the
    fast-axis size (DESIGN.md §10).  K/V writes always see the full
    chunk, so the cache contents are layout-independent.

    Attention-only families (dense) only: recurrent states (ssm/hybrid/
    rwkv) advance token-by-token and cannot skip pad tokens, and MoE
    routing capacity is load-dependent, so those families admit via the
    full-prefill path instead (see ``parallel.steps.build_admit_step``).
    """
    cfg = ap.cfg
    if cfg.family != "dense":
        raise NotImplementedError(
            f"chunked prefill supports attention-only dense families, "
            f"not {cfg.family!r}")
    if "k_scale" in cache:
        raise NotImplementedError("chunked prefill with kv_quant")
    B, C = tokens.shape
    sp = _seq_parallel_active(ctx, cfg, B * C, C, sp)
    block_tbl = cache.get("block_tbl")
    # The EF residual is a decode-loop state keyed to the (B, 1, D) token
    # message; prefill reductions over (B, C, D) chunks take the one-shot
    # rounding and the admitted slot's decode EF restarts from zero.
    ef_buf = cache.get("ef")
    kv_cache = {k2: v for k2, v in cache.items()
                if k2 not in ("block_tbl", "ef")}
    x = L.embed_lookup(params["embed"], tokens, ctx, ap.vocab_pad, sp=sp)

    def body(x, inp):
        bp, cl = inp
        if layer_map is not None:
            bp = layer_map(bp)
        h = _gathered(L.apply_norm(x, bp["ln1"], cfg), ctx, sp)
        # Same residual idiom as block_decode: unprojected attention output
        # through _residual_proj (overlapped when ctx asks for it).
        attn_out, kv_new = L.attention_chunk_step(
            bp["attn"], h, cl, cfg, ap.gqa, ctx, positions=positions,
            q_mask_tbl=ap.q_mask_tbl, chunk=attn_chunk,
            project=False, block_tbl=block_tbl, slot=slot)
        x = _residual_proj(x, attn_out, bp["attn"]["wo"],
                           "bsqh,qhd->bsd", ctx, sp=sp)
        h2 = _gathered(L.apply_norm(x, bp["ln2"], cfg), ctx, sp)
        x = _residual_proj(x, L.mlp_hidden(bp["mlp"], h2, cfg),
                           L.mlp_down_w(bp["mlp"], cfg), "bsf,fd->bsd",
                           ctx, sp=sp)
        return x, kv_new

    if scan_layers:
        x, new_cache = lax.scan(body, x, (params["blocks"], kv_cache))
    else:
        ncs = []
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda t: t[i], params["blocks"])
            cl = jax.tree.map(lambda t: t[i], kv_cache)
            x, nc = body(x, (bp, cl))
            ncs.append(nc)
        new_cache = _stack(ncs)
    if block_tbl is not None:
        new_cache["block_tbl"] = block_tbl
    if ef_buf is not None:
        if slot is None:
            new_cache["ef"] = jnp.zeros_like(ef_buf)
        else:
            zero = jnp.zeros(ef_buf.shape[:3] + (1,) + ef_buf.shape[4:],
                             ef_buf.dtype)
            new_cache["ef"] = lax.dynamic_update_slice(
                ef_buf, zero, (0, 0, 0, slot, 0))
    if not return_logits:
        return None, new_cache
    x = L.apply_norm(x, params["final_norm"], cfg)
    if sp:
        x = hier.tp_all_gather(x, ctx, dim=1)
    logits = L.lm_logits(params["embed"], x)
    return logits, new_cache


__all__ = ["ArchPlan", "make_plan", "init_params", "init_cache",
           "ef_sites_for", "forward_lm", "decode_step", "prefill_chunk",
           "seed_cache", "block_forward", "block_decode",
           "encoder_forward"]
