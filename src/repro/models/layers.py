"""Core transformer layers, written against local (per-device) shapes.

Every function here runs either single-device (``ParallelCtx`` with no axes;
shapes are the logical model shapes) or inside ``jax.shard_map`` on a
production mesh (shapes are the per-device shards produced by the sharding
specs in :mod:`repro.parallel.sharding`).  TP partial sums are *returned* by
layers; callers reduce them with :func:`repro.core.tp_all_reduce` (decode, the
paper's regime) or :func:`repro.core.tp_reduce_scatter` (sequence-parallel
training) so the all-reduce strategy stays a deployment decision.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.pcontext import ParallelCtx
from ..core import hierarchical as hier
from ..core import overlap as ov
from .common import ModelConfig, GQAPlan, dense_init, split_keys, place_heads

Params = Dict[str, jax.Array]

# ---------------------------------------------------------------------------
# Rank helpers (device-dependent constants under shard_map)
# ---------------------------------------------------------------------------


def tp_rank(ctx: ParallelCtx):
    """Linearized rank within the TP group (slow axes outermost), matching
    how PartitionSpec ``(slow..., fast...)`` slices a sharded dimension."""
    axes = ctx.tp_slow + ctx.tp_fast
    if not axes:
        return jnp.int32(0)
    r = jnp.int32(0)
    for a in axes:
        r = r * lax.axis_size(a) + lax.axis_index(a)
    return r


def take_local(table: np.ndarray, ctx: ParallelCtx) -> jax.Array:
    """Select this device's row of a small per-rank constant table."""
    t = jnp.asarray(table)
    return jnp.take(t, tp_rank(ctx), axis=0)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * lax.rsqrt(var + eps)).astype(x.dtype)
            * w.astype(x.dtype))


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return y.astype(x.dtype) * w.astype(x.dtype) + b.astype(x.dtype)


def apply_norm(x: jax.Array, p: Params, cfg: ModelConfig) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, d: Optional[int] = None) -> Params:
    d = d or cfg.d_model
    p = {"w": jnp.ones((d,), cfg.dtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((d,), cfg.dtype)
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions: (..., S) int32 -> cos/sin (..., S, head_dim/2), f32."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, N, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos_, sin_ = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos_, sin_ = cos[:, :, None, :], sin[:, :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos_ - x2f * sin_,
                           x2f * cos_ + x1f * sin_], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

NEG_INF = -1.0e30


def init_attention(key, cfg: ModelConfig, plan: GQAPlan,
                   d_model: Optional[int] = None) -> Params:
    """Weights in the *padded global slot layout* (shardable on the slot
    axis).  Live slots get fresh init; dead/replicated slots follow the map.
    """
    d = d_model or cfg.d_model
    hd = cfg.head_dim
    kq, kk, kv_, ko = split_keys(key, 4)
    wq = dense_init(kq, (cfg.n_heads, d, hd), d, cfg.dtype)
    wk = dense_init(kk, (cfg.n_kv_heads, d, hd), d, cfg.dtype)
    wv = dense_init(kv_, (cfg.n_kv_heads, d, hd), d, cfg.dtype)
    wo = dense_init(ko, (cfg.n_heads, hd, d), cfg.n_heads * hd, cfg.dtype)
    p = {
        "wq": place_heads(wq, plan.q_map).transpose(1, 0, 2),   # (D, Q, hd)
        "wk": place_heads(wk, plan.kv_map).transpose(1, 0, 2),  # (D, U, hd)
        "wv": place_heads(wv, plan.kv_map).transpose(1, 0, 2),
        "wo": place_heads(wo, plan.q_map),                      # (Q, hd, D)
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((plan.q_slots, hd), cfg.dtype)
        p["bk"] = jnp.zeros((plan.kv_slots, hd), cfg.dtype)
        p["bv"] = jnp.zeros((plan.kv_slots, hd), cfg.dtype)
    return p


def _qkv(p: Params, h: jax.Array, plan: GQAPlan):
    q = jnp.einsum("bsd,dqh->bsqh", h, p["wq"])
    k = jnp.einsum("bsd,duh->bsuh", h, p["wk"])
    v = jnp.einsum("bsd,duh->bsuh", h, p["wv"])
    if "bq" in p:
        # Under shard_map the biases are already this device's slot slice.
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return q, k, v


def _mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
          window: int, k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Boolean mask (..., Sq, Sk).  q_pos: (..., Sq); k_pos: (Sk,) or (..., Sk)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :] if k_pos.ndim == q_pos.ndim else k_pos[None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    if k_valid is not None:
        kv_ = k_valid[..., None, :] if k_valid.ndim == q_pos.ndim else k_valid[None, :]
        m &= kv_
    return m


def attn_core(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array,
              g: int, *, chunk: int = 0, k_scale=None,
              v_scale=None) -> jax.Array:
    """Grouped attention.  q: (B,Sq,U*g,hd), k/v: (B,Sk,U,hd),
    mask: (B,Sq,Sk) or (Sq,Sk) bool.  Returns (B,Sq,U*g,hd).

    ``k_scale``/``v_scale`` ((B,Sk,U) bf16) dequantize int8 K/V caches
    chunk-by-chunk (the cache is streamed, never materialized in bf16).
    """
    B, Sq, QL, hd = q.shape
    U = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, U, g, hd)
    if mask.ndim == 2:
        mask = mask[None]
    if chunk and k.shape[1] > chunk:
        return _attn_chunked(qg, k, v, mask, scale, chunk=chunk,
                             k_scale=k_scale, v_scale=v_scale
                             ).reshape(B, Sq, QL, hd)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[..., None].astype(jnp.float32)
        v = v.astype(jnp.float32) * v_scale[..., None].astype(jnp.float32)
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    s = jnp.einsum("bsugh,btuh->bugst", qg, k).astype(jnp.float32) * scale
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bugst,btuh->bsugh", p, v)
    return o.reshape(B, Sq, QL, hd)


def _attn_chunked(qg, k, v, mask, scale, chunk: int = 1024,
                  k_scale=None, v_scale=None):
    """Online-softmax attention, scanned over KV chunks (inference paths for
    long sequences).  Chunks are sliced inside the scan body — the cache is
    streamed, never copied/transposed, so peak extra memory is
    O(Sq * chunk) and the KV bytes-accessed term is the cache read itself."""
    B, Sq, U, g, hd = qg.shape
    Sk = k.shape[1]
    CH = chunk
    n = (Sk + CH - 1) // CH
    pad = n * CH - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, pad)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))

    def body(carry, i):
        m_prev, l_prev, acc = carry
        kb = lax.dynamic_slice_in_dim(k, i * CH, CH, axis=1)
        vb = lax.dynamic_slice_in_dim(v, i * CH, CH, axis=1)
        mb = lax.dynamic_slice_in_dim(mask, i * CH, CH, axis=2)
        if k_scale is not None:
            ks = lax.dynamic_slice_in_dim(k_scale, i * CH, CH, axis=1)
            vs = lax.dynamic_slice_in_dim(v_scale, i * CH, CH, axis=1)
            kb = (kb.astype(jnp.float32)
                  * ks[..., None].astype(jnp.float32)).astype(qg.dtype)
            vb = (vb.astype(jnp.float32)
                  * vs[..., None].astype(jnp.float32)).astype(qg.dtype)
        s = jnp.einsum("bsugh,btuh->bugst", qg, kb).astype(jnp.float32) * scale
        s = jnp.where(mb[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bugst,btuh->bugsh", p.astype(vb.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, U, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, U, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, U, g, Sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              jnp.arange(n, dtype=jnp.int32))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.transpose(0, 3, 1, 2, 4).astype(qg.dtype)  # (B,Sq,U,g,hd)


def attention(p: Params, h: jax.Array, cfg: ModelConfig, plan: GQAPlan,
              ctx: ParallelCtx, *, positions: jax.Array, causal: bool = True,
              q_mask_tbl: Optional[np.ndarray] = None,
              chunk: int = 0) -> jax.Array:
    """Full-sequence attention (train / prefill).  Returns the TP-partial
    output projection; caller reduces."""
    q, k, v = _qkv(p, h, plan)
    if cfg.rope_theta > 0:
        cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    mask = _mask(positions, positions, causal=causal,
                 window=cfg.sliding_window)
    o = attn_core(q, k, v, mask, plan.g, chunk=chunk)
    if q_mask_tbl is not None:
        o = o * take_local(q_mask_tbl, ctx)[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bsqh,qhd->bsd", o, p["wo"])


def attention_decode(p: Params, h: jax.Array, cache: Dict[str, jax.Array],
                     cfg: ModelConfig, plan: GQAPlan, ctx: ParallelCtx, *,
                     positions: jax.Array,
                     q_mask_tbl: Optional[np.ndarray] = None,
                     chunk: Optional[int] = None, ring: bool = False,
                     project: bool = True,
                     block_tbl: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode step against a KV cache.

    ``project=False`` returns the pre-projection per-head output
    (B, 1, Q, hd) instead of the wo-projected TP partial — the overlapped
    decode path feeds it to :func:`repro.core.overlap.collective_matmul` so
    the output projection pipelines against its own all-reduce.

    h: (B, 1, D); cache['k']/cache['v']: (B, S_max, U, hd);
    positions: (B,) index where the new token is written.

    Variants (all selected by the cache layout itself):
    * int8 KV: cache['k'] is int8 with per-(pos, head) bf16 scales in
      cache['k_scale']/['v_scale'] — K/V are dequantized chunk-by-chunk.
    * ring buffer: ``ring=True`` with S_max == sliding_window — slot
      ``pos % W`` is overwritten and every slot is one of the last W
      positions, so the sliding-window mask degenerates to slot-validity.
    * paged: ``block_tbl`` (B, max_blocks) int32 maps logical blocks to
      physical blocks of cache['k'] (n_blocks, block_size, U, hd); the new
      token scatters through the table and K/V are gathered back to the
      logical (B, max_blocks*block_size, U, hd) layout before attention, so
      the math is identical to the dense path on the same logical contents.
    """
    if block_tbl is not None:
        assert not ring, "paged cache is incompatible with the ring buffer"
        assert cache["k"].dtype != jnp.int8, \
            "paged cache is incompatible with int8 KV"
        return _attention_decode_paged(p, h, cache, cfg, plan, ctx,
                                       positions=positions,
                                       q_mask_tbl=q_mask_tbl, chunk=chunk,
                                       project=project, block_tbl=block_tbl)
    q, k_new, v_new = _qkv(p, h, plan)
    if cfg.rope_theta > 0:
        cos, sin = rope_tables(positions[:, None], cfg.head_dim,
                               cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    B, S_max = cache["k"].shape[0], cache["k"].shape[1]
    quant = cache["k"].dtype == jnp.int8
    write_pos = positions % S_max if ring else positions
    bidx = jnp.arange(B)
    if quant:
        def q8(t):  # (B,1,U,hd) -> int8 payload + (B,U) scale
            tf = t[:, 0].astype(jnp.float32)
            sc = jnp.maximum(jnp.max(jnp.abs(tf), axis=-1) / 127.0, 1e-30)
            qq = jnp.clip(jnp.round(tf / sc[..., None]), -127, 127)
            return qq.astype(jnp.int8), sc.astype(jnp.bfloat16)
        kq, ksc = q8(k_new)
        vq, vsc = q8(v_new)
        k = cache["k"].at[bidx, write_pos].set(kq)
        v = cache["v"].at[bidx, write_pos].set(vq)
        k_scale = cache["k_scale"].at[bidx, write_pos].set(ksc)
        v_scale = cache["v_scale"].at[bidx, write_pos].set(vsc)
    else:
        k = cache["k"].at[bidx, write_pos].set(
            k_new[:, 0].astype(cache["k"].dtype))
        v = cache["v"].at[bidx, write_pos].set(
            v_new[:, 0].astype(cache["v"].dtype))
        k_scale = v_scale = None
    kpos = jnp.arange(S_max, dtype=jnp.int32)
    if ring:
        # every live slot is within the window by construction; only slots
        # not yet written (pos < W) are masked out
        mask = kpos[None, :] <= positions[:, None]
        mask = jnp.broadcast_to(mask[:, None, :], (B, 1, S_max))
    else:
        mask = _mask(positions[:, None], kpos, causal=True,
                     window=cfg.sliding_window)
    if chunk is None:
        chunk = 1024 if S_max > 8192 else 0
    o = attn_core(q, k, v, mask, plan.g, chunk=chunk, k_scale=k_scale,
                  v_scale=v_scale)
    if q_mask_tbl is not None:
        o = o * take_local(q_mask_tbl, ctx)[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bsqh,qhd->bsd", o, p["wo"]) if project else o
    new_cache = {"k": k, "v": v}
    if quant:
        new_cache["k_scale"] = k_scale
        new_cache["v_scale"] = v_scale
    return out, new_cache


def _attention_decode_paged(p: Params, h: jax.Array,
                            cache: Dict[str, jax.Array], cfg: ModelConfig,
                            plan: GQAPlan, ctx: ParallelCtx, *,
                            positions: jax.Array, q_mask_tbl, chunk,
                            project: bool, block_tbl: jax.Array):
    """Paged one-token decode: scatter the new K/V through the block table,
    gather the logical view, then attend exactly like the dense path.

    Table rows of inactive slots point at the reserved trash block (0);
    their writes land there and their reads are discarded by the caller, so
    the whole fixed-shape batch keeps stepping without masking."""
    q, k_new, v_new = _qkv(p, h, plan)
    if cfg.rope_theta > 0:
        cos, sin = rope_tables(positions[:, None], cfg.head_dim,
                               cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    B = h.shape[0]
    bs_blk = cache["k"].shape[1]
    S_max = block_tbl.shape[1] * bs_blk
    bidx = jnp.arange(B)
    pb = block_tbl[bidx, positions // bs_blk]        # (B,) physical block
    off = positions % bs_blk
    k = cache["k"].at[pb, off].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[pb, off].set(v_new[:, 0].astype(cache["v"].dtype))
    U, hd = k.shape[-2], k.shape[-1]
    k_log = k[block_tbl].reshape(B, S_max, U, hd)
    v_log = v[block_tbl].reshape(B, S_max, U, hd)
    kpos = jnp.arange(S_max, dtype=jnp.int32)
    mask = _mask(positions[:, None], kpos, causal=True,
                 window=cfg.sliding_window)
    if chunk is None:
        chunk = 1024 if S_max > 8192 else 0
    o = attn_core(q, k_log, v_log, mask, plan.g, chunk=chunk)
    if q_mask_tbl is not None:
        o = o * take_local(q_mask_tbl, ctx)[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bsqh,qhd->bsd", o, p["wo"]) if project else o
    return out, {"k": k, "v": v}


def attention_chunk_step(p: Params, h: jax.Array,
                         cache: Dict[str, jax.Array], cfg: ModelConfig,
                         plan: GQAPlan, ctx: ParallelCtx, *,
                         positions: jax.Array,
                         q_mask_tbl: Optional[np.ndarray] = None,
                         chunk: int = 0, project: bool = True,
                         block_tbl: Optional[jax.Array] = None,
                         slot: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill attention: C prompt tokens write into the decode
    cache, then attend causally over everything written so far.

    h: (B, C, D); positions: (B, C) int32 write positions.  ``slot`` (a
    traced scalar) admits a single request (B == 1) into one row of a
    batch-wide cache — the on-device splice the continuous batcher's
    admission step uses.  Dense and paged (``block_tbl``) layouts share the
    call; the paged path scatters/gathers through the table first.

    Trailing pad tokens are safe *by the write-ordering invariant*: a pad at
    position p >= prompt_len writes garbage K/V, but every later read at
    decode position q only exposes kpos <= q, and position q is overwritten
    by the real decode write before any such read (see DESIGN.md §7).
    """
    q, k_new, v_new = _qkv(p, h, plan)
    if cfg.rope_theta > 0:
        cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    B = h.shape[0]
    paged = block_tbl is not None
    if paged:
        assert cache["k"].dtype != jnp.int8
        bs_blk = cache["k"].shape[1]
        S_max = block_tbl.shape[1] * bs_blk
    else:
        S_max = cache["k"].shape[1]
    kd = cache["k"].dtype
    if slot is not None:
        assert B == 1, "slot admission is per-request"
        pos_row = positions[0]                       # (C,)
        if paged:
            row = lax.dynamic_index_in_dim(block_tbl, slot, 0,
                                           keepdims=False)  # (max_blocks,)
            # pads beyond the logical capacity must not clamp into the
            # slot's last live block — route them to the trash block (0)
            pb = jnp.where(pos_row < S_max, row[jnp.minimum(
                pos_row // bs_blk, block_tbl.shape[1] - 1)], 0)
            off = pos_row % bs_blk
            k = cache["k"].at[pb, off].set(k_new[0].astype(kd))
            v = cache["v"].at[pb, off].set(v_new[0].astype(kd))
            U, hd = k.shape[-2], k.shape[-1]
            k_att = k[row].reshape(1, S_max, U, hd)
            v_att = v[row].reshape(1, S_max, U, hd)
        else:
            k = cache["k"].at[slot, pos_row].set(k_new[0].astype(kd))
            v = cache["v"].at[slot, pos_row].set(v_new[0].astype(kd))
            k_att = lax.dynamic_index_in_dim(k, slot, 0, keepdims=True)
            v_att = lax.dynamic_index_in_dim(v, slot, 0, keepdims=True)
    else:
        bidx = jnp.arange(B)[:, None]
        if paged:
            pb = block_tbl[bidx, jnp.minimum(positions // bs_blk,
                                             block_tbl.shape[1] - 1)]
            pb = jnp.where(positions < S_max, pb, 0)     # (B, C)
            off = positions % bs_blk
            k = cache["k"].at[pb, off].set(k_new.astype(kd))
            v = cache["v"].at[pb, off].set(v_new.astype(kd))
            U, hd = k.shape[-2], k.shape[-1]
            k_att = k[block_tbl].reshape(B, S_max, U, hd)
            v_att = v[block_tbl].reshape(B, S_max, U, hd)
        else:
            k = cache["k"].at[bidx, positions].set(k_new.astype(kd))
            v = cache["v"].at[bidx, positions].set(v_new.astype(kd))
            k_att, v_att = k, v
    kpos = jnp.arange(S_max, dtype=jnp.int32)
    mask = _mask(positions, kpos, causal=True, window=cfg.sliding_window)
    o = attn_core(q, k_att, v_att, mask, plan.g, chunk=chunk)
    if q_mask_tbl is not None:
        o = o * take_local(q_mask_tbl, ctx)[None, None, :, None].astype(o.dtype)
    out = jnp.einsum("bsqh,qhd->bsd", o, p["wo"]) if project else o
    return out, {"k": k, "v": v}


def cross_attention(p: Params, h: jax.Array, enc_k: jax.Array,
                    enc_v: jax.Array, cfg: ModelConfig, plan: GQAPlan,
                    ctx: ParallelCtx,
                    q_mask_tbl: Optional[np.ndarray] = None) -> jax.Array:
    """Decoder cross-attention over precomputed encoder K/V (no RoPE, no
    mask — whisper style)."""
    q = jnp.einsum("bsd,dqh->bsqh", h, p["wq"])
    if "bq" in p:
        q = q + p["bq"][None, None]
    Sq, Sk = h.shape[1], enc_k.shape[1]
    mask = jnp.ones((Sq, Sk), bool)
    o = attn_core(q, enc_k, enc_v, mask, plan.g,
                  chunk=1024 if Sk > 8192 else 0)
    if q_mask_tbl is not None:
        o = o * take_local(q_mask_tbl, ctx)[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bsqh,qhd->bsd", o, p["wo"])


def cross_kv(p: Params, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("btd,duh->btuh", enc_out, p["wk"])
    v = jnp.einsum("btd,duh->btuh", enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        kg, ku, kd = split_keys(key, 3)
        return {"wg": dense_init(kg, (d, f), d, cfg.dtype),
                "wu": dense_init(ku, (d, f), d, cfg.dtype),
                "wd": dense_init(kd, (f, d), f, cfg.dtype)}
    k1, k2 = split_keys(key, 2)
    return {"w1": dense_init(k1, (d, f), d, cfg.dtype),
            "b1": jnp.zeros((f,), cfg.dtype),
            "w2": dense_init(k2, (f, d), f, cfg.dtype)}


def mlp(p: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Returns TP-partial output (wd/w2 row-sharded)."""
    act = mlp_hidden(p, h, cfg)
    return jnp.einsum("bsf,fd->bsd", act, mlp_down_w(p, cfg))


def mlp_hidden(p: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Up-projection + activation only: the (B, S, f_local) tensor feeding
    the row-parallel down-projection (split out so the overlapped decode
    path can fuse that GEMM with its all-reduce)."""
    if cfg.act == "swiglu":
        a = jnp.einsum("bsd,df->bsf", h, p["wg"])
        b = jnp.einsum("bsd,df->bsf", h, p["wu"])
        return jax.nn.silu(a) * b
    return jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["w1"]) + p["b1"])


def mlp_down_w(p: Params, cfg: ModelConfig) -> jax.Array:
    """The row-sharded down-projection weight ((f_local, D), output last)."""
    return p["wd"] if cfg.act == "swiglu" else p["w2"]


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / logits / loss
# ---------------------------------------------------------------------------


def init_embed(key, cfg: ModelConfig, vocab_pad: int) -> Params:
    ke, kh = split_keys(key, 2)
    p = {"tok": dense_init(ke, (vocab_pad, cfg.d_model), cfg.d_model,
                           cfg.dtype)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(kh, (cfg.d_model, vocab_pad), cfg.d_model,
                               cfg.dtype)
    return p


def embed_lookup(p: Params, ids: jax.Array, ctx: ParallelCtx,
                 vocab_pad: int, *, sp: bool = False) -> jax.Array:
    """Vocab-parallel lookup: local gather + TP reduce (paper AR site #0)."""
    table = p["tok"]
    v_loc = table.shape[0]
    if v_loc == vocab_pad and not ctx.has_tp:
        return table[ids]
    start = tp_rank(ctx) * v_loc
    local = ids - start
    ok = (local >= 0) & (local < v_loc)
    x = table[jnp.clip(local, 0, v_loc - 1)]
    x = jnp.where(ok[..., None], x, 0)
    if sp:
        return hier.tp_reduce_scatter(x, ctx, dim=1)
    return hier.tp_all_reduce(x, ctx, scatter_dim=-1)


def lm_logits(p: Params, x: jax.Array) -> jax.Array:
    """Local (vocab-sharded) logits."""
    head = p["head"] if "head" in p else p["tok"].T
    return jnp.einsum("bsd,dv->bsv", x, head)


def _pmax_const(x: jax.Array, axes) -> jax.Array:
    """lax.pmax treated as a constant under AD (it has no JVP rule; the
    logsumexp max-shift never needs one)."""
    @jax.custom_jvp
    def f(v):
        return lax.pmax(v, axes)

    @f.defjvp
    def f_jvp(primals, tangents):
        (v,) = primals
        return f(v), jnp.zeros_like(v)

    return f(lax.stop_gradient(x))


def sharded_xent(logits_loc: jax.Array, labels: jax.Array,
                 ctx: ParallelCtx, vocab_pad: int, vocab_real: int,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Cross-entropy over vocab-sharded logits without gathering the vocab.

    logits_loc: (..., V_loc) this device's slice; labels: (...) global ids.
    Returns mean loss (scalar, already averaged over batch axes).
    """
    v_loc = logits_loc.shape[-1]
    start = tp_rank(ctx) * v_loc
    lf = logits_loc.astype(jnp.float32)
    # mask vocab padding slots (global ids >= vocab_real)
    gidx = start + jnp.arange(v_loc)
    lf = jnp.where((gidx < vocab_real)[None, None, :]
                   if lf.ndim == 3 else (gidx < vocab_real), lf, NEG_INF)
    m = jnp.max(lf, axis=-1)
    if ctx.has_tp:
        m = _pmax_const(m, ctx.tp_axes)
    # standard logsumexp trick: the max shift is a constant wrt gradients
    m = lax.stop_gradient(m)
    se = jnp.sum(jnp.exp(lf - m[..., None]), axis=-1)
    local = labels - start
    ok = (local >= 0) & (local < v_loc)
    picked = jnp.take_along_axis(
        lf, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1)[..., 0]
    picked = jnp.where(ok, picked, 0.0)
    if ctx.has_tp:
        se = lax.psum(se, ctx.tp_axes)
        picked = lax.psum(picked, ctx.tp_axes)
    nll = jnp.log(se) + m - picked
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
    else:
        denom = float(np.prod(nll.shape))
    loss = jnp.sum(nll) / denom
    return hier.dp_psum_mean(loss, ctx)


def greedy_sample(logits_loc: jax.Array, ctx: ParallelCtx,
                  vocab_real: int) -> jax.Array:
    """Greedy next-token over vocab-sharded logits: argmax via pmax+pick.
    logits_loc: (B, V_loc) -> (B,) int32 global token ids."""
    v_loc = logits_loc.shape[-1]
    start = tp_rank(ctx) * v_loc
    lf = logits_loc.astype(jnp.float32)
    gidx = start + jnp.arange(v_loc)
    lf = jnp.where(gidx[None, :] < vocab_real, lf, NEG_INF)
    loc_best = jnp.argmax(lf, axis=-1)
    loc_max = jnp.take_along_axis(lf, loc_best[:, None], axis=-1)[:, 0]
    if not ctx.has_tp:
        return loc_best.astype(jnp.int32)
    gmax = lax.pmax(loc_max, ctx.tp_axes)
    # Prefer the lowest global id among ties.
    cand = jnp.where(loc_max >= gmax, start + loc_best, jnp.int32(2**30))
    return lax.pmin(cand.astype(jnp.int32), ctx.tp_axes)


def sample_token(logits: jax.Array, rng: jax.Array, *,
                 temperature: float = 1.0, top_k: int = 0,
                 vocab_real: Optional[int] = None) -> jax.Array:
    """Temperature / top-k sampling over FULL (unsharded) logits.

    logits: (B, V); returns (B,) int32.  temperature=0 -> greedy.
    (The sharded serving path gathers logits first via sample=False on the
    decode builder; vocab padding slots are masked here.)
    """
    lf = logits.astype(jnp.float32)
    if vocab_real is not None and vocab_real < lf.shape[-1]:
        mask = jnp.arange(lf.shape[-1]) < vocab_real
        lf = jnp.where(mask[None, :], lf, NEG_INF)
    if temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    lf = lf / temperature
    if top_k > 0 and top_k < lf.shape[-1]:
        kth = jnp.sort(lf, axis=-1)[:, -top_k][:, None]
        lf = jnp.where(lf >= kth, lf, NEG_INF)
    return jax.random.categorical(rng, lf, axis=-1).astype(jnp.int32)


__all__ = [
    "rms_norm", "layer_norm", "apply_norm", "init_norm", "rope_tables",
    "apply_rope", "init_attention", "attention", "attention_decode",
    "attention_chunk_step",
    "cross_attention", "cross_kv", "attn_core", "init_mlp", "mlp",
    "mlp_hidden", "mlp_down_w",
    "init_embed", "embed_lookup", "lm_logits", "sharded_xent",
    "greedy_sample", "sample_token", "tp_rank", "take_local", "NEG_INF",
]
