"""RWKV6 ("Finch") blocks: time-mix with data-dependent per-channel decay and
channel-mix FFN.

Faithfulness notes (see DESIGN.md §Assumptions): the data-dependent decay
LoRA — the defining RWKV6 feature — is implemented exactly
(``w_t = exp(-exp(w0 + tanh(x_w @ w_a) @ w_b))``); the token-shift
interpolation uses static per-channel mixing vectors (RWKV6's dynamic ddlerp
LoRA on the shift mix is folded into the decay LoRA's capacity).

Sharding: heads (A = n_heads * 64 channels) are TP-sharded for r/k/v/g/decay
and the recurrent state; w_o is row-sharded (TP-partial output).  The
channel-mix returns a *stacked* (value, receptance-logit) partial so the
caller completes both with one fused all-reduce and applies the sigmoid gate
after reduction — keeping the paper's one-collective-per-sublayer structure.

The sequence recurrence per head (key dim x value dim state S):

    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

is evaluated in a chunked parallel form (flash-linear-attention style) for
full sequences and as a single-step update for decode.  ``rwkv_scan_ref`` is
the step-exact oracle used by tests and by kernels/rwkv6_scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..core.pcontext import ParallelCtx
from .common import ModelConfig, dense_init, split_keys

Params = Dict[str, jax.Array]


def init_rwkv_time_mix(key, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    a = d  # attention dim == d_model (heads = d / 64)
    kr, kk, kv_, kg, ka, kb, ko = split_keys(key, 7)
    hd = cfg.rwkv_head_dim
    nh = a // hd
    return {
        "mu": jnp.full((5, d), 0.5, cfg.dtype),  # r,k,v,w,g shift mixes
        "w_r": dense_init(kr, (d, a), d, cfg.dtype),
        "w_k": dense_init(kk, (d, a), d, cfg.dtype),
        "w_v": dense_init(kv_, (d, a), d, cfg.dtype),
        "w_g": dense_init(kg, (d, a), d, cfg.dtype),
        "w0": jnp.tile(jnp.linspace(-6.0, -0.5, hd)[None, :],
                       (nh, 1)).reshape(a).astype(jnp.float32),
        "w_a": dense_init(ka, (d, cfg.decay_lora), d, cfg.dtype),
        "w_b": dense_init(kb, (cfg.decay_lora, a), cfg.decay_lora,
                          cfg.dtype),
        "u": jnp.zeros((a,), jnp.float32),
        "ln_w": jnp.ones((a,), cfg.dtype),
        "ln_b": jnp.zeros((a,), cfg.dtype),
        "w_o": dense_init(ko, (a, d), a, cfg.dtype),
    }


def init_rwkv_channel_mix(key, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    kk, kv_, kr = split_keys(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, cfg.dtype),  # k, r shift mixes
        "wk": dense_init(kk, (d, f), d, cfg.dtype),
        "wv": dense_init(kv_, (f, d), f, cfg.dtype),
        "wr": dense_init(kr, (d, d), d, cfg.dtype),  # row-sharded
    }


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} along the sequence; ``prev`` (B, D) seeds position 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu[None, None, :]


def _group_norm(y: jax.Array, w: jax.Array, b: jax.Array, hd: int,
                eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm over the value channels.  y: (B,T,H,hd)."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(yf - mu), axis=-1, keepdims=True)
    yn = (yf - mu) * lax.rsqrt(var + eps)
    B, T, H, _ = y.shape
    return yn.reshape(B, T, -1) * w[None, None, :] + b[None, None, :]


def _rkvwg(p: Params, x: jax.Array, prev: Optional[jax.Array], hd: int):
    xs = _shift(x, prev)
    mu = p["mu"]
    xr = _mix(x, xs, mu[0])
    xk = _mix(x, xs, mu[1])
    xv = _mix(x, xs, mu[2])
    xw = _mix(x, xs, mu[3])
    xg = _mix(x, xs, mu[4])
    r = jnp.einsum("btd,da->bta", xr, p["w_r"])
    k = jnp.einsum("btd,da->bta", xk, p["w_k"])
    v = jnp.einsum("btd,da->bta", xv, p["w_v"])
    g = jnp.einsum("btd,da->bta", xg, p["w_g"])
    # data-dependent decay (the RWKV6 signature feature)
    lora = jnp.einsum("btl,la->bta",
                      jnp.tanh(jnp.einsum("btd,dl->btl", xw, p["w_a"])),
                      p["w_b"]).astype(jnp.float32)
    logw = -jnp.exp(p["w0"][None, None, :] + lora)     # log decay < 0
    B, T, A = r.shape
    H = A // hd
    hview = lambda t: t.reshape(B, T, H, hd)
    return (hview(r.astype(jnp.float32)), hview(k.astype(jnp.float32)),
            hview(v.astype(jnp.float32)), g, hview(logw), x[:, -1, :])


def rwkv_scan_ref(r, k, v, logw, u, s0=None):
    """Step-exact recurrence (oracle).  r/k/v/logw: (B,T,H,hd) f32;
    u: (H, hd); s0: (B,H,hd,hd).  Returns y (B,T,H,hd), s_final."""
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, inp):
        rt, kt, vt, lwt = inp                      # (B,H,hd)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,hd,hd)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., :, None] * s + kv
        return s, yt

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    s_fin, ys = lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_fin


def rwkv_scan_chunked(r, k, v, logw, u, s0=None, chunk: int = 64):
    """Chunked parallel evaluation of the same recurrence (train/prefill).

    Within a chunk of length C: with L_t = cumsum(logw)_t (inclusive),
      y_t = r_t . diag(exp(L_{t-1})) S_in                       (inter-chunk)
            + sum_{s<t} (r_t * exp(L_{t-1}-L_s)) . k_s v_s^T    (intra)
            + (r_t * u) . k_t v_t^T                             (diagonal)
      S_out = diag(exp(L_C)) S_in + sum_s diag(exp(L_C - L_s)) k_s v_s^T
    """
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n = -(-T // chunk)
    pad = n * chunk - T
    if pad:
        padder = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padder(r), padder(k), padder(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    C = chunk
    resh = lambda t: t.reshape(B, n, C, H, hd).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(logw)

    def body(s, inp):
        rb, kb, vb, wb = inp                       # (B,C,H,hd)
        L = jnp.cumsum(wb, axis=1)                 # inclusive per-channel
        Lm1 = L - wb                               # exclusive (L_{t-1})
        # inter-chunk: r_t decayed against carried state
        rdec = rb * jnp.exp(Lm1)
        y = jnp.einsum("bthk,bhkv->bthv", rdec, s)
        # intra-chunk: scores_ts = sum_c r_tc k_sc exp(L(t-1)c - L(s)c)
        # (exponent clipped for f32 safety; clipped terms are multiplied by
        # exp(L_{t-1}) ~ 0 in exactly those regimes)
        kdec = kb * jnp.exp(jnp.minimum(-L, 60.0))
        scores = jnp.einsum("bthc,bshc->bhts", rdec, kdec)
        mask = jnp.tril(jnp.ones((C, C), bool), -1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        y = y + jnp.einsum("bhts,bshv->bthv", scores, vb)
        # diagonal (current token) with bonus u:  y += (r . (u*k)) v
        y = y + jnp.sum(rb * u[None, None] * kb, axis=-1, keepdims=True) * vb
        # state update
        Lc = L[:, -1:, :, :]                       # (B,1,H,hd)
        kfac = kb * jnp.exp(Lc - L)
        s_new = jnp.exp(Lc[:, 0])[..., :, None] * s \
            + jnp.einsum("bshk,bshv->bhkv", kfac, vb)
        return s_new, y

    s_fin, ys = lax.scan(body, s0, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n * C, H, hd)
    return y[:, :T], s_fin


def rwkv_time_mix(p: Params, x: jax.Array, cfg: ModelConfig,
                  ctx: ParallelCtx,
                  state: Optional[Dict[str, jax.Array]] = None,
                  return_state: bool = False, chunk: int = 64):
    """Full-sequence time-mix.  Returns TP-partial (B,T,D) output."""
    hd = cfg.rwkv_head_dim
    prev = state["shift_tm"] if state is not None else None
    r, k, v, g, logw, last = _rkvwg(p, x, prev, hd)
    H = r.shape[2]
    u = p["u"].reshape(H, hd)
    s0 = state["wkv"] if state is not None else None
    y, s_fin = rwkv_scan_chunked(r, k, v, logw, u, s0, chunk=chunk)
    y = _group_norm(y, p["ln_w"], p["ln_b"], hd)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bta,ad->btd", y, p["w_o"])
    if return_state:
        return out, {"shift_tm": last, "wkv": s_fin}
    return out


def rwkv_time_mix_step(p: Params, x: jax.Array,
                       state: Dict[str, jax.Array], cfg: ModelConfig,
                       ctx: ParallelCtx):
    """Single-token decode step.  x: (B,1,D)."""
    hd = cfg.rwkv_head_dim
    r, k, v, g, logw, last = _rkvwg(p, x, state["shift_tm"], hd)
    H = r.shape[2]
    u = p["u"].reshape(H, hd)
    rt, kt, vt, lwt = r[:, 0], k[:, 0], v[:, 0], logw[:, 0]
    kv = kt[..., :, None] * vt[..., None, :]
    s = state["wkv"]
    yt = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
    s_new = jnp.exp(lwt)[..., :, None] * s + kv
    y = _group_norm(yt[:, None], p["ln_w"], p["ln_b"], hd)
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bta,ad->btd", y, p["w_o"])
    return out, {"shift_tm": last, "wkv": s_new}


def rwkv_channel_mix(p: Params, x: jax.Array, cfg: ModelConfig,
                     ctx: ParallelCtx,
                     state: Optional[Dict[str, jax.Array]] = None,
                     return_state: bool = False):
    """Channel-mix.  Returns STACKED TP-partials (2, B, T, D): [value,
    receptance-logit]; caller reduces once and gates:
    ``out = sigmoid(r) * v``."""
    prev = state["shift_cm"] if state is not None else None
    xs = _shift(x, prev)
    xk = _mix(x, xs, p["mu"][0])
    xr = _mix(x, xs, p["mu"][1])
    kk = jnp.einsum("btd,df->btf", xk, p["wk"])
    kk = jnp.square(jax.nn.relu(kk))
    val = jnp.einsum("btf,fd->btd", kk, p["wv"])
    # wr is row-sharded: contract this device's slice of xr with its rows so
    # the receptance logit is a TP-partial just like ``val``.
    dloc = p["wr"].shape[0]
    if dloc != xr.shape[-1]:
        from .layers import tp_rank  # local import to avoid cycle
        start = tp_rank(ctx) * dloc
        xr_loc = lax.dynamic_slice_in_dim(xr, start, dloc, axis=-1)
    else:
        xr_loc = xr
    rlog = jnp.einsum("btd,de->bte", xr_loc, p["wr"])
    stacked = jnp.stack([val, rlog.astype(val.dtype)], axis=0)
    if return_state:
        return stacked, {"shift_cm": x[:, -1, :]}
    return stacked


def init_rwkv_state(cfg: ModelConfig, batch: int, heads_local: int,
                    d_ff_unused: int = 0, dtype=jnp.bfloat16
                    ) -> Dict[str, jax.Array]:
    hd = cfg.rwkv_head_dim
    return {
        "shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, heads_local, hd, hd), jnp.float32),
    }


__all__ = [
    "init_rwkv_time_mix", "init_rwkv_channel_mix", "rwkv_time_mix",
    "rwkv_time_mix_step", "rwkv_channel_mix", "rwkv_scan_ref",
    "rwkv_scan_chunked", "init_rwkv_state",
]
