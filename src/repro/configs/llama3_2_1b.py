"""llama3.2-1b [dense] — small llama3.
[hf:meta-llama/Llama-3.2-1B; unverified]  16L d_model=2048 32H (GQA kv=8)
d_ff=8192 vocab=128256."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256,
    rope_theta=5.0e5,
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=97,
)
