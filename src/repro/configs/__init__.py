"""Assigned architectures (exact public configs) + reduced smoke variants."""
from .registry import (ARCH_IDS, get_config, get_smoke, SHAPES, Shape,
                       shape_applicable, cell_plan, CellPlan, all_cells)

__all__ = ["ARCH_IDS", "get_config", "get_smoke", "SHAPES", "Shape",
           "shape_applicable", "cell_plan", "CellPlan", "all_cells"]
