"""qwen1.5-32b [dense] — MHA with QKV bias.
[hf:Qwen/Qwen1.5-0.5B; hf]  64L d_model=5120 40H (kv=40) d_ff=27392
vocab=152064."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True, rope_theta=1.0e6,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=5, head_dim=16,
    d_ff=128, vocab_size=97, qkv_bias=True,
)
