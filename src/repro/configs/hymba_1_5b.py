"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block.
[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 ssm_state=16.  Sliding-window attention (1024) + SSM state make
this one of the two archs that run the long_500k cell."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, d_inner=3200, d_conv=4, dt_rank=100,
    sliding_window=1024, rope_theta=1.0e4,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=5, head_dim=16,
    d_ff=128, vocab_size=97,
    ssm_state=8, d_inner=128, d_conv=4, dt_rank=8,
    sliding_window=8,
)
