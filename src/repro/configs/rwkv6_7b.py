"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.
[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536.
O(1) recurrent state -> runs the long_500k cell."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, decay_lora=64, rope_theta=0.0,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=97, rwkv_head_dim=32, decay_lora=8,
    rope_theta=0.0,
)
