"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB: input_specs provides patch
embeddings) + mistral-nemo decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]  40L d_model=5120 32H (GQA kv=8)
d_ff=14336 vocab=131072."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    n_patches=1024, rope_theta=1.0e6,
)

SMOKE = ModelConfig(
    name="pixtral-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=97, n_patches=4,
)
