"""whisper-medium [audio/encdec] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed frame embeddings, per task spec).
[arXiv:2212.04356; unverified]  24+24L d_model=1024 16H d_ff=4096
vocab=51865.  Deviation noted in DESIGN.md: RoPE replaces Whisper's learned
absolute positions (backbone-only reproduction)."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    enc_layers=24, enc_seq=1500,
    norm="layernorm", act="gelu", rope_theta=1.0e4,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=97, enc_layers=2, enc_seq=12,
    norm="layernorm", act="gelu",
)
