"""Architecture registry + assigned input shapes + per-cell execution plans.

``--arch <id>`` resolution for every launcher/benchmark entry point, the
4-shape grid from the assignment, applicability rules (which cells are
skipped and why — mirrored in DESIGN.md §Arch-applicability), and the
execution plan for each (arch x shape x mesh) dry-run cell.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from ..models.common import ModelConfig

_MODULES = {
    "hymba-1.5b": "hymba_1_5b",
    "dbrx-132b": "dbrx_132b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "whisper-medium": "whisper_medium",
    "rwkv6-7b": "rwkv6_7b",
    "pixtral-12b": "pixtral_12b",
    "qwen1.5-32b": "qwen1_5_32b",
    "mistral-large-123b": "mistral_large_123b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama3.2-1b": "llama3_2_1b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(f".{_MODULES[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch; decode/long lower serve_step.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}

# archs with a sub-quadratic long-context path (SSM state / sliding window)
_SUBQUADRATIC = ("rwkv6-7b", "hymba-1.5b")


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in _SUBQUADRATIC:
        return False, ("pure full-attention arch: 500k dense-KV decode is "
                       "not the sub-quadratic regime this cell requires "
                       "(skip noted in DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Per-cell execution plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: Shape
    microbatches: int = 1          # train grad-accum steps
    fsdp_serve: bool = False       # weight-gather serving (big models)
    batch_replicated: bool = False  # long_500k: batch=1 cannot DP-shard
    notes: str = ""


# Models whose bf16 weights exceed a single v5e chip budget under 16-way TP
# (params/16 > ~8 GB) serve with FSDP weight-gathering.
_BIG_SERVE = ("mistral-large-123b", "dbrx-132b", "qwen1.5-32b")

_TRAIN_MB = {  # grad-accum microbatch counts (per-device batch is gb/|dp|)
    "mistral-large-123b": 8, "dbrx-132b": 8, "qwen1.5-32b": 8,
    "pixtral-12b": 4, "rwkv6-7b": 4, "codeqwen1.5-7b": 4,
    "qwen3-moe-30b-a3b": 4, "whisper-medium": 2, "hymba-1.5b": 2,
    "llama3.2-1b": 2,
}


def cell_plan(arch: str, shape_name: str) -> CellPlan:
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        raise ValueError(f"{arch} x {shape_name} skipped: {why}")
    if shape.kind == "train":
        return CellPlan(arch, shape, microbatches=_TRAIN_MB[arch])
    if shape_name == "long_500k":
        return CellPlan(arch, shape, batch_replicated=True,
                        notes="batch=1: dp axes idle (replicated)")
    return CellPlan(arch, shape, fsdp_serve=arch in _BIG_SERVE)


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """[(arch, shape, applicable, skip_reason)] for the full 40-cell grid."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            ok, why = shape_applicable(a, s)
            out.append((a, s, ok, why))
    return out


__all__ = ["ARCH_IDS", "get_config", "get_smoke", "SHAPES", "Shape",
           "shape_applicable", "cell_plan", "CellPlan", "all_cells"]
