"""Llama 3.1 70B / 405B — the paper's own models (benchmark harness only;
not part of the assigned 10-arch grid).  [arXiv:2407.21783]"""
from ..models.common import ModelConfig

LLAMA31_70B = ModelConfig(
    name="llama3.1-70b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256, rope_theta=5.0e5,
)

LLAMA31_405B = ModelConfig(
    name="llama3.1-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab_size=128256, rope_theta=5.0e5,
)
