"""mistral-large-123b [dense].
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]  88L d_model=12288
96H (GQA kv=8) d_ff=28672 vocab=32768."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768,
    rope_theta=1.0e6,
)

SMOKE = ModelConfig(
    name="mistral-large-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, head_dim=16,
    d_ff=192, vocab_size=97,
)
