"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained.
[hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936."""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    n_experts=128, top_k=8, d_ff_expert=768,
    rope_theta=1.0e6,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=97, n_experts=16, top_k=4, d_ff_expert=32,
)
