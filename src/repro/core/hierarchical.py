"""Hierarchical all-reduce strategies (the paper's core contribution, in JAX).

The paper's NVRAR decomposes a multi-node all-reduce into
(1) intra-node reduce-scatter, (2) inter-node recursive doubling,
(3) intra-node all-gather.  Here the "node" boundary is the TPU pod boundary:
fast axes live on ICI, slow axes on DCN.  All functions in this module are
called *inside* ``jax.shard_map``; with empty axis tuples they are identities,
so the same model code runs single-device.

Strategies (selected by ``ParallelCtx.ar_strategy``):

====================  =======================================================
flat                  one XLA all-reduce over all TP axes (NCCL-default
                      analogue; XLA picks its own lowering)
hier_ring             RS(fast) + psum(slow) + AG(fast) (2D-HRA style baseline)
hier_rd               RS(fast) + XOR-peer recursive doubling(slow) + AG(fast)
                      == NVRAR (Algorithm 1) expressed with lax.ppermute
hier_rd_halving       RS(fast) + recursive halving/doubling(slow) + AG(fast)
                      (bandwidth-optimal beyond-paper variant)
====================  =======================================================

Extras mirroring the paper's Sec. 4.2 optimizations where they transfer to
TPU: chunked slow-axis exchange (4.2.1) and an int8-compressed exchange whose
piggybacked scales play the role of the paper's fused payload metadata (4.2.2;
see DESIGN.md for why flag words themselves do not transfer).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import compat  # noqa: F401  (installs lax.axis_size on older jax)
from .pcontext import ParallelCtx

Axes = Tuple[str, ...]


# ---------------------------------------------------------------------------
# Axis utilities
# ---------------------------------------------------------------------------


def axes_size(axes: Sequence[str]) -> int:
    """Product of axis sizes (static inside shard_map)."""
    n = 1
    for a in axes:
        n *= lax.axis_size(a)
    return n


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _xor_perm(n: int, stride: int):
    return [(j, j ^ stride) for j in range(n)]


# ---------------------------------------------------------------------------
# Recursive doubling over one (slow) axis  — paper Algorithm 1, inter phase
# ---------------------------------------------------------------------------


def rd_all_reduce(x: jax.Array, axis: str, chunks: int = 1) -> jax.Array:
    """Recursive-doubling all-reduce over ``axis`` via XOR-peer ppermute.

    log2(N) steps; at step i every rank exchanges its full partial sum with
    peer ``rank ^ 2**i`` and reduces locally — exactly Algorithm 1's
    ``RD_inter`` (full-exchange form).  Requires a power-of-two axis size
    (falls back to ``lax.psum`` otherwise, mirroring how NVRAR falls back to
    NCCL on non-power-of-two node counts).

    ``chunks>1`` splits the payload into independently exchanged chunks
    (paper Sec. 4.2.1): each chunk's ppermute/add chain is independent, which
    the TPU scheduler can overlap (exchange of chunk q+1 with reduction of
    chunk q).
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)
    if chunks <= 1:
        y = x
        step = 1
        while step < n:
            y = y + lax.ppermute(y, axis, _xor_perm(n, step))
            step <<= 1
        return y
    # Chunked: flatten, pad to a multiple of `chunks`, exchange per chunk.
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % chunks
    if pad:
        flat = jnp.pad(flat, (0, pad))
    parts = list(jnp.split(flat, chunks))
    step = 1
    while step < n:
        perm = _xor_perm(n, step)
        recv = [lax.ppermute(p, axis, perm) for p in parts]
        parts = [p + r for p, r in zip(parts, recv)]
        step <<= 1
    out = jnp.concatenate(parts)
    if pad:
        out = out[: out.shape[0] - pad]
    return out.reshape(x.shape)


def rd_halving_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Recursive-halving reduce-scatter + recursive-doubling all-gather.

    Bandwidth-optimal all-reduce over the slow axis: step i of the RS phase
    exchanges half of the remaining payload with peer ``rank ^ 2**i``; the AG
    phase mirrors it.  Total payload 2(N-1)/N |M| vs Algorithm 1's
    log2(N) |M|.  Beyond-paper optimization for the medium-message regime.
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)

    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))

    # --- reduce-scatter by recursive halving -------------------------------
    # Work on a (n, chunk) view; each rank keeps a shrinking slice.  We track
    # the slice implicitly by reordering: at every step each rank splits its
    # current buffer in two halves; which half it keeps depends on its rank
    # bit.  lax.ppermute sends the *other* half.
    buf = flat.reshape(n, -1)  # n logical chunks
    # Textbook recursive halving: at the step with stride s, each rank keeps
    # the half of its current slice that contains its own chunk (decided by
    # the rank bit at that level) and sends the other half to peer rank^s.
    # The kept-slice size is identical on every rank, so halves can be
    # selected branchlessly on the traced rank index.
    idx = lax.axis_index(axis)
    stride = n >> 1
    size = n
    while size > 1:
        half = size // 2
        keep_hi = ((idx // stride) % 2).astype(bool)  # True -> keep upper half
        lower, upper = buf[:half], buf[half:]
        send_buf = jnp.where(keep_hi, lower, upper)
        keep_buf = jnp.where(keep_hi, upper, lower)
        recv = lax.ppermute(send_buf, axis, _xor_perm(n, stride))
        buf = keep_buf + recv
        size = half
        stride >>= 1
    # buf: (1, chunk) — this rank's fully reduced chunk (chunk index == rank
    # bit pattern).  All-gather back by recursive doubling.
    stride = 1
    while stride < n:
        recv = lax.ppermute(buf, axis, _xor_perm(n, stride))
        # Order matters: the peer's slice is adjacent; whether it goes before
        # or after ours depends on the rank bit at this level.
        bit = ((idx // stride) % 2).astype(bool)  # True -> our slice is upper
        buf = jnp.where(bit,
                        jnp.concatenate([recv, buf], axis=0),
                        jnp.concatenate([buf, recv], axis=0))
        stride <<= 1
    out = buf.reshape(-1)
    if pad:
        out = out[: out.shape[0] - pad]
    return out.reshape(shape)


def compressed_rd_all_reduce(x: jax.Array, axis: str,
                             group: int = 128) -> jax.Array:
    """Recursive doubling with int8-quantized exchanges.

    Each step quantizes the outgoing partial sum to int8 with per-group
    (``group`` elements) bf16 scales, exchanges payload+scales (the TPU
    analogue of the paper's eta-packed fused payload), dequantizes and
    reduces in f32.  eta = 1 + 2/group /? (int8 payload is 4x smaller than
    f32; scales add 2/group bytes per element).
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)
    orig_dtype = x.dtype
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    acc = flat
    step = 1
    while step < n:
        g = acc.reshape(-1, group)
        scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        perm = _xor_perm(n, step)
        q_peer = lax.ppermute(q, axis, perm)
        s_peer = lax.ppermute(scale.astype(jnp.bfloat16), axis, perm)
        acc = acc + (q_peer.astype(jnp.float32)
                     * s_peer.astype(jnp.float32)).reshape(-1)
        step <<= 1
    if pad:
        acc = acc[: acc.shape[0] - pad]
    return acc.reshape(shape).astype(orig_dtype)


# ---------------------------------------------------------------------------
# The hierarchical all-reduce entry points (used by every TP layer)
# ---------------------------------------------------------------------------


def _resolve_auto(x: jax.Array, ctx: ParallelCtx) -> ParallelCtx:
    """Concretize ar_strategy='auto' for this call site.

    Shapes are static under jit/shard_map, so the dispatch happens at trace
    time: each call site lowers with the strategy the autotuner picks for its
    (message bytes, fast size, slow size, dtype) key."""
    if ctx.ar_strategy != "auto":
        return ctx
    from . import autotune
    msg_bytes = x.size * x.dtype.itemsize
    return autotune.resolve(ctx, msg_bytes, axes_size(ctx.tp_fast),
                            axes_size(ctx.tp_slow), x.dtype.name)


def _slow_phase(x: jax.Array, slow: Axes, ctx: ParallelCtx) -> jax.Array:
    for ax in slow:
        if ctx.ar_strategy == "hier_ring":
            x = lax.psum(x, ax)
        elif ctx.ar_strategy == "hier_rd":
            if ctx.compress_slow:
                x = compressed_rd_all_reduce(x, ax)
            else:
                x = rd_all_reduce(x, ax, chunks=ctx.rd_chunks)
        elif ctx.ar_strategy == "hier_rd_halving":
            x = rd_halving_all_reduce(x, ax)
        else:  # pragma: no cover
            raise ValueError(ctx.ar_strategy)
    return x


def quantized_all_gather(x: jax.Array, axes: Axes, dim: int,
                         group: int = 128) -> jax.Array:
    """All-gather with int8 payload + per-group bf16 scales.

    The gathered value is each shard's FINAL (already-reduced) slice, so
    quantization error does not accumulate across devices — one rounding of
    the output activations (per-128-group scales keep it ~0.3% relative).
    """
    orig_dtype = x.dtype
    moved = jnp.moveaxis(x, dim, -1)
    shape = moved.shape
    flat = moved.reshape(-1)
    pad = (-flat.shape[0]) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, group).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=-1, keepdims=True)
                        / 127.0, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    qg = lax.all_gather(q.reshape(-1), axes, axis=0, tiled=False)
    sg = lax.all_gather(scale.astype(jnp.bfloat16).reshape(-1), axes,
                        axis=0, tiled=False)
    # qg: (n, flat) stacked shards -> dequantize and stitch along dim
    n = qg.shape[0]
    deq = (qg.reshape(n, -1, group).astype(jnp.float32)
           * sg.reshape(n, -1, 1).astype(jnp.float32)).reshape(n, -1)
    if pad:
        deq = deq[:, :-pad]
    out = deq.reshape((n,) + shape)
    out = jnp.concatenate(list(out), axis=-1)
    return jnp.moveaxis(out, -1, dim).astype(orig_dtype)


def tp_all_reduce(x: jax.Array, ctx: ParallelCtx,
                  scatter_dim: int = -1) -> jax.Array:
    """All-reduce a TP partial sum according to the configured strategy.

    This is the operation the paper optimizes: in decode it runs twice per
    transformer layer on a (B, 1, d_model) tensor (the B x H small-message
    regime of Sec. 3.5).

    ``scatter_dim`` is the dimension along which the hierarchical strategies
    reduce-scatter over the fast axes (must be divisible by the fast-axes
    size; model dims here always are — validated at config time).
    """
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if not fast and not slow:
        return x
    ctx = _resolve_auto(x, ctx)
    if (ctx.ar_strategy == "flat" or (not slow and len(fast) <= 1)) \
            and not ctx.quant_ag:
        # Single-level group: hand the whole reduction to XLA (the paper's
        # "NCCL default" baseline) — hierarchy needs two levels to matter.
        return lax.psum(x, slow + fast)
    if not slow and len(fast) > 1:
        # Two+ fast axes (e.g. 256-way TP over ("data","model")): treat the
        # innermost axis as the fast level and the rest as slow-ish levels.
        fast, slow = fast[-1:], fast[:-1]
    dim = scatter_dim % x.ndim
    if not fast:
        return _slow_phase(x, slow, ctx)
    # Phase 1: reduce-scatter over the fast level (paper Eq. 3).
    y = lax.psum_scatter(x, fast, scatter_dimension=dim, tiled=True)
    # Phase 2: recursive doubling (or ring) over the slow level (Eq. 4).
    if slow:
        y = _slow_phase(y, slow, ctx if ctx.ar_strategy != "flat"
                        else ctx.replace(ar_strategy="hier_ring"))
    # Phase 3: all-gather over the fast level (Eq. 5).
    if ctx.quant_ag:
        return quantized_all_gather(y, fast, dim)
    return lax.all_gather(y, fast, axis=dim, tiled=True)


def tp_reduce_scatter(x: jax.Array, ctx: ParallelCtx,
                      dim: int) -> jax.Array:
    """Sequence-parallel form: reduce TP partials, leave result sharded on
    ``dim`` over the fast axes (Megatron-SP).  Slow-axis phase still runs in
    full so the result is correct across pods.

    Slow-phase strategy selection mirrors :func:`tp_all_reduce`: ``flat``
    hands the cross-pod sum to XLA (``lax.psum``), every hierarchical
    strategy runs its own inter phase via ``_slow_phase`` (ring / recursive
    doubling / halving).  (PR 5 bugfix: this used to bury the flat case in
    a conditional that could never fire, so ``hier_ring`` bypassed
    ``_slow_phase`` and ``flat`` was selected by dead code.)
    """
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if not fast and not slow:
        return x
    ctx = _resolve_auto(x, ctx)
    dim = dim % x.ndim
    if fast:
        x = lax.psum_scatter(x, fast, scatter_dimension=dim, tiled=True)
    if slow:
        if ctx.ar_strategy == "flat":
            x = lax.psum(x, slow)
        else:
            x = _slow_phase(x, slow, ctx)
    return x


def tp_all_gather(x: jax.Array, ctx: ParallelCtx, dim: int) -> jax.Array:
    """Gather a sequence-sharded activation back to full along ``dim``."""
    if not ctx.tp_fast:
        return x
    if ctx.quant_ag:
        return quantized_all_gather(x, ctx.tp_fast, dim % x.ndim)
    return lax.all_gather(x, ctx.tp_fast, axis=dim % x.ndim, tiled=True)


# ---------------------------------------------------------------------------
# Gradient reduction across pods (training integration of the technique)
# ---------------------------------------------------------------------------


def grad_cross_pod_reduce(grads, ctx: ParallelCtx, pod_axes: Axes):
    """Reduce gradients across the slow (pod) axes.

    Gradients are already reduce-scattered over the FSDP axis by AD; what
    remains is the cross-pod sum — the exact regime of the paper's inter-node
    phase.  Strategy per ``ctx.grad_reduce_strategy``:
      flat     - lax.psum (XLA default)
      rd       - recursive doubling (NVRAR inter-node phase)
      rd_int8  - recursive doubling with int8-compressed exchange
    """
    if not pod_axes:
        return grads
    strat = ctx.grad_reduce_strategy

    def red(g):
        out = g
        for ax in pod_axes:
            if strat == "flat":
                out = lax.psum(out, ax)
            elif strat == "rd":
                out = rd_all_reduce(out, ax, chunks=ctx.rd_chunks)
            elif strat == "rd_int8":
                out = compressed_rd_all_reduce(out, ax)
            else:  # pragma: no cover
                raise ValueError(strat)
        return out

    return jax.tree.map(red, grads)


def dp_psum_mean(x: jax.Array, ctx: ParallelCtx) -> jax.Array:
    """Mean over all batch axes (for loss/metric aggregation)."""
    axes = ctx.dp
    if not axes:
        return x
    return lax.psum(x, axes) / axes_size(axes)


__all__ = [
    "rd_all_reduce", "rd_halving_all_reduce", "compressed_rd_all_reduce",
    "tp_all_reduce", "tp_reduce_scatter", "tp_all_gather",
    "grad_cross_pod_reduce", "dp_psum_mean", "axes_size",
]
