"""Hierarchical all-reduce strategies (the paper's core contribution, in JAX).

The paper's NVRAR decomposes a multi-node all-reduce into
(1) intra-node reduce-scatter, (2) inter-node recursive doubling,
(3) intra-node all-gather.  Here the "node" boundary is the TPU pod boundary:
fast axes live on ICI, slow axes on DCN.  All functions in this module are
called *inside* ``jax.shard_map``; with empty axis tuples they are identities,
so the same model code runs single-device.

Strategies (selected by ``ParallelCtx.ar_strategy``):

====================  =======================================================
flat                  one XLA all-reduce over all TP axes (NCCL-default
                      analogue; XLA picks its own lowering)
hier_ring             RS(fast) + psum(slow) + AG(fast) (2D-HRA style baseline)
hier_rd               RS(fast) + XOR-peer recursive doubling(slow) + AG(fast)
                      == NVRAR (Algorithm 1) expressed with lax.ppermute
hier_rd_halving       RS(fast) + recursive halving/doubling(slow) + AG(fast)
                      (bandwidth-optimal beyond-paper variant)
====================  =======================================================

Extras mirroring the paper's Sec. 4.2 optimizations where they transfer to
TPU: chunked slow-axis exchange (4.2.1) and an int8-compressed exchange whose
piggybacked scales play the role of the paper's fused payload metadata (4.2.2;
see DESIGN.md for why flag words themselves do not transfer).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import compat  # noqa: F401  (installs lax.axis_size on older jax)
from .pcontext import ParallelCtx
from ..kernels.rd_allreduce import quant as _q

Axes = Tuple[str, ...]

# ctx.ar_quant level -> wire bits (levels beyond "none"/"auto").
QUANT_BITS = {"int8": 8, "int4": 4}


# ---------------------------------------------------------------------------
# Axis utilities
# ---------------------------------------------------------------------------


def axes_size(axes: Sequence[str]) -> int:
    """Product of axis sizes (static inside shard_map)."""
    n = 1
    for a in axes:
        n *= lax.axis_size(a)
    return n


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def _xor_perm(n: int, stride: int):
    return [(j, j ^ stride) for j in range(n)]


# ---------------------------------------------------------------------------
# Recursive doubling over one (slow) axis  — paper Algorithm 1, inter phase
# ---------------------------------------------------------------------------


def rd_all_reduce(x: jax.Array, axis: str, chunks: int = 1) -> jax.Array:
    """Recursive-doubling all-reduce over ``axis`` via XOR-peer ppermute.

    log2(N) steps; at step i every rank exchanges its full partial sum with
    peer ``rank ^ 2**i`` and reduces locally — exactly Algorithm 1's
    ``RD_inter`` (full-exchange form).  Requires a power-of-two axis size
    (falls back to ``lax.psum`` otherwise, mirroring how NVRAR falls back to
    NCCL on non-power-of-two node counts).

    ``chunks>1`` splits the payload into independently exchanged chunks
    (paper Sec. 4.2.1): each chunk's ppermute/add chain is independent, which
    the TPU scheduler can overlap (exchange of chunk q+1 with reduction of
    chunk q).
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)
    if chunks <= 1:
        y = x
        step = 1
        while step < n:
            y = y + lax.ppermute(y, axis, _xor_perm(n, step))
            step <<= 1
        return y
    # Chunked: flatten, pad to a multiple of `chunks`, exchange per chunk.
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % chunks
    if pad:
        flat = jnp.pad(flat, (0, pad))
    parts = list(jnp.split(flat, chunks))
    step = 1
    while step < n:
        perm = _xor_perm(n, step)
        recv = [lax.ppermute(p, axis, perm) for p in parts]
        parts = [p + r for p, r in zip(parts, recv)]
        step <<= 1
    out = jnp.concatenate(parts)
    if pad:
        out = out[: out.shape[0] - pad]
    return out.reshape(x.shape)


def rd_halving_all_reduce(x: jax.Array, axis: str) -> jax.Array:
    """Recursive-halving reduce-scatter + recursive-doubling all-gather.

    Bandwidth-optimal all-reduce over the slow axis: step i of the RS phase
    exchanges half of the remaining payload with peer ``rank ^ 2**i``; the AG
    phase mirrors it.  Total payload 2(N-1)/N |M| vs Algorithm 1's
    log2(N) |M|.  Beyond-paper optimization for the medium-message regime.
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)

    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))

    # --- reduce-scatter by recursive halving -------------------------------
    # Work on a (n, chunk) view; each rank keeps a shrinking slice.  We track
    # the slice implicitly by reordering: at every step each rank splits its
    # current buffer in two halves; which half it keeps depends on its rank
    # bit.  lax.ppermute sends the *other* half.
    buf = flat.reshape(n, -1)  # n logical chunks
    # Textbook recursive halving: at the step with stride s, each rank keeps
    # the half of its current slice that contains its own chunk (decided by
    # the rank bit at that level) and sends the other half to peer rank^s.
    # The kept-slice size is identical on every rank, so halves can be
    # selected branchlessly on the traced rank index.
    idx = lax.axis_index(axis)
    stride = n >> 1
    size = n
    while size > 1:
        half = size // 2
        keep_hi = ((idx // stride) % 2).astype(bool)  # True -> keep upper half
        lower, upper = buf[:half], buf[half:]
        send_buf = jnp.where(keep_hi, lower, upper)
        keep_buf = jnp.where(keep_hi, upper, lower)
        recv = lax.ppermute(send_buf, axis, _xor_perm(n, stride))
        buf = keep_buf + recv
        size = half
        stride >>= 1
    # buf: (1, chunk) — this rank's fully reduced chunk (chunk index == rank
    # bit pattern).  All-gather back by recursive doubling.
    stride = 1
    while stride < n:
        recv = lax.ppermute(buf, axis, _xor_perm(n, stride))
        # Order matters: the peer's slice is adjacent; whether it goes before
        # or after ours depends on the rank bit at this level.
        bit = ((idx // stride) % 2).astype(bool)  # True -> our slice is upper
        buf = jnp.where(bit,
                        jnp.concatenate([recv, buf], axis=0),
                        jnp.concatenate([buf, recv], axis=0))
        stride <<= 1
    out = buf.reshape(-1)
    if pad:
        out = out[: out.shape[0] - pad]
    return out.reshape(shape)


def compressed_rd_all_reduce(x: jax.Array, axis: str,
                             group: int = 128) -> jax.Array:
    """Recursive doubling with int8-quantized exchanges.

    Each step quantizes the outgoing partial sum to int8 with per-group
    (``group`` elements) bf16 scales, exchanges payload+scales (the TPU
    analogue of the paper's eta-packed fused payload), dequantizes and
    reduces in f32.  eta = 1 + 2/group /? (int8 payload is 4x smaller than
    f32; scales add 2/group bytes per element).
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)
    orig_dtype = x.dtype
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    acc = flat
    step = 1
    while step < n:
        g = acc.reshape(-1, group)
        scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        perm = _xor_perm(n, step)
        q_peer = lax.ppermute(q, axis, perm)
        s_peer = lax.ppermute(scale.astype(jnp.bfloat16), axis, perm)
        acc = acc + (q_peer.astype(jnp.float32)
                     * s_peer.astype(jnp.float32)).reshape(-1)
        step <<= 1
    if pad:
        acc = acc[: acc.shape[0] - pad]
    return acc.reshape(shape).astype(orig_dtype)


# ---------------------------------------------------------------------------
# Quantized collective phases (ar_quant = int8 | int4)
# ---------------------------------------------------------------------------
#
# Flash-Communication-style low-bit wire: every phase of the hierarchical
# all-reduce carries nibble/byte-packed int8 payloads + per-group bf16
# scales (layout contract in kernels.rd_allreduce.quant).  The fast-level
# reduce-scatter becomes an all_to_all on packed data with a local
# dequantize-sum — every rank sums the SAME dequantized values, so the
# reduced result is exactly replicated (no rank drift).  Error feedback:
# the RS phase is where this rank's contribution is quantized, so it
# returns ``err = v - dequant(quantize(v))`` for the caller to re-inject
# into the next step's message (the accumulator rides in the decode
# cache; DESIGN.md §12).  Slow-phase and all-gather requantization of the
# already-reduced partials is NOT captured by EF — it is one rounding of
# the output, not a per-rank bias, and is bounded by the logit-divergence
# gate instead.


def quant_rd_all_reduce(x: jax.Array, axis: str, bits: int) -> jax.Array:
    """Recursive doubling with a symmetric low-bit exchange.

    Unlike :func:`compressed_rd_all_reduce` (which keeps its own
    accumulator unquantized and lets XOR peers drift apart), BOTH sides of
    every step requantize: ``acc <- deq(Q(acc)) + deq(Q(acc_peer))``.
    The two peers of a step hold the same pair {acc, acc_peer}, so they
    compute identical sums — by induction the final accumulator is exactly
    replicated across the axis, which the all-gather phase requires.
    """
    n = lax.axis_size(axis)
    if n == 1:
        return x
    if not _is_pow2(n):
        return lax.psum(x, axis)
    orig_dtype, shape = x.dtype, x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    # Pad to an int8/int4 group-cap multiple; zero pads quantize exactly
    # and group windows stay cap-aligned (chunk-invariance contract).
    pad = (-flat.shape[0]) % 256
    if pad:
        flat = jnp.pad(flat, (0, pad))
    group = _q.GROUP_CAP[bits]
    acc = flat
    step = 1
    while step < n:
        q, s = _q.quantize_pack(acc, bits, group)
        perm = _xor_perm(n, step)
        q_peer = lax.ppermute(q, axis, perm)
        s_peer = lax.ppermute(s, axis, perm)
        acc = (_q.unpack_dequant(q, s, bits, group)
               + _q.unpack_dequant(q_peer, s_peer, bits, group))
        step <<= 1
    if pad:
        acc = acc[: acc.shape[0] - pad]
    return acc.reshape(shape).astype(orig_dtype)


def _pad_last(x: jax.Array, mult: int):
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def _quant_rs_one(v: jax.Array, axis: str, dim: int, bits: int,
                  want_err: bool):
    """One-axis reduce-scatter on a packed low-bit wire.

    ``v`` f32; splits ``dim`` into per-rank chunks, all_to_alls the packed
    payload+scales, and dequant-sums locally -> (scattered f32, err f32 or
    None) where ``err = v - deq(Q(v))`` over the full pre-scatter shape.
    """
    n = lax.axis_size(axis)
    if n == 1:
        return v, (jnp.zeros_like(v) if want_err else None)
    dim = dim % v.ndim
    last = v.ndim - 1
    if dim == last:
        shard = v.shape[-1] // n
        group = _q.group_for(shard, bits)
        vq = v.reshape(v.shape[:-1] + (n, shard))
        q, s = _q.quantize_pack(vq, bits, group)
        ax_i = q.ndim - 2
        qx = lax.all_to_all(q, axis, split_axis=ax_i, concat_axis=ax_i)
        sx = lax.all_to_all(s, axis, split_axis=ax_i, concat_axis=ax_i)
        red = _q.unpack_dequant(qx, sx, bits, group).sum(axis=-2)
        err = None
        if want_err:
            err = v - _q.unpack_dequant(q, s, bits, group).reshape(v.shape)
        return red, err
    # Scatter along a non-trailing dim (e.g. the SP sequence dim): groups
    # stay on the feature (last) dim, untouched by the split.
    size = v.shape[dim]
    vm = jnp.moveaxis(v, dim, 0)
    vm = vm.reshape((n, size // n) + vm.shape[1:])
    vmp, pad = (vm, 0)
    if bits == 4 and vm.shape[-1] % 2:
        vmp, pad = _pad_last(vm, 2)
    group = _q.group_for(vmp.shape[-1], bits)
    q, s = _q.quantize_pack(vmp, bits, group)
    qx = lax.all_to_all(q, axis, split_axis=0, concat_axis=0)
    sx = lax.all_to_all(s, axis, split_axis=0, concat_axis=0)
    deq = _q.unpack_dequant(qx, sx, bits, group)
    deq_own = _q.unpack_dequant(q, s, bits, group) if want_err else None
    if pad:
        deq = deq[..., :-pad]
        deq_own = deq_own[..., :-pad] if want_err else None
    red = jnp.moveaxis(deq.sum(axis=0), 0, dim)
    err = None
    if want_err:
        own = jnp.moveaxis(deq_own.reshape((size,) + vm.shape[2:]), 0, dim)
        err = v - own
    return red, err


def _quant_reduce_scatter(v: jax.Array, axes: Axes, dim: int, bits: int,
                          want_err: bool):
    """Reduce-scatter over ``axes`` (applied per axis, innermost last) with
    packed wire.  ``err`` captures the FIRST stage's quantization of ``v``
    (where this rank's own contribution is rounded); later stages
    requantize partial sums, which EF by design does not chase."""
    err = None
    for i, ax in enumerate(axes):
        v, e = _quant_rs_one(v, ax, dim, bits, want_err and i == 0)
        if i == 0:
            err = e
    return v, err


def _quant_ag_one(y: jax.Array, axis: str, dim: int, bits: int):
    n = lax.axis_size(axis)
    if n == 1:
        return y
    dim = dim % y.ndim
    yp, pad = (y, 0)
    if bits == 4 and y.shape[-1] % 2:
        yp, pad = _pad_last(y, 2)
    group = _q.group_for(yp.shape[-1], bits)
    q, s = _q.quantize_pack(yp, bits, group)
    qg = lax.all_gather(q, axis, axis=0, tiled=False)
    sg = lax.all_gather(s, axis, axis=0, tiled=False)
    deq = _q.unpack_dequant(qg, sg, bits, group)      # (n,) + yp.shape
    if pad:
        deq = deq[..., :-pad]
    out = jnp.moveaxis(deq, 0, dim)                   # n right before dim
    return out.reshape(y.shape[:dim] + (n * y.shape[dim],)
                       + y.shape[dim + 1:])


def _quant_all_gather(y: jax.Array, axes: Axes, dim: int,
                      bits: int) -> jax.Array:
    """All-gather over ``axes`` with packed wire — inverse shard order of
    :func:`_quant_reduce_scatter` (innermost axis gathered first)."""
    for ax in reversed(axes):
        y = _quant_ag_one(y, ax, dim, bits)
    return y


def _quant_slow_phase(x: jax.Array, slow: Axes, ctx: ParallelCtx,
                      bits: int) -> jax.Array:
    """Slow-axis phase under ar_quant: recursive-doubling strategies carry
    the quantized exchange; ring/flat hand XLA a bf16 sum (full-precision
    wire at bf16 width, matching the unquantized path's cost model)."""
    for ax in slow:
        if ctx.ar_strategy in ("hier_rd", "hier_rd_halving"):
            x = quant_rd_all_reduce(x, ax, bits)
        else:
            x = lax.psum(x.astype(jnp.bfloat16), ax).astype(x.dtype)
    return x


def _quant_scatter_ok(x: jax.Array, fast: Axes, dim: int,
                      bits: int) -> bool:
    """Static shape guard for the packed RS path: every axis split must
    divide the scatter dim, and an int4 trailing-dim shard must be even
    (nibble pairs); otherwise callers keep full-precision wire."""
    dim = dim % x.ndim
    size = x.shape[dim]
    for ax in fast:
        n = lax.axis_size(ax)
        if size % n:
            return False
        size //= n
    if bits == 4 and dim == x.ndim - 1 and size % 2:
        return False
    return True


def _quant_tp_all_reduce(x: jax.Array, ctx: ParallelCtx, scatter_dim: int,
                         ef: Optional[jax.Array]):
    """Quantized-wire all-reduce: RS(packed) + slow(packed RD) + AG(packed).

    Returns (y, new_ef); ``new_ef`` is None iff ``ef`` is None, otherwise
    the error-feedback residue this rank must re-inject next step."""
    bits = QUANT_BITS[ctx.ar_quant]
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if ctx.ar_strategy == "flat":
        # Single-level group: still quantize the wire — RS+AG over ALL tp
        # axes is the AR-equivalent decomposition with packed payloads.
        fast, slow = slow + fast, ()
    elif not slow and len(fast) > 1:
        fast, slow = fast[-1:], fast[:-1]
    dim = scatter_dim % x.ndim
    v = x.astype(jnp.float32)
    if ef is not None:
        v = v + ef.astype(jnp.float32)
    if not fast:
        # Slow-only TP group: the quantized RD rounds the whole exchange;
        # there is no per-rank RS rounding to feed back, so EF stays zero.
        y = _quant_slow_phase(v, slow, ctx, bits)
        return y.astype(x.dtype), (jnp.zeros_like(v) if ef is not None
                                   else None)
    if not _quant_scatter_ok(x, fast, dim, bits):
        # Shape can't shard cleanly: keep full-precision wire, EF untouched.
        y = lax.psum(x, ctx.tp_slow + ctx.tp_fast)
        return y, (ef if ef is not None else None)
    red, err = _quant_reduce_scatter(v, fast, dim, bits,
                                     want_err=ef is not None)
    if slow:
        red = _quant_slow_phase(red, slow, ctx, bits)
    y = _quant_all_gather(red, fast, dim, bits)
    return y.astype(x.dtype), err


# ---------------------------------------------------------------------------
# The hierarchical all-reduce entry points (used by every TP layer)
# ---------------------------------------------------------------------------


def _resolve_auto(x: jax.Array, ctx: ParallelCtx) -> ParallelCtx:
    """Concretize ar_strategy='auto' for this call site.

    Shapes are static under jit/shard_map, so the dispatch happens at trace
    time: each call site lowers with the strategy the autotuner picks for its
    (message bytes, fast size, slow size, dtype) key."""
    if ctx.ar_strategy != "auto":
        return ctx
    from . import autotune
    msg_bytes = x.size * x.dtype.itemsize
    return autotune.resolve(ctx, msg_bytes, axes_size(ctx.tp_fast),
                            axes_size(ctx.tp_slow), x.dtype.name)


def _slow_phase(x: jax.Array, slow: Axes, ctx: ParallelCtx) -> jax.Array:
    for ax in slow:
        if ctx.ar_strategy == "hier_ring":
            x = lax.psum(x, ax)
        elif ctx.ar_strategy == "hier_rd":
            if ctx.compress_slow:
                x = compressed_rd_all_reduce(x, ax)
            else:
                x = rd_all_reduce(x, ax, chunks=ctx.rd_chunks)
        elif ctx.ar_strategy == "hier_rd_halving":
            x = rd_halving_all_reduce(x, ax)
        else:  # pragma: no cover
            raise ValueError(ctx.ar_strategy)
    return x


def quantized_all_gather(x: jax.Array, axes: Axes, dim: int,
                         group: int = 128) -> jax.Array:
    """All-gather with int8 payload + per-group bf16 scales.

    The gathered value is each shard's FINAL (already-reduced) slice, so
    quantization error does not accumulate across devices — one rounding of
    the output activations (per-128-group scales keep it ~0.3% relative).
    """
    orig_dtype = x.dtype
    moved = jnp.moveaxis(x, dim, -1)
    shape = moved.shape
    flat = moved.reshape(-1)
    pad = (-flat.shape[0]) % group
    if pad:
        flat = jnp.pad(flat, (0, pad))
    g = flat.reshape(-1, group).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g), axis=-1, keepdims=True)
                        / 127.0, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    qg = lax.all_gather(q.reshape(-1), axes, axis=0, tiled=False)
    sg = lax.all_gather(scale.astype(jnp.bfloat16).reshape(-1), axes,
                        axis=0, tiled=False)
    # qg: (n, flat) stacked shards -> dequantize and stitch along dim
    n = qg.shape[0]
    deq = (qg.reshape(n, -1, group).astype(jnp.float32)
           * sg.reshape(n, -1, 1).astype(jnp.float32)).reshape(n, -1)
    if pad:
        deq = deq[:, :-pad]
    out = deq.reshape((n,) + shape)
    out = jnp.concatenate(list(out), axis=-1)
    return jnp.moveaxis(out, -1, dim).astype(orig_dtype)


def _tp_all_reduce_fp(x: jax.Array, ctx: ParallelCtx,
                      scatter_dim: int) -> jax.Array:
    """Full-precision-wire all-reduce body (strategy already resolved)."""
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if (ctx.ar_strategy == "flat" or (not slow and len(fast) <= 1)) \
            and not ctx.quant_ag:
        # Single-level group: hand the whole reduction to XLA (the paper's
        # "NCCL default" baseline) — hierarchy needs two levels to matter.
        return lax.psum(x, slow + fast)
    if not slow and len(fast) > 1:
        # Two+ fast axes (e.g. 256-way TP over ("data","model")): treat the
        # innermost axis as the fast level and the rest as slow-ish levels.
        fast, slow = fast[-1:], fast[:-1]
    dim = scatter_dim % x.ndim
    if not fast:
        return _slow_phase(x, slow, ctx)
    # Phase 1: reduce-scatter over the fast level (paper Eq. 3).
    y = lax.psum_scatter(x, fast, scatter_dimension=dim, tiled=True)
    # Phase 2: recursive doubling (or ring) over the slow level (Eq. 4).
    if slow:
        y = _slow_phase(y, slow, ctx if ctx.ar_strategy != "flat"
                        else ctx.replace(ar_strategy="hier_ring"))
    # Phase 3: all-gather over the fast level (Eq. 5).
    if ctx.quant_ag:
        return quantized_all_gather(y, fast, dim)
    return lax.all_gather(y, fast, axis=dim, tiled=True)


def tp_all_reduce(x: jax.Array, ctx: ParallelCtx, scatter_dim: int = -1,
                  ef: Optional[jax.Array] = None):
    """All-reduce a TP partial sum according to the configured strategy.

    This is the operation the paper optimizes: in decode it runs twice per
    transformer layer on a (B, 1, d_model) tensor (the B x H small-message
    regime of Sec. 3.5).

    ``scatter_dim`` is the dimension along which the hierarchical strategies
    reduce-scatter over the fast axes (must be divisible by the fast-axes
    size; model dims here always are — validated at config time).

    ``ctx.ar_quant`` in {int8, int4} (forced, or resolved per call site by
    the autotuner when ar_quant="auto") routes through the packed low-bit
    wire.  ``ef`` is the error-feedback accumulator for this call site:
    when given, the call returns ``(y, new_ef)`` — the quantized paths add
    ``ef`` to the outgoing message and return the fresh rounding residue;
    unquantized paths pass ``ef`` through untouched — so call sites can
    thread EF unconditionally and let dispatch decide.  Without ``ef`` the
    return is the plain array (lossy levels then quantize one-shot).
    """
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if not fast and not slow:
        return (x, ef) if ef is not None else x
    ctx = _resolve_auto(x, ctx)
    if ctx.ar_quant in QUANT_BITS:
        y, ef2 = _quant_tp_all_reduce(x, ctx, scatter_dim, ef)
        return (y, ef2) if ef is not None else y
    y = _tp_all_reduce_fp(x, ctx, scatter_dim)
    return (y, ef) if ef is not None else y


def tp_reduce_scatter(x: jax.Array, ctx: ParallelCtx,
                      dim: int) -> jax.Array:
    """Sequence-parallel form: reduce TP partials, leave result sharded on
    ``dim`` over the fast axes (Megatron-SP).  Slow-axis phase still runs in
    full so the result is correct across pods.

    Slow-phase strategy selection mirrors :func:`tp_all_reduce`: ``flat``
    hands the cross-pod sum to XLA (``lax.psum``), every hierarchical
    strategy runs its own inter phase via ``_slow_phase`` (ring / recursive
    doubling / halving).  (PR 5 bugfix: this used to bury the flat case in
    a conditional that could never fire, so ``hier_ring`` bypassed
    ``_slow_phase`` and ``flat`` was selected by dead code.)
    """
    fast, slow = ctx.tp_fast, ctx.tp_slow
    if not fast and not slow:
        return x
    ctx = _resolve_auto(x, ctx)
    dim = dim % x.ndim
    if ctx.ar_quant in QUANT_BITS and fast \
            and _quant_scatter_ok(x, fast, dim, QUANT_BITS[ctx.ar_quant]):
        bits = QUANT_BITS[ctx.ar_quant]
        y, _ = _quant_reduce_scatter(x.astype(jnp.float32), fast, dim,
                                     bits, want_err=False)
        if slow:
            y = _quant_slow_phase(y, slow, ctx, bits)
        return y.astype(x.dtype)
    if fast:
        x = lax.psum_scatter(x, fast, scatter_dimension=dim, tiled=True)
    if slow:
        if ctx.ar_strategy == "flat":
            x = lax.psum(x, slow)
        else:
            x = _slow_phase(x, slow, ctx)
    return x


def tp_all_gather(x: jax.Array, ctx: ParallelCtx, dim: int) -> jax.Array:
    """Gather a sequence-sharded activation back to full along ``dim``."""
    if not ctx.tp_fast:
        return x
    if ctx.ar_quant in QUANT_BITS:
        return _quant_all_gather(x.astype(jnp.float32), ctx.tp_fast,
                                 dim % x.ndim,
                                 QUANT_BITS[ctx.ar_quant]).astype(x.dtype)
    if ctx.quant_ag:
        return quantized_all_gather(x, ctx.tp_fast, dim % x.ndim)
    return lax.all_gather(x, ctx.tp_fast, axis=dim % x.ndim, tiled=True)


# ---------------------------------------------------------------------------
# Gradient reduction across pods (training integration of the technique)
# ---------------------------------------------------------------------------


def grad_cross_pod_reduce(grads, ctx: ParallelCtx, pod_axes: Axes):
    """Reduce gradients across the slow (pod) axes.

    Gradients are already reduce-scattered over the FSDP axis by AD; what
    remains is the cross-pod sum — the exact regime of the paper's inter-node
    phase.  Strategy per ``ctx.grad_reduce_strategy``:
      flat     - lax.psum (XLA default)
      rd       - recursive doubling (NVRAR inter-node phase)
      rd_int8  - recursive doubling with int8-compressed exchange
    """
    if not pod_axes:
        return grads
    strat = ctx.grad_reduce_strategy

    def red(g):
        out = g
        for ax in pod_axes:
            if strat == "flat":
                out = lax.psum(out, ax)
            elif strat == "rd":
                out = rd_all_reduce(out, ax, chunks=ctx.rd_chunks)
            elif strat == "rd_int8":
                out = compressed_rd_all_reduce(out, ax)
            else:  # pragma: no cover
                raise ValueError(strat)
        return out

    return jax.tree.map(red, grads)


def dp_psum_mean(x: jax.Array, ctx: ParallelCtx) -> jax.Array:
    """Mean over all batch axes (for loss/metric aggregation)."""
    axes = ctx.dp
    if not axes:
        return x
    return lax.psum(x, axes) / axes_size(axes)


__all__ = [
    "rd_all_reduce", "rd_halving_all_reduce", "compressed_rd_all_reduce",
    "quant_rd_all_reduce", "tp_all_reduce", "tp_reduce_scatter",
    "tp_all_gather", "grad_cross_pod_reduce", "dp_psum_mean", "axes_size",
    "QUANT_BITS",
]
