"""Message-size-aware all-reduce strategy autotuner.

The paper's Sec. 4.3/5 finding is that the best all-reduce algorithm is a
function of message size and topology: recursive doubling (NVRAR) wins in the
latency-bound 128 KB-2 MB regime, ring-style algorithms win once the transfer
is bandwidth-bound.  A single statically chosen ``ParallelCtx.ar_strategy``
therefore leaves performance on the table whenever one program contains
all-reduces on both sides of the crossover (decode: B x H activations; embed:
vocab partials; training: gradient buckets).

This module provides the dispatcher behind ``ar_strategy="auto"``:

* a **dispatch table** keyed on (message-byte bucket, fast-axis size,
  slow-axis size, dtype) mapping to an :class:`ARChoice`
  (strategy + rd_chunks + compression);
* **analytic seeding** from the alpha-beta models in
  :mod:`repro.core.comm_model` (each strategy's predicted time on the
  configured :class:`NetworkSpec`, honest full-exchange form for RD since
  that is what :func:`repro.core.hierarchical.rd_all_reduce` implements);
* **measurement refinement**: benchmarks record observed latencies with
  :meth:`AutoTuner.record`; :meth:`AutoTuner.refine` overrides the analytic
  pick wherever a measured winner exists;
* **JSON persistence** (:meth:`AutoTuner.save` / :meth:`AutoTuner.load`) so a
  tuned table survives across runs and can be shipped with a deployment.

Resolution happens at *trace time* inside ``tp_all_reduce`` — message sizes
are static under jit/shard_map, so "auto" costs nothing at runtime: each call
site is lowered with its own concrete strategy.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import threading
import warnings
from typing import Dict, List, Optional, Tuple, Union

from . import comm_model as cm

# Strategies the dispatcher may pick from (ParallelCtx.ar_strategy values).
DISPATCHABLE = ("flat", "hier_ring", "hier_rd", "hier_rd_halving")

# Wire-quantization levels a table entry may carry (ParallelCtx.ar_quant
# values minus "auto"; kept literal to avoid an import knot with pcontext).
QUANT_LEVELS = ("none", "int8", "int4")

# Persisted-table schema version (``to_json``); bump on incompatible
# layout changes.  ``load`` treats an unknown version as a corrupt table
# and degrades to analytic seeding rather than guessing.
TABLE_VERSION = 1

# Chunked slow-axis exchange kicks in once the per-step inter payload crosses
# this size (paper Sec. 4.2.1: overlap chunk q's reduce with chunk q+1's
# transfer); capped so per-chunk DMA issue latency stays amortized.
_CHUNK_THRESHOLD_BYTES = 256 * 1024
_MAX_RD_CHUNKS = 8


@dataclasses.dataclass(frozen=True)
class ARChoice:
    """One dispatch-table entry: a fully resolved all-reduce configuration."""

    strategy: str                 # one of DISPATCHABLE
    rd_chunks: int = 1            # slow-axis pipeline chunks (hier_rd only)
    compress_slow: bool = False   # int8-compress the slow exchange (lossy)
    quant: str = "none"           # wire quantization level (QUANT_LEVELS)

    def apply(self, ctx):
        """Concretize a ctx whose ar_strategy is 'auto' with this choice.

        ``quant`` is written back only when the ctx asked for
        ``ar_quant="auto"`` — a forced level (or "none") is the caller's
        decision and must survive dispatch.  Both fields go through one
        ``replace`` so the ctx validator never sees the half-resolved
        state (ar_quant='auto' with a concrete strategy)."""
        kw = dict(ar_strategy=self.strategy, rd_chunks=self.rd_chunks,
                  compress_slow=self.compress_slow)
        if getattr(ctx, "ar_quant", "none") == "auto":
            kw["ar_quant"] = self.quant
        return ctx.replace(**kw)


# ---------------------------------------------------------------------------
# Analytic model: predicted time per strategy
# ---------------------------------------------------------------------------


def predict_times(msg_bytes: float, fast_size: int, slow_size: int,
                  net: cm.NetworkSpec) -> Dict[str, float]:
    """Predicted all-reduce seconds per strategy on ``net``.

    Maps our TP topology onto the paper's (N nodes x G GPUs/node) frame:
    G = fast-axis (ICI) size, N = slow-axis (DCN) size.  ``flat`` is the
    XLA-default single-level ring whose inter-node links dominate (Eq. 1);
    the hierarchical strategies share RS/AG intra phases (Eqs. 3/5) and
    differ in the inter phase: ring, full-exchange recursive doubling
    (Algorithm 1 — what ``rd_all_reduce`` implements), or recursive
    halving/doubling.
    """
    g, n = max(1, fast_size), max(1, slow_size)
    if n <= 1:
        # Single-level group: every strategy degenerates to RS+AG over the
        # fast axis; only 'flat' vs hierarchy-with-one-level remain, and
        # they lower to the same collectives.  Report the intra ring time.
        t = 2.0 * cm.t_reduce_scatter_intra(msg_bytes, g, net)
        return {s: t for s in DISPATCHABLE}
    intra = (cm.t_reduce_scatter_intra(msg_bytes, g, net)
             + cm.t_allgather_intra(msg_bytes, g, net))
    shard = msg_bytes / g  # slow phase operates on the RS-scattered shard
    # inter-node ring all-reduce of the shard over n endpoints
    ring_inter = 2.0 * (n - 1) * net.alpha_inter \
        + 2.0 * (n - 1) / n * (shard / net.beta_inter)
    rd_inter = cm.t_rd_inter_full_exchange(msg_bytes, n, g, net)
    halving_inter = cm.t_rd_halving_inter(msg_bytes, n, g, net)
    return {
        "flat": cm.t_ring_allreduce(msg_bytes, n, g, net),
        "hier_ring": intra + ring_inter,
        "hier_rd": intra + rd_inter,
        "hier_rd_halving": intra + halving_inter,
    }


def _rd_chunks_for(msg_bytes: float, fast_size: int) -> int:
    """Pipeline chunk count for the hier_rd slow exchange (Sec. 4.2.1):
    one chunk per _CHUNK_THRESHOLD_BYTES of the RS-scattered shard,
    capped so per-chunk issue latency stays amortized."""
    shard = msg_bytes / max(1, fast_size)
    return int(min(_MAX_RD_CHUNKS,
                   max(1, shard // _CHUNK_THRESHOLD_BYTES)))


def predict_sp_times(msg_bytes: float, fast_size: int, slow_size: int,
                     net: cm.NetworkSpec) -> Dict[str, float]:
    """Fused-AR vs RS+AG (sequence-parallel) predicted seconds.

    ``fused`` is the best dispatchable all-reduce strategy at this size
    (what ``ar_strategy="auto"`` would run for the residual); ``rs_ag`` is
    the Megatron-SP decomposition — reduce-scatter ending the row-parallel
    projection, all-gather deferred to the next column-parallel input —
    modelled by :func:`repro.core.comm_model.t_sp_rs_ag`.
    """
    fused = min(predict_times(msg_bytes, fast_size, slow_size, net)
                .values())
    return {"fused": fused,
            "rs_ag": cm.t_sp_rs_ag(msg_bytes, max(1, slow_size),
                                   max(1, fast_size), net)}


def analytic_sp_choice(msg_bytes: float, fast_size: int, slow_size: int,
                       net: cm.NetworkSpec) -> bool:
    """True when the RS+AG decomposition beats the best fused all-reduce
    under the alpha-beta model — large (bandwidth-bound) prefill messages;
    False in the latency-bound one-token decode regime, where the extra
    collective launch is pure overhead."""
    if fast_size <= 1:
        return False
    t = predict_sp_times(msg_bytes, fast_size, slow_size, net)
    return t["rs_ag"] < t["fused"]


def analytic_choice(msg_bytes: float, fast_size: int, slow_size: int,
                    net: cm.NetworkSpec, *,
                    allow_lossy: bool = False) -> ARChoice:
    """Best strategy under the alpha-beta model (ties break toward the
    fewest-latency-steps strategy by dict order: flat < hier_ring < hier_rd
    is not the right order, so we order candidates explicitly)."""
    times = predict_times(msg_bytes, fast_size, slow_size, net)
    # Tie-break order: fewest inter-phase latency steps first.
    order = ("hier_rd", "hier_rd_halving", "hier_ring", "flat")
    best = min(order, key=lambda s: times[s])
    rd_chunks = 1
    if best == "hier_rd" and slow_size > 1:
        rd_chunks = _rd_chunks_for(msg_bytes, fast_size)
    compress = False
    if allow_lossy and slow_size > 1:
        # int8 exchange quarters (f32) / halves (bf16) the slow payload at
        # eta = 1 + 2/group overhead; worth it only when bandwidth-bound.
        shard = msg_bytes / max(1, fast_size)
        bw_term = (slow_size - 1) / slow_size * shard / net.beta_inter
        lat_term = math.log2(max(2, slow_size)) * net.alpha_inter
        compress = bw_term > 4.0 * lat_term
    return ARChoice(strategy=best, rd_chunks=rd_chunks,
                    compress_slow=compress)


def predict_quant_times(msg_bytes: float, fast_size: int, slow_size: int,
                        net: cm.NetworkSpec) -> Dict[str, float]:
    """Predicted seconds per wire-quantization level.

    ``none`` is the best full-precision strategy at this size; int8/int4
    run the quantized hierarchical path (packed RS + quantized RD inter +
    packed AG) whose bandwidth terms shrink by the wire factor while its
    latency terms — and per-phase pack overhead — do not.  That asymmetry
    is the whole point: quantization wins only past the crossover where
    the transfer is bandwidth-bound (paper Sec. 4.3 frame, Flash-
    Communication payload model)."""
    t_none = min(predict_times(msg_bytes, fast_size, slow_size, net)
                 .values())
    return {
        "none": t_none,
        "int8": cm.t_quant_hier_allreduce(msg_bytes, slow_size, fast_size,
                                          net, 8),
        "int4": cm.t_quant_hier_allreduce(msg_bytes, slow_size, fast_size,
                                          net, 4),
    }


def analytic_quant_choice(msg_bytes: float, fast_size: int, slow_size: int,
                          net: cm.NetworkSpec, mode: str) -> ARChoice:
    """Dispatch entry for a quant-aware call site (``mode`` != "none").

    Forced modes ("int8"/"int4") always quantize — the user overrode the
    accuracy tradeoff — and route through hier_rd when a slow axis
    exists, since that is the topology the quantized path implements.
    ``"auto"`` climbs an accuracy ladder: each lossier level must beat
    the previous by >10% predicted time to be worth its extra error, so
    the lossless choice wins ties and int4 only appears where bandwidth
    savings are decisive."""
    base = analytic_choice(msg_bytes, fast_size, slow_size, net)
    if mode in ("int8", "int4"):
        strat = "hier_rd" if slow_size > 1 else base.strategy
        return ARChoice(strategy=strat, rd_chunks=1, quant=mode)
    t = predict_quant_times(msg_bytes, fast_size, slow_size, net)
    quant = "none"
    if t["int8"] < 0.9 * t["none"]:
        quant = "int8"
        if t["int4"] < 0.9 * t["int8"]:
            quant = "int4"
    if quant == "none":
        return base
    strat = "hier_rd" if slow_size > 1 else base.strategy
    return ARChoice(strategy=strat, rd_chunks=1, quant=quant)


# ---------------------------------------------------------------------------
# Dispatch table
# ---------------------------------------------------------------------------


def _bucket(msg_bytes: int) -> int:
    """Power-of-two message-size bucket (log2, clamped)."""
    return max(8, int(math.ceil(math.log2(max(1, int(msg_bytes))))))


def bucket_of(msg_bytes: int) -> int:
    """Public form of the table's message-size bucketing (log2 exponent).

    Lets callers report the operating point a workload *would* dispatch on
    (e.g. per-pool AR buckets in disaggregated serving metrics) without a
    mesh in the loop — the same exponent ``choose`` keys the table with.
    """
    return _bucket(msg_bytes)


def _key(msg_bytes: int, fast_size: int, slow_size: int,
         dtype: str) -> str:
    return f"b{_bucket(msg_bytes)}/f{fast_size}/s{slow_size}/{dtype}"


def _parse_key(key: str) -> Tuple[int, int, int, str]:
    """(bucket_bytes, fast_size, slow_size, dtype) back out of a table key.

    ``bucket_bytes`` is the bucket's representative size — the power-of-two
    upper bound ``2**b`` the key was bucketed to, NOT the original message
    size (which is lost to bucketing; every consumer must treat it as the
    bucket bound).  Round-trip invariant:
    ``_key(*_parse_key(k)) == k`` for every well-formed key ``k``.
    """
    b, f, s, dtype = key.split("/")
    return 2 ** int(b[1:]), int(f[1:]), int(s[1:]), dtype


@dataclasses.dataclass
class _Measurement:
    strategy: str
    seconds: float
    quant: str = "none"


class AutoTuner:
    """Per-call-site all-reduce dispatcher.

    Analytic predictions seed every lookup; measurements (from
    ``benchmarks/bench_allreduce.py --sweep`` or production telemetry)
    override them after :meth:`refine`.  Thread-safe for the trace-time
    lookup pattern.
    """

    def __init__(self, net: cm.NetworkSpec = cm.TPU_V5E, *,
                 allow_lossy: bool = False):
        self.net = net
        self.allow_lossy = allow_lossy
        self.table: Dict[str, ARChoice] = {}
        self.measurements: Dict[str, List[_Measurement]] = {}
        # trace-time lookup log: key -> times dispatched.  Lets a caller
        # that owns a tuner instance (e.g. one serving pool) prove which
        # message-size buckets its workload actually keyed the table on.
        self.lookups: Dict[str, int] = {}
        # sequence-parallel dispatch: key -> use RS+AG instead of the
        # fused all-reduce for that residual message size (PR 5 tentpole;
        # consulted by ``seq_parallel="auto"`` call sites at trace time).
        self.sp_table: Dict[str, bool] = {}
        self.sp_lookups: Dict[str, int] = {}
        self._lock = threading.Lock()

    # -- lookup ------------------------------------------------------------

    def choose(self, msg_bytes: int, fast_size: int, slow_size: int,
               dtype: str = "bfloat16", quant: str = "none") -> ARChoice:
        """Dispatch one call site.  ``quant`` is the ctx's ar_quant policy:
        "none" keys and seeds exactly as before (old persisted tables stay
        valid); any other policy gets its own key namespace via a dtype
        suffix (``bfloat16:qauto``) so quant-aware and plain dispatch never
        alias the same bucket."""
        kdtype = dtype if quant == "none" else f"{dtype}:q{quant}"
        key = _key(msg_bytes, fast_size, slow_size, kdtype)
        with self._lock:
            self.lookups[key] = self.lookups.get(key, 0) + 1
            hit = self.table.get(key)
            if hit is not None:
                return hit
            if quant == "none":
                choice = analytic_choice(msg_bytes, fast_size, slow_size,
                                         self.net,
                                         allow_lossy=self.allow_lossy)
            else:
                choice = analytic_quant_choice(msg_bytes, fast_size,
                                               slow_size, self.net, quant)
            self.table[key] = choice
            return choice

    def choose_sp(self, msg_bytes: int, fast_size: int, slow_size: int,
                  dtype: str = "bfloat16") -> bool:
        """Per-call-site sequence-parallel dispatch: True routes the
        residual through the RS+AG decomposition, False keeps the fused
        all-reduce.  Seeded analytically (:func:`analytic_sp_choice`);
        persisted entries override."""
        key = _key(msg_bytes, fast_size, slow_size, dtype)
        with self._lock:
            self.sp_lookups[key] = self.sp_lookups.get(key, 0) + 1
            hit = self.sp_table.get(key)
            if hit is None:
                hit = analytic_sp_choice(msg_bytes, fast_size, slow_size,
                                         self.net)
                self.sp_table[key] = hit
            return hit

    def lookup_buckets(self) -> List[int]:
        """Sorted message-size bucket exponents this tuner has dispatched
        on (one entry per distinct table key seen by :meth:`choose`)."""
        with self._lock:
            return sorted({int(k.split("/")[0][1:]) for k in self.lookups})

    def sp_lookup_buckets(self) -> List[int]:
        """Bucket exponents the SP dispatcher was consulted on (one entry
        per distinct key seen by :meth:`choose_sp`)."""
        with self._lock:
            return sorted({int(k.split("/")[0][1:])
                           for k in self.sp_lookups})

    # -- measurement refinement -------------------------------------------

    def record(self, msg_bytes: int, fast_size: int, slow_size: int,
               dtype: str, strategy: str, seconds: float,
               quant: str = "none",
               policy: Optional[str] = None) -> None:
        """File one measured (strategy, quant) latency.

        ``quant`` is the concrete wire level that was measured; ``policy``
        is the dispatch namespace to file it under and defaults to
        ``quant``.  A sweep tuning the ``"auto"`` policy measures concrete
        levels as candidates but files them all under ``policy="auto"`` so
        :meth:`refine` crowns one winner per auto-keyed bucket."""
        ns = quant if policy is None else policy
        kdtype = dtype if ns == "none" else f"{dtype}:q{ns}"
        key = _key(msg_bytes, fast_size, slow_size, kdtype)
        with self._lock:
            self.measurements.setdefault(key, []).append(
                _Measurement(strategy, seconds, quant))

    def refine(self) -> int:
        """Overwrite table entries with measured winners; returns the number
        of entries changed."""
        changed = 0
        with self._lock:
            for key, ms in self.measurements.items():
                best = min(ms, key=lambda m: m.seconds)
                prev = self.table.get(key)
                rd_chunks = 1
                if best.strategy == "hier_rd" and best.quant == "none":
                    # Recompute from the bucket bound, not from the
                    # previous entry: the analytic seed only sets chunks
                    # when it itself picked hier_rd.  (The original
                    # message size is gone — the bucket bound is the only
                    # coherent size to chunk on, same as ``choose``.)
                    # Quantized winners keep rd_chunks=1: the quantized
                    # slow exchange requantizes per step and does not
                    # pipeline chunks.
                    bucket_bytes, fast, slow, _ = _parse_key(key)
                    if slow > 1:
                        rd_chunks = _rd_chunks_for(bucket_bytes, fast)
                new = ARChoice(strategy=best.strategy, rd_chunks=rd_chunks,
                               compress_slow=prev.compress_slow
                               if prev else False,
                               quant=best.quant)
                if prev != new:
                    self.table[key] = new
                    changed += 1
        return changed

    # -- persistence -------------------------------------------------------

    def to_json(self) -> Dict:
        return {
            "version": TABLE_VERSION,
            "net": self.net.name,
            "allow_lossy": self.allow_lossy,
            "table": {k: dataclasses.asdict(v)
                      for k, v in sorted(self.table.items())},
            "sp_table": dict(sorted(self.sp_table.items())),
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)

    @classmethod
    def _degraded(cls, path: str, why: str) -> "AutoTuner":
        """Degraded-mode fallback for an unusable persisted table: warn
        and seed a fresh analytic tuner — a serving process must come up
        with the comm-model dispatch rather than crash on a bad file
        (DESIGN.md §11)."""
        warnings.warn(f"ar-table {path!r} unusable ({why}); degrading to "
                      f"analytic comm-model seeding", RuntimeWarning,
                      stacklevel=3)
        return cls()

    @classmethod
    def load(cls, path: str) -> "AutoTuner":
        """Load a persisted table, degrading (never raising) on a corrupt
        or wrong-schema file: unreadable JSON, a non-object document, or
        an unknown schema version falls back to a fresh analytic tuner
        with a ``RuntimeWarning``; individually malformed table entries
        are dropped (counted in the warning) and the rest kept."""
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as e:
            return cls._degraded(path, f"unreadable: {e}")
        if isinstance(doc, dict) and "tuned_table" in doc \
                and "table" not in doc:
            # accept a BENCH_allreduce.json sweep artifact directly
            doc = doc["tuned_table"]
        if not isinstance(doc, dict):
            return cls._degraded(path, f"JSON {type(doc).__name__}, "
                                       f"not an object")
        version = doc.get("version", 1)
        if version != TABLE_VERSION:
            return cls._degraded(path, f"schema version {version!r} != "
                                       f"{TABLE_VERSION}")
        net = cm.NETWORKS.get(doc.get("net", "tpu_v5e"), cm.TPU_V5E)
        t = cls(net, allow_lossy=bool(doc.get("allow_lossy", False)))
        table = doc.get("table", {})
        sp_table = doc.get("sp_table", {})
        if not isinstance(table, dict) or not isinstance(sp_table, dict):
            return cls._degraded(path, "table/sp_table not objects")
        dropped = 0
        for k, v in table.items():
            try:
                _parse_key(k)   # malformed keys never dispatch — reject
                c = ARChoice(**v)
                if c.strategy not in DISPATCHABLE:
                    raise ValueError(f"unknown strategy {c.strategy!r}")
                if int(c.rd_chunks) < 1:
                    raise ValueError(f"rd_chunks {c.rd_chunks!r} < 1")
                if c.quant not in QUANT_LEVELS:
                    raise ValueError(f"unknown quant {c.quant!r}")
            except (TypeError, ValueError, AttributeError, IndexError):
                dropped += 1
                continue
            t.table[k] = c
        for k, v in sp_table.items():
            try:
                int(str(k).split("/")[0][1:])   # "b{bucket}/..." shape
            except (TypeError, ValueError, IndexError):
                dropped += 1
                continue
            t.sp_table[k] = bool(v)
        if dropped:
            warnings.warn(f"ar-table {path!r}: dropped {dropped} "
                          f"malformed entr{'y' if dropped == 1 else 'ies'}"
                          f"; kept {len(t.table) + len(t.sp_table)}",
                          RuntimeWarning, stacklevel=2)
        return t


# ---------------------------------------------------------------------------
# Process-wide active tuner (what ar_strategy="auto" resolves against)
# ---------------------------------------------------------------------------

_ACTIVE = AutoTuner()


def active() -> AutoTuner:
    return _ACTIVE


def install(tuner: AutoTuner) -> AutoTuner:
    """Swap the process-wide tuner (returns the previous one)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tuner
    return prev


def install_from_path(path: Optional[str]) -> AutoTuner:
    """Install a persisted table if ``path`` exists; else keep/seed default.

    Also honors the ``REPRO_AR_TABLE`` environment variable when ``path`` is
    None, so deployments can point every entry point at one tuned table."""
    if path is None:
        path = os.environ.get("REPRO_AR_TABLE")
    if path and os.path.exists(path):
        install(AutoTuner.load(path))
    return _ACTIVE


def tuner_for(path: Optional[Union[str, AutoTuner]]) -> AutoTuner:
    """Resolve (without installing) the tuner a build should capture:
    an :class:`AutoTuner` instance passes through untouched (per-pool
    tables in disaggregated serving), an explicit path loads, else
    ``REPRO_AR_TABLE``, else the active default."""
    if isinstance(path, AutoTuner):
        return path
    if path is None:
        path = os.environ.get("REPRO_AR_TABLE")
    if path and os.path.exists(path):
        return AutoTuner.load(path)
    return _ACTIVE


@contextlib.contextmanager
def using(tuner: AutoTuner):
    """Temporarily make ``tuner`` the active dispatcher.

    Step builders wrap their (traced) bodies with this so each built step
    resolves 'auto' against the table captured at build time, even when
    jit defers tracing past a later build that installed a different
    table."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tuner
    try:
        yield tuner
    finally:
        _ACTIVE = prev


def resolve(ctx, msg_bytes: int, fast_size: int, slow_size: int,
            dtype: str):
    """Concretize ctx.ar_strategy == 'auto' for one call site.  The ctx's
    ar_quant policy flows into the lookup so quant-aware strategy picks
    (and, under ``ar_quant="auto"``, the per-bucket level itself) come
    from the same table."""
    choice = _ACTIVE.choose(int(msg_bytes), fast_size, slow_size,
                            str(dtype),
                            quant=getattr(ctx, "ar_quant", "none"))
    return choice.apply(ctx)


def resolve_sp(msg_bytes: int, fast_size: int, slow_size: int,
               dtype: str) -> bool:
    """Concretize ``seq_parallel="auto"`` for one prefill call site against
    the active tuner (trace-time, like :func:`resolve`)."""
    return _ACTIVE.choose_sp(int(msg_bytes), fast_size, slow_size,
                             str(dtype))


__all__ = [
    "ARChoice", "AutoTuner", "predict_times", "analytic_choice",
    "predict_sp_times", "analytic_sp_choice",
    "predict_quant_times", "analytic_quant_choice", "QUANT_LEVELS",
    "active", "install", "install_from_path", "tuner_for", "using",
    "resolve", "resolve_sp", "bucket_of", "DISPATCHABLE",
    "TABLE_VERSION",
]
