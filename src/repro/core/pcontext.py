"""Parallel execution context.

``ParallelCtx`` carries the mesh-axis wiring for a jitted step function.  All
model code takes a ctx and calls the collective helpers in
:mod:`repro.core.hierarchical`; with an empty ctx (no axes) every collective
degenerates to the identity, so the same model code runs single-device (CPU
tests, smoke tests) and under ``jax.shard_map`` on a production mesh.

Axis roles
----------
- ``tp_fast``: tensor-parallel axes on the fast interconnect (ICI).  The
  paper's "intra-node" level.
- ``tp_slow``: tensor-parallel axes on the slow interconnect (DCN).  The
  paper's "inter-node" level; non-empty only for cross-pod TP deployments.
- ``dp``:     pure batch-parallel axes (gradients reduced across them).
- ``fsdp``:   weight-sharding axes; weights are all-gathered per layer on the
  forward pass (ZeRO-3 style), which AD transposes into gradient
  reduce-scatters.
- ``ep``:     expert-parallel axes for MoE layers (usually == tp_fast).
- ``sp``:     sequence-parallel axes (activations sequence-sharded between
  blocks; usually == tp_fast).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

AxisNames = Tuple[str, ...]

AR_STRATEGIES = ("flat", "hier_ring", "hier_rd", "hier_rd_halving", "auto")

SEQ_PARALLEL_MODES = ("off", "on", "auto")

# Quantized-collective levels for the TP all-reduce / RS+AG family.
# "none" keeps full-precision wire; "int8"/"int4" force that level at every
# call site; "auto" lets the autotuner pick {none, int8, int4} per call site
# (requires ar_strategy="auto" so the same trace-time dispatch hook fires).
AR_QUANT_LEVELS = ("none", "int8", "int4")
AR_QUANT_MODES = AR_QUANT_LEVELS + ("auto",)


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    tp_fast: AxisNames = ()
    tp_slow: AxisNames = ()
    dp: AxisNames = ()
    fsdp: AxisNames = ()
    ep: AxisNames = ()
    sp: AxisNames = ()
    # All-reduce strategy for TP partial sums (the paper's subject):
    #   flat             - single XLA all-reduce over all TP axes (NCCL baseline)
    #   hier_ring        - RS(fast) + psum(slow, XLA ring) + AG(fast)
    #   hier_rd          - RS(fast) + recursive doubling(slow) + AG(fast)  [NVRAR]
    #   hier_rd_halving  - RS(fast) + recursive halving/doubling(slow) + AG(fast)
    #   auto             - per-call-site dispatch on (message bytes, topology,
    #                      dtype) via repro.core.autotune (resolved at trace
    #                      time; see DESIGN.md §Overlap-and-autotune)
    ar_strategy: str = "flat"
    # Gradient cross-pod reduction strategy ("flat" | "rd" | "rd_int8").
    grad_reduce_strategy: str = "rd"
    # Chunk count for pipelined slow-axis exchanges (paper Sec. 4.2.1 analogue).
    rd_chunks: int = 1
    # int8-compress the slow-axis TP exchange (beyond-paper; eta-packing).
    compress_slow: bool = False
    # Quantized all-gather: TP AR runs as RS(bf16) + AG(int8 + scales) —
    # cuts fast-axis AR wire bytes ~25-45% (beyond-paper optimization).
    # Legacy force-knob; superseded by ``ar_quant`` which quantizes every
    # phase and is autotuner-dispatchable.
    quant_ag: bool = False
    # Quantized collective level for tp_all_reduce / tp_reduce_scatter /
    # tp_all_gather: "none" | "int8" | "int4" | "auto".  int8/int4 carry
    # nibble/byte-packed payloads + per-group bf16 scales on the wire
    # (Flash-Communication-style low-bit comm); "auto" lets the AutoTuner
    # pick {none, int8, int4} per call site alongside the strategy (needs
    # ar_strategy="auto").  Error feedback for the lossy levels rides in
    # the decode cache (see DESIGN.md §12).
    ar_quant: str = "none"
    # Overlapped collective-matmul: route row-parallel output projections
    # (attention wo / MLP down-proj) through repro.core.overlap so chunk q's
    # all-reduce pipelines against chunk q+1's GEMM (Flash-Communication
    # style comm/compute fusion; see DESIGN.md §Overlap-and-autotune).
    overlap_matmul: bool = False
    # Output-feature chunk count for the overlapped path (1 disables
    # chunking even when overlap_matmul is set).
    overlap_chunks: int = 4
    # Sequence-parallel prefill (Megatron-SP residual layout): the residual
    # stream stays sequence-sharded over tp_fast between sublayers — the
    # row-parallel projections (attention wo / MLP down) end in
    # tp_reduce_scatter on the sequence dim, norms run on sequence shards,
    # and tp_all_gather restores full sequence only where QKV / up-proj
    # need it.  "off" keeps the fused per-residual all-reduce, "on" forces
    # the RS+AG decomposition wherever the sequence divides tp_fast, and
    # "auto" dispatches per call site on message size via the autotuner's
    # SP table (decode steps never decompose — their one-token messages
    # live in the latency-bound regime; see DESIGN.md §10).
    seq_parallel: str = "off"

    def __post_init__(self):
        if self.ar_strategy not in AR_STRATEGIES:
            raise ValueError(f"unknown ar_strategy {self.ar_strategy!r}")
        if self.seq_parallel not in SEQ_PARALLEL_MODES:
            raise ValueError(
                f"unknown seq_parallel mode {self.seq_parallel!r}")
        if self.ar_quant not in AR_QUANT_MODES:
            raise ValueError(f"unknown ar_quant mode {self.ar_quant!r}")
        if self.ar_quant == "auto" and self.ar_strategy != "auto":
            raise ValueError(
                "ar_quant='auto' requires ar_strategy='auto' (quant level "
                "is picked by the same trace-time autotune dispatch); got "
                f"ar_strategy={self.ar_strategy!r}")

    # -- derived -----------------------------------------------------------
    @property
    def tp_axes(self) -> AxisNames:
        return self.tp_slow + self.tp_fast

    @property
    def has_tp(self) -> bool:
        return bool(self.tp_axes)

    @property
    def batch_axes(self) -> AxisNames:
        return self.dp

    def replace(self, **kw) -> "ParallelCtx":
        return dataclasses.replace(self, **kw)


# A fully-local context: every collective is the identity.
LOCAL = ParallelCtx()


def single_pod_ctx(ar_strategy: str = "flat", **kw) -> ParallelCtx:
    """Default wiring for the (16,16) = ("data","model") mesh."""
    return ParallelCtx(tp_fast=("model",), dp=("data",), fsdp=("data",),
                       ep=("model",), sp=("model",), ar_strategy=ar_strategy,
                       **kw)


def multi_pod_ctx(ar_strategy: str = "flat", cross_pod_tp: bool = False,
                  **kw) -> ParallelCtx:
    """Wiring for the (2,16,16) = ("pod","data","model") mesh.

    ``cross_pod_tp=True`` reproduces the paper's headline scenario: the TP
    group spans the slow interconnect, so the per-layer all-reduce crosses
    DCN and the hierarchical strategies apply verbatim.
    """
    if cross_pod_tp:
        return ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                           dp=("data",), fsdp=("data",), ep=("model",),
                           sp=("model",), ar_strategy=ar_strategy, **kw)
    return ParallelCtx(tp_fast=("model",), dp=("pod", "data"),
                       fsdp=("data",), ep=("model",), sp=("model",),
                       ar_strategy=ar_strategy, **kw)


__all__ = ["ParallelCtx", "LOCAL", "single_pod_ctx", "multi_pod_ctx",
           "AR_STRATEGIES", "SEQ_PARALLEL_MODES", "AR_QUANT_LEVELS",
           "AR_QUANT_MODES"]
