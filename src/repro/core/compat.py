"""Version-compat shims for the JAX API surface this repo targets.

The code is written against the current JAX API (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``, ``lax.axis_size``,
``pltpu.CompilerParams`` / ``pltpu.InterpretParams``).  Older installs (e.g.
jax 0.4.x) expose the same functionality under different names; everything
routes through here so the rest of the tree stays on the modern spelling.

Import this module before (or instead of) reaching for the raw JAX names:

    from repro.core.compat import shard_map, make_mesh
    from repro.core.compat import tpu_compiler_params, tpu_interpret_params

``tpu_interpret_params()`` returns ``None`` when the installed Pallas has no
TPU interpret mode capable of emulating remote DMA + semaphores on CPU; the
callers (dist cases, benchmarks) skip those paths gracefully.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence

import jax
from jax import lax

# ---------------------------------------------------------------------------
# shard_map: jax.shard_map(check_vma=...) vs jax.experimental (check_rep=...)
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):                         # modern jax
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:                                                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)


# ---------------------------------------------------------------------------
# make_mesh: axis_types only exists on newer jax; older meshes are all-Auto
# ---------------------------------------------------------------------------

try:
    from jax.sharding import AxisType as _AxisType  # noqa: F401
    _HAS_AXIS_TYPES = True
except ImportError:
    _AxisType = None
    _HAS_AXIS_TYPES = False


if _HAS_AXIS_TYPES:
    AxisType = _AxisType
else:
    class AxisType:  # placeholder: every axis is Auto on older jax anyway
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def auto_axis_types(n: int):
    """(AxisType.Auto,) * n on modern jax, None on older jax."""
    if _HAS_AXIS_TYPES:
        return (_AxisType.Auto,) * n
    return None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Any = None, devices=None):
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _HAS_AXIS_TYPES and axis_types is not None \
            and not isinstance(axis_types[0] if axis_types else None, str):
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types,
                             **kw)
    return jax.make_mesh(axis_shapes, axis_names, **kw)


# ---------------------------------------------------------------------------
# lax.axis_size: added to lax recently; psum(1, axis) folds to a python int
# under both shard_map and pmap tracing on every version we support.
# ---------------------------------------------------------------------------

if not hasattr(lax, "axis_size"):
    def _axis_size(axis_name) -> int:
        return lax.psum(1, axis_name)

    lax.axis_size = _axis_size  # patched once, at first repro.core import


# ---------------------------------------------------------------------------
# Pallas TPU params
# ---------------------------------------------------------------------------

try:
    from jax.experimental.pallas import tpu as _pltpu
except ImportError:  # pallas not available at all (pure-CPU minimal install)
    _pltpu = None


def tpu_compiler_params(**kw):
    """pltpu.CompilerParams on modern jax, TPUCompilerParams on 0.4.x.

    Silently drops kwargs the installed dataclass does not know (e.g.
    ``collective_id`` predates some 0.4.x releases) — the params are
    performance/bookkeeping hints, not semantics.
    """
    if _pltpu is None:
        return None
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    import dataclasses
    if dataclasses.is_dataclass(cls):
        fields = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in kw.items() if k in fields}
    return cls(**kw)


def tpu_interpret_params() -> Optional[Any]:
    """TPU interpret-mode params (emulates remote DMA + semaphores on CPU).

    Returns None when unsupported; callers must skip the kernel path then
    (plain ``interpret=True`` cannot emulate cross-device semaphores).
    """
    if _pltpu is None:
        return None
    cls = getattr(_pltpu, "InterpretParams", None) \
        or getattr(_pltpu, "TPUInterpretParams", None)
    return cls() if cls is not None else None


HAS_TPU_INTERPRET = tpu_interpret_params() is not None


__all__ = [
    "shard_map", "make_mesh", "auto_axis_types", "AxisType",
    "tpu_compiler_params", "tpu_interpret_params", "HAS_TPU_INTERPRET",
]
