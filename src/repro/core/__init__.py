"""Core: the paper's contribution — hierarchical all-reduce for multi-node
(multi-pod) LLM inference/training, plus its alpha-beta performance models."""
from . import compat  # installs the lax.axis_size shim on older jax
from .pcontext import ParallelCtx, LOCAL, single_pod_ctx, multi_pod_ctx
from .hierarchical import (
    rd_all_reduce, rd_halving_all_reduce, compressed_rd_all_reduce,
    tp_all_reduce, tp_reduce_scatter, tp_all_gather,
    grad_cross_pod_reduce, dp_psum_mean, axes_size,
)
from .overlap import collective_matmul, collective_matmul_reduce_scatter
from . import comm_model
from . import autotune

__all__ = [
    "ParallelCtx", "LOCAL", "single_pod_ctx", "multi_pod_ctx",
    "rd_all_reduce", "rd_halving_all_reduce", "compressed_rd_all_reduce",
    "tp_all_reduce", "tp_reduce_scatter", "tp_all_gather",
    "grad_cross_pod_reduce", "dp_psum_mean", "axes_size", "comm_model",
    "collective_matmul", "collective_matmul_reduce_scatter", "autotune",
]
