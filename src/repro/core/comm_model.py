"""Alpha-beta communication cost models from the paper (Eqs. 1-6).

The paper models all-reduce time on a system of ``N`` nodes x ``G`` GPUs/node
with intra-node latency/bandwidth (alpha_intra, beta_intra) and inter-node
(alpha_inter, beta_inter).  We reproduce the Ring (Eq. 1), Tree (Eq. 2) and
NVRAR (Eqs. 3-6) models verbatim, add a bandwidth-corrected recursive-doubling
variant, and provide network constants for the paper's two systems
(Perlmutter: A100 + Slingshot-11; Vista: GH200 + InfiniBand) plus the TPU v5e
target (ICI intra-pod, DCN inter-pod).

All times are in seconds; message sizes in bytes; bandwidths in bytes/second.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Tuple

# ---------------------------------------------------------------------------
# Network specifications
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """alpha-beta parameters of a two-level interconnect."""

    name: str
    alpha_intra: float  # s, latency of the fast (intra-node / ICI) level
    beta_intra: float   # B/s, bandwidth of the fast level (per link)
    alpha_inter: float  # s, latency of the slow (inter-node / DCN) level
    beta_inter: float   # B/s, bandwidth of the slow level (per endpoint)
    gpus_per_node: int = 4


# Perlmutter: 4x A100 per node, NVLink3 (~300 GB/s/dir usable ~ 2.4e11),
# Slingshot-11 (~25 GB/s/NIC/dir); latencies from NCCL/OSU small-message
# plateaus in the paper's Fig. 4 (~8-10 us intra via NCCL launch, ~15-20 us
# inter per hop).
PERLMUTTER = NetworkSpec(
    name="perlmutter",
    alpha_intra=8.0e-6,
    beta_intra=2.4e11,
    alpha_inter=16.0e-6,
    beta_inter=2.5e10,
    gpus_per_node=4,
)

# Vista: GH200, 1 GPU/node, InfiniBand NDR (~25 GB/s usable per direction).
VISTA = NetworkSpec(
    name="vista",
    alpha_intra=5.0e-6,
    beta_intra=4.5e11,   # irrelevant: G=1
    alpha_inter=12.0e-6,
    beta_inter=2.5e10,
    gpus_per_node=1,
)

# TPU v5e target: "node" = pod (fast ICI torus), "inter" = DCN between pods.
# ICI: ~50 GB/s/link/direction, ~1 us neighbour latency.  DCN: per-host
# ~ 25 GB/s aggregate shared by 4 chips -> ~6.25 GB/s/chip, ~10 us latency.
TPU_V5E = NetworkSpec(
    name="tpu_v5e",
    alpha_intra=1.0e-6,
    beta_intra=5.0e10,
    alpha_inter=10.0e-6,
    beta_inter=6.25e9,
    gpus_per_node=256,  # chips per pod
)

NETWORKS: Dict[str, NetworkSpec] = {
    n.name: n for n in (PERLMUTTER, VISTA, TPU_V5E)
}


# ---------------------------------------------------------------------------
# Paper equations
# ---------------------------------------------------------------------------


def t_ring_allreduce(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                     net: NetworkSpec) -> float:
    """Eq. (1): NCCL Ring all-reduce (flat ring, inter-node links dominate).

    T = 2(NG-1) a_inter + 2 (NG-1)/(NG) * |M| / b_inter
    """
    ng = n_nodes * gpus_per_node
    if ng <= 1:
        return 0.0
    return 2.0 * (ng - 1) * net.alpha_inter + \
        2.0 * (ng - 1) / ng * (msg_bytes / net.beta_inter)


def t_tree_allreduce(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                     net: NetworkSpec) -> float:
    """Eq. (2): NCCL Tree all-reduce (double binary tree + intra chain).

    T ~= 2(G-1) a_intra + 2 log2(N) a_inter + 2 (N-1)/N * |M| / b_inter
    """
    if n_nodes * gpus_per_node <= 1:
        return 0.0
    t = 2.0 * (gpus_per_node - 1) * net.alpha_intra
    if n_nodes > 1:
        t += 2.0 * math.log2(n_nodes) * net.alpha_inter
        t += 2.0 * (n_nodes - 1) / n_nodes * (msg_bytes / net.beta_inter)
    return t


def t_reduce_scatter_intra(msg_bytes: float, gpus_per_node: int,
                           net: NetworkSpec) -> float:
    """Eq. (3): intra-node ring reduce-scatter."""
    g = gpus_per_node
    if g <= 1:
        return 0.0
    return (g - 1) * net.alpha_intra + (g - 1) / g * (msg_bytes / net.beta_intra)


def t_allgather_intra(msg_bytes: float, gpus_per_node: int,
                      net: NetworkSpec) -> float:
    """Eq. (5): intra-node ring all-gather (same cost shape as Eq. 3)."""
    return t_reduce_scatter_intra(msg_bytes, gpus_per_node, net)


def t_rd_inter(msg_bytes: float, n_nodes: int, gpus_per_node: int,
               net: NetworkSpec, eta: float = 1.0) -> float:
    """Eq. (4): inter-node recursive-doubling phase on |M|/G bytes.

    T = log2(N) a_inter + (N-1)/N * (eta |M| / (G b_inter))

    ``eta`` in (1, 2] models the paper's fused data+flag payload expansion
    (eta=2 for the 4B-data+4B-flag LL layout; our compressed TPU variant packs
    quantization scales instead, eta ~= 1.03 for 128-element groups).
    """
    if n_nodes <= 1:
        return 0.0
    return math.log2(n_nodes) * net.alpha_inter + \
        (n_nodes - 1) / n_nodes * (eta * msg_bytes / (gpus_per_node * net.beta_inter))


def t_nvrar(msg_bytes: float, n_nodes: int, gpus_per_node: int,
            net: NetworkSpec, eta: float = 1.0) -> float:
    """Eq. (6): total NVRAR = RS_intra + RD_inter + AG_intra."""
    return (t_reduce_scatter_intra(msg_bytes, gpus_per_node, net)
            + t_rd_inter(msg_bytes, n_nodes, gpus_per_node, net, eta=eta)
            + t_allgather_intra(msg_bytes, gpus_per_node, net))


def t_rd_inter_full_exchange(msg_bytes: float, n_nodes: int,
                             gpus_per_node: int, net: NetworkSpec,
                             eta: float = 1.0) -> float:
    """Bandwidth-corrected recursive doubling (Algorithm 1 semantics).

    Algorithm 1 exchanges the *full* |M|/G payload at every one of the
    log2(N) steps (no halving), so the bandwidth term is log2(N) * |M|/G
    rather than Eq. (4)'s (N-1)/N * |M|/G.  The paper's small-message regime
    is latency-dominated so both agree there; we keep both for honesty.
    """
    if n_nodes <= 1:
        return 0.0
    steps = math.log2(n_nodes)
    return steps * net.alpha_inter + \
        steps * (eta * msg_bytes / (gpus_per_node * net.beta_inter))


def t_rd_halving_inter(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                       net: NetworkSpec, eta: float = 1.0) -> float:
    """Recursive halving RS + recursive doubling AG over the slow level.

    Bandwidth-optimal variant (beyond-paper optimization): total payload
    2 (N-1)/N * |M|/G with 2 log2(N) latency steps.
    """
    if n_nodes <= 1:
        return 0.0
    return 2.0 * math.log2(n_nodes) * net.alpha_inter + \
        2.0 * (n_nodes - 1) / n_nodes * (eta * msg_bytes / (gpus_per_node * net.beta_inter))


def t_sp_rs_ag(msg_bytes: float, n_nodes: int, gpus_per_node: int,
               net: NetworkSpec, overlap: float = 0.5) -> float:
    """Sequence-parallel RS + AG pair replacing one fused TP all-reduce.

    The Megatron-SP / Flash-Communication decomposition (arXiv 2412.04964):
    the row-parallel projection ends in a reduce-scatter on the sequence
    dim and the all-gather is deferred to the next column-parallel input,
    so each collective moves half the fused all-reduce's wire bytes and
    the norm / residual between them runs on the 1/G sequence shard.

    Cost shape: RS_intra + best inter phase on the scattered shard +
    AG_intra, where a fraction ``overlap`` of the AG's *bandwidth* term is
    hidden behind the adjacent column-parallel GEMM (the AG has no data
    dependence on that GEMM's weight operand, so the latency-hiding
    scheduler can pipeline them — the deferred-gather claim of Flash
    Communication).  The extra collective launch costs one more
    ``alpha_intra`` latency chain, which is why one-token decode messages
    stay on the fused path: there the alpha term dominates and the
    overlappable bandwidth term is negligible.
    """
    g, n = max(1, gpus_per_node), max(1, n_nodes)
    if g <= 1 and n <= 1:
        return 0.0
    rs = t_reduce_scatter_intra(msg_bytes, g, net)
    ag = t_allgather_intra(msg_bytes, g, net)
    inter = 0.0
    if n > 1:
        # the slow phase inherits whichever inter algorithm is cheapest at
        # this shard size (mirrors tp_reduce_scatter's strategy dispatch)
        shard = msg_bytes / g
        ring = 2.0 * (n - 1) * net.alpha_inter \
            + 2.0 * (n - 1) / n * (shard / net.beta_inter)
        inter = min(t_rd_inter_full_exchange(msg_bytes, n, g, net),
                    t_rd_halving_inter(msg_bytes, n, g, net), ring)
    ag_alpha = (g - 1) * net.alpha_intra
    ag_bw = max(ag - ag_alpha, 0.0)
    return rs + inter + ag_alpha + (1.0 - overlap) * ag_bw + net.alpha_intra


# ---------------------------------------------------------------------------
# Quantized (low-bit wire) collective terms — Flash-Communication analogue
# ---------------------------------------------------------------------------

# Per-group scale granularity of the quantized collectives (mirrors
# kernels.rd_allreduce.quant.GROUP_CAP — kept literal here so the
# alpha-beta model stays dependency-free).
QUANT_GROUPS = {8: 128, 4: 64}

# Per-phase pack/unpack cost (absmax + round/clip + nibble pack over VMEM,
# plus kernel issue): charged once per quantized phase so latency-bound
# small messages are not scored as free wins.
QUANT_PACK_OVERHEAD = 2.0e-6


def quant_wire_factor(bits: int, group: int = 0,
                      dtype_bytes: float = 2.0) -> float:
    """Wire bytes per full-precision byte for a quantized payload.

    ``bits``-wide values plus one bf16 scale per ``group`` elements:
    int8/g128 -> 0.508 (1.97x reduction vs bf16), int4/g64 -> 0.266
    (3.76x).  ``group=0`` uses the level's default granularity.
    """
    if group <= 0:
        group = QUANT_GROUPS[bits]
    return (bits / 8.0 + 2.0 / group) / dtype_bytes


def t_quant_hier_allreduce(msg_bytes: float, n_nodes: int,
                           gpus_per_node: int, net: NetworkSpec,
                           bits: int) -> float:
    """Quantized hierarchical all-reduce: RS(packed a2a) + quantized RD
    inter + AG(packed), every phase's bandwidth term scaled by the wire
    factor, plus pack/unpack overhead per phase.  Step counts (alpha
    terms) are unchanged — quantization buys bandwidth, not latency,
    which is exactly why the autotuner must arbitrate the crossover
    instead of a global flag."""
    g, n = max(1, gpus_per_node), max(1, n_nodes)
    wm = msg_bytes * quant_wire_factor(bits)
    phases = 2
    t = (t_reduce_scatter_intra(wm, g, net)
         + t_allgather_intra(wm, g, net))
    if n > 1:
        t += t_rd_inter_full_exchange(wm, n, g, net)
        # symmetric RD requantizes the running sum every exchange step
        phases += int(math.log2(n))
    return t + phases * QUANT_PACK_OVERHEAD


def t_nvrar_variant(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                    net: NetworkSpec, inter: str = "paper",
                    eta: float = 1.0) -> float:
    """NVRAR total with a selectable inter-node phase model."""
    inter_fn = {
        "paper": t_rd_inter,
        "full_exchange": t_rd_inter_full_exchange,
        "halving": t_rd_halving_inter,
    }[inter]
    return (t_reduce_scatter_intra(msg_bytes, gpus_per_node, net)
            + inter_fn(msg_bytes, n_nodes, gpus_per_node, net, eta=eta)
            + t_allgather_intra(msg_bytes, gpus_per_node, net))


# ---------------------------------------------------------------------------
# Derived analyses (used by benchmarks reproducing Figs. 4 and 6)
# ---------------------------------------------------------------------------


def nccl_model_best(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                    net: NetworkSpec) -> Tuple[str, float]:
    """NCCL's effective algorithm choice = min(Ring, Tree) under the model."""
    ring = t_ring_allreduce(msg_bytes, n_nodes, gpus_per_node, net)
    tree = t_tree_allreduce(msg_bytes, n_nodes, gpus_per_node, net)
    return ("ring", ring) if ring <= tree else ("tree", tree)


def nvrar_speedup(msg_bytes: float, n_nodes: int, gpus_per_node: int,
                  net: NetworkSpec, eta: float = 1.0) -> float:
    """Speedup of NVRAR over the best NCCL model choice (paper Fig. 6)."""
    _, nccl = nccl_model_best(msg_bytes, n_nodes, gpus_per_node, net)
    nv = t_nvrar(msg_bytes, n_nodes, gpus_per_node, net, eta=eta)
    if nv <= 0.0:
        return 1.0
    return nccl / nv


def speedup_table(net: NetworkSpec,
                  msg_sizes: List[int],
                  gpu_counts: List[int]) -> List[Dict[str, object]]:
    """Speedup grid across message sizes and GPU counts (Fig. 6 middle/right)."""
    rows: List[Dict[str, object]] = []
    for m in msg_sizes:
        for ngpu in gpu_counts:
            n_nodes = max(1, ngpu // net.gpus_per_node)
            g = min(ngpu, net.gpus_per_node)
            algo, nccl_t = nccl_model_best(m, n_nodes, g, net)
            nv_t = t_nvrar(m, n_nodes, g, net)
            rows.append({
                "network": net.name, "msg_bytes": m, "ngpu": ngpu,
                "n_nodes": n_nodes, "gpus_per_node": g,
                "nccl_algo": algo, "nccl_t": nccl_t, "nvrar_t": nv_t,
                "speedup": (nccl_t / nv_t) if nv_t > 0 else 1.0,
            })
    return rows


def decode_allreduce_bytes(batch: int, d_model: int,
                           dtype_bytes: int = 2) -> int:
    """Per-layer TP all-reduce message size in decode: B x H (paper Sec. 3.5)."""
    return batch * d_model * dtype_bytes


__all__ = [
    "NetworkSpec", "PERLMUTTER", "VISTA", "TPU_V5E", "NETWORKS",
    "t_ring_allreduce", "t_tree_allreduce", "t_reduce_scatter_intra",
    "t_allgather_intra", "t_rd_inter", "t_nvrar", "t_rd_inter_full_exchange",
    "t_rd_halving_inter", "t_sp_rs_ag", "t_nvrar_variant", "nccl_model_best",
    "nvrar_speedup", "speedup_table", "decode_allreduce_bytes",
    "QUANT_GROUPS", "QUANT_PACK_OVERHEAD", "quant_wire_factor",
    "t_quant_hier_allreduce",
]
