"""Overlapped collective-matmul: the TP output-projection + all-reduce pair
as one software-pipelined primitive.

In decode, every transformer layer ends with two row-parallel GEMMs
(attention wo, MLP down-projection) whose partial sums are immediately
all-reduced — and the paper shows that all-reduce dominating multi-node step
time.  Running the GEMM to completion *then* reducing serializes compute and
communication; Flash-Communication-style chunking recovers the overlap:

    split the output features D into K chunks
    for q in 0..K-1:   partial_q = x @ w[:, q]        (GEMM chunk q)
                       y_q = tp_all_reduce(partial_q) (comm chunk q)
    y = concat(y_0..y_{K-1})

Chunk q's all-reduce has no data dependency on chunk q+1's GEMM, so the XLA
latency-hiding scheduler can run them concurrently (the same independence
idiom ``rd_all_reduce``'s chunked slow-axis exchange relies on).  Because the
split is along the *output* dimension, every output element is produced by
exactly the same dot product and reduction tree as the unchunked path — the
result is bit-consistent with GEMM-then-``tp_all_reduce`` (a strict
requirement: decode greedy tokens must not depend on the overlap knob).

Total wire bytes are unchanged.  With ``ar_strategy="auto"`` the dispatch is
resolved ONCE from the unchunked projection output and shared by every
chunk: a per-chunk lookup on the |M|/K message could select a different
strategy (a different device-sum order) than the unfused path and void the
bit-consistency guarantee above.  For the same reason the lossy reduction
knobs (``quant_ag``, ``compress_slow``) force the unchunked path: their
per-message quantization groups would shift with the chunk boundaries.

A Pallas TPU variant that fuses the slow-axis RD exchange into the GEMM
epilogue lives in ``repro.kernels.rd_allreduce.fused_matmul`` (selected with
``backend="pallas"``); this module's lax implementation is the portable
default and the parity reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import hierarchical as hier
from .pcontext import ParallelCtx


def _resolve_auto_for_matmul(x: jax.Array, w: jax.Array,
                             ctx: ParallelCtx) -> ParallelCtx:
    """Concretize ar_strategy='auto' from the UNCHUNKED projection output.

    Resolution must happen once, before chunking: a per-chunk lookup on the
    |M|/K message could pick a different strategy (a different device-sum
    order) than the unfused path and void the bit-consistency guarantee."""
    if ctx.ar_strategy != "auto":
        return ctx
    from . import autotune
    out_elems = w.shape[-1]
    for s in x.shape[: x.ndim - (w.ndim - 1)]:
        out_elems *= s
    dt = jnp.result_type(x, w)
    return autotune.resolve(ctx, out_elems * dt.itemsize,
                            hier.axes_size(ctx.tp_fast),
                            hier.axes_size(ctx.tp_slow), dt.name)


def _resolve_chunks(d_out: int, fast_size: int, requested: int) -> int:
    """Largest chunk count <= requested that divides d_out into equal chunks
    each still divisible by the fast-axis size (psum_scatter tiling needs
    chunk_len % fast == 0)."""
    k = max(1, min(requested, d_out))
    while k > 1 and (d_out % k or (d_out // k) % max(1, fast_size)):
        k -= 1
    return k


def collective_matmul(x: jax.Array, w: jax.Array, ctx: ParallelCtx, *,
                      spec: str = "bsf,fd->bsd",
                      chunks: Optional[int] = None,
                      backend: str = "lax") -> jax.Array:
    """Row-parallel projection fused with its TP all-reduce.

    x: local activation shard (the einsum lhs); w: this device's weight shard
    whose **last dim is the replicated output features** (einsum rhs);
    ``spec``: einsum spec mapping (x, w) -> partial output with the feature
    dim last (e.g. ``"bsqh,qhd->bsd"`` for attention wo, ``"bsf,fd->bsd"``
    for the MLP down-projection).

    Returns the **fully reduced** output (what GEMM + ``tp_all_reduce``
    would produce), with chunk q's reduction overlapped against chunk q+1's
    GEMM when ``chunks > 1``.
    """
    if chunks is None:
        chunks = ctx.overlap_chunks if ctx.overlap_matmul else 1
    if not ctx.has_tp:
        return jnp.einsum(spec, x, w)
    d_out = w.shape[-1]
    fast_n = hier.axes_size(ctx.tp_fast)
    k = _resolve_chunks(d_out, fast_n, chunks)
    ctx = _resolve_auto_for_matmul(x, w, ctx)
    if ctx.quant_ag or ctx.compress_slow:
        # Lossy reductions quantize per-message: chunking would change the
        # quantization-group boundaries and make the output depend on the
        # overlap knob.  Keep one message so the knob stays numerics-free.
        k = 1
    if backend == "pallas" and ctx.tp_slow:
        from ..kernels.rd_allreduce.fused_matmul import (
            collective_matmul_pallas)
        return collective_matmul_pallas(x, w, ctx, spec=spec, chunks=k)
    if k <= 1:
        return hier.tp_all_reduce(jnp.einsum(spec, x, w), ctx,
                                  scatter_dim=-1)
    step = d_out // k
    outs = []
    for q in range(k):
        wq = lax.slice_in_dim(w, q * step, (q + 1) * step, axis=-1)
        partial = jnp.einsum(spec, x, wq)
        outs.append(hier.tp_all_reduce(partial, ctx, scatter_dim=-1))
    return jnp.concatenate(outs, axis=-1)


def collective_matmul_reduce_scatter(x: jax.Array, w: jax.Array,
                                     ctx: ParallelCtx, *, dim: int,
                                     spec: str = "bsf,fd->bsd",
                                     chunks: Optional[int] = None
                                     ) -> jax.Array:
    """Sequence-parallel variant: chunked GEMM pipelined against
    ``tp_reduce_scatter`` (Megatron-SP's projection + RS pair).  The scatter
    runs along ``dim`` (sequence), the chunking along the feature dim, so
    the two never interact and the concat order is preserved."""
    if chunks is None:
        chunks = ctx.overlap_chunks if ctx.overlap_matmul else 1
    if not ctx.has_tp:
        return jnp.einsum(spec, x, w)
    d_out = w.shape[-1]
    k = _resolve_chunks(d_out, 1, chunks)
    ctx = _resolve_auto_for_matmul(x, w, ctx)
    if ctx.compress_slow:
        k = 1  # same lossy-quantization-boundary rule as collective_matmul
    if k <= 1:
        return hier.tp_reduce_scatter(jnp.einsum(spec, x, w), ctx, dim=dim)
    step = d_out // k
    outs = []
    for q in range(k):
        wq = lax.slice_in_dim(w, q * step, (q + 1) * step, axis=-1)
        outs.append(hier.tp_reduce_scatter(jnp.einsum(spec, x, wq), ctx,
                                           dim=dim))
    return jnp.concatenate(outs, axis=-1)


__all__ = ["collective_matmul", "collective_matmul_reduce_scatter"]
