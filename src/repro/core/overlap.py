"""Overlapped collective-matmul: the TP output-projection + all-reduce pair
as one software-pipelined primitive.

In decode, every transformer layer ends with two row-parallel GEMMs
(attention wo, MLP down-projection) whose partial sums are immediately
all-reduced — and the paper shows that all-reduce dominating multi-node step
time.  Running the GEMM to completion *then* reducing serializes compute and
communication; Flash-Communication-style chunking recovers the overlap:

    split the output features D into K chunks
    for q in 0..K-1:   partial_q = x @ w[:, q]        (GEMM chunk q)
                       y_q = tp_all_reduce(partial_q) (comm chunk q)
    y = concat(y_0..y_{K-1})

Chunk q's all-reduce has no data dependency on chunk q+1's GEMM, so the XLA
latency-hiding scheduler can run them concurrently (the same independence
idiom ``rd_all_reduce``'s chunked slow-axis exchange relies on).  Because the
split is along the *output* dimension, every output element is produced by
exactly the same dot product and reduction tree as the unchunked path — the
result is bit-consistent with GEMM-then-``tp_all_reduce`` (a strict
requirement: decode greedy tokens must not depend on the overlap knob).

Total wire bytes are unchanged.  With ``ar_strategy="auto"`` the dispatch is
resolved ONCE from the unchunked projection output and shared by every
chunk: a per-chunk lookup on the |M|/K message could select a different
strategy (a different device-sum order) than the unfused path and void the
bit-consistency guarantee above.  The legacy lossy knobs (``quant_ag``,
``compress_slow``) still force the unchunked path: their per-message
quantization groups shift with the chunk boundaries.

The first-class quantized wire (``ar_quant``) DOES compose with chunking.
Its quantization groups are cap-aligned windows along the trailing feature
dim (``kernels.rd_allreduce.quant``), so when both the full output and every
chunk's per-rank scattered shard are multiples of the group cap, the chunked
path quantizes exactly the same absolute feature windows as the unchunked
one — bit-identical output, overlap preserved (:func:`_quant_chunk_ok`).
Misaligned shapes fall back to one message rather than silently changing
numerics.  Error feedback rides along: the EF buffer is sliced per chunk on
the same feature boundaries and the per-chunk residuals concat back.

A Pallas TPU variant that fuses the slow-axis RD exchange into the GEMM
epilogue lives in ``repro.kernels.rd_allreduce.fused_matmul`` (selected with
``backend="pallas"``); this module's lax implementation is the portable
default and the parity reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from . import hierarchical as hier
from ..kernels.rd_allreduce import quant as _q
from .pcontext import ParallelCtx


def _resolve_auto_for_matmul(x: jax.Array, w: jax.Array,
                             ctx: ParallelCtx) -> ParallelCtx:
    """Concretize ar_strategy='auto' from the UNCHUNKED projection output.

    Resolution must happen once, before chunking: a per-chunk lookup on the
    |M|/K message could pick a different strategy (a different device-sum
    order) than the unfused path and void the bit-consistency guarantee."""
    if ctx.ar_strategy != "auto":
        return ctx
    from . import autotune
    out_elems = w.shape[-1]
    for s in x.shape[: x.ndim - (w.ndim - 1)]:
        out_elems *= s
    dt = jnp.result_type(x, w)
    return autotune.resolve(ctx, out_elems * dt.itemsize,
                            hier.axes_size(ctx.tp_fast),
                            hier.axes_size(ctx.tp_slow), dt.name)


def _resolve_chunks(d_out: int, fast_size: int, requested: int) -> int:
    """Largest chunk count <= requested that divides d_out into equal chunks
    each still divisible by the fast-axis size (psum_scatter tiling needs
    chunk_len % fast == 0)."""
    k = max(1, min(requested, d_out))
    while k > 1 and (d_out % k or (d_out // k) % max(1, fast_size)):
        k -= 1
    return k


def _quant_chunk_ok(d_out: int, k: int, n_scatter: int, bits: int) -> bool:
    """True when chunking into ``k`` pieces is bit-identical to the
    unchunked quantized all-reduce.

    The quantized wire groups cap-aligned windows along the trailing
    feature dim of each rank's scattered shard.  When both the full
    output (``d_out / n_scatter``) and every chunk's shard
    (``d_out / k / n_scatter``) are multiples of the group cap, chunked
    and unchunked paths quantize the same absolute feature windows with
    the same scales — so the overlap knob stays numerics-free.  Any
    misalignment shifts group boundaries and must fall back to one
    message."""
    cap = _q.GROUP_CAP[bits] * max(1, n_scatter)
    return d_out % cap == 0 and (d_out // k) % cap == 0


def collective_matmul(x: jax.Array, w: jax.Array, ctx: ParallelCtx, *,
                      spec: str = "bsf,fd->bsd",
                      chunks: Optional[int] = None,
                      backend: str = "lax", ef=None):
    """Row-parallel projection fused with its TP all-reduce.

    x: local activation shard (the einsum lhs); w: this device's weight shard
    whose **last dim is the replicated output features** (einsum rhs);
    ``spec``: einsum spec mapping (x, w) -> partial output with the feature
    dim last (e.g. ``"bsqh,qhd->bsd"`` for attention wo, ``"bsf,fd->bsd"``
    for the MLP down-projection).

    Returns the **fully reduced** output (what GEMM + ``tp_all_reduce``
    would produce), with chunk q's reduction overlapped against chunk q+1's
    GEMM when ``chunks > 1``.

    ``ef``: optional error-feedback residual with the output's shape.  When
    given, the return value is ``(y, new_ef)`` — same contract as
    ``tp_all_reduce``; the residual is sliced per chunk along the feature
    dim so chunked and unchunked EF states are element-identical.
    """
    if chunks is None:
        chunks = ctx.overlap_chunks if ctx.overlap_matmul else 1
    if not ctx.has_tp:
        y = jnp.einsum(spec, x, w)
        return (y, ef) if ef is not None else y
    d_out = w.shape[-1]
    fast_n = hier.axes_size(ctx.tp_fast)
    ctx = _resolve_auto_for_matmul(x, w, ctx)
    k = _resolve_chunks(d_out, fast_n, chunks)
    if ctx.quant_ag or ctx.compress_slow:
        # Legacy lossy knobs quantize per-message: chunking would change
        # the quantization-group boundaries and make the output depend on
        # the overlap knob.  Keep one message so the knob stays
        # numerics-free.
        k = 1
    bits = hier.QUANT_BITS.get(ctx.ar_quant)
    if bits is not None and k > 1:
        # First-class quantized wire: chunking is allowed exactly when the
        # chunk shards stay group-cap aligned (see _quant_chunk_ok); the
        # autotuner already scored this call site on the unchunked message,
        # so a misaligned fallback only loses overlap, never dispatch.
        n_tp = fast_n * hier.axes_size(ctx.tp_slow)
        if not _quant_chunk_ok(d_out, k, n_tp, bits):
            k = 1
    if backend == "pallas" and ctx.tp_slow and bits is None and ef is None:
        from ..kernels.rd_allreduce.fused_matmul import (
            collective_matmul_pallas)
        return collective_matmul_pallas(x, w, ctx, spec=spec, chunks=k)
    if k <= 1:
        return hier.tp_all_reduce(jnp.einsum(spec, x, w), ctx,
                                  scatter_dim=-1, ef=ef)
    step = d_out // k
    outs, errs = [], []
    for q in range(k):
        wq = lax.slice_in_dim(w, q * step, (q + 1) * step, axis=-1)
        partial = jnp.einsum(spec, x, wq)
        if ef is None:
            outs.append(hier.tp_all_reduce(partial, ctx, scatter_dim=-1))
        else:
            eq = lax.slice_in_dim(ef, q * step, (q + 1) * step, axis=-1)
            yq, eq2 = hier.tp_all_reduce(partial, ctx, scatter_dim=-1,
                                         ef=eq)
            outs.append(yq)
            errs.append(eq2)
    y = jnp.concatenate(outs, axis=-1)
    if ef is not None:
        return y, jnp.concatenate(errs, axis=-1)
    return y


def collective_matmul_reduce_scatter(x: jax.Array, w: jax.Array,
                                     ctx: ParallelCtx, *, dim: int,
                                     spec: str = "bsf,fd->bsd",
                                     chunks: Optional[int] = None
                                     ) -> jax.Array:
    """Sequence-parallel variant: chunked GEMM pipelined against
    ``tp_reduce_scatter`` (Megatron-SP's projection + RS pair).  The scatter
    runs along ``dim`` (sequence), the chunking along the feature dim, so
    the two never interact and the concat order is preserved."""
    if chunks is None:
        chunks = ctx.overlap_chunks if ctx.overlap_matmul else 1
    if not ctx.has_tp:
        return jnp.einsum(spec, x, w)
    d_out = w.shape[-1]
    ctx = _resolve_auto_for_matmul(x, w, ctx)
    k = _resolve_chunks(d_out, 1, chunks)
    if ctx.compress_slow:
        k = 1  # same lossy-quantization-boundary rule as collective_matmul
    bits = hier.QUANT_BITS.get(ctx.ar_quant)
    if bits is not None and k > 1 and not _quant_chunk_ok(d_out, k, 1,
                                                          bits):
        # RS scatters along the sequence dim; quant groups live on the
        # feature dim, so only feature-cap alignment matters (n_scatter=1).
        k = 1
    if k <= 1:
        return hier.tp_reduce_scatter(jnp.einsum(spec, x, w), ctx, dim=dim)
    step = d_out // k
    outs = []
    for q in range(k):
        wq = lax.slice_in_dim(w, q * step, (q + 1) * step, axis=-1)
        outs.append(hier.tp_reduce_scatter(jnp.einsum(spec, x, wq), ctx,
                                           dim=dim))
    return jnp.concatenate(outs, axis=-1)


__all__ = ["collective_matmul", "collective_matmul_reduce_scatter"]
