"""Multi-replica router benchmark: placement-policy A/B over bursty and
diurnal arrival patterns on a 2-replica fleet.

Two synthetic traces stress the placement decision in opposite ways:

* ``bursty_skewed`` — arrival bursts that alternate *heavy* requests
  (long prompt, long generation) with *light* ones (short prompt, short
  generation).  With 2 replicas, arrival-index ``round_robin`` pins
  every heavy request on the same replica (the adversarial case for
  load-oblivious placement) — and so does ``least_queue``, because the
  alternation keeps the request *counts* balanced while the *work* is
  maximally skewed.  ``ttft_aware`` estimates each replica's
  wait-to-first-token — the queued prefill cost under the analytic model
  (chip roofline + comm model) plus, when every slot is busy, the drain
  time of the active decodes — so it steers arrivals away from replicas
  whose slots the heavy decodes will hold longest.  The bench asserts
  the headline A/B result: ``ttft_aware`` p99 TTFT strictly below
  ``round_robin``'s, and fleet goodput (tokens per logical step) at
  least as high.
* ``diurnal`` — a slow sinusoidal rate modulation with mixed prompt
  lengths: the steady-state case where all policies should complete and
  keep both replicas busy.

Every cell runs the same shared logical clock as the serve benches, so
all gated fields are deterministic (steps, token counts, step-domain
percentiles, per-replica placements).

    python -m benchmarks.bench_router --sweep   # writes BENCH_router.json
    python -m benchmarks.bench_router           # quick smoke cell
"""
from __future__ import annotations

import json

import numpy as np

from .common import emit

S_MAX = 160
SLOTS = 2
REPLICAS = 2
LONG_P, SHORT_P = 112, 8
POLICIES = ("round_robin", "least_queue", "ttft_aware")


def _spec():
    from repro.inference.spec import ReplicaSpec
    return ReplicaSpec(arch="llama3.2-1b", slots=SLOTS, s_max=S_MAX,
                       block_size=8, admit_mode="chunked", admit_chunk=16)


def _bursty_trace(vocab, seed=11):
    """3 bursts x 8 requests; heavy (long prompt, long decode) and light
    (short prompt, short decode) alternate by arrival index, so
    round_robin(2) lands every heavy on replica 0."""
    from repro.inference.scheduler import Request
    rng = np.random.default_rng(seed)
    reqs, rid = [], 0
    for b in range(3):
        for k in range(8):
            heavy = k % 2 == 0
            n = LONG_P if heavy else SHORT_P
            reqs.append(Request(
                rid=rid, prompt=rng.integers(0, vocab, n).astype(np.int32),
                max_new=24 if heavy else 4, arrival_s=0.8 * b))
            rid += 1
    return reqs


def _diurnal_trace(vocab, seed=12):
    """24 arrivals over ~4s whose instantaneous rate follows a sinusoid
    (peak ~3x trough), mixed prompt lengths."""
    from repro.inference.scheduler import Request
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for rid in range(24):
        # modulate the inter-arrival gap: dense near the "peak hours"
        rate = 8.0 + 5.0 * np.sin(2.0 * np.pi * t / 4.0)
        t += float(rng.exponential(1.0 / rate))
        n = int(rng.choice((SHORT_P, 24, 56, LONG_P)))
        # decode length tracks prompt length (heavy requests are heavy in
        # both phases), plus jitter
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab, n).astype(np.int32),
            max_new=max(3, n // 8 + int(rng.integers(0, 4))), arrival_s=t))
    return reqs


def _fleet(spec, ap, params, policy):
    from repro.inference.router import Router, prefill_cost_model
    from repro.inference.spec import build_replica
    return Router([build_replica(spec, ap=ap, params=params, replica_id=i)
                   for i in range(REPLICAS)], policy=policy,
                  cost_fn=prefill_cost_model(spec))


def _cell(spec, ap, params, trace_name, reqs, policy):
    from repro.inference.scheduler import Request
    fleet = _fleet(spec, ap, params, policy)
    done = fleet.run([Request(rid=r.rid, prompt=r.prompt, max_new=r.max_new,
                              arrival_s=r.arrival_s) for r in reqs])
    m = fleet.metrics(done)
    assert m.fleet.completed == len(reqs), (policy, m.fleet.completed)
    assert all(p > 0 for p in fleet.placements), \
        f"{trace_name}/{policy}: a replica got no traffic"
    row = {"trace": trace_name, "policy": policy,
           "replicas": REPLICAS,
           "placements_0": fleet.placements[0],
           "placements_1": fleet.placements[1],
           "load_imbalance": m.load_imbalance,
           "goodput_tok_per_step": m.fleet.total_new_tokens
           / max(m.fleet.steps, 1),
           **m.fleet.to_dict()}
    return row, m


def sweep(out_path: str = "BENCH_router.json"):
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    spec = _spec()
    traces = {"bursty_skewed": _bursty_trace(cfg.vocab_size),
              "diurnal": _diurnal_trace(cfg.vocab_size)}
    rows, by = [], {}
    for tname, reqs in traces.items():
        for policy in POLICIES:
            row, m = _cell(spec, ap, params, tname, reqs, policy)
            rows.append(row)
            by[(tname, policy)] = row
            emit(f"router/{tname}_{policy}", row["ttft_steps_p99"],
                 f"p50={row['ttft_steps_p50']:.0f};"
                 f"steps={row['steps']};"
                 f"place={row['placements_0']}:{row['placements_1']};"
                 f"tok_per_step={row['goodput_tok_per_step']:.2f}")
    # the headline A/B: cost-aware placement beats arrival-index placement
    # on the adversarially skewed bursts — tail TTFT and goodput
    rr = by[("bursty_skewed", "round_robin")]
    ta = by[("bursty_skewed", "ttft_aware")]
    assert ta["ttft_steps_p99"] < rr["ttft_steps_p99"], \
        ("ttft_aware p99 TTFT must beat round_robin on the skewed trace",
         ta["ttft_steps_p99"], rr["ttft_steps_p99"])
    assert ta["goodput_tok_per_step"] >= rr["goodput_tok_per_step"], \
        (ta["goodput_tok_per_step"], rr["goodput_tok_per_step"])
    summary = {
        "bursty_p99_ttft_by_policy": {p: by[("bursty_skewed", p)]
                                      ["ttft_steps_p99"] for p in POLICIES},
        "bursty_ttft_aware_speedup_p99":
            rr["ttft_steps_p99"] / max(ta["ttft_steps_p99"], 1.0),
        "diurnal_imbalance_by_policy": {p: by[("diurnal", p)]
                                        ["load_imbalance"]
                                        for p in POLICIES},
    }
    with open(out_path, "w") as f:
        json.dump({"arch": "llama3.2-1b(smoke)", "s_max": S_MAX,
                   "slots": SLOTS, "replicas": REPLICAS,
                   "policies": POLICIES, "summary": summary, "rows": rows},
                  f, indent=2, sort_keys=True, default=float)
    emit("router/json_written", float(len(rows)), out_path)
    return rows


def run():
    import jax
    from repro.configs import get_smoke
    from repro.models.transformer import make_plan, init_params
    cfg = get_smoke("llama3.2-1b")
    ap = make_plan(cfg, 1)
    params = init_params(jax.random.PRNGKey(0), ap)
    spec = _spec()
    reqs = _bursty_trace(cfg.vocab_size)
    rr, _ = _cell(spec, ap, params, "bursty_skewed", reqs, "round_robin")
    ta, _ = _cell(spec, ap, params, "bursty_skewed", reqs, "ttft_aware")
    emit("router/smoke_ab", ta["ttft_steps_p99"],
         f"rr_p99={rr['ttft_steps_p99']:.0f};"
         f"ta_place={ta['placements_0']}:{ta['placements_1']}")


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", action="store_true",
                    help="trace x policy A/B grid (BENCH_router.json)")
    ap.add_argument("--out", default="BENCH_router.json")
    args = ap.parse_args(argv)
    if args.sweep:
        sweep(args.out)
    else:
        run()


if __name__ == "__main__":
    main()
