"""Paper Fig. 7 (+ Fig. 16): end-to-end NVRAR-vs-NCCL speedup for
decode-heavy batched inference across models and GPU counts, plus a REAL
numerical end-to-end run: the tiny engine generating with flat vs
hierarchical all-reduce strategies produces identical tokens (correctness of
the integration the speedups rely on)."""
from __future__ import annotations

from .common import emit


def simulated():
    from repro.inference.simulator import simulate_batch_latency, A100, GH200
    from repro.core.comm_model import PERLMUTTER, VISTA
    from repro.configs.llama3_paper import LLAMA31_70B, LLAMA31_405B

    for model, gpus in ((LLAMA31_70B, (8, 16, 32)),
                        (LLAMA31_405B, (32, 64, 128))):
        for npr in (8, 32):
            for n in gpus:
                t_n, _ = simulate_batch_latency(
                    model, A100, PERLMUTTER, n, scheme="tp",
                    ar_algo="nccl", prompt_len=1426, decode_len=3072,
                    n_prompts=npr)
                t_v, _ = simulate_batch_latency(
                    model, A100, PERLMUTTER, n, scheme="tp",
                    ar_algo="nvrar", prompt_len=1426, decode_len=3072,
                    n_prompts=npr)
                emit(f"fig7/{model.name}/P{npr}/gpus{n}", t_v * 1e6,
                     f"nccl_s={t_n:.1f};speedup={t_n/t_v:.2f}x")
    # Vista (Fig. 16): 1 GPU/node
    for n in (4, 8, 16):
        t_n, _ = simulate_batch_latency(
            LLAMA31_70B, GH200, VISTA, n, scheme="tp", ar_algo="nccl",
            prompt_len=1426, decode_len=3072, n_prompts=32)
        t_v, _ = simulate_batch_latency(
            LLAMA31_70B, GH200, VISTA, n, scheme="tp", ar_algo="nvrar",
            prompt_len=1426, decode_len=3072, n_prompts=32)
        emit(f"fig16/vista/llama70b/P32/gpus{n}", t_v * 1e6,
             f"nccl_s={t_n:.1f};speedup={t_n/t_v:.2f}x")


def real_integration():
    """Numerical equivalence of the AR strategies inside a real generate()
    loop (8 simulated devices; run via the dist harness when available)."""
    import jax
    if len(jax.devices()) < 8:
        emit("fig7/real_integration", 0.0, "skipped=needs_8_devices")
        return
    import numpy as np
    import jax.numpy as jnp
    from jax.sharding import AxisType
    from repro.core.pcontext import ParallelCtx
    from repro.models import ModelConfig, make_plan, init_params
    from repro.parallel.steps import build_decode_step, build_prefill
    cfg = ModelConfig(name="bench-tiny", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab_size=96, dtype=jnp.float32)
    mesh = jax.make_mesh((2, 4), ("pod", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    toks = {}
    for strat in ("flat", "hier_rd"):
        ctx = ParallelCtx(tp_fast=("model",), tp_slow=("pod",),
                          ep=("model",), ar_strategy=strat)
        ap = make_plan(cfg, 8)
        params = init_params(jax.random.PRNGKey(0), ap)
        pre = build_prefill(ap, ctx, mesh, s_max=24)
        dec = build_decode_step(ap, ctx, mesh)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, 96)
        nxt, cache = jax.jit(pre.fn)(params, prompts)
        seq = [np.asarray(nxt)]
        pos = jnp.full((4,), 8, jnp.int32)
        for i in range(6):
            nxt, cache = dec.jit()(params, cache, nxt, pos + i)
            seq.append(np.asarray(nxt))
        toks[strat] = np.stack(seq)
    same = bool(np.array_equal(toks["flat"], toks["hier_rd"]))
    emit("fig7/real_integration_tokens_match", float(same),
         "flat_vs_hier_rd_identical_generations")
    assert same


def run():
    simulated()
    real_integration()


if __name__ == "__main__":
    run()
